//! Fig 15: end-to-end carbon vs TTFT/TPOT trade-off across strategies,
//! plus the cumulative benefit of stacking EcoServe's optimizations.
use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::planner::Phase;
use ecoserve::strategies::Strategy;
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::slo_for;
use ecoserve::workload::{generate_trace, merge_traces, Arrivals, LengthDist,
                         RequestClass};

fn main() {
    let m = models::llm("llama-8b").unwrap();
    let slo = slo_for("llama-8b", false).unwrap().slo;
    let online = generate_trace(Arrivals::Bursty { rate: 24.0, cv: 2.0 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                600.0, 15);
    let offline = generate_trace(Arrivals::Poisson { rate: 10.0 },
                                 LengthDist::LongBench, RequestClass::Offline,
                                 600.0, 16);
    let trace = merge_traces(vec![online, offline]);
    let slices = cluster_slices(&slice_trace(m, &trace, 600.0, slo, 1));
    let ci = 261.0;

    println!("== Fig 15 (left/center): carbon + latency vs perf-opt ==");
    let base = Strategy::PerfOpt.plan(&slices, ci);
    let mut t = Table::new(&["strategy", "carbon kg/hr", "saving %",
                             "TTFT (model) s", "TPOT (model) s", "gpus"]);
    for strat in Strategy::all() {
        let p = strat.plan(&slices, ci);
        t.row(&[strat.name().into(), fnum(p.carbon_kg_per_hr()),
                fnum(100.0 * (1.0 - p.carbon_kg_per_hr() / base.carbon_kg_per_hr())),
                fnum(p.mean_latency(Phase::Prompt)),
                fnum(p.mean_latency(Phase::Decode)),
                format!("{}", p.total_gpus())]);
    }
    t.print();

    println!("\n== Fig 15 (right): cumulative stacking of optimizations ==");
    let stack = [
        ("baseline (perf-opt)", Strategy::PerfOpt),
        ("+ reduce", Strategy::EcoReduce),
        ("+ rightsize", Strategy::EcoRightsize),
        ("+ reuse", Strategy::EcoReuse),
        ("ecoserve (all 4R)", Strategy::EcoFull),
    ];
    let mut t = Table::new(&["config", "carbon kg/hr", "cumulative saving %"]);
    for (name, strat) in stack {
        let c = strat.plan(&slices, ci).carbon_kg_per_hr();
        t.row(&[name.into(), fnum(c),
                fnum(100.0 * (1.0 - c / base.carbon_kg_per_hr()))]);
    }
    t.print();
}
