//! Fig 16: which strategy EcoServe's planner engages as workload length,
//! SLO slack, and carbon intensity vary (Llama-70B).
use ecoserve::models;
use ecoserve::planner::slicing::Slice;
use ecoserve::planner::{plan, Phase, PlanConfig};
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::Slo;

fn main() {
    let m = models::llm("llama-70b").unwrap();
    println!("== Fig 16: sampled reuse/rightsize configs (Llama-70B) ==");
    let mut t = Table::new(&["ctx", "slo slack", "CI", "decode device",
                             "reuse?", "carbon kg/hr"]);
    for &ctx in &[512usize, 2048, 8192] {
        for &slack in &[1.0f64, 3.0] {
            for &ci in &[17.0f64, 261.0, 501.0] {
                let slices = vec![
                    Slice { model: m, rate: 2.0, prompt: ctx, output: 256,
                            slo: Slo { ttft_s: 15.0 * slack, tpot_s: 0.24 * slack },
                            offline: false },
                    Slice { model: m, rate: 1.0, prompt: ctx, output: 256,
                            slo: Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY },
                            offline: true },
                ];
                let p = plan(&slices, &PlanConfig { ci, ..Default::default() });
                let decode_dev = p.assignments.iter()
                    .find(|a| a.slice_idx == 1 && a.phase == Phase::Decode)
                    .map(|a| a.device.clone())
                    .unwrap_or_else(|| "-".into());
                let reuse = decode_dev == "cpu-host";
                t.row(&[format!("{ctx}"), fnum(slack), fnum(ci), decode_dev,
                        format!("{reuse}"), fnum(p.carbon_kg_per_hr())]);
            }
        }
    }
    t.print();
    println!("(longer requests + lower CI -> reuse; higher CI -> rightsize)");
}
