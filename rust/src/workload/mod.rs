//! Workload substrate: request length distributions, arrival processes,
//! online/offline demand traces, and SLO definitions (paper §5, Fig 10).
//!
//! Public datasets (ShareGPT, LongBench, Azure Function Traces) and the
//! production traces are not available offline; generators reproduce their
//! *published summary statistics* — length mixes, burstiness, diurnal
//! online/offline split — which is what the planner and simulator consume.

pub mod demand;
pub mod slo;
pub mod stream;
pub mod trace;

pub use stream::{ArrivalSource, GeneratorSource, MergedSource, PartitionSource,
                 SliceSource};
pub use trace::{Burstiness, TraceDialect, TraceErrorPolicy, TraceRescale,
                TraceSource, TraceStats};

use crate::util::rng::Rng;

/// A single inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Online (interactive SLO) or offline (24 h batch SLO).
    pub class: RequestClass,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestClass {
    Online,
    Offline,
}

/// Token-length distribution families fit to the public datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LengthDist {
    /// ShareGPT-like chat: short-to-medium prompts, medium outputs.
    ShareGpt,
    /// LongBench-like long-context: multi-k prompts, short outputs.
    LongBench,
    /// Azure-Functions-like short bursts.
    AzureCode,
}

impl LengthDist {
    /// Sample (prompt_tokens, output_tokens). Lognormal fits to published
    /// means/long tails, clamped to serving-realistic ranges.
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        let (p, o) = match self {
            // mean ≈ 250 in / 320 out, heavy tail.
            LengthDist::ShareGpt => (
                rng.lognormal(5.0, 1.0),
                rng.lognormal(5.4, 0.9),
            ),
            // mean ≈ 6k in / 130 out.
            LengthDist::LongBench => (
                rng.lognormal(8.5, 0.7),
                rng.lognormal(4.5, 0.7),
            ),
            // mean ≈ 900 in / 180 out.
            LengthDist::AzureCode => (
                rng.lognormal(6.5, 0.8),
                rng.lognormal(4.9, 0.8),
            ),
        };
        (
            (p as usize).clamp(8, 32_768),
            (o as usize).clamp(4, 4_096),
        )
    }

    pub fn mean_prompt(&self) -> f64 {
        match self {
            LengthDist::ShareGpt => (5.0f64 + 0.5).exp(),
            LengthDist::LongBench => (8.5f64 + 0.245).exp(),
            LengthDist::AzureCode => (6.5f64 + 0.32).exp(),
        }
    }
}

/// Arrival process. No longer `Copy`: the [`Arrivals::Trace`] variant
/// owns its file path — clone at use sites instead.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Memoryless with the given rate (req/s).
    Poisson { rate: f64 },
    /// Gamma-renewal bursty arrivals (cv > 1 ⇒ burstier than Poisson) —
    /// the scaled-AZF emulation from §6.1 ("bursty behavior of online
    /// samples").
    Bursty { rate: f64, cv: f64 },
    /// Diurnal-modulated Poisson: rate(t) = rate·(1 + amp·sin) (Fig 10's
    /// day shape).
    Diurnal { rate: f64, amplitude: f64 },
    /// [`Arrivals::Diurnal`]'s day shape compressed onto `period_s`
    /// seconds, matching `CiTrace::compressed_diurnal` so short traces
    /// see demand and grid CI swing together. `period_s <= 0` means one
    /// day per trace duration.
    CompressedDiurnal { rate: f64, amplitude: f64, period_s: f64 },
    /// Step-function load: `base` req/s with `surge` extra req/s inside
    /// `[start_frac, end_frac]` of the trace duration — the
    /// re-provisioning stress case (GreenLLM-style demand spikes).
    Step { base: f64, surge: f64, start_frac: f64, end_frac: f64 },
    /// Seven compressed diurnal day cycles mapped onto the trace duration
    /// with weekday/weekend amplitude: days 0–4 run at `rate`, the
    /// weekend days 5–6 at `rate · weekend_factor` — one production week
    /// for the scale scenarios.
    Week { rate: f64, amplitude: f64, weekend_factor: f64 },
    /// Replay a recorded production trace from a CSV file — not a
    /// generator at all: it streams through [`trace::TraceSource`], which
    /// provides its own timestamps and token lengths (the workload's
    /// `LengthDist` is ignored). See [`trace`] for dialects, the error
    /// policy, and rescaling.
    Trace {
        path: String,
        dialect: TraceDialect,
        rescale: TraceRescale,
        errors: TraceErrorPolicy,
    },
}

impl Arrivals {
    /// Next inter-arrival gap at absolute time `t_s`. `duration_s` is the
    /// trace length, which anchors the duration-relative processes
    /// (compressed diurnal periods, surge windows).
    pub fn next_gap(&self, rng: &mut Rng, t_s: f64, duration_s: f64) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rng.exp(rate),
            Arrivals::Bursty { rate, cv } => {
                // Gamma renewal: shape k = 1/cv², scale = 1/(rate·k).
                let k = 1.0 / (cv * cv);
                rng.gamma(k, 1.0 / (rate * k))
            }
            Arrivals::Diurnal { rate, amplitude } => {
                let hour = (t_s / 3600.0) % 24.0;
                rng.exp(diurnal_rate(rate, amplitude, hour))
            }
            Arrivals::CompressedDiurnal { rate, amplitude, period_s } => {
                let period = if period_s > 0.0 { period_s } else { duration_s };
                let hour = (t_s / period.max(1e-9)).fract() * 24.0;
                rng.exp(diurnal_rate(rate, amplitude, hour))
            }
            Arrivals::Step { base, surge, start_frac, end_frac } => {
                let in_surge = t_s >= start_frac * duration_s
                    && t_s < end_frac * duration_s;
                let rate = base + if in_surge { surge } else { 0.0 };
                rng.exp(rate.max(1e-9))
            }
            Arrivals::Week { rate, amplitude, weekend_factor } => {
                let day_len = (duration_s / 7.0).max(1e-9);
                let day = ((t_s / day_len) as usize).min(6);
                let base = if day >= 5 { rate * weekend_factor } else { rate };
                let hour = (t_s / day_len).fract() * 24.0;
                rng.exp(diurnal_rate(base, amplitude, hour))
            }
            Arrivals::Trace { .. } => unreachable!(
                "trace workloads replay through TraceSource, never through \
                 a generator gap"),
        }
    }
}

/// Sinusoidal day modulation shared by the diurnal processes: peak at
/// 14:00 local, trough at 02:00, floored at 5% of the base rate.
fn diurnal_rate(rate: f64, amplitude: f64, hour: f64) -> f64 {
    let modulated = rate
        * (1.0 + amplitude * ((hour - 8.0) / 24.0 * std::f64::consts::TAU).sin());
    modulated.max(rate * 0.05)
}

/// Generate a request trace by draining the equivalent lazy generator
/// ([`GeneratorSource`] is the primary implementation; this materialized
/// form remains for small planning windows, tests, and examples).
pub fn generate_trace(
    arrivals: Arrivals,
    lengths: LengthDist,
    class: RequestClass,
    duration_s: f64,
    seed: u64,
) -> Vec<Request> {
    GeneratorSource::new(arrivals, lengths, class, duration_s, seed).materialize()
}

/// Merge traces preserving arrival order.
pub fn merge_traces(mut traces: Vec<Vec<Request>>) -> Vec<Request> {
    let mut all: Vec<Request> = traces.drain(..).flatten().collect();
    all.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i as u64;
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_respected() {
        let tr = generate_trace(Arrivals::Poisson { rate: 10.0 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                300.0, 1);
        let rate = tr.len() as f64 / 300.0;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
        assert!(tr.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
    }

    #[test]
    fn sharegpt_lengths_in_band() {
        let mut rng = Rng::new(2);
        let n = 20_000;
        let mut psum = 0.0;
        let mut osum = 0.0;
        for _ in 0..n {
            let (p, o) = LengthDist::ShareGpt.sample(&mut rng);
            psum += p as f64;
            osum += o as f64;
        }
        let (pm, om) = (psum / n as f64, osum / n as f64);
        assert!(pm > 150.0 && pm < 400.0, "prompt mean {pm}");
        assert!(om > 200.0 && om < 500.0, "output mean {om}");
    }

    #[test]
    fn longbench_much_longer_prompts() {
        let mut rng = Rng::new(3);
        let n = 5_000;
        let mean: f64 = (0..n)
            .map(|_| LengthDist::LongBench.sample(&mut rng).0 as f64)
            .sum::<f64>() / n as f64;
        assert!(mean > 3_000.0, "longbench mean {mean}");
    }

    #[test]
    fn bursty_has_higher_cv() {
        let gaps = |a: Arrivals, seed| -> Vec<f64> {
            let tr = generate_trace(a, LengthDist::ShareGpt,
                                    RequestClass::Online, 2_000.0, seed);
            tr.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect()
        };
        let cv = |xs: &[f64]| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
            v.sqrt() / m
        };
        let poisson_cv = cv(&gaps(Arrivals::Poisson { rate: 5.0 }, 4));
        let bursty_cv = cv(&gaps(Arrivals::Bursty { rate: 5.0, cv: 3.0 }, 4));
        assert!((poisson_cv - 1.0).abs() < 0.15, "poisson cv {poisson_cv}");
        assert!(bursty_cv > 1.8, "bursty cv {bursty_cv}");
    }

    #[test]
    fn diurnal_peaks_afternoon() {
        let tr = generate_trace(Arrivals::Diurnal { rate: 5.0, amplitude: 0.8 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                86_400.0, 5);
        let count_in = |lo: f64, hi: f64| tr.iter()
            .filter(|r| r.arrival_s >= lo * 3600.0 && r.arrival_s < hi * 3600.0)
            .count();
        let afternoon = count_in(12.0, 16.0);
        let night = count_in(0.0, 4.0);
        assert!(afternoon > night * 2, "afternoon {afternoon} night {night}");
    }

    #[test]
    fn compressed_diurnal_swings_within_a_short_trace() {
        // One compressed day over 240 s: the 12:00–16:00 band (t in
        // [120, 160)) must far outnumber the 00:00–04:00 band ([0, 40)).
        let tr = generate_trace(
            Arrivals::CompressedDiurnal { rate: 20.0, amplitude: 0.8, period_s: 0.0 },
            LengthDist::ShareGpt, RequestClass::Online, 240.0, 9);
        let count_in = |lo: f64, hi: f64| tr.iter()
            .filter(|r| r.arrival_s >= lo && r.arrival_s < hi)
            .count();
        let afternoon = count_in(120.0, 160.0);
        let night = count_in(0.0, 40.0);
        assert!(afternoon > night * 2, "afternoon {afternoon} night {night}");
    }

    #[test]
    fn step_surge_concentrates_in_its_window() {
        let tr = generate_trace(
            Arrivals::Step { base: 2.0, surge: 18.0, start_frac: 0.4, end_frac: 0.6 },
            LengthDist::ShareGpt, RequestClass::Online, 300.0, 10);
        let count_in = |lo: f64, hi: f64| tr.iter()
            .filter(|r| r.arrival_s >= lo && r.arrival_s < hi)
            .count();
        // Surge window [120, 180) runs at 20 req/s vs 2 req/s outside.
        let surge = count_in(120.0, 180.0) as f64 / 60.0;
        let before = count_in(0.0, 120.0) as f64 / 120.0;
        assert!(surge > 5.0 * before, "surge {surge} base {before}");
        assert!((surge - 20.0).abs() < 5.0, "surge rate {surge}");
    }

    #[test]
    fn week_weekends_are_quieter_and_days_cycle() {
        // 7 compressed days over 700 s (100 s per day): weekday day 1
        // must far outnumber weekend day 6 at weekend_factor 0.3, and
        // each day keeps the afternoon-peak shape.
        let tr = generate_trace(
            Arrivals::Week { rate: 20.0, amplitude: 0.6, weekend_factor: 0.3 },
            LengthDist::ShareGpt, RequestClass::Online, 700.0, 8);
        let count_in = |lo: f64, hi: f64| tr.iter()
            .filter(|r| r.arrival_s >= lo && r.arrival_s < hi)
            .count();
        let weekday = count_in(100.0, 200.0);
        let weekend = count_in(600.0, 700.0);
        assert!(weekday as f64 > 2.0 * weekend as f64,
                "weekday {weekday} weekend {weekend}");
        // Within day 0 the 12:00-16:00 band beats the 00:00-04:00 band.
        let afternoon = count_in(50.0, 66.0);
        let night = count_in(0.0, 16.0);
        assert!(afternoon > night, "afternoon {afternoon} night {night}");
    }

    #[test]
    fn merge_sorted_and_reindexed() {
        let a = generate_trace(Arrivals::Poisson { rate: 2.0 },
                               LengthDist::ShareGpt, RequestClass::Online, 50.0, 6);
        let b = generate_trace(Arrivals::Poisson { rate: 2.0 },
                               LengthDist::LongBench, RequestClass::Offline, 50.0, 7);
        let m = merge_traces(vec![a, b]);
        assert!(m.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        assert!(m.iter().enumerate().all(|(i, r)| r.id == i as u64));
    }
}
