//! Fig 1: TDP and embodied-carbon split between host and GPU on a
//! DGX-A100-like node, plus the 4R savings overview.
use ecoserve::carbon::embodied::platform_embodied;
use ecoserve::hw::platform::azure_nd96_a100;
use ecoserve::util::table::{fnum, Table};

fn main() {
    let p = azure_nd96_a100();
    let (host, gpus) = platform_embodied(&p);
    let host_tdp = p.host.tdp_w();
    let gpu_tdp = p.gpu.tdp_w * p.gpu_count as f64;
    println!("== Fig 1 (left): TDP vs embodied split, {} ==", p.name);
    let mut t = Table::new(&["metric", "host", "gpus", "host %"]);
    t.row(&["TDP (W)".into(), fnum(host_tdp), fnum(gpu_tdp),
            fnum(100.0 * host_tdp / (host_tdp + gpu_tdp))]);
    t.row(&["embodied (kgCO2e)".into(), fnum(host.total()), fnum(gpus.total()),
            fnum(100.0 * host.total() / (host.total() + gpus.total()))]);
    t.print();
    println!("\n== Fig 1 (right): 4R carbon savings vs perf-opt ==");
    use ecoserve::models;
    use ecoserve::planner::slicing::Slice;
    use ecoserve::strategies::Strategy;
    use ecoserve::workload::slo::Slo;
    let m = models::llm("llama-8b").unwrap();
    let mk = |offline_rate: f64| vec![
        Slice { model: m, rate: 30.0, prompt: 256, output: 128,
                slo: Slo { ttft_s: 0.5, tpot_s: 0.1 }, offline: false },
        Slice { model: m, rate: offline_rate, prompt: 4096, output: 256,
                slo: Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY }, offline: true },
    ];
    let mut t = Table::new(&["strategy", "online-heavy %", "offline-heavy %"]);
    for strat in [Strategy::EcoReuse, Strategy::EcoRightsize, Strategy::EcoReduce,
                  Strategy::EcoRecycle, Strategy::EcoFull] {
        let mut cells = vec![strat.name().to_string()];
        for off in [6.0, 30.0] {
            let s = mk(off);
            let base = Strategy::PerfOpt.plan(&s, 261.0).carbon_kg_per_hr();
            let c = strat.plan(&s, 261.0).carbon_kg_per_hr();
            cells.push(fnum(100.0 * (1.0 - c / base)));
        }
        t.row(&cells);
    }
    t.print();
}
