//! Carbon-accounting invariants: the simulator's energy/carbon bookkeeping
//! and the operational/embodied task model stay self-consistent.

use ecoserve::carbon::operational::{amortized_emb_kg, device_power, op_kg,
                                    op_kg_from_joules, task_carbon,
                                    GPU_POWER_GAMMA};
use ecoserve::models;
use ecoserve::sim::{homogeneous_fleet, simulate, Router, SimConfig, SimReport};
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, Request,
                         RequestClass};

fn run_sim(gpus: usize, rate: f64, ci: f64, class: RequestClass)
    -> (SimReport, Vec<Request>) {
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate }, LengthDist::ShareGpt,
                            class, 120.0, 99);
    let servers = homogeneous_fleet("A100-40", gpus, m, 2048);
    let n = servers.len();
    let cfg = SimConfig::flat(servers, Router::WorkloadAware, ci, vec![0.005; n]);
    let r = simulate(m, &tr, &cfg, 0.5, 0.1);
    (r, tr)
}

#[test]
fn sim_carbon_is_op_plus_embodied() {
    let (r, _) = run_sim(4, 3.0, 261.0, RequestClass::Online);
    assert!(r.op_kg > 0.0 && r.emb_kg > 0.0);
    assert!((r.carbon_kg() - (r.op_kg + r.emb_kg)).abs() < 1e-12,
            "carbon {} != {} + {}", r.carbon_kg(), r.op_kg, r.emb_kg);
    // Operational carbon is exactly energy × CI for a flat signal (the
    // meter sums linearly over busy/idle intervals, so the total must
    // match a single conversion of the total energy draw).
    let expect = op_kg_from_joules(r.energy_j, 261.0);
    assert!((r.op_kg - expect).abs() <= 1e-9 * expect.max(1e-12),
            "op {} vs energy-derived {}", r.op_kg, expect);
}

#[test]
fn sim_conserves_tokens_and_energy_is_non_negative() {
    let (r, tr) = run_sim(4, 3.0, 261.0, RequestClass::Online);
    assert_eq!(r.completed, tr.len(), "requests lost");
    let want: usize = tr.iter().map(|x| x.output_tokens.max(1)).sum();
    assert_eq!(r.generated_tokens, want, "token conservation violated");
    assert!(r.energy_j.is_finite() && r.energy_j > 0.0);
    assert!(r.sim_duration_s > 0.0);
    assert!(r.throughput_tok_s() > 0.0);
}

#[test]
fn slo_attainment_stays_in_unit_interval() {
    // Light load, overload, and offline-only (no online SLO samples).
    for (gpus, rate, class) in [(8, 0.5, RequestClass::Online),
                                (1, 12.0, RequestClass::Online),
                                (2, 2.0, RequestClass::Offline)] {
        let (r, _) = run_sim(gpus, rate, 261.0, class);
        assert!((0.0..=1.0).contains(&r.slo_attainment),
                "gpus={gpus} rate={rate}: slo {}", r.slo_attainment);
        if class == RequestClass::Offline {
            // No online requests -> attainment is vacuously perfect.
            assert_eq!(r.slo_attainment, 1.0);
        }
    }
}

#[test]
fn op_carbon_scales_linearly_with_ci() {
    let (lo, _) = run_sim(4, 2.0, 17.0, RequestClass::Online);
    let (hi, _) = run_sim(4, 2.0, 501.0, RequestClass::Online);
    // Same seed/fleet: identical energy, op ∝ CI, embodied unchanged.
    assert!((lo.energy_j - hi.energy_j).abs() < 1e-6);
    let ratio = hi.op_kg / lo.op_kg;
    assert!((ratio - 501.0 / 17.0).abs() < 1e-6, "ratio {ratio}");
    assert!((lo.emb_kg - hi.emb_kg).abs() < 1e-12);
}

#[test]
fn task_carbon_components_sum() {
    let tc = task_carbon(300.0, 400.0, 7200.0, 261.0, 800.0, 120.0, 9.0, 3.0);
    let total = tc.op_kg + tc.emb_host_kg + tc.emb_gpu_kg;
    assert!((tc.total() - total).abs() < 1e-12);
    assert!(tc.op_kg > 0.0 && tc.emb_host_kg > 0.0 && tc.emb_gpu_kg > 0.0);
    // Op term matches the closed form; embodied amortizes over lifetime.
    assert!((tc.op_kg - op_kg(700.0, 7200.0, 261.0)).abs() < 1e-12);
    let full_lt_s = 3.0 * 365.25 * 86_400.0;
    assert!((amortized_emb_kg(120.0, full_lt_s, 3.0) - 120.0).abs() < 1e-9);
}

#[test]
fn device_power_bounded_by_idle_and_tdp() {
    for util in [0.0, 0.1, 0.5, 0.9, 1.0] {
        let p = device_power(50.0, 400.0, util, GPU_POWER_GAMMA);
        assert!((50.0..=400.0).contains(&p), "util {util}: {p}");
    }
    assert_eq!(device_power(50.0, 400.0, 0.0, GPU_POWER_GAMMA), 50.0);
    assert_eq!(device_power(50.0, 400.0, 1.0, GPU_POWER_GAMMA), 400.0);
}
