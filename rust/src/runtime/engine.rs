//! Inference engine: loads AOT HLO-text artifacts via the PJRT CPU client
//! and owns the per-bucket executables, the weight literals, and the
//! rust-side KV cache.
//!
//! Python never runs here: `make artifacts` produced the HLO + weights at
//! build time; this engine is the whole request-path compute layer.

use super::manifest::Manifest;
use super::weights;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Batched KV cache, rust-owned, shaped [L, B, max_seq, KVH, Dh].
#[derive(Debug, Clone)]
pub struct KvCache {
    pub batch: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    layers: usize,
    max_seq: usize,
    kvh: usize,
    dh: usize,
}

impl KvCache {
    fn new(layers: usize, batch: usize, max_seq: usize, kvh: usize, dh: usize) -> Self {
        let n = layers * batch * max_seq * kvh * dh;
        KvCache { batch, k: vec![0.0; n], v: vec![0.0; n], layers, max_seq, kvh, dh }
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.layers, self.batch, self.max_seq, self.kvh, self.dh]
    }

    /// Per-(layer, sequence) contiguous extent.
    fn seq_stride(&self) -> usize {
        self.max_seq * self.kvh * self.dh
    }

    /// Copy sequence `src_idx` of `src` into slot `dst_idx` of `self`.
    pub fn copy_slot_from(&mut self, dst_idx: usize, src: &KvCache, src_idx: usize) {
        assert_eq!(self.seq_stride(), src.seq_stride(), "cache geometry mismatch");
        assert!(dst_idx < self.batch && src_idx < src.batch);
        let stride = self.seq_stride();
        for l in 0..self.layers {
            let dst_off = (l * self.batch + dst_idx) * stride;
            let src_off = (l * src.batch + src_idx) * stride;
            self.k[dst_off..dst_off + stride]
                .copy_from_slice(&src.k[src_off..src_off + stride]);
            self.v[dst_off..dst_off + stride]
                .copy_from_slice(&src.v[src_off..src_off + stride]);
        }
    }

    /// Zero a slot (freed sequence).
    pub fn clear_slot(&mut self, idx: usize) {
        let stride = self.seq_stride();
        for l in 0..self.layers {
            let off = (l * self.batch + idx) * stride;
            self.k[off..off + stride].fill(0.0);
            self.v[off..off + stride].fill(0.0);
        }
    }
}

/// Timing for one engine call (feeds the serving metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTiming {
    pub exec_s: f64,
    pub marshal_s: f64,
}

pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Weight literals in HLO parameter order.
    weights: Vec<xla::Literal>,
    prefill_exes: BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Cumulative timings.
    pub decode_steps: std::cell::Cell<u64>,
}

/// Prefill result for a batch of prompts.
pub struct PrefillOut {
    /// Per-prompt logits at the last prompt token ([vocab] each).
    pub logits: Vec<Vec<f32>>,
    /// Bucket-sized KV cache holding the prefilled sequences.
    pub cache: KvCache,
    pub bucket: (usize, usize),
    pub timing: StepTiming,
}

impl Engine {
    /// Load artifacts from a directory (compiles all buckets eagerly).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;

        let tensors = weights::load(&manifest.weights_path())?;
        if tensors.len() != manifest.params.len() {
            bail!("weights.bin has {} tensors, manifest expects {}",
                  tensors.len(), manifest.params.len());
        }
        let mut wlits = Vec::with_capacity(tensors.len());
        for (t, p) in tensors.iter().zip(&manifest.params) {
            if t.name != p.name || t.dims != p.shape {
                bail!("weight order mismatch: {} {:?} vs manifest {} {:?}",
                      t.name, t.dims, p.name, p.shape);
            }
            wlits.push(literal_f32(&t.data, &t.dims)?);
        }

        let mut prefill_exes = BTreeMap::new();
        for &(b, s) in &manifest.prefill_buckets {
            let path = manifest.prefill_path(b, s);
            prefill_exes.insert((b, s), compile(&client, &path)?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &manifest.decode_buckets {
            let path = manifest.decode_path(b);
            decode_exes.insert(b, compile(&client, &path)?);
        }
        Ok(Engine {
            client,
            manifest,
            weights: wlits,
            prefill_exes,
            decode_exes,
            decode_steps: std::cell::Cell::new(0),
        })
    }

    pub fn max_seq(&self) -> usize {
        self.manifest.model.max_seq
    }

    pub fn vocab(&self) -> usize {
        self.manifest.model.vocab
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.manifest.decode_buckets
    }

    pub fn empty_cache(&self, batch: usize) -> KvCache {
        let m = &self.manifest.model;
        KvCache::new(m.n_layers, batch, m.max_seq, m.n_kv_heads, m.head_dim)
    }

    /// Run prefill over `prompts` (token id sequences). Picks the smallest
    /// bucket that fits; prompts longer than the largest bucket are an error
    /// (callers chunk or reject upstream).
    pub fn prefill(&self, prompts: &[Vec<i32>]) -> Result<PrefillOut> {
        let t0 = std::time::Instant::now();
        let batch = prompts.len();
        let longest = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
        if longest == 0 {
            bail!("empty prompt batch");
        }
        let bucket = self.manifest.pick_prefill_bucket(batch, longest)
            .ok_or_else(|| anyhow!(
                "no prefill bucket fits batch={batch} len={longest}"))?;
        let (bb, bs) = bucket;
        let exe = &self.prefill_exes[&bucket];

        // Pad prompts to the bucket.
        let pad = self.manifest.model.pad;
        let mut tokens = vec![pad; bb * bs];
        let mut lengths = vec![1i32; bb]; // dummy rows get length 1
        for (i, p) in prompts.iter().enumerate() {
            tokens[i * bs..i * bs + p.len()].copy_from_slice(p);
            lengths[i] = p.len() as i32;
        }
        let tok_lit = literal_i32(&tokens, &[bb, bs])?;
        let len_lit = literal_i32(&lengths, &[bb])?;

        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&tok_lit);
        args.push(&len_lit);
        let marshal_s = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let result = exe.execute::<&xla::Literal>(&args).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        let exec_s = t1.elapsed().as_secs_f64();

        let parts = tuple_parts(out, 3)?;
        let (logits_l, k_l, v_l) = (&parts[0], &parts[1], &parts[2]);

        let vocab = self.vocab();
        let flat: Vec<f32> = logits_l.to_vec::<f32>().map_err(wrap)?;
        let logits = prompts.iter().enumerate()
            .map(|(i, _)| flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect();

        let mut cache = self.empty_cache(bb);
        k_l.copy_raw_to::<f32>(&mut cache.k).map_err(wrap)?;
        v_l.copy_raw_to::<f32>(&mut cache.v).map_err(wrap)?;

        Ok(PrefillOut { logits, cache, bucket, timing: StepTiming { exec_s, marshal_s } })
    }

    /// One decode step over the whole cache batch. `tokens[i]` is fed at
    /// position `pos[i]` for slot i (PAD for inactive slots). Returns
    /// per-slot logits and updates the cache in place.
    pub fn decode_step(&self, cache: &mut KvCache, tokens: &[i32], pos: &[i32])
        -> Result<(Vec<Vec<f32>>, StepTiming)> {
        let b = cache.batch;
        if tokens.len() != b || pos.len() != b {
            bail!("decode arity: cache batch {b}, tokens {}, pos {}",
                  tokens.len(), pos.len());
        }
        let exe = self.decode_exes.get(&b)
            .ok_or_else(|| anyhow!("no decode bucket for batch {b}"))?;
        let dims = cache.dims();
        let dim_slice = [dims[0], dims[1], dims[2], dims[3], dims[4]];

        let t0 = std::time::Instant::now();
        let k_lit = literal_f32(&cache.k, &dim_slice)?;
        let v_lit = literal_f32(&cache.v, &dim_slice)?;
        let tok_lit = literal_i32(tokens, &[b])?;
        let pos_lit = literal_i32(pos, &[b])?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.extend([&k_lit, &v_lit, &tok_lit, &pos_lit]);
        let marshal0 = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let result = exe.execute::<&xla::Literal>(&args).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?;
        let exec_s = t1.elapsed().as_secs_f64();

        let t2 = std::time::Instant::now();
        let parts = tuple_parts(out, 3)?;
        let vocab = self.vocab();
        let flat: Vec<f32> = parts[0].to_vec::<f32>().map_err(wrap)?;
        let logits = (0..b)
            .map(|i| flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        parts[1].copy_raw_to::<f32>(&mut cache.k).map_err(wrap)?;
        parts[2].copy_raw_to::<f32>(&mut cache.v).map_err(wrap)?;
        let marshal_s = marshal0 + t2.elapsed().as_secs_f64();

        self.decode_steps.set(self.decode_steps.get() + 1);
        Ok((logits, StepTiming { exec_s, marshal_s }))
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    ).map_err(wrap).with_context(|| format!("parsing {}", path.display()))?;
    client.compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(wrap)
        .with_context(|| format!("compiling {}", path.display()))
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims_i64).map_err(wrap)
}

fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims_i64).map_err(wrap)
}

fn tuple_parts(lit: xla::Literal, n: usize) -> Result<Vec<xla::Literal>> {
    let mut l = lit;
    let parts = l.decompose_tuple().map_err(wrap)?;
    if parts.len() != n {
        bail!("expected {n}-tuple output, got {}", parts.len());
    }
    Ok(parts)
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Temperature + top-k sampling (deterministic given `u` in [0,1)).
pub fn sample_topk(logits: &[f32], temperature: f32, k: usize, u: f64) -> i32 {
    if temperature <= 0.0 || k <= 1 {
        return argmax(logits);
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    // A NaN logit (overflowed kernel, bad checkpoint) used to abort the
    // decode via partial_cmp().unwrap(); key it as -inf so it sorts out of
    // the top-k window instead. (Plain total_cmp would rank +NaN *above*
    // +inf and poison the softmax.)
    let key = |i: usize| {
        let v = logits[i];
        if v.is_nan() { f32::NEG_INFINITY } else { v }
    };
    idx.sort_by(|&a, &b| key(b).total_cmp(&key(a)));
    idx.truncate(k);
    let max = logits[idx[0]];
    let weights: Vec<f64> = idx.iter()
        .map(|&i| (((logits[i] - max) / temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut x = u * total;
    for (i, w) in idx.iter().zip(&weights) {
        x -= w;
        if x <= 0.0 {
            return *i as i32;
        }
    }
    idx[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_cache_slot_copy() {
        let mut dst = KvCache::new(2, 4, 8, 2, 4);
        let mut src = KvCache::new(2, 1, 8, 2, 4);
        for (i, x) in src.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        src.v.copy_from_slice(&src.k);
        dst.copy_slot_from(2, &src, 0);
        let stride = 8 * 2 * 4;
        // Layer 0, slot 2 of dst == layer 0 of src.
        assert_eq!(dst.k[2 * stride..3 * stride], src.k[0..stride]);
        // Layer 1, slot 2.
        let d_off = (4 + 2) * stride;
        let s_off = stride;
        assert_eq!(dst.k[d_off..d_off + stride], src.k[s_off..s_off + stride]);
        // Other slots untouched.
        assert!(dst.k[..2 * stride].iter().all(|&x| x == 0.0));
        dst.clear_slot(2);
        assert!(dst.k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn argmax_and_topk() {
        let logits = [0.1f32, 2.0, -1.0, 0.5];
        assert_eq!(argmax(&logits), 1);
        assert_eq!(sample_topk(&logits, 0.0, 5, 0.3), 1);
        // top-1 is argmax regardless of u.
        assert_eq!(sample_topk(&logits, 1.0, 1, 0.99), 1);
        // top-2, u near 0 → most likely token.
        assert_eq!(sample_topk(&logits, 1.0, 2, 0.0), 1);
        let t = sample_topk(&logits, 1.0, 2, 0.999);
        assert!(t == 1 || t == 3);
    }

    #[test]
    fn topk_survives_nan_logits() {
        // Regression: the descending sort used partial_cmp().unwrap(), so
        // one NaN logit aborted decoding. total_cmp sorts NaN last, out of
        // the top-k window.
        let logits = [0.1f32, f32::NAN, 2.0, 0.5];
        let t = sample_topk(&logits, 1.0, 2, 0.0);
        assert!(t == 2 || t == 3, "NaN must not enter the top-k: {t}");
    }
}
