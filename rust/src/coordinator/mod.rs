//! L3 serving coordinator: admission, continuous batching, and metrics over
//! the AOT engine (runtime/).
//!
//! This is the *real* (non-simulated) request path used by the end-to-end
//! example: requests enter online/offline queues, the scheduler admits them
//! into free KV slots (online first — the paper's pool priority), prefill
//! runs on the smallest fitting bucket, and all active slots advance
//! together through batched decode steps — vLLM-style iteration-level
//! continuous batching, sized to the AOT decode bucket.

use crate::runtime::engine::{argmax, sample_topk, Engine, KvCache};
use crate::runtime::tokenizer;
use crate::util::rng::Rng;
use crate::workload::RequestClass;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    pub class: RequestClass,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    MaxSeq,
    Rejected,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub class: RequestClass,
    pub output: Vec<i32>,
    pub prompt_len: usize,
    /// Submit → first token.
    pub ttft_s: f64,
    /// Submit → finish.
    pub e2e_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    pub finish: FinishReason,
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Decode bucket (batch slots). Must be one of the AOT decode buckets.
    pub decode_batch: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { decode_batch: 8, temperature: 0.0, top_k: 1, seed: 0 }
    }
}

struct Active {
    id: u64,
    class: RequestClass,
    prompt_len: usize,
    submit: Instant,
    first_token_at: Instant,
    /// Next decode position (index of the slot the next token's KV writes).
    pos: i32,
    last_token: i32,
    output: Vec<i32>,
    max_new: usize,
}

/// Aggregate serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub generated_tokens: usize,
    pub decode_steps: usize,
    /// Sum over steps of active slots (for mean batch occupancy).
    pub occupancy_sum: usize,
    pub prefill_exec_s: f64,
    pub decode_exec_s: f64,
    pub marshal_s: f64,
}

impl ServeStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / self.decode_steps as f64
    }
}

pub struct Coordinator<'e> {
    engine: &'e Engine,
    cfg: CoordinatorConfig,
    cache: KvCache,
    slots: Vec<Option<Active>>,
    online_q: VecDeque<(ServeRequest, Instant)>,
    offline_q: VecDeque<(ServeRequest, Instant)>,
    rng: Rng,
    pub stats: ServeStats,
    completions: Vec<Completion>,
}

impl<'e> Coordinator<'e> {
    pub fn new(engine: &'e Engine, cfg: CoordinatorConfig) -> Result<Self> {
        anyhow::ensure!(
            engine.decode_buckets().contains(&cfg.decode_batch),
            "decode bucket {} not AOT-compiled (have {:?})",
            cfg.decode_batch, engine.decode_buckets()
        );
        let cache = engine.empty_cache(cfg.decode_batch);
        let slots = (0..cfg.decode_batch).map(|_| None).collect();
        Ok(Coordinator {
            engine,
            rng: Rng::new(cfg.seed),
            cfg,
            cache,
            slots,
            online_q: VecDeque::new(),
            offline_q: VecDeque::new(),
            stats: ServeStats::default(),
            completions: Vec::new(),
        })
    }

    /// Enqueue a request (timestamped now).
    pub fn submit(&mut self, req: ServeRequest) {
        let entry = (req, Instant::now());
        match entry.0.class {
            RequestClass::Online => self.online_q.push_back(entry),
            RequestClass::Offline => self.offline_q.push_back(entry),
        }
    }

    pub fn pending(&self) -> usize {
        self.online_q.len() + self.offline_q.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.pending() == 0 && self.active() == 0
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    fn next_queued(&mut self) -> Option<(ServeRequest, Instant)> {
        // Online pool drains first (paper's priority admission).
        self.online_q.pop_front().or_else(|| self.offline_q.pop_front())
    }

    /// Admit as many queued requests as fit into free slots.
    fn admit(&mut self) -> Result<()> {
        while self.free_slot().is_some() && self.pending() > 0 {
            let (req, submit) = self.next_queued().unwrap();
            let slot = self.free_slot().unwrap();
            // Reject prompts no prefill bucket can hold.
            if self.engine.manifest.pick_prefill_bucket(1, req.tokens.len()).is_none() {
                self.completions.push(Completion {
                    id: req.id,
                    class: req.class,
                    output: Vec::new(),
                    prompt_len: req.tokens.len(),
                    ttft_s: 0.0,
                    e2e_s: 0.0,
                    tpot_s: 0.0,
                    finish: FinishReason::Rejected,
                });
                continue;
            }
            let out = self.engine.prefill(std::slice::from_ref(&req.tokens))?;
            self.stats.prefill_exec_s += out.timing.exec_s;
            self.stats.marshal_s += out.timing.marshal_s;
            self.cache.copy_slot_from(slot, &out.cache, 0);
            let first = self.sample(&out.logits[0]);
            let now = Instant::now();
            self.slots[slot] = Some(Active {
                id: req.id,
                class: req.class,
                prompt_len: req.tokens.len(),
                submit,
                first_token_at: now,
                pos: req.tokens.len() as i32,
                last_token: first,
                output: vec![first],
                max_new: req.max_new_tokens.max(1),
            });
        }
        Ok(())
    }

    fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.cfg.temperature <= 0.0 || self.cfg.top_k <= 1 {
            argmax(logits)
        } else {
            sample_topk(logits, self.cfg.temperature, self.cfg.top_k, self.rng.f64())
        }
    }

    /// One scheduler iteration: admit, then one batched decode step.
    /// Returns the number of tokens generated this step.
    pub fn step(&mut self) -> Result<usize> {
        self.admit()?;
        let occupancy = self.active();
        if occupancy == 0 {
            return Ok(0);
        }

        let b = self.cfg.decode_batch;
        let mut tokens = vec![tokenizer::PAD; b];
        let mut pos = vec![0i32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(a) = s {
                tokens[i] = a.last_token;
                pos[i] = a.pos;
            }
        }
        let (logits, timing) = self.engine.decode_step(&mut self.cache, &tokens, &pos)?;
        self.stats.decode_exec_s += timing.exec_s;
        self.stats.marshal_s += timing.marshal_s;
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += occupancy;

        let max_seq = self.engine.max_seq() as i32;
        let mut produced = 0;
        for i in 0..b {
            // Sample next token for live slots; detach finished ones.
            let Some(a) = self.slots[i].as_mut() else { continue };
            let tok = if self.cfg.temperature <= 0.0 || self.cfg.top_k <= 1 {
                argmax(&logits[i])
            } else {
                sample_topk(&logits[i], self.cfg.temperature, self.cfg.top_k,
                            self.rng.f64())
            };
            a.output.push(tok);
            a.last_token = tok;
            a.pos += 1;
            produced += 1;
            self.stats.generated_tokens += 1;

            let finish = if tok == tokenizer::EOS {
                Some(FinishReason::Eos)
            } else if a.output.len() >= a.max_new {
                Some(FinishReason::MaxTokens)
            } else if a.pos + 1 >= max_seq {
                Some(FinishReason::MaxSeq)
            } else {
                None
            };
            if let Some(f) = finish {
                let a = self.slots[i].take().unwrap();
                let now = Instant::now();
                let ttft = (a.first_token_at - a.submit).as_secs_f64();
                let e2e = (now - a.submit).as_secs_f64();
                let n = a.output.len();
                self.completions.push(Completion {
                    id: a.id,
                    class: a.class,
                    tpot_s: if n > 1 { (e2e - ttft) / (n - 1) as f64 } else { 0.0 },
                    output: a.output,
                    prompt_len: a.prompt_len,
                    ttft_s: ttft,
                    e2e_s: e2e,
                    finish: f,
                });
                self.stats.completed += 1;
                self.cache.clear_slot(i);
            }
        }
        Ok(produced)
    }

    /// Drive until every submitted request completes; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.completions))
    }

    /// Drain currently-finished completions without waiting.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }
}
