//! `ecoserve` CLI: serve (real AOT model), plan (capacity planner),
//! simulate (cluster sim), report (carbon models), sweep (parallel
//! scenario-sweep engine), scale (sharded-runtime capacity study),
//! inspect (observability-artifact summarizer).

use ecoserve::util::cli::Args;
use ecoserve::util::log;

const USAGE: &str = "\
ecoserve <command> [--flags]

global flags:
  --quiet          only errors on stderr
  -v, --verbose    debug logging on stderr

commands:
  serve     --artifacts DIR --requests N --rate R   serve the AOT model
  plan      --model NAME --rate R --ci CI [--config F]  run the capacity planner
  simulate  --model NAME --gpus N --gpu SKU --rate R [--ci-trace diurnal]
            run the cluster sim
  report    --gpu SKU                               embodied-carbon breakdown
  sweep     --all | --scenario A,B | --pack core|replay|failure
            [--list] [--threads N] [--seed S]
            [--duration SECS] [--ci-trace flat|diurnal|week] [--ci-file F]
            [--trace FILE] [--trace-dialect azure|burstgpt|auto]
            [--trace-errors skip|fail] [--trace-rate R] [--epoch SECS]
            [--shards N] [--coldstart SECS] [--keepalive POLICY]
            [--obs-dir DIR] [--obs-interval SECS] [--trace-jobs-rate R]
            [--progress SECS] [--out FILE] [--json]
            run registered end-to-end scenarios in parallel (--epoch
            overrides the rolling-horizon re-provisioning period; --shards
            runs every scenario on the sharded runtime with up to N shard
            threads, byte-identical for any N; --coldstart forces a
            provisioning boot delay; --keepalive forces a drain policy:
            immediate, fixed:SECS, or hybrid[:BIN_S:PCT:MAX_S]; --trace
            replays a production request-trace csv as every scenario's
            workload, fit to --duration, with the dialect sniffed from the
            file unless pinned; --ci-file streams a grid-CI csv as every
            scenario's carbon signal; long-haul scale scenarios join --all
            only when --duration is given, or when selected by name;
            --pack sweeps one registry group: core design points, replay
            trace studies, or the failure fault-injection pack;
            --obs-dir writes per-scenario observability artifacts — a
            fleet timeline csv, a Chrome-trace span json loadable in
            Perfetto/chrome://tracing, and a self-profile json — sampled
            every --obs-interval seconds with jobs span-traced at
            --trace-jobs-rate, outcome bytes unchanged; --progress prints
            a wall-clock heartbeat for long-haul runs)
  inspect   <obs-dir>                               summarize a sweep's
            observability artifacts (timeline coverage, carbon, spans,
            stage timings)
  scale     [--scenario production-day] [--durations A,B] [--shards 1,2,4]
            [--seed S] [--out FILE] [--json]
            simulator-capacity study: sweep trace duration x shard count,
            report events/sec + peak RSS + peak live jobs per cell, and
            verify the outcome bytes are shard-count-invariant
  plan-bench [--fleets 100,1000,10000] [--epochs 32] [--reps 3] [--seed S]
            [--out FILE] [--json]
            planner-scaling study: schedule a step-surge day over fleets of
            each size twice — cold (full ILP re-solve every epoch) and warm
            (incremental planner: memoization + drift early-out + interval
            cuts) — and report plans/sec, warm/cold speedup, and where each
            epoch went (solves / hits / skips / cut patches)
";

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    init_log(&args);
    match args.subcommand() {
        Some("serve") => serve(&args),
        Some("plan") => { plan(&args); Ok(()) }
        Some("simulate") => simulate(&args),
        Some("report") => { report(&args); Ok(()) }
        Some("sweep") => sweep(&args),
        Some("scale") => scale(&args),
        Some("plan-bench") => plan_bench(&args),
        Some("inspect") => inspect(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve `--quiet` / `-v` / `--verbose` into the process log level
/// (`-v` has no `--` prefix, so the parser files it as a positional).
fn init_log(args: &Args) {
    use ecoserve::util::log::Level;
    let verbose = args.bool("verbose")
        || args.positional().iter().any(|p| p == "-v");
    if args.bool("quiet") {
        log::set_level(Level::Error);
    } else if verbose {
        log::set_level(Level::Debug);
    }
}

fn ci_profile_flag(args: &Args) -> anyhow::Result<Option<ecoserve::scenarios::CiProfile>> {
    use ecoserve::scenarios::CiProfile;
    match args.opt_str("ci-trace") {
        None => Ok(None),
        Some("flat") => Ok(Some(CiProfile::Flat)),
        Some("diurnal") => Ok(Some(CiProfile::CompressedDiurnal)),
        Some("week") => Ok(Some(CiProfile::CompressedWeek)),
        Some(other) => anyhow::bail!(
            "unknown --ci-trace '{other}' (expected flat, diurnal, or week)"),
    }
}

/// Parse the `--trace FILE` replay family: `--trace-dialect
/// azure|burstgpt|auto` (default: sniff the header/field shape),
/// `--trace-errors skip|fail` (default: skip and count malformed lines),
/// `--trace-rate R` (default 1.0; the recorded span is always fit to
/// `--duration`). The file is probed up front so a malformed trace under
/// the fail policy exits with a clean line-numbered error before any
/// scenario runs; under the skip policy the skip/repair counts are echoed
/// to stderr.
fn trace_flag(args: &Args)
    -> anyhow::Result<Option<ecoserve::scenarios::TraceOverride>> {
    use ecoserve::scenarios::TraceOverride;
    use ecoserve::workload::trace::{self, TraceDialect, TraceErrorPolicy};
    let Some(path) = args.opt_str("trace") else {
        for flag in ["trace-dialect", "trace-errors", "trace-rate"] {
            anyhow::ensure!(!args.has(flag), "--{flag} requires --trace FILE");
        }
        return Ok(None);
    };
    let dialect = match args.opt_str("trace-dialect") {
        None | Some("auto") => trace::sniff_dialect(path)?,
        Some(f) => TraceDialect::from_flag(f).ok_or_else(|| anyhow::anyhow!(
            "unknown --trace-dialect '{f}' (expected azure, burstgpt, or \
             auto)"))?,
    };
    let errors = match args.opt_str("trace-errors") {
        None => TraceErrorPolicy::Skip,
        Some(f) => TraceErrorPolicy::from_flag(f).ok_or_else(|| anyhow::anyhow!(
            "unknown --trace-errors '{f}' (expected skip or fail)"))?,
    };
    let rate = args.f64("trace-rate", 1.0);
    anyhow::ensure!(rate.is_finite() && rate > 0.0,
                    "--trace-rate must be a positive finite multiplier");
    let stats = trace::probe(path, dialect, errors)?;
    anyhow::ensure!(stats.records > 0, "trace {path}: no replayable records");
    if stats.skipped_lines > 0 || stats.repaired_timestamps > 0 {
        log::warn(&format!(
            "trace {path}: {} records ({} malformed lines skipped, \
             {} timestamps repaired)",
            stats.records, stats.skipped_lines, stats.repaired_timestamps));
    }
    Ok(Some(TraceOverride { path: path.to_string(), dialect, errors, rate }))
}

/// Parse the `--keepalive POLICY` grammar: `immediate`, `fixed:SECS`, or
/// `hybrid[:BIN_S:PCT:MAX_S]` (hybrid defaults: 10s bins, p90, 60s cap).
fn keepalive_flag(args: &Args)
    -> anyhow::Result<Option<ecoserve::sim::KeepAlivePolicy>> {
    use ecoserve::sim::KeepAlivePolicy;
    let Some(spec) = args.opt_str("keepalive") else { return Ok(None) };
    let parts: Vec<&str> = spec.split(':').collect();
    let num = |s: &str, what: &str| -> anyhow::Result<f64> {
        let v: f64 = s.parse()
            .map_err(|_| anyhow::anyhow!("bad --keepalive {what} '{s}'"))?;
        anyhow::ensure!(v.is_finite() && v >= 0.0,
                        "--keepalive {what} must be finite and non-negative");
        Ok(v)
    };
    match parts.as_slice() {
        ["immediate"] => Ok(Some(KeepAlivePolicy::Immediate)),
        ["fixed", w] => Ok(Some(KeepAlivePolicy::Fixed {
            window_s: num(w, "window")?,
        })),
        ["hybrid"] => Ok(Some(KeepAlivePolicy::HybridHistogram {
            bin_s: 10.0, percentile: 0.9, max_window_s: 60.0,
        })),
        ["hybrid", b, p, m] => {
            let percentile = num(p, "percentile")?;
            anyhow::ensure!((0.0..=1.0).contains(&percentile),
                            "--keepalive percentile must be in [0, 1]");
            Ok(Some(KeepAlivePolicy::HybridHistogram {
                bin_s: num(b, "bin")?.max(1e-9),
                percentile,
                max_window_s: num(m, "max window")?,
            }))
        }
        _ => anyhow::bail!(
            "unknown --keepalive '{spec}' (expected immediate, fixed:SECS, \
             or hybrid[:BIN_S:PCT:MAX_S])"),
    }
}

fn sweep(args: &Args) -> anyhow::Result<()> {
    use ecoserve::scenarios::{catalog, registry, run_sweep, SweepConfig};

    if args.bool("list") {
        println!("registered scenarios:");
        for s in registry() {
            let tag = if s.long_haul() { " [long-haul]" } else { "" };
            println!("  {:<22} [{:<7}] {}{tag}", s.name(), s.pack().name(),
                     s.description());
        }
        return Ok(());
    }

    let pack = match args.opt_str("pack") {
        None => None,
        Some(p) => Some(ecoserve::scenarios::Pack::parse(p).ok_or_else(|| {
            anyhow::anyhow!("unknown --pack '{p}' (core, replay, failure)")
        })?),
    };
    let scenarios = if args.has("scenario") {
        anyhow::ensure!(pack.is_none(),
                        "--pack and --scenario are mutually exclusive");
        let spec = args.str("scenario", "");
        let names: Vec<&str> = spec.split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!names.is_empty(), "empty --scenario list");
        catalog::by_names(&names).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario in '{spec}' (try `ecoserve sweep --list`)")
        })?
    } else {
        // Full sweep, optionally restricted to one `--pack` group.
        // Long-haul scale scenarios only join when the caller sized the
        // sweep explicitly; `--scenario` selection by name always runs
        // them.
        let mut all = registry();
        if let Some(p) = pack {
            all.retain(|s| s.pack() == p);
        }
        if !args.has("duration") {
            let skipped: Vec<&str> = all.iter()
                .filter(|s| s.long_haul())
                .map(|s| s.name())
                .collect();
            if !skipped.is_empty() {
                log::info(&format!(
                    "skipping long-haul scenarios without --duration: {}",
                    skipped.join(", ")));
            }
            all.retain(|s| !s.long_haul());
        }
        anyhow::ensure!(!all.is_empty(), "no scenarios selected");
        all
    };

    let epoch_s = if args.has("epoch") {
        Some(args.f64("epoch", 15.0))
    } else {
        None
    };
    let shards = if args.has("shards") {
        Some(args.usize("shards", 1))
    } else {
        None
    };
    let coldstart_s = if args.has("coldstart") {
        Some(args.f64("coldstart", 0.0))
    } else {
        None
    };
    let ci_file = match args.opt_str("ci-file") {
        None => None,
        Some(p) => {
            // Validate schema + monotonic uniform timestamps up front so a
            // malformed CI file exits with a clean error before any
            // scenario runs; the region and duration here are metadata
            // only and never reach the sweep.
            ecoserve::carbon::CiStream::open(
                p, ecoserve::carbon::intensity::Region::California, 1.0)?;
            Some(p.to_string())
        }
    };
    let obs_dir = args.opt_str("obs-dir").map(|s| s.to_string());
    for flag in ["obs-interval", "trace-jobs-rate"] {
        anyhow::ensure!(obs_dir.is_some() || !args.has(flag),
                        "--{flag} requires --obs-dir DIR");
    }
    let obs_interval_s = args.f64("obs-interval", 60.0);
    anyhow::ensure!(obs_interval_s.is_finite() && obs_interval_s > 0.0,
                    "--obs-interval must be a positive finite number of \
                     seconds");
    let trace_jobs_rate = args.f64("trace-jobs-rate", 0.05);
    anyhow::ensure!((0.0..=1.0).contains(&trace_jobs_rate),
                    "--trace-jobs-rate must be in [0, 1]");
    let progress_s = if args.has("progress") {
        let p = args.f64("progress", 10.0);
        anyhow::ensure!(p.is_finite() && p > 0.0,
                        "--progress must be a positive finite number of \
                         seconds");
        Some(p)
    } else {
        None
    };
    let cfg = SweepConfig {
        threads: args.usize("threads", 0),
        seed: args.u64("seed", 42),
        duration_s: args.f64("duration", 180.0),
        ci_profile: ci_profile_flag(args)?,
        epoch_s,
        shards,
        coldstart_s,
        keepalive: keepalive_flag(args)?,
        trace: trace_flag(args)?,
        ci_file,
        obs_dir,
        obs_interval_s,
        trace_jobs_rate,
        progress_s,
    };
    anyhow::ensure!(cfg.duration_s.is_finite() && cfg.duration_s > 0.0,
                    "--duration must be a positive finite number of seconds");
    if let Some(c) = cfg.coldstart_s {
        anyhow::ensure!(c.is_finite() && c >= 0.0,
                        "--coldstart must be a non-negative finite number of \
                         seconds");
    }
    if let Some(e) = cfg.epoch_s {
        anyhow::ensure!(e.is_finite() && e > 0.0,
                        "--epoch must be a positive finite number of seconds");
    }
    if let Some(n) = cfg.shards {
        anyhow::ensure!(n >= 1, "--shards must be at least 1");
    }
    log::info(&format!("sweeping {} scenarios (seed {}, {}s traces) ...",
                       scenarios.len(), cfg.seed, cfg.duration_s));
    let t0 = std::time::Instant::now();
    let report = run_sweep(&scenarios, &cfg);
    let wall = t0.elapsed().as_secs_f64();

    let json = report.to_json().to_string();
    if args.bool("json") {
        println!("{json}");
    } else {
        report.summary_table().print();
        for o in &report.outcomes {
            for (k, v) in &o.extras {
                println!("  {}: {k} = {v:.4}", o.name);
            }
        }
        for w in report.truncation_warnings() {
            log::warn(&w);
        }
    }
    // Table mode always persists the machine-readable report; --json mode
    // already streams it to stdout, so only write a file when asked.
    if !args.bool("json") || args.has("out") {
        let out = args.str("out", "sweep-report.json");
        std::fs::write(&out, json.as_bytes())
            .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
        log::info(&format!("{} scenarios in {:.1}s -> {}",
                           report.outcomes.len(), wall, out));
    } else {
        log::info(&format!("{} scenarios in {:.1}s",
                           report.outcomes.len(), wall));
    }
    Ok(())
}

/// Summarize a directory of observability artifacts (`sweep --obs-dir`):
/// one row per scenario with the timeline's coverage, peak fleet power,
/// and final cumulative carbon, the span trace's event count, and the
/// self-profile's stage split.
fn inspect(args: &Args) -> anyhow::Result<()> {
    use ecoserve::util::json::Json;
    use ecoserve::util::table::{fnum, Table};
    use std::collections::BTreeMap;

    let dir = args.positional().get(1).cloned()
        .or_else(|| args.opt_str("dir").map(|s| s.to_string()))
        .ok_or_else(|| anyhow::anyhow!("usage: ecoserve inspect <obs-dir>"))?;

    #[derive(Default)]
    struct Entry {
        timeline: Option<String>,
        spans: Option<String>,
        profile: Option<String>,
    }
    let mut entries: BTreeMap<String, Entry> = BTreeMap::new();
    let rd = std::fs::read_dir(&dir)
        .map_err(|e| anyhow::anyhow!("reading {dir}: {e}"))?;
    for e in rd {
        let path = e?.path();
        let Some(fname) = path.file_name().and_then(|s| s.to_str()) else {
            continue;
        };
        for suffix in [".timeline.csv", ".spans.json", ".profile.json"] {
            if let Some(name) = fname.strip_suffix(suffix) {
                let body = std::fs::read_to_string(&path)
                    .map_err(|e| anyhow::anyhow!("reading {fname}: {e}"))?;
                let en = entries.entry(name.to_string()).or_default();
                match suffix {
                    ".timeline.csv" => en.timeline = Some(body),
                    ".spans.json" => en.spans = Some(body),
                    _ => en.profile = Some(body),
                }
            }
        }
    }
    anyhow::ensure!(!entries.is_empty(),
                    "{dir}: no observability artifacts (expected \
                     *.timeline.csv / *.spans.json / *.profile.json from \
                     `sweep --obs-dir`)");

    let mut t = Table::new(&[
        "scenario", "samples", "span s", "peak W", "op kg", "emb kg",
        "spans ev", "plan s", "sim s",
    ]);
    for (name, en) in &entries {
        let (mut rows, mut last_t, mut peak_w) = (0usize, 0.0f64, 0.0f64);
        let (mut op, mut emb) = (0.0f64, 0.0f64);
        if let Some(csv) = &en.timeline {
            let mut lines = csv.lines();
            let header: Vec<&str> =
                lines.next().unwrap_or("").split(',').collect();
            let col = |n: &str| header.iter().position(|h| *h == n);
            let (it, ip, iop, iemb) =
                (col("t_s"), col("power_w"), col("op_kg"), col("emb_kg"));
            for line in lines.filter(|l| !l.is_empty()) {
                let f: Vec<&str> = line.split(',').collect();
                let num = |i: Option<usize>| {
                    i.and_then(|i| f.get(i))
                        .and_then(|v| v.parse::<f64>().ok())
                        .unwrap_or(0.0)
                };
                rows += 1;
                last_t = num(it);
                peak_w = peak_w.max(num(ip));
                op = num(iop); // cumulative: last row is the total
                emb = num(iemb);
            }
        }
        let span_events = en.spans.as_ref().map(|body| {
            match Json::parse(body) {
                Ok(j) => match j.get("traceEvents") {
                    Some(Json::Arr(evs)) => evs.len(),
                    _ => 0,
                },
                Err(_) => 0,
            }
        });
        let stage = |key: &str| -> Option<f64> {
            let body = en.profile.as_ref()?;
            match Json::parse(body).ok()?.get(key)? {
                Json::Num(v) => Some(*v),
                _ => None,
            }
        };
        let opt = |v: Option<f64>| {
            v.map(fnum).unwrap_or_else(|| "-".into())
        };
        t.row(&[
            name.clone(),
            if en.timeline.is_some() { format!("{rows}") } else { "-".into() },
            if en.timeline.is_some() { fnum(last_t) } else { "-".into() },
            if en.timeline.is_some() { fnum(peak_w) } else { "-".into() },
            if en.timeline.is_some() { fnum(op) } else { "-".into() },
            if en.timeline.is_some() { fnum(emb) } else { "-".into() },
            span_events.map(|n| format!("{n}")).unwrap_or_else(|| "-".into()),
            opt(stage("plan_s")),
            opt(stage("sim_s")),
        ]);
    }
    t.print();
    Ok(())
}

/// The Özcan-style simulator-capacity study: sweep trace duration x shard
/// count on one scenario, measure events/sec, peak live jobs, and peak
/// RSS per cell, and check that the outcome bytes are shard-count
/// invariant within each duration. Wall-clock numbers are measurements
/// (not deterministic); the outcome JSON they are computed from is.
///
/// `events_per_sec` is *pipeline* throughput: the main run's event count
/// over the wall time of the full scenario pipeline (planning passes and
/// baseline simulations included) — a conservative lower bound on raw
/// core throughput (`perf_sim` measures that), but every cell runs the
/// identical pipeline, so the duration x shards scaling curve is
/// apples-to-apples.
fn scale(args: &Args) -> anyhow::Result<()> {
    use ecoserve::obs::{peak_rss_kb, reset_peak_rss};
    use ecoserve::scenarios::{catalog, scenario_seed, Overrides};
    use ecoserve::util::json::Json;
    use ecoserve::util::table::{fnum, Table};

    let name = args.str("scenario", "production-day");
    let sc = catalog::by_names(&[name.as_str()])
        .ok_or_else(|| anyhow::anyhow!(
            "unknown scenario '{name}' (try `ecoserve sweep --list`)"))?
        .remove(0);
    let durations: Vec<f64> = args.str("durations", "300,900")
        .split(',')
        .map(|s| s.trim().parse::<f64>()
            .map_err(|_| anyhow::anyhow!("bad --durations entry '{s}'")))
        .collect::<Result<_, _>>()?;
    anyhow::ensure!(!durations.is_empty()
                        && durations.iter().all(|d| d.is_finite() && *d > 0.0),
                    "--durations must be positive finite seconds");
    let shard_counts: Vec<usize> = args.str("shards", "1,2,4")
        .split(',')
        .map(|s| s.trim().parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --shards entry '{s}'")))
        .collect::<Result<_, _>>()?;
    anyhow::ensure!(!shard_counts.is_empty()
                        && shard_counts.iter().all(|n| *n >= 1),
                    "--shards must be counts of at least 1");
    let master_seed = args.u64("seed", 42);
    let seed = scenario_seed(master_seed, sc.name());

    log::info(&format!(
        "scale study: {} over {} durations x {} shard counts ...",
        sc.name(), durations.len(), shard_counts.len()));
    let mut table = Table::new(&[
        "duration s", "shards", "req", "events", "wall s", "events/s",
        "peak-jobs", "peak-RSS MB", "det",
    ]);
    let mut cells: Vec<Json> = Vec::new();
    let mut all_deterministic = true;
    for &d in &durations {
        let mut reference: Option<String> = None;
        for &n in &shard_counts {
            let ov = Overrides { shards: Some(n), ..Default::default() };
            reset_peak_rss();
            let t0 = std::time::Instant::now();
            let o = sc.run_with(seed, d, &ov);
            let wall = t0.elapsed().as_secs_f64();
            let outcome_json = o.to_json().to_string();
            let deterministic = match &reference {
                None => {
                    reference = Some(outcome_json.clone());
                    true
                }
                Some(r) => *r == outcome_json,
            };
            all_deterministic &= deterministic;
            let events_per_sec = o.events as f64 / wall.max(1e-9);
            let rss_kb = peak_rss_kb();
            table.row(&[
                fnum(d),
                format!("{n}"),
                format!("{}", o.requests),
                format!("{}", o.events),
                fnum(wall),
                fnum(events_per_sec),
                format!("{}", o.peak_live_jobs),
                rss_kb.map(|kb| fnum(kb as f64 / 1024.0))
                    .unwrap_or_else(|| "-".into()),
                if deterministic { "ok".into() } else { "DIVERGED".into() },
            ]);
            cells.push(Json::obj()
                .set("duration_s", d)
                .set("shards", n)
                .set("requests", o.requests)
                .set("events", o.events)
                .set("peak_live_jobs", o.peak_live_jobs)
                .set("wall_s", wall)
                .set("events_per_sec", events_per_sec)
                .set("peak_rss_kb", match rss_kb {
                    Some(kb) => Json::Num(kb as f64),
                    None => Json::Null,
                })
                .set("identical_across_shards", deterministic));
        }
    }

    let report = Json::obj()
        .set("bench", "scale")
        .set("scenario", sc.name())
        .set("master_seed", format!("{master_seed:#018x}"))
        .set("cells", cells);
    let json = report.to_string();
    if args.bool("json") {
        println!("{json}");
    } else {
        table.print();
    }
    if !args.bool("json") || args.has("out") {
        let out = args.str("out", "scale-report.json");
        std::fs::write(&out, json.as_bytes())
            .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
        log::info(&format!("capacity curve -> {out}"));
    }
    anyhow::ensure!(all_deterministic,
                    "sharded outcomes diverged across shard counts");
    Ok(())
}

/// The planner-scaling study behind the CI `plan-scale` gate: for each
/// fleet size, build one fused [`DemandProfile`] of a step-surge day and
/// schedule it twice over the same template — cold (a full ILP re-solve
/// every epoch, `IncrementalPlanner::disabled()`) and warm (memoization +
/// drift early-out + interval cuts). Wall clocks are measurements; the
/// schedules themselves stay deterministic, and the epoch accounting
/// (solves / hits / skips / patches) is byte-stable evidence of *why* the
/// warm planner is faster.
fn plan_bench(args: &Args) -> anyhow::Result<()> {
    use ecoserve::carbon::intensity::CiSignal;
    use ecoserve::planner::fused::DemandProfile;
    use ecoserve::planner::horizon::{self, HorizonConfig, IncrementalPlanner,
                                     PlannerStats};
    use ecoserve::planner::PlanConfig;
    use ecoserve::sim::homogeneous_fleet;
    use ecoserve::util::json::Json;
    use ecoserve::util::table::{fnum, Table};
    use ecoserve::workload::slo::{slo_for, Slo};
    use ecoserve::workload::{Arrivals, GeneratorSource, LengthDist,
                             RequestClass};

    let fleets: Vec<usize> = args.str("fleets", "100,1000,10000")
        .split(',')
        .map(|s| s.trim().parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --fleets entry '{s}'")))
        .collect::<Result<_, _>>()?;
    anyhow::ensure!(!fleets.is_empty() && fleets.iter().all(|f| *f >= 1),
                    "--fleets must be counts of at least 1");
    let epochs = args.usize("epochs", 32);
    anyhow::ensure!(epochs >= 4, "--epochs must be at least 4");
    let reps = args.usize("reps", 3).max(1);
    let seed = args.u64("seed", 42);

    let model = "llama-8b";
    let m = ecoserve::models::llm(model).expect("catalog model");
    let slo = slo_for(model, false).map(|w| w.slo)
        .unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 });
    let cold_h = HorizonConfig::default();
    let warm_h = HorizonConfig { drift_tol: 0.1, interval_cuts: true,
                                 ..Default::default() };
    let duration_s = epochs as f64 * cold_h.epoch_s;
    let ci = CiSignal::flat(261.0);
    let plan_cfg = PlanConfig::default();

    log::info(&format!(
        "plan-bench: {} fleet sizes x {} epochs (best of {} reps) ...",
        fleets.len(), epochs, reps));
    let mut table = Table::new(&[
        "fleet", "epochs", "cold s", "cold plans/s", "warm s", "warm plans/s",
        "speedup", "solves", "hits", "skips", "patches", "cuts",
    ]);
    let mut cells: Vec<Json> = Vec::new();
    for &fleet in &fleets {
        let template = homogeneous_fleet("A100-40", fleet, m, 2048);
        // Demand scales with the fleet: a steady base with one 2.5x step
        // surge mid-day, so the warm planner sees plateaus (skips), one
        // growth edge (cut patch), and one shrink edge (forced re-solve).
        let arrivals = Arrivals::Step {
            base: 0.1 * fleet as f64,
            surge: 0.25 * fleet as f64,
            start_frac: 0.55,
            end_frac: 0.7,
        };
        let mut src = GeneratorSource::new(arrivals, LengthDist::ShareGpt,
                                           RequestClass::Online, duration_s,
                                           seed ^ fleet as u64);
        let epoch = cold_h.effective_epoch(duration_s);
        let profile = DemandProfile::build(&mut src, epoch, cold_h.window_s,
                                           duration_s);

        // Best-of-N wall clock per planner; stats are identical across
        // reps (the planner is deterministic), so keep the last.
        let run = |h: &HorizonConfig, warm: bool| -> (f64, PlannerStats) {
            let mut best = f64::INFINITY;
            let mut stats = PlannerStats::default();
            for _ in 0..reps {
                let mut inc = if warm {
                    IncrementalPlanner::from_horizon(h)
                } else {
                    IncrementalPlanner::disabled()
                };
                let t0 = std::time::Instant::now();
                let sched = horizon::plan_schedule_from_profile(
                    m, &profile, &template, &plan_cfg, &ci, slo, h,
                    duration_s, &mut inc);
                best = best.min(t0.elapsed().as_secs_f64());
                stats = inc.stats();
                assert!(sched.events.windows(2).all(|w| w[0].t <= w[1].t));
            }
            (best, stats)
        };
        let (cold_s, cold) = run(&cold_h, false);
        let (warm_s, warm) = run(&warm_h, true);

        let cold_pps = cold.epochs as f64 / cold_s.max(1e-9);
        let warm_pps = warm.epochs as f64 / warm_s.max(1e-9);
        let speedup = cold_s / warm_s.max(1e-9);
        table.row(&[
            format!("{fleet}"),
            format!("{}", warm.epochs),
            fnum(cold_s),
            fnum(cold_pps),
            fnum(warm_s),
            fnum(warm_pps),
            fnum(speedup),
            format!("{}", warm.full_solves),
            format!("{}", warm.warm_hits),
            format!("{}", warm.drift_skips),
            format!("{}", warm.cut_patches),
            format!("{}", warm.cuts),
        ]);
        cells.push(Json::obj()
            .set("fleet", fleet)
            .set("epochs", warm.epochs)
            .set("cold_wall_s", cold_s)
            .set("cold_plans_per_sec", cold_pps)
            .set("cold_nodes", cold.nodes)
            .set("warm_wall_s", warm_s)
            .set("warm_plans_per_sec", warm_pps)
            .set("warm_nodes", warm.nodes)
            .set("speedup", speedup)
            .set("full_solves", warm.full_solves)
            .set("warm_hits", warm.warm_hits)
            .set("drift_skips", warm.drift_skips)
            .set("cut_patches", warm.cut_patches)
            .set("cuts", warm.cuts));
    }

    let report = Json::obj()
        .set("bench", "plan")
        .set("model", model)
        .set("epochs", epochs)
        .set("reps", reps)
        .set("seed", format!("{seed:#018x}"))
        .set("cells", cells);
    let json = report.to_string();
    if args.bool("json") {
        println!("{json}");
    } else {
        table.print();
    }
    if !args.bool("json") || args.has("out") {
        let out = args.str("out", "BENCH_plan.json");
        std::fs::write(&out, json.as_bytes())
            .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
        log::info(&format!("planner scaling curve -> {out}"));
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use ecoserve::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
    use ecoserve::runtime::{engine::Engine, tokenizer};
    use ecoserve::workload::RequestClass;
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let eng = Engine::load(&dir)?;
    let mut coord = Coordinator::new(&eng, CoordinatorConfig::default())?;
    let n = args.usize("requests", 8);
    for i in 0..n {
        coord.submit(ServeRequest {
            id: i as u64,
            tokens: tokenizer::encode(&format!("request {i}: carbon-aware serving")),
            max_new_tokens: args.usize("max-new-tokens", 16),
            class: RequestClass::Online,
        });
    }
    let done = coord.run_to_completion()?;
    for c in &done {
        println!("req {}: {} tokens, ttft {:.1} ms, tpot {:.2} ms",
                 c.id, c.output.len(), c.ttft_s * 1e3, c.tpot_s * 1e3);
    }
    println!("mean batch occupancy: {:.2}", coord.stats.mean_batch_occupancy());
    Ok(())
}

fn plan(args: &Args) {
    use ecoserve::planner::slicing::{cluster_slices, slice_trace};
    use ecoserve::strategies::Strategy;
    use ecoserve::workload::slo::{slo_for, Slo};
    use ecoserve::workload::*;
    if let Some(path) = args.opt_str("config") {
        // Config-file driven planning (config::DeployConfig).
        let cfg = ecoserve::config::DeployConfig::load(std::path::Path::new(path))
            .expect("config");
        let slices = cfg.to_slices(300.0, args.u64("seed", 42));
        let p = ecoserve::planner::plan(&slices, &cfg.plan);
        println!("region {} (CI {} g/kWh), {} slices",
                 cfg.region.name(), cfg.region.avg_ci(), slices.len());
        println!("fleet: {:?}", p.counts);
        println!("carbon: {:.3} kg/hr (op {:.3} + emb {:.3}), cost ${:.2}/hr",
                 p.carbon_kg_per_hr(), p.op_kg_per_hr, p.emb_kg_per_hr, p.cost_hr);
        return;
    }
    let model = args.str("model", "llama-8b");
    let m = ecoserve::models::llm(&model).expect("unknown model");
    let slo = slo_for(&model, false).map(|w| w.slo)
        .unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 });
    let tr = generate_trace(Arrivals::Poisson { rate: args.f64("rate", 10.0) },
                            LengthDist::ShareGpt, RequestClass::Online, 300.0, 1);
    let slices = cluster_slices(&slice_trace(m, &tr, 300.0, slo, 1));
    let p = Strategy::EcoFull.plan(&slices, args.f64("ci", 261.0));
    println!("fleet: {:?}", p.counts);
    println!("carbon: {:.3} kg/hr (op {:.3} + emb {:.3}), cost ${:.2}/hr",
             p.carbon_kg_per_hr(), p.op_kg_per_hr, p.emb_kg_per_hr, p.cost_hr);
    println!("solved in {:.0} ms / {} nodes", p.solve_s * 1e3, p.nodes);
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    use ecoserve::carbon::intensity::{CiSignal, CiTrace, Region};
    use ecoserve::scenarios::CiProfile;
    use ecoserve::sim::*;
    use ecoserve::workload::*;
    let model = args.str("model", "llama-8b");
    let m = ecoserve::models::llm(&model).expect("unknown model");
    let duration = args.f64("duration", 120.0);
    let ci = args.f64("ci", 261.0);
    let tr = generate_trace(Arrivals::Poisson { rate: args.f64("rate", 4.0) },
                            LengthDist::ShareGpt, RequestClass::Online,
                            duration, 1);
    let n = args.usize("gpus", 4);
    let servers = homogeneous_fleet(&args.str("gpu", "A100-40"), n, m, 2048);
    let mut cfg = SimConfig::flat(servers, Router::WorkloadAware, ci,
                                  vec![0.005; n]);
    // Compressed solar day(s) mapped onto the trace duration, rescaled so
    // the trace mean tracks the requested --ci level. Periods overshoot
    // the duration so post-trace-end completion time keeps cycling
    // instead of clamping to the final step.
    let day = match ci_profile_flag(args)? {
        Some(CiProfile::CompressedDiurnal) => Some((duration, 2)),
        Some(CiProfile::CompressedWeek) => Some((duration / 7.0, 8)),
        Some(CiProfile::Flat) | None => None,
        Some(CiProfile::TraceFile { .. }) => unreachable!(
            "--ci-trace only names synthetic profiles; file streaming is \
             sweep --ci-file"),
    };
    if let Some((period_s, periods)) = day {
        let mut trace =
            CiTrace::compressed_diurnal(Region::California, period_s, periods,
                                        96, args.u64("seed", 1));
        let scale = ci / Region::California.avg_ci();
        for v in &mut trace.values {
            *v *= scale;
        }
        cfg.ci = CiSignal::Trace(trace);
    }
    let r = simulate(m, &tr, &cfg, 0.5, 0.1);
    println!("completed {} | TTFT p50 {:.0} ms p90 {:.0} ms | TPOT p50 {:.1} ms",
             r.completed, r.ttft.p50() * 1e3, r.ttft.p90() * 1e3,
             r.tpot.p50() * 1e3);
    println!("throughput {:.1} tok/s | energy {:.1} kJ | carbon {:.4} kg (op {:.4} emb {:.4}) | SLO {:.1}%",
             r.throughput_tok_s(), r.energy_j / 1e3, r.carbon_kg(), r.op_kg,
             r.emb_kg, 100.0 * r.slo_attainment);
    println!("events {} | deferred {} | offline deadline {:.1}%",
             r.events, r.deferred_requests,
             100.0 * r.offline_deadline_attainment);
    if r.truncated_prompts > 0 {
        log::warn(&format!("warning: {} prompts clipped to {} tokens",
                           r.truncated_prompts, MAX_PROMPT_TOKENS));
    }
    Ok(())
}

fn report(args: &Args) {
    use ecoserve::carbon::embodied::*;
    let gpu = args.str("gpu", "A100-40");
    let g = ecoserve::hw::gpu(&gpu).expect("unknown gpu");
    let b = gpu_embodied(g);
    println!("{gpu} embodied breakdown (kgCO2e):");
    println!("  soc {:.1} | memory {:.1} | pcb {:.1} | cooling {:.1} | pdn {:.1} | total {:.1}",
             b.soc, b.memory, b.pcb, b.cooling, b.pdn, b.total());
}
