//! Online/offline demand traces for the two production services (Fig 10).
//!
//! The paper reports, for Services A and B over a week: offline demand
//! averages 21% (A) and 45% (B) of total serving capacity, peaking at 27%
//! and 55%. The synthetic traces reproduce those aggregates with diurnal
//! online load and anti-correlated offline backfill (batch jobs queue up
//! and run preferentially off-peak).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Service {
    A,
    B,
}

impl Service {
    /// (average, peak) offline share of total capacity, per the paper.
    pub fn offline_share(&self) -> (f64, f64) {
        match self {
            Service::A => (0.21, 0.27),
            Service::B => (0.45, 0.55),
        }
    }
}

/// One point of the demand trace, in normalized capacity units
/// (1.0 = service's mean total demand).
#[derive(Debug, Clone, Copy)]
pub struct DemandPoint {
    pub t_s: f64,
    pub online: f64,
    pub offline: f64,
}

impl DemandPoint {
    pub fn total(&self) -> f64 {
        self.online + self.offline
    }

    pub fn offline_frac(&self) -> f64 {
        self.offline / self.total().max(1e-12)
    }
}

/// Synthesize a demand trace for `days` at `step_s` resolution.
pub fn demand_trace(service: Service, days: usize, step_s: f64, seed: u64)
    -> Vec<DemandPoint> {
    let (avg_off, peak_off) = service.offline_share();
    let mut rng = Rng::new(seed ^ match service { Service::A => 0xA, Service::B => 0xB });
    let n = ((days as f64 * 86_400.0) / step_s).ceil() as usize;
    // Solve for component scales: with online mean 1-avg_off and offline
    // mean avg_off of a unit-total trace.
    let on_mean = 1.0 - avg_off;
    let off_mean = avg_off;
    // Offline swing chosen so the *share* peaks near peak_off when online
    // troughs (the share peak is driven mostly by the online trough, so a
    // fraction of the raw ratio suffices).
    let off_swing = (0.6 * (peak_off / avg_off - 1.0)).clamp(0.05, 0.5);
    let mut noise_on = 0.0f64;
    let mut noise_off = 0.0f64;
    (0..n)
        .map(|i| {
            let t = i as f64 * step_s;
            let hour = (t / 3600.0) % 24.0;
            let dow = ((t / 86_400.0) as usize) % 7;
            // Weekends scale total demand (both classes), not the mix.
            let weekday = if dow < 5 { 1.0 } else { 0.85 };
            // Online peaks mid-afternoon.
            let diurnal_on = 1.0
                + 0.25 * (((hour - 8.0) / 24.0) * std::f64::consts::TAU).sin();
            // Offline backfill runs anti-cyclic (overnight batches).
            let diurnal_off = 1.0
                + off_swing * (((hour - 20.0) / 24.0) * std::f64::consts::TAU).sin();
            noise_on = 0.85 * noise_on + 0.15 * rng.normal() * 0.05;
            noise_off = 0.85 * noise_off + 0.15 * rng.normal() * 0.07;
            DemandPoint {
                t_s: t,
                online: (on_mean * diurnal_on * weekday * (1.0 + noise_on)).max(0.01),
                offline: (off_mean * diurnal_off * weekday * (1.0 + noise_off)).max(0.01),
            }
        })
        .collect()
}

/// Aggregate statistics of a trace: (avg offline share, peak offline share,
/// peak total demand).
pub fn trace_stats(trace: &[DemandPoint]) -> (f64, f64, f64) {
    let total: f64 = trace.iter().map(|p| p.total()).sum();
    let off: f64 = trace.iter().map(|p| p.offline).sum();
    let peak_share = trace.iter().map(|p| p.offline_frac()).fold(0.0, f64::max);
    let peak_total = trace.iter().map(|p| p.total()).fold(0.0, f64::max);
    (off / total, peak_share, peak_total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_a_matches_published_shares() {
        let tr = demand_trace(Service::A, 7, 900.0, 42);
        let (avg, peak, _) = trace_stats(&tr);
        assert!((avg - 0.21).abs() < 0.04, "avg {avg}");
        assert!(peak > 0.23 && peak < 0.36, "peak {peak}");
    }

    #[test]
    fn service_b_matches_published_shares() {
        let tr = demand_trace(Service::B, 7, 900.0, 42);
        let (avg, peak, _) = trace_stats(&tr);
        assert!((avg - 0.45).abs() < 0.05, "avg {avg}");
        assert!(peak > 0.50 && peak < 0.65, "peak {peak}");
    }

    #[test]
    fn offline_anticorrelated_with_online() {
        let tr = demand_trace(Service::B, 3, 900.0, 7);
        let on_mean = tr.iter().map(|p| p.online).sum::<f64>() / tr.len() as f64;
        let off_mean = tr.iter().map(|p| p.offline).sum::<f64>() / tr.len() as f64;
        let cov: f64 = tr.iter()
            .map(|p| (p.online - on_mean) * (p.offline - off_mean))
            .sum::<f64>() / tr.len() as f64;
        assert!(cov < 0.0, "cov {cov} should be negative");
    }

    #[test]
    fn demand_positive_and_daily_periodic() {
        let tr = demand_trace(Service::A, 2, 3600.0, 9);
        assert!(tr.iter().all(|p| p.online > 0.0 && p.offline > 0.0));
        // Afternoon online exceeds small-hours online on both days.
        let day = |d: usize, h: usize| tr[d * 24 + h].online;
        assert!(day(0, 14) > day(0, 2));
        assert!(day(1, 14) > day(1, 2));
    }
}
