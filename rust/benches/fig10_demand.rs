//! Fig 10: online vs offline demand for Services A and B over a week and
//! over a day.
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::demand::{demand_trace, trace_stats, Service};

fn main() {
    println!("== Fig 10: online/offline demand split (synthetic A/B traces) ==");
    let mut t = Table::new(&["service", "avg offline %", "peak offline %",
                             "paper avg %", "paper peak %"]);
    for (svc, pa, pp) in [(Service::A, 21.0, 27.0), (Service::B, 45.0, 55.0)] {
        let tr = demand_trace(svc, 7, 900.0, 42);
        let (avg, peak, _) = trace_stats(&tr);
        t.row(&[format!("{svc:?}"), fnum(avg * 100.0), fnum(peak * 100.0),
                fnum(pa), fnum(pp)]);
    }
    t.print();
    println!("\nService B, one day (hourly):");
    let tr = demand_trace(Service::B, 1, 3600.0, 42);
    let mut t = Table::new(&["hour", "online", "offline", "offline %"]);
    for (h, p) in tr.iter().enumerate().step_by(3) {
        t.row(&[format!("{h:02}"), fnum(p.online), fnum(p.offline),
                fnum(100.0 * p.offline_frac())]);
    }
    t.print();
}
