//! Quickstart: plan a carbon-aware deployment for a small workload and
//! print the fleet, carbon, and savings vs a performance-optimized
//! baseline.
//!
//! Run: `cargo run --release --example quickstart`

use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::strategies::Strategy;
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::slo_for;
use ecoserve::workload::{generate_trace, merge_traces, Arrivals, LengthDist,
                         RequestClass};

fn main() {
    // 1. A workload: bursty online chat + long-context offline batch.
    let model = models::llm("llama-8b").unwrap();
    let online = generate_trace(Arrivals::Bursty { rate: 12.0, cv: 2.0 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                300.0, 7);
    let offline = generate_trace(Arrivals::Poisson { rate: 5.0 },
                                 LengthDist::LongBench, RequestClass::Offline,
                                 300.0, 8);
    let trace = merge_traces(vec![online, offline]);
    println!("workload: {} requests over 5 min", trace.len());

    // 2. Slice it for the planner.
    let slo = slo_for("llama-8b", false).unwrap().slo;
    let slices = cluster_slices(&slice_trace(model, &trace, 300.0, slo, 1));
    println!("planner slices: {}", slices.len());

    // 3. Plan under EcoServe and the perf-optimized baseline (mid CI).
    let eco = Strategy::EcoFull.plan(&slices, 261.0);
    let perf = Strategy::PerfOpt.plan(&slices, 261.0);

    let mut t = Table::new(&["strategy", "fleet", "carbon kg/hr", "op", "embodied",
                             "$/hr"]);
    for (name, p) in [("ecoserve", &eco), ("perf-opt", &perf)] {
        t.row(&[name.into(), format!("{:?}", p.counts), fnum(p.carbon_kg_per_hr()),
                fnum(p.op_kg_per_hr), fnum(p.emb_kg_per_hr), fnum(p.cost_hr)]);
    }
    t.print();
    println!("\ncarbon saving: {:.1}%  (solve {:.0} ms, {} B&B nodes)",
             100.0 * (1.0 - eco.carbon_kg_per_hr() / perf.carbon_kg_per_hr()),
             eco.solve_s * 1e3, eco.nodes);
    for a in &eco.assignments {
        println!("  slice {} {:?} -> {} (load {:.2}, lat {})",
                 a.slice_idx, a.phase, a.device, a.load,
                 ecoserve::util::table::ftime(a.latency_s));
    }
}
