//! Table 2: power/latency/cost/carbon/energy when doubling tensor
//! parallelism.
use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::roofline::Device;
use ecoserve::strategies::tp_scaling;
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Table 2: relative metrics for TP n -> 2n (Llama-70B, A100-80) ==");
    let m = models::llm("llama-70b").unwrap();
    let dev = Device::from_gpu(hw::gpu("A100-80").unwrap());
    let mut t = Table::new(&["n", "power", "latency", "cost", "carbon", "energy"]);
    for n in [1usize, 2, 4] {
        let s = tp_scaling(m, &dev, n, 700.0, 800.0, 119.0, 0.08);
        t.row(&[format!("{n}->{}", 2 * n), fnum(s.power_ratio), fnum(s.latency_ratio),
                fnum(s.cost_ratio), fnum(s.carbon_ratio), fnum(s.energy_ratio)]);
    }
    t.print();
    println!("(latency ~0.5+comm; carbon improves with higher CPU/GPU emb ratio)");
}
