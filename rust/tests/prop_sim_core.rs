//! Property tests (testkit::forall with shrinking) for the streaming
//! core's data structures: the `(t, seq)` total-order event queue, the
//! per-class FIFO `ClassQueue`, and the slot-recycling `JobArena`.

use ecoserve::sim::{ClassQueue, EventKind, EventQueue, Job, JobArena};
use ecoserve::testkit::{forall, shrink_vec, PropConfig};
use ecoserve::util::rng::Rng;
use ecoserve::workload::RequestClass;
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// EventQueue: pops follow (t, seq) total order, ties FIFO.

#[derive(Debug, Clone, Copy)]
enum QueueOp {
    /// Push at one of a small set of timestamps (small set ⇒ many ties).
    Push(f64),
    Pop,
}

fn gen_queue_ops(r: &mut Rng) -> Vec<QueueOp> {
    let times = [0.0, 1.0, 1.0, 2.0, 2.5, f64::INFINITY];
    (0..8 + r.below(60))
        .map(|_| {
            if r.bool(0.6) {
                QueueOp::Push(times[r.below(times.len())])
            } else {
                QueueOp::Pop
            }
        })
        .collect()
}

#[test]
fn prop_event_queue_pops_in_t_seq_order_with_fifo_ties() {
    forall(
        &PropConfig { cases: 300, ..Default::default() },
        gen_queue_ops,
        |ops| shrink_vec(ops, |_| Vec::new()),
        |ops| {
            let mut q = EventQueue::default();
            // Shadow model: (t, push index) pairs still in the queue. The
            // payload encodes the push index so pops are identifiable.
            let mut shadow: Vec<(f64, usize)> = Vec::new();
            let mut pushed = 0usize;
            for op in ops {
                match *op {
                    QueueOp::Push(t) => {
                        q.push(t, EventKind::Wake(pushed));
                        shadow.push((t, pushed));
                        pushed += 1;
                    }
                    QueueOp::Pop => {
                        let got = q.pop();
                        if shadow.is_empty() {
                            if got.is_some() {
                                return Err("pop from empty returned Some".into());
                            }
                            continue;
                        }
                        // Expected: min by (total_cmp t, push order). The
                        // shadow list is push-ordered, so the first minimal
                        // t is the FIFO tie-winner.
                        let (best_i, &(bt, bid)) = shadow.iter().enumerate()
                            .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0))
                            .unwrap();
                        let ev = got.ok_or("pop returned None with items queued")?;
                        let EventKind::Wake(gid) = ev.kind else {
                            return Err("payload corrupted".into());
                        };
                        if gid != bid || ev.t.to_bits() != bt.to_bits() {
                            return Err(format!(
                                "popped (t={}, id={gid}), expected (t={bt}, id={bid})",
                                ev.t));
                        }
                        shadow.remove(best_i);
                    }
                }
            }
            // Drain: the remainder must come out sorted by (t, push id).
            let mut last: Option<(f64, u64)> = None;
            while let Some(ev) = q.pop() {
                if let Some((lt, ls)) = last {
                    if ev.t.total_cmp(&lt) == std::cmp::Ordering::Less
                        || (ev.t.to_bits() == lt.to_bits() && ev.seq < ls)
                    {
                        return Err(format!(
                            "drain out of order: ({}, {}) after ({lt}, {ls})",
                            ev.t, ev.seq));
                    }
                }
                last = Some((ev.t, ev.seq));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// ClassQueue: per-class FIFO, cross-class arrival interleaving, O(batch)
// pops that agree with a straightforward shadow model.

#[derive(Debug, Clone, Copy)]
enum CqOp {
    Push(bool), // true = online
    PopFifo(usize),
    PopOnlineFirst(usize),
}

fn gen_cq_ops(r: &mut Rng) -> Vec<CqOp> {
    (0..8 + r.below(80))
        .map(|_| match r.below(4) {
            0 | 1 => CqOp::Push(r.bool(0.5)),
            2 => CqOp::PopFifo(r.below(6)),
            _ => CqOp::PopOnlineFirst(r.below(6)),
        })
        .collect()
}

#[test]
fn prop_class_queue_preserves_per_class_fifo_under_random_ops() {
    forall(
        &PropConfig { cases: 300, ..Default::default() },
        gen_cq_ops,
        |ops| shrink_vec(ops, |_| Vec::new()),
        |ops| {
            let mut q = ClassQueue::default();
            // Shadow: (global seq, job id) per class.
            let mut online: VecDeque<(usize, usize)> = VecDeque::new();
            let mut offline: VecDeque<(usize, usize)> = VecDeque::new();
            let mut next = 0usize;
            for op in ops {
                match *op {
                    CqOp::Push(is_online) => {
                        let class = if is_online { RequestClass::Online }
                                    else { RequestClass::Offline };
                        q.push(next, class);
                        if is_online { online.push_back((next, next)); }
                        else { offline.push_back((next, next)); }
                        next += 1;
                    }
                    CqOp::PopFifo(max) => {
                        let got = q.pop_fifo(max);
                        let mut want = Vec::new();
                        while want.len() < max {
                            let take_online =
                                match (online.front(), offline.front()) {
                                    (Some(a), Some(b)) => a.0 < b.0,
                                    (Some(_), None) => true,
                                    (None, Some(_)) => false,
                                    (None, None) => break,
                                };
                            let d = if take_online { &mut online }
                                    else { &mut offline };
                            want.push(d.pop_front().unwrap().1);
                        }
                        if got != want {
                            return Err(format!("fifo {got:?} != {want:?}"));
                        }
                    }
                    CqOp::PopOnlineFirst(max) => {
                        let got = q.pop_online_first(max);
                        let mut want = Vec::new();
                        while want.len() < max {
                            let Some((_, j)) = online.pop_front() else { break };
                            want.push(j);
                        }
                        while want.len() < max {
                            let Some((_, j)) = offline.pop_front() else { break };
                            want.push(j);
                        }
                        if got != want {
                            return Err(format!("online-first {got:?} != {want:?}"));
                        }
                    }
                }
                if q.len() != online.len() + offline.len() {
                    return Err(format!("len {} != shadow {}", q.len(),
                                       online.len() + offline.len()));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// JobArena: slot recycling never aliases a live job.

#[derive(Debug, Clone, Copy)]
enum ArenaOp {
    Alloc,
    /// Free the live slot at this (modular) position.
    Free(usize),
}

fn gen_arena_ops(r: &mut Rng) -> Vec<ArenaOp> {
    (0..8 + r.below(100))
        .map(|_| {
            if r.bool(0.6) { ArenaOp::Alloc } else { ArenaOp::Free(r.below(64)) }
        })
        .collect()
}

fn tagged_job(tag: f64) -> Job {
    Job {
        arrival: tag,
        prompt: 8,
        output: 4,
        class: RequestClass::Online,
        slo_ttft: 1.0,
        slo_tpot: 0.1,
        deadline: f64::INFINITY,
        dispatched_t: tag,
        first_token_t: None,
        decoded: 0,
    }
}

#[test]
fn prop_arena_recycling_never_aliases_a_live_job() {
    forall(
        &PropConfig { cases: 300, ..Default::default() },
        gen_arena_ops,
        |ops| shrink_vec(ops, |_| Vec::new()),
        |ops| {
            let mut arena = JobArena::new();
            // Shadow: live slot -> unique tag, in insertion order.
            let mut live: Vec<(usize, f64)> = Vec::new();
            let mut next_tag = 0.0f64;
            let mut peak = 0usize;
            for op in ops {
                match *op {
                    ArenaOp::Alloc => {
                        next_tag += 1.0;
                        let slot = arena.alloc(tagged_job(next_tag));
                        if live.iter().any(|&(s, _)| s == slot) {
                            return Err(format!(
                                "alloc returned live slot {slot}"));
                        }
                        live.push((slot, next_tag));
                        peak = peak.max(live.len());
                    }
                    ArenaOp::Free(i) => {
                        if live.is_empty() {
                            continue;
                        }
                        let (slot, _) = live.remove(i % live.len());
                        arena.free(slot);
                    }
                }
                // Every live job still carries its own tag — no aliasing.
                for &(slot, tag) in &live {
                    if !arena.is_live(slot) {
                        return Err(format!("live slot {slot} reported dead"));
                    }
                    if arena[slot].arrival != tag {
                        return Err(format!(
                            "slot {slot} holds tag {} instead of {tag}",
                            arena[slot].arrival));
                    }
                }
                if arena.live() != live.len() {
                    return Err(format!("live {} != shadow {}", arena.live(),
                                       live.len()));
                }
                if arena.peak_live() != peak {
                    return Err(format!("peak {} != shadow {peak}",
                                       arena.peak_live()));
                }
            }
            // Capacity is bounded by the peak concurrency, not the number
            // of allocations — the recycling guarantee itself.
            if arena.capacity() > peak {
                return Err(format!("capacity {} > peak {peak}",
                                   arena.capacity()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// histogram_window: the hybrid keep-alive policy's window choice is the
// smallest bin boundary covering the requested observation mass, capped at
// the policy maximum, and monotone in the percentile.

use ecoserve::sim::histogram_window;

fn gen_window_case(r: &mut Rng) -> (Vec<u64>, f64, f64, f64) {
    let hist: Vec<u64> = (0..r.below(8)).map(|_| r.below(5) as u64).collect();
    let bins = [1.0, 10.0, 60.0];
    let pcts = [0.0, 0.1, 0.5, 0.9, 0.95, 1.0];
    let caps = [0.0, 30.0, 600.0];
    (hist, bins[r.below(3)], pcts[r.below(6)], caps[r.below(3)])
}

#[test]
fn prop_histogram_window_is_a_minimal_covering_bin_boundary() {
    forall(
        &PropConfig { cases: 400, ..Default::default() },
        gen_window_case,
        |_| Vec::new(),
        |(hist, bin_s, pct, max_w)| {
            let total: u64 = hist.iter().sum();
            let w = histogram_window(hist, total, *bin_s, *pct, *max_w);
            if total == 0 {
                // No observations: conservatively hold the full cap.
                return if w == *max_w { Ok(()) } else {
                    Err(format!("empty histogram gave {w}, not cap {max_w}"))
                };
            }
            if !(0.0..=*max_w).contains(&w) {
                return Err(format!("window {w} outside [0, {max_w}]"));
            }
            // Shadow: smallest boundary whose cumulative count covers the
            // requested mass, then capped — exactly the policy contract.
            let target = pct * total as f64;
            let mut cum = 0u64;
            let mut want = *max_w;
            for (i, &c) in hist.iter().enumerate() {
                cum += c;
                if cum as f64 >= target {
                    want = ((i as f64 + 1.0) * bin_s).min(*max_w);
                    break;
                }
            }
            if w.to_bits() != want.to_bits() {
                return Err(format!(
                    "window {w} != minimal covering boundary {want} \
                     (hist {hist:?}, bin {bin_s}, pct {pct}, cap {max_w})"));
            }
            // Monotone in the percentile: asking for less mass never asks
            // for a longer window.
            let lo = histogram_window(hist, total, *bin_s, pct * 0.5, *max_w);
            if lo > w {
                return Err(format!(
                    "not monotone: p{} -> {lo} exceeds p{pct} -> {w}",
                    pct * 0.5));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Histogram::merge: the shard-merge primitive must be commutative and
// associative on everything percentiles are computed from (bin counts,
// sample count, min/max) — bitwise — and on the running sum to float
// rounding. This is what makes a sharded run's latency report a pure
// function of the partition set.

use ecoserve::util::stats::Histogram;

fn gen_latency_parts(r: &mut Rng) -> Vec<Vec<f64>> {
    (0..3)
        .map(|_| {
            (0..r.below(120))
                .map(|_| 1e-4 * (1.0 + r.below(1_000_000) as f64).powf(0.55))
                .collect()
        })
        .collect()
}

fn hist_of(xs: &[f64]) -> Histogram {
    let mut h = Histogram::new();
    for &x in xs {
        h.push(x);
    }
    h
}

fn same_shape(a: &Histogram, b: &Histogram) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("len {} vs {}", a.len(), b.len()));
    }
    if a.is_empty() {
        return Ok(());
    }
    for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
        let (pa, pb) = (a.percentile(q), b.percentile(q));
        if pa.to_bits() != pb.to_bits() {
            return Err(format!("p{q}: {pa} vs {pb}"));
        }
    }
    if a.min().to_bits() != b.min().to_bits()
        || a.max().to_bits() != b.max().to_bits()
    {
        return Err("min/max diverged".into());
    }
    let (ma, mb) = (a.mean(), b.mean());
    if (ma - mb).abs() > 1e-12 * ma.abs().max(1.0) {
        return Err(format!("mean {ma} vs {mb}"));
    }
    Ok(())
}

#[test]
fn prop_histogram_merge_is_commutative_and_associative() {
    forall(
        &PropConfig { cases: 200, ..Default::default() },
        gen_latency_parts,
        // No shrinking: the check indexes exactly three parts.
        |_| Vec::new(),
        |parts| {
            let (a, b, c) = (hist_of(&parts[0]), hist_of(&parts[1]),
                             hist_of(&parts[2]));
            // Commutativity: a+b == b+a.
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            same_shape(&ab, &ba).map_err(|e| format!("commutativity: {e}"))?;
            // Associativity: (a+b)+c == a+(b+c).
            let mut left = ab.clone();
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            same_shape(&left, &right)
                .map_err(|e| format!("associativity: {e}"))?;
            // Merge == pushing every sample into one histogram.
            let whole: Vec<f64> = parts.iter().flatten().copied().collect();
            same_shape(&left, &hist_of(&whole))
                .map_err(|e| format!("merge vs sequential: {e}"))
        },
    );
}
