//! Fig 6: embodied vs operational carbon per second across grid regions
//! (A100 node running Llama-13B, 4-year lifetime).
use ecoserve::carbon::embodied::platform_embodied;
use ecoserve::carbon::intensity::Region;
use ecoserve::carbon::operational::{device_power, op_kg, GPU_POWER_GAMMA};
use ecoserve::hw::platform::standard_platform;
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 6: op vs embodied carbon rate by region (A100, Llama-13B) ==");
    let p = standard_platform("A100-40", 1);
    let (host, gpu) = platform_embodied(&p);
    let lt_s = 4.0 * 365.25 * 86_400.0;
    let host_rate = host.total() / lt_s * 1e6; // mg/s
    let gpu_rate = gpu.total() / lt_s * 1e6;
    let gpu_p = device_power(p.gpu.idle_w, p.gpu.tdp_w, 0.8, GPU_POWER_GAMMA);
    let host_p = p.host.idle_w() + 60.0;
    let mut t = Table::new(&["region", "CI g/kWh", "op mg/s", "emb-host mg/s",
                             "emb-gpu mg/s", "emb share %"]);
    for r in Region::all() {
        let op = op_kg(gpu_p + host_p, 1.0, r.avg_ci()) * 1e6;
        let emb = host_rate + gpu_rate;
        t.row(&[r.name().into(), fnum(r.avg_ci()), fnum(op), fnum(host_rate),
                fnum(gpu_rate), fnum(100.0 * emb / (op + emb))]);
    }
    t.print();
    println!("(clean grids: embodied dominates; host dominates embodied)");
}
