//! Carbon models: embodied (Table 1 / ACT-style), operational (power × CI),
//! grid carbon-intensity traces, component aging, and lifecycle/upgrade
//! schedules. See DESIGN.md §3 and paper §3-4.

pub mod ci_stream;
pub mod embodied;
pub mod intensity;
pub mod lifecycle;
pub mod operational;
pub mod reliability;

pub use ci_stream::CiStream;
pub use embodied::{gpu_embodied, host_embodied, platform_embodied, Breakdown};
pub use intensity::{CiTrace, Region};
pub use operational::{busy_energy_j, device_power, dynamic_power, idle_power,
                      op_kg, op_kg_per_hr, server_power, task_carbon, Phase,
                      TaskCarbon, PLANNING_UTIL};
