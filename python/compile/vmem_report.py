"""L1 perf analysis: VMEM footprint + MXU-utilization estimates for the
Pallas kernels at serving shapes (DESIGN.md §7).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so kernel
performance is assessed structurally: does the BlockSpec schedule keep the
per-program working set inside VMEM with double-buffering headroom, and how
full are the MXU tiles?

Run: cd python && python -m compile.vmem_report
"""

from compile.kernels import decode_attention as da
from compile.kernels import gemm

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPUs


def report():
    print("== decode_attention (split-KV) per-program VMEM ==")
    print(f"{'dh':>4} {'chunk':>6} {'bytes':>10} {'2x-buffered %VMEM':>18}")
    for dh in (32, 64, 128):
        for chunk in (32, 64, 128, 256):
            b = da.vmem_bytes_per_program(dh, chunk)
            frac = 2 * b / VMEM_BYTES * 100
            print(f"{dh:>4} {chunk:>6} {b:>10} {frac:>17.2f}%")

    print("\n== gemm tiles ==")
    print(f"{'tile':>12} {'bytes':>10} {'2x %VMEM':>10} {'MXU util':>9}")
    for t in (32, 64, 128, 256):
        b = gemm.vmem_bytes_per_program(t, t, t)
        u = gemm.mxu_utilization_estimate(t, t, t)
        print(f"{t:>4}x{t:<4}x{t:<3} {b:>10} {2 * b / VMEM_BYTES * 100:>9.2f}% "
              f"{u * 100:>8.1f}%")

    print("\nServing shapes (tiny e2e model, d_head=32, chunk=64):")
    b = da.vmem_bytes_per_program(32, 64)
    print(f"  decode-attn program: {b} B "
          f"({2 * b / VMEM_BYTES * 100:.3f}% VMEM double-buffered) — "
          f"far under budget; grid parallelism (B x H x chunks) is the "
          f"occupancy lever, mirroring the paper's CPU core-scaling.")
    print("  gemm default 128^3 tile: 100% MXU-shaped, "
          f"{2 * gemm.vmem_bytes_per_program(128, 128, 128) / VMEM_BYTES * 100:.1f}%"
          " VMEM double-buffered.")


if __name__ == "__main__":
    report()
