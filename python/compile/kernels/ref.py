"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness references: deliberately written in the most
direct (unfused, materialize-everything) style so a bug in the blocked /
split-KV kernels cannot be mirrored here. pytest + hypothesis sweep shapes
and dtypes against these in python/tests/.
"""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos):
    """Reference decode attention with GQA and position masking.

    q: [B, H, Dh]; k, v: [B, S, KVH, Dh]; pos: [B] int32.
    Returns [B, H, Dh].
    """
    b, h, dh = q.shape
    _, s, kvh, _ = k.shape
    group = h // kvh
    # Expand KV heads to query heads: head i uses kv head i // group.
    k_e = jnp.repeat(k, group, axis=2)      # [B, S, H, Dh]
    v_e = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, k_e) / (dh ** 0.5)
    idx = jnp.arange(s)[None, None, :]                      # [1, 1, S]
    mask = idx <= pos[:, None, None]                        # [B, 1, S]
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", w, v_e)


def gemm_ref(a, b):
    """Reference matmul."""
    return jnp.dot(a, b)


def prefill_attention_ref(q, k, v, lengths):
    """Reference causal prefill attention with per-sequence length masking.

    q: [B, S, H, Dh]; k, v: [B, S, KVH, Dh]; lengths: [B] int32.
    Returns [B, S, H, Dh].
    """
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    group = h // kvh
    k_e = jnp.repeat(k, group, axis=2)
    v_e = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k_e) / (dh ** 0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    causal = j <= i                                          # [S, S]
    live = jnp.arange(s)[None, :] < lengths[:, None]         # [B, S]
    mask = causal[None, None, :, :] & live[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhij,bjhd->bihd", w, v_e)
