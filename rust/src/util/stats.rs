//! Summary-statistics substrate: means, percentiles, streaming accumulators.
//!
//! Used by the simulator's SLO accounting (TTFT/TPOT p50/p90/p99), the bench
//! harness, and experiment reports.

/// Streaming accumulator (Welford) for mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 { self.n }
    pub fn mean(&self) -> f64 { if self.n == 0 { f64::NAN } else { self.mean } }
    pub fn min(&self) -> f64 { self.min }
    pub fn max(&self) -> f64 { self.max }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 { self.variance().sqrt() }

    pub fn merge(&mut self, other: &Accum) {
        if other.n == 0 { return; }
        if self.n == 0 { *self = other.clone(); return; }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// A recorded sample set with percentile queries (sorts lazily).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self { Samples { xs: Vec::new(), sorted: true } }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.xs.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize { self.xs.len() }
    pub fn is_empty(&self) -> bool { self.xs.is_empty() }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 { self.xs.iter().sum() }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi { return self.xs[lo]; }
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 { self.percentile(50.0) }
    pub fn p90(&mut self) -> f64 { self.percentile(90.0) }
    pub fn p99(&mut self) -> f64 { self.percentile(99.0) }
    pub fn max(&mut self) -> f64 { self.percentile(100.0) }
    pub fn min(&mut self) -> f64 { self.percentile(0.0) }

    /// Median absolute deviation — robust spread for outlier rejection.
    pub fn mad(&mut self) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        let med = self.p50();
        let mut devs = Samples::new();
        let xs = self.xs.clone();
        for x in xs { devs.push((x - med).abs()); }
        devs.p50()
    }
}

/// Exponential moving average for runtime load tracking.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> { self.value }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_basic() {
        let mut a = Accum::new();
        for x in [1.0, 2.0, 3.0, 4.0] { a.push(x); }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn accum_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accum::new();
        for &x in &xs { whole.push(x); }
        let mut left = Accum::new();
        let mut right = Accum::new();
        for &x in &xs[..37] { left.push(x); }
        for &x in &xs[37..] { right.push(x); }
        left.merge(&right);
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
        assert!((left.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 { s.push(i as f64); }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn empty_samples_nan() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn mad_robust() {
        let mut s = Samples::new();
        s.extend(&[1.0, 1.0, 1.0, 1.0, 1000.0]);
        assert_eq!(s.mad(), 0.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(10.0), 10.0);
        let mut v = 0.0;
        for _ in 0..50 { v = e.push(20.0); }
        assert!((v - 20.0).abs() < 1e-6);
    }
}
