//! Workload slicing: histogram bucketing of a request trace into the
//! planner's (prompt, output) slices (paper §4.2.2, "Workload Slicing and
//! Disaggregation").

use crate::models::LlmSpec;
use crate::workload::slo::Slo;
use crate::workload::{Request, RequestClass};

/// One planner slice: a (length-bucket, SLO-class) aggregate with a rate.
#[derive(Debug, Clone)]
pub struct Slice {
    pub model: &'static LlmSpec,
    /// Requests per second.
    pub rate: f64,
    /// Representative prompt length (bucket geometric mean).
    pub prompt: usize,
    /// Representative output length.
    pub output: usize,
    pub slo: Slo,
    pub offline: bool,
}

/// Histogram bucket edges (tokens) for prompt and output dimensions.
pub const PROMPT_EDGES: &[usize] = &[0, 128, 512, 2048, 8192, 40_000];
pub const OUTPUT_EDGES: &[usize] = &[0, 64, 256, 1024, 8_192];

fn bucket_of(x: usize, edges: &[usize]) -> usize {
    for (i, w) in edges.windows(2).enumerate() {
        if x >= w[0] && x < w[1] {
            return i;
        }
    }
    edges.len().saturating_sub(2)
}

fn representative(edges: &[usize], idx: usize) -> usize {
    let lo = edges[idx].max(1);
    let hi = edges[idx + 1];
    ((lo as f64 * hi as f64).sqrt()) as usize
}

/// Streaming bucket accumulator: the counting half of [`slice_trace`],
/// split out so planning passes can ingest requests one at a time from an
/// arrival stream (or a sliding demand window) without materializing a
/// trace. `slice_trace` delegates here, so the two paths are identical by
/// construction — bucket counts are integers, and the rate arithmetic in
/// [`SliceAccum::slices`] is shared.
#[derive(Debug, Clone)]
pub struct SliceAccum {
    /// counts[class][p][o]
    counts: Vec<Vec<Vec<usize>>>,
    total: usize,
}

impl Default for SliceAccum {
    fn default() -> Self {
        SliceAccum::new()
    }
}

impl SliceAccum {
    pub fn new() -> SliceAccum {
        let np = PROMPT_EDGES.len() - 1;
        let no = OUTPUT_EDGES.len() - 1;
        SliceAccum { counts: vec![vec![vec![0usize; no]; np]; 2], total: 0 }
    }

    pub fn push(&mut self, r: &Request) {
        let ci = match r.class { RequestClass::Online => 0, RequestClass::Offline => 1 };
        let p = bucket_of(r.prompt_tokens, PROMPT_EDGES);
        let o = bucket_of(r.output_tokens, OUTPUT_EDGES);
        self.counts[ci][p][o] += 1;
        self.total += 1;
    }

    /// Requests ingested so far.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Fold the accumulated buckets into planner slices over `duration_s`
    /// seconds of demand.
    pub fn slices(&self, model: &'static LlmSpec, duration_s: f64,
                  online_slo: Slo, slice_factor: usize) -> Vec<Slice> {
        assert!(duration_s > 0.0 && slice_factor >= 1);
        let mut out = Vec::new();
        for (ci, class_counts) in self.counts.iter().enumerate() {
            let offline = ci == 1;
            for (p, row) in class_counts.iter().enumerate() {
                for (o, &n) in row.iter().enumerate() {
                    if n == 0 {
                        continue;
                    }
                    let total_rate = n as f64 / duration_s;
                    let slo = if offline {
                        Slo { ttft_s: crate::workload::slo::OFFLINE_DEADLINE_S,
                              tpot_s: f64::INFINITY }
                    } else {
                        online_slo
                    };
                    for _ in 0..slice_factor {
                        out.push(Slice {
                            model,
                            rate: total_rate / slice_factor as f64,
                            prompt: representative(PROMPT_EDGES, p),
                            output: representative(OUTPUT_EDGES, o),
                            slo,
                            offline,
                        });
                    }
                }
            }
        }
        out
    }
}

/// Bucket a trace into slices. `slice_factor` ≥ 1 subdivides each bucket's
/// rate into f equal slices for finer-grained allocation (the paper's f).
pub fn slice_trace(
    model: &'static LlmSpec,
    trace: &[Request],
    duration_s: f64,
    online_slo: Slo,
    slice_factor: usize,
) -> Vec<Slice> {
    let mut acc = SliceAccum::new();
    for r in trace {
        acc.push(r);
    }
    acc.slices(model, duration_s, online_slo, slice_factor)
}

/// Merge slices that are identical (bucket, class) — the clustering that
/// gives the control plane its sub-linear scaling (paper §6.2.2).
pub fn cluster_slices(slices: &[Slice]) -> Vec<Slice> {
    let mut out: Vec<Slice> = Vec::new();
    for s in slices {
        if let Some(e) = out.iter_mut().find(|e| {
            e.prompt == s.prompt && e.output == s.output && e.offline == s.offline
                && e.model.name == s.model.name
        }) {
            e.rate += s.rate;
        } else {
            out.push(s.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::{generate_trace, Arrivals, LengthDist};

    fn trace() -> Vec<Request> {
        generate_trace(Arrivals::Poisson { rate: 10.0 }, LengthDist::ShareGpt,
                       RequestClass::Online, 600.0, 11)
    }

    #[test]
    fn rates_conserved() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let slices = slice_trace(m, &tr, 600.0, slo, 1);
        let total: f64 = slices.iter().map(|s| s.rate).sum();
        assert!((total - tr.len() as f64 / 600.0).abs() < 1e-9);
    }

    #[test]
    fn slice_factor_subdivides() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let s1 = slice_trace(m, &tr, 600.0, slo, 1);
        let s4 = slice_trace(m, &tr, 600.0, slo, 4);
        assert_eq!(s4.len(), 4 * s1.len());
        let t1: f64 = s1.iter().map(|s| s.rate).sum();
        let t4: f64 = s4.iter().map(|s| s.rate).sum();
        assert!((t1 - t4).abs() < 1e-9);
    }

    #[test]
    fn clustering_inverts_slicing() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let s4 = slice_trace(m, &tr, 600.0, slo, 4);
        let clustered = cluster_slices(&s4);
        let s1 = slice_trace(m, &tr, 600.0, slo, 1);
        assert_eq!(clustered.len(), s1.len());
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0, PROMPT_EDGES), 0);
        assert_eq!(bucket_of(127, PROMPT_EDGES), 0);
        assert_eq!(bucket_of(128, PROMPT_EDGES), 1);
        assert_eq!(bucket_of(1_000_000, PROMPT_EDGES), PROMPT_EDGES.len() - 2);
        let rep = representative(PROMPT_EDGES, 1);
        assert!(rep >= 128 && rep < 512);
    }
}
