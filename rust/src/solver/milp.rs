//! Branch-and-bound MILP on top of the simplex core (solver/lp.rs).
//!
//! Depth-first search branching on the most-fractional integer variable,
//! pruning on the incumbent. Branch constraints are appended as rows
//! (x_j <= floor / x_j >= ceil), so each node is an ordinary LP solve.
//! Node and wall-clock limits make the planner's periodic re-solve
//! (paper §6.2.2, Table 3) predictable.

use super::lp::{self, Cmp, LpStatus, Row};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MilpStatus {
    Optimal,
    /// Feasible incumbent found but search truncated by limits.
    Feasible,
    Infeasible,
    /// No incumbent before hitting limits.
    Unknown,
}

#[derive(Debug, Clone)]
pub struct MilpSolution {
    pub status: MilpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    pub nodes: usize,
}

#[derive(Debug, Clone)]
pub struct MilpConfig {
    pub max_nodes: usize,
    pub time_limit: Duration,
    pub int_tol: f64,
    /// Relative optimality gap at which search stops.
    pub gap: f64,
    /// Known upper bound (e.g. a heuristic incumbent's objective): nodes
    /// whose relaxation can't beat it are pruned immediately.
    pub cutoff: Option<f64>,
}

impl Default for MilpConfig {
    fn default() -> Self {
        MilpConfig {
            max_nodes: 20_000,
            time_limit: Duration::from_secs(20),
            int_tol: 1e-6,
            gap: 1e-6,
            cutoff: None,
        }
    }
}

/// Minimize c·x with rows, x >= 0, and `integer[j]` flagging integrality.
pub fn solve(
    ncols: usize,
    c: &[f64],
    rows: &[Row],
    integer: &[bool],
    cfg: &MilpConfig,
) -> MilpSolution {
    assert_eq!(integer.len(), ncols);
    let start = Instant::now();
    let mut nodes = 0usize;
    let mut incumbent: Option<(f64, Vec<f64>)> = None;
    // DFS stack of extra branch rows.
    let mut stack: Vec<Vec<Row>> = vec![Vec::new()];

    while let Some(extra) = stack.pop() {
        if nodes >= cfg.max_nodes || start.elapsed() > cfg.time_limit {
            break;
        }
        nodes += 1;
        let mut all = rows.to_vec();
        all.extend(extra.iter().cloned());
        let rel = lp::solve(ncols, c, &all);
        match rel.status {
            LpStatus::Infeasible | LpStatus::IterLimit => continue,
            LpStatus::Unbounded => {
                // Unbounded relaxation at the root means the MILP is
                // unbounded or model error; deeper nodes: prune.
                if extra.is_empty() && incumbent.is_none() {
                    return MilpSolution {
                        status: MilpStatus::Unknown,
                        x: vec![0.0; ncols],
                        objective: f64::NEG_INFINITY,
                        nodes,
                    };
                }
                continue;
            }
            LpStatus::Optimal => {}
        }
        // Bound: prune if not better than the incumbent / external cutoff.
        let bound = incumbent.as_ref().map(|(b, _)| *b)
            .or(cfg.cutoff)
            .map(|b| incumbent.as_ref().map_or(b, |(i, _)| b.min(*i)));
        if let Some(best) = bound {
            if rel.objective >= best - cfg.gap * best.abs().max(1.0) {
                continue;
            }
        }
        // Find most-fractional integer variable.
        let mut branch_var = None;
        let mut best_frac = cfg.int_tol;
        for j in 0..ncols {
            if integer[j] {
                let f = (rel.x[j] - rel.x[j].round()).abs();
                if f > best_frac {
                    best_frac = f;
                    branch_var = Some(j);
                }
            }
        }
        match branch_var {
            None => {
                // Integral — candidate incumbent.
                let mut x = rel.x.clone();
                for j in 0..ncols {
                    if integer[j] {
                        x[j] = x[j].round();
                    }
                }
                if incumbent.as_ref().map(|(b, _)| rel.objective < *b).unwrap_or(true) {
                    incumbent = Some((rel.objective, x));
                }
            }
            Some(j) => {
                let v = rel.x[j];
                let lo = v.floor();
                // Push "up" branch first so DFS explores "down" (<= floor)
                // first — tends to find feasible packings earlier.
                let mut up = extra.clone();
                up.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Ge, rhs: lo + 1.0 });
                stack.push(up);
                let mut down = extra;
                down.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Le, rhs: lo });
                stack.push(down);
            }
        }
    }

    match incumbent {
        Some((obj, x)) => {
            let truncated = !stack.is_empty();
            MilpSolution {
                status: if truncated { MilpStatus::Feasible } else { MilpStatus::Optimal },
                x,
                objective: obj,
                nodes,
            }
        }
        None => MilpSolution {
            status: if stack.is_empty() { MilpStatus::Infeasible } else { MilpStatus::Unknown },
            x: vec![0.0; ncols],
            objective: f64::NAN,
            nodes,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(coeffs: &[(usize, f64)], cmp: Cmp, rhs: f64) -> Row {
        Row { coeffs: coeffs.to_vec(), cmp, rhs }
    }

    #[test]
    fn knapsack() {
        // max 8a + 11b + 6c + 4d, w = [5,7,4,3] <= 14, binary.
        // Optimal: b + c + d = 21, w = 14.
        let c = [-8.0, -11.0, -6.0, -4.0];
        let mut rows = vec![row(
            &[(0, 5.0), (1, 7.0), (2, 4.0), (3, 3.0)], Cmp::Le, 14.0)];
        for j in 0..4 {
            rows.push(row(&[(j, 1.0)], Cmp::Le, 1.0));
        }
        let s = solve(4, &c, &rows, &[true; 4], &MilpConfig::default());
        assert_eq!(s.status, MilpStatus::Optimal);
        assert!((s.objective + 21.0).abs() < 1e-6, "{s:?}");
        assert_eq!(s.x, vec![0.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn integer_rounding_matters() {
        // min y s.t. y >= 1.2 x, x >= 2.5, x integer → x = 3, y = 3.6.
        let s = solve(
            2,
            &[0.0, 1.0],
            &[
                row(&[(1, 1.0), (0, -1.2)], Cmp::Ge, 0.0),
                row(&[(0, 1.0)], Cmp::Ge, 2.5),
            ],
            &[true, false],
            &MilpConfig::default(),
        );
        assert_eq!(s.status, MilpStatus::Optimal);
        assert!((s.x[0] - 3.0).abs() < 1e-6);
        assert!((s.objective - 3.6).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn infeasible_integer() {
        // 0.4 <= x <= 0.6, x integer → infeasible.
        let s = solve(
            1,
            &[1.0],
            &[
                row(&[(0, 1.0)], Cmp::Ge, 0.4),
                row(&[(0, 1.0)], Cmp::Le, 0.6),
            ],
            &[true],
            &MilpConfig::default(),
        );
        assert_eq!(s.status, MilpStatus::Infeasible);
    }

    #[test]
    fn assignment_problem() {
        // 2 tasks × 2 machines, costs [[1, 10], [10, 1]]; each task on one
        // machine → diagonal assignment, cost 2.
        let costs = [1.0, 10.0, 10.0, 1.0]; // x[t*2+m]
        let mut rows = Vec::new();
        for t in 0..2 {
            rows.push(row(&[(t * 2, 1.0), (t * 2 + 1, 1.0)], Cmp::Eq, 1.0));
        }
        for j in 0..4 {
            rows.push(row(&[(j, 1.0)], Cmp::Le, 1.0));
        }
        let s = solve(4, &costs, &rows, &[true; 4], &MilpConfig::default());
        assert_eq!(s.status, MilpStatus::Optimal);
        assert!((s.objective - 2.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn respects_node_limit() {
        let cfg = MilpConfig { max_nodes: 1, ..Default::default() };
        let s = solve(
            2,
            &[0.0, 1.0],
            &[
                row(&[(1, 1.0), (0, -1.2)], Cmp::Ge, 0.0),
                row(&[(0, 1.0)], Cmp::Ge, 2.5),
            ],
            &[true, false],
            &cfg,
        );
        assert!(s.nodes <= 1);
        assert!(matches!(s.status, MilpStatus::Unknown | MilpStatus::Feasible));
    }
}
