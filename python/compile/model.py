"""L2: the served transformer (JAX, build-time only).

A small GQA + RoPE + SwiGLU decoder-only transformer whose decode path calls
the L1 split-KV Pallas attention kernel (kernels/decode_attention.py). The
model is AOT-lowered by aot.py into per-bucket HLO-text artifacts; the Rust
runtime executes those artifacts — Python never runs on the request path.

Weights live in a params pytree whose *flatten order* is the contract with
the Rust side: aot.py records (name, shape) per leaf in model_config.json and
writes weights.bin in the same order; the lowered HLO takes one parameter per
leaf followed by the runtime inputs, in signature order.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import decode_attention_ref, prefill_attention_ref


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Architecture of the served model (defaults: the tiny e2e model)."""
    vocab: int = 259          # 256 bytes + PAD/BOS/EOS
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    ffn_hidden: int = 512
    max_seq: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim
        assert self.n_heads % self.n_kv_heads == 0


PAD, BOS, EOS = 0, 1, 2  # byte b encodes as token b + 3


def init_params(cfg: ModelCfg, seed: int = 42):
    """Seeded init — the 'small real model' stand-in (DESIGN.md §1)."""
    key = jax.random.PRNGKey(seed)
    d, f, v = cfg.d_model, cfg.ffn_hidden, cfg.vocab
    kvd = cfg.n_kv_heads * cfg.head_dim

    def mat(key, shape, scale=0.02):
        return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)

    keys = iter(jax.random.split(key, 3 + 7 * cfg.n_layers))
    params = {
        "embed": mat(next(keys), (v, d)),
        "lm_head": mat(next(keys), (d, v)),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "w_q": mat(next(keys), (d, d)),
            "w_k": mat(next(keys), (d, kvd)),
            "w_v": mat(next(keys), (d, kvd)),
            "w_o": mat(next(keys), (d, d)),
            "w_gate": mat(next(keys), (d, f)),
            "w_up": mat(next(keys), (d, f)),
            "w_down": mat(next(keys), (f, d)),
        })
    params["layers"] = layers
    return params


def rms_norm(x, w, eps=1e-5):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, positions, theta=10000.0):
    """Rotary embedding, half-split convention.

    x: [..., n_heads, head_dim]; positions: broadcastable to x[..., 0, 0].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def silu(x):
    return x * jax.nn.sigmoid(x)


def _mlp(layer, x):
    return (silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def _update_cache(cache_l, new, pos):
    """Write new [B, KVH, Dh] at per-sequence slot pos [B] of [B, S, KVH, Dh]."""
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n[None], (p, 0, 0))
    )(cache_l, new, pos)


def decode_step(cfg: ModelCfg, params, k_cache, v_cache, token, pos,
                use_pallas: bool = True):
    """One batched decode step.

    k_cache/v_cache: [L, B, S, KVH, Dh]; token, pos: [B] int32. The new
    token's K/V is written at slot ``pos`` *before* attention, so attention
    masks positions > pos (inclusive of the current token).

    Returns (logits [B, V], k_cache, v_cache).
    """
    b = token.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if use_pallas:
        # Perf pass (EXPERIMENTS.md §Perf): fatter KV chunks cut grid-
        # program count 4x; 256 keeps (B x H x 2) parallelism and a 65 KB
        # per-program VMEM footprint.
        chunk = max(c for c in (64, 128, 256) if cfg.max_seq % c == 0
                    and c <= cfg.max_seq)
        attn = functools.partial(decode_attention, chunk=chunk)
    else:
        attn = decode_attention_ref

    x = params["embed"][token]                                    # [B, D]
    for li, layer in enumerate(params["layers"]):
        hid = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        q = (hid @ layer["w_q"]).reshape(b, h, dh)
        k_new = (hid @ layer["w_k"]).reshape(b, kvh, dh)
        v_new = (hid @ layer["w_v"]).reshape(b, kvh, dh)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)

        k_l = _update_cache(k_cache[li], k_new, pos)
        v_l = _update_cache(v_cache[li], v_new, pos)
        k_cache = k_cache.at[li].set(k_l)
        v_cache = v_cache.at[li].set(v_l)

        a = attn(q, k_l, v_l, pos)                                # [B, H, Dh]
        x = x + a.reshape(b, cfg.d_model) @ layer["w_o"]

        hid2 = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _mlp(layer, hid2)

    logits = rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    return logits, k_cache, v_cache


def prefill(cfg: ModelCfg, params, tokens, lengths):
    """Batched prefill over bucket-padded prompts.

    tokens: [B, S] int32 (PAD beyond lengths); lengths: [B] int32.

    Returns (last_logits [B, V], k_cache, v_cache) with caches shaped
    [L, B, max_seq, KVH, Dh], zeroed beyond S and beyond each length.
    """
    b, s = tokens.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.arange(s, dtype=jnp.int32)
    live = (positions[None, :] < lengths[:, None])               # [B, S]

    x = params["embed"][tokens]                                   # [B, S, D]
    ks, vs = [], []
    for layer in params["layers"]:
        hid = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        q = (hid @ layer["w_q"]).reshape(b, s, h, dh)
        k = (hid @ layer["w_k"]).reshape(b, s, kvh, dh)
        v = (hid @ layer["w_v"]).reshape(b, s, kvh, dh)
        q = rope(q, positions[None, :], cfg.rope_theta)
        k = rope(k, positions[None, :], cfg.rope_theta)
        # Zero padded slots so the decode-phase mask can be purely positional.
        k = k * live[..., None, None]
        v = v * live[..., None, None]

        a = prefill_attention_ref(q, k, v, lengths)               # [B,S,H,Dh]
        x = x + a.reshape(b, s, cfg.d_model) @ layer["w_o"]
        hid2 = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _mlp(layer, hid2)
        ks.append(k)
        vs.append(v)

    k_cache = jnp.stack(ks)                                       # [L,B,S,KVH,Dh]
    v_cache = jnp.stack(vs)
    pad = cfg.max_seq - s
    if pad > 0:
        widths = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)

    last = jnp.clip(lengths - 1, 0, s - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None].astype(jnp.int32), 1)[:, 0]
    logits = rms_norm(x_last, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    return logits, k_cache, v_cache


def empty_cache(cfg: ModelCfg, batch: int):
    shape = (cfg.n_layers, batch, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def full_forward_ref(cfg: ModelCfg, params, tokens, lengths):
    """Oracle: all-positions logits via prefill-style full attention.

    Used by tests to check prefill+decode chains: the logits the decode path
    produces at step t must match column t of this full forward.
    """
    b, s = tokens.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.arange(s, dtype=jnp.int32)
    live = (positions[None, :] < lengths[:, None])
    x = params["embed"][tokens]
    for layer in params["layers"]:
        hid = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        q = rope((hid @ layer["w_q"]).reshape(b, s, h, dh), positions[None, :],
                 cfg.rope_theta)
        k = rope((hid @ layer["w_k"]).reshape(b, s, kvh, dh), positions[None, :],
                 cfg.rope_theta)
        v = (hid @ layer["w_v"]).reshape(b, s, kvh, dh)
        k = k * live[..., None, None]
        v = v * live[..., None, None]
        a = prefill_attention_ref(q, k, v, lengths)
        x = x + a.reshape(b, s, cfg.d_model) @ layer["w_o"]
        x = x + _mlp(layer, rms_norm(x, layer["mlp_norm"], cfg.norm_eps))
    return rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
