//! LLM descriptors: the model suite the paper evaluates (§5), with the
//! per-phase FLOPs / bytes / KV-footprint arithmetic the roofline
//! performance model consumes.

/// Architecture descriptor. `active_params_b` differs from `params_b` for
/// MoE models (Mixtral activates 2 of 8 experts).
#[derive(Debug, Clone)]
pub struct LlmSpec {
    pub name: &'static str,
    /// Total parameters, billions.
    pub params_b: f64,
    /// Parameters active per token, billions.
    pub active_params_b: f64,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// FP16/BF16 weight bytes.
    pub dtype_bytes: f64,
}

impl LlmSpec {
    pub fn weight_gb(&self) -> f64 {
        self.params_b * self.dtype_bytes
    }

    /// KV-cache bytes per token per sequence (both K and V, all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64 * self.n_kv_heads as f64
            * self.head_dim as f64 * self.dtype_bytes
    }

    /// Prefill FLOPs for a batch of `batch` prompts of length `prompt`.
    /// 2·P per token for the dense path plus the quadratic attention term
    /// (×2 matmuls, ×0.5 causal).
    pub fn prefill_flops(&self, batch: usize, prompt: usize) -> f64 {
        let tok = (batch * prompt) as f64;
        let dense = 2.0 * self.active_params_b * 1e9 * tok;
        let attn = 2.0 * self.n_layers as f64 * (batch as f64)
            * (prompt as f64).powi(2) * self.d_model as f64;
        dense + attn
    }

    /// HBM bytes moved during prefill (weights once per batch pass; the
    /// activations are small relative to weights at serving batch sizes).
    pub fn prefill_bytes(&self, batch: usize, prompt: usize) -> f64 {
        let weights = self.params_b * 1e9 * self.dtype_bytes;
        let kv_write = batch as f64 * prompt as f64 * self.kv_bytes_per_token();
        weights + kv_write
    }

    /// FLOPs for one decode step across a batch at context length `ctx`.
    pub fn decode_step_flops(&self, batch: usize, ctx: usize) -> f64 {
        let dense = 2.0 * self.active_params_b * 1e9 * batch as f64;
        // Attention: QK^T and PV, each 2·ctx·(kv_heads·head_dim)·group reads
        // ≈ 4·ctx·d_model per layer per sequence.
        let attn = 4.0 * self.n_layers as f64 * batch as f64 * ctx as f64
            * self.d_model as f64;
        dense + attn
    }

    /// HBM bytes for one decode step: full weight read + KV history read.
    pub fn decode_step_bytes(&self, batch: usize, ctx: usize) -> f64 {
        let weights = self.params_b * 1e9 * self.dtype_bytes;
        let kv = batch as f64 * ctx as f64 * self.kv_bytes_per_token();
        weights + kv
    }

    /// Arithmetic intensity (FLOPs/byte) of a decode step.
    pub fn decode_intensity(&self, batch: usize, ctx: usize) -> f64 {
        self.decode_step_flops(batch, ctx) / self.decode_step_bytes(batch, ctx)
    }

    /// Max batch fitting in `mem_gb` at context `ctx` (capacity model).
    /// The 0.5 reserve covers activations, fragmentation, and runtime
    /// buffers — calibrated to the paper's Fig 8 datapoint (A100-40 holds
    /// batch ≈16 for Llama-8B at ctx 2048 in FP16).
    pub fn max_batch(&self, mem_gb: f64, ctx: usize, tp: usize) -> usize {
        let reserve = 0.5;
        let avail = (mem_gb * tp as f64 * reserve - self.weight_gb()) * 1e9;
        if avail <= 0.0 {
            return 0;
        }
        (avail / (ctx as f64 * self.kv_bytes_per_token())) as usize
    }
}

pub fn catalog() -> &'static [LlmSpec] {
    &[
        LlmSpec { name: "opt-125m", params_b: 0.125, active_params_b: 0.125,
                  n_layers: 12, d_model: 768, n_heads: 12, n_kv_heads: 12,
                  head_dim: 64, dtype_bytes: 2.0 },
        LlmSpec { name: "gemma-2b", params_b: 2.6, active_params_b: 2.6,
                  n_layers: 26, d_model: 2304, n_heads: 8, n_kv_heads: 4,
                  head_dim: 256, dtype_bytes: 2.0 },
        LlmSpec { name: "llama-8b", params_b: 8.0, active_params_b: 8.0,
                  n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8,
                  head_dim: 128, dtype_bytes: 2.0 },
        LlmSpec { name: "llama-13b", params_b: 13.0, active_params_b: 13.0,
                  n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40,
                  head_dim: 128, dtype_bytes: 2.0 },
        LlmSpec { name: "gemma-27b", params_b: 27.2, active_params_b: 27.2,
                  n_layers: 46, d_model: 4608, n_heads: 32, n_kv_heads: 16,
                  head_dim: 128, dtype_bytes: 2.0 },
        LlmSpec { name: "mixtral-8x7b", params_b: 46.7, active_params_b: 12.9,
                  n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8,
                  head_dim: 128, dtype_bytes: 2.0 },
        LlmSpec { name: "llama-70b", params_b: 70.0, active_params_b: 70.0,
                  n_layers: 80, d_model: 8192, n_heads: 64, n_kv_heads: 8,
                  head_dim: 128, dtype_bytes: 2.0 },
        LlmSpec { name: "bloom-176b", params_b: 176.0, active_params_b: 176.0,
                  n_layers: 70, d_model: 14336, n_heads: 112, n_kv_heads: 112,
                  head_dim: 128, dtype_bytes: 2.0 },
    ]
}

pub fn llm(name: &str) -> Option<&'static LlmSpec> {
    catalog().iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(llm("llama-8b").unwrap().n_layers, 32);
        assert!(llm("gpt-5").is_none());
    }

    #[test]
    fn weight_sizes_sane() {
        assert!((llm("llama-8b").unwrap().weight_gb() - 16.0).abs() < 0.1);
        assert!((llm("llama-70b").unwrap().weight_gb() - 140.0).abs() < 0.5);
    }

    #[test]
    fn gqa_shrinks_kv() {
        // llama-8b GQA (8 kv heads of 32) vs llama-13b MHA.
        let l8 = llm("llama-8b").unwrap();
        let l13 = llm("llama-13b").unwrap();
        assert!(l8.kv_bytes_per_token() < l13.kv_bytes_per_token());
        // 2*32*8*128*2 = 131072 B/token.
        assert!((l8.kv_bytes_per_token() - 131072.0).abs() < 1.0);
    }

    #[test]
    fn decode_is_memory_bound_at_small_batch() {
        // AI ≈ batch at small ctx — far below any GPU's knee (~100s).
        let m = llm("llama-8b").unwrap();
        assert!(m.decode_intensity(1, 512) < 2.0);
        assert!(m.decode_intensity(64, 512) > 20.0);
    }

    #[test]
    fn moe_activates_fewer_flops() {
        let mx = llm("mixtral-8x7b").unwrap();
        let dense_like = mx.decode_step_flops(1, 128);
        assert!(dense_like < 2.0 * 46.7e9 * 1.1); // ≈ active 12.9B, not 46.7B
    }

    #[test]
    fn max_batch_capacity() {
        let m = llm("llama-8b").unwrap();
        // A100-40 at ctx 2048: ≈16 seqs (Fig 8's ★ capacity bound).
        let b = m.max_batch(40.0, 2048, 1);
        assert!(b >= 10 && b <= 24, "batch {b}");
        // Model too large for the card → 0.
        assert_eq!(llm("llama-70b").unwrap().max_batch(40.0, 2048, 1), 0);
        // TP=4 makes it fit.
        assert!(llm("llama-70b").unwrap().max_batch(40.0, 2048, 8) > 0);
    }

    #[test]
    fn flops_scale_with_tokens() {
        let m = llm("gemma-27b").unwrap();
        let f1 = m.prefill_flops(1, 512);
        let f2 = m.prefill_flops(2, 512);
        assert!((f2 / f1 - 2.0).abs() < 0.01);
        let d1 = m.decode_step_flops(4, 100);
        let d2 = m.decode_step_flops(8, 100);
        assert!((d2 / d1 - 2.0).abs() < 0.01);
    }
}
