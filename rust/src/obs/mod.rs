//! Passive, deterministic observability: a fleet [`Timeline`]
//! ([`timeline`]), per-job span tracing ([`spans`]), and harness
//! self-profiling ([`profile`]) — all **byte-neutral when disabled**
//! (the engine hooks are `Option`-gated reads that push zero events and
//! never touch simulation state) and **order-fixed-mergeable** across
//! shards, the same discipline as `Histogram::merge`. With observers off,
//! every registry scenario's outcome bytes are unchanged; with observers
//! on, the timeline/span artifacts are byte-identical across shard-thread
//! budgets because the shard partition is a pure function of the fleet
//! and recorders fold in ascending shard index.
//!
//! Surface: `sweep --obs-dir DIR [--obs-interval SECS]
//! [--trace-jobs-rate R] [--progress SECS]` writes
//! `<name>.timeline.csv`, `<name>.spans.json`, `<name>.profile.json` per
//! scenario; `ecoserve inspect <obs-dir>` summarizes a directory of
//! artifacts. The profile artifact carries wall clocks and is excluded
//! from byte-diff gates; timeline and spans are fully deterministic.

pub mod profile;
pub mod spans;
pub mod timeline;

pub use self::profile::{peak_rss_kb, reset_peak_rss, Profile, Progress};
pub use self::spans::{JobSpan, SpanEvent, SpanTrace};
pub use self::timeline::{Timeline, TimelineSample};

/// What to record, resolved from the CLI `--obs-*` flags.
#[derive(Debug, Clone)]
pub struct ObsSettings {
    /// Fleet-timeline sample interval; `None` disables the timeline.
    pub timeline_interval_s: Option<f64>,
    /// Span-sampling rate in [0, 1]; 0 disables span tracing.
    pub trace_jobs_rate: f64,
    /// Record pipeline stage timings + planner counters.
    pub profile: bool,
    /// Wall-clock progress heartbeat period; `None` disables it.
    pub progress_s: Option<f64>,
}

impl Default for ObsSettings {
    fn default() -> ObsSettings {
        ObsSettings {
            timeline_interval_s: Some(60.0),
            trace_jobs_rate: 0.05,
            profile: true,
            progress_s: None,
        }
    }
}

impl ObsSettings {
    /// Heartbeat only — what `--progress` without `--obs-dir` requests.
    pub fn progress_only(every_s: f64) -> ObsSettings {
        ObsSettings {
            timeline_interval_s: None,
            trace_jobs_rate: 0.0,
            profile: false,
            progress_s: Some(every_s),
        }
    }
}

/// Rendered artifacts of one observed scenario run.
#[derive(Debug, Clone, Default)]
pub struct ObsArtifacts {
    pub timeline_csv: Option<String>,
    pub spans_json: Option<String>,
    pub profile_json: Option<String>,
}

/// The recorder bundle the engine carries (`Option<&mut Observer>` beside
/// the `MetricsSink`). A sharded run gives each shard a fresh
/// [`Observer::shard`] clone and folds them back with
/// [`Observer::merge`] in ascending shard index.
#[derive(Debug)]
pub struct Observer {
    pub timeline: Option<Timeline>,
    pub spans: Option<SpanTrace>,
    pub progress: Option<Progress>,
    /// Settings + grid facts kept for spawning shard observers.
    settings: ObsSettings,
    duration_s: f64,
    span_seed: u64,
    ci_names: Vec<String>,
}

impl Observer {
    /// Build the fleet-level observer for one scenario run. `ci_names`
    /// are the timeline's CI column labels (primary first, then one per
    /// configured region signal); `span_seed` derives from the scenario
    /// seed so span sampling is per-scenario deterministic.
    pub fn for_run(settings: &ObsSettings, duration_s: f64, span_seed: u64,
                   ci_names: Vec<String>, n_servers: usize) -> Observer {
        let timeline = settings.timeline_interval_s.map(|iv| {
            Timeline::new(iv, duration_s, ci_names.clone())
        });
        let spans = (settings.trace_jobs_rate > 0.0).then(|| {
            SpanTrace::new(span_seed, settings.trace_jobs_rate,
                           (0..n_servers).collect())
        });
        let progress = settings.progress_s.map(|p| {
            Progress::new(p, "", duration_s)
        });
        Observer {
            timeline,
            spans,
            progress,
            settings: settings.clone(),
            duration_s,
            span_seed,
            ci_names,
        }
    }

    /// A fresh observer for one shard: same grids and seed, recorders
    /// scoped to the shard's servers (`servers[local] = global id`).
    pub fn shard(&self, servers: &[usize], label: &str) -> Observer {
        let timeline = self.timeline.as_ref().and_then(|_| {
            self.settings.timeline_interval_s.map(|iv| {
                Timeline::new(iv, self.duration_s, self.ci_names.clone())
            })
        });
        let spans = self.spans.as_ref().map(|_| {
            SpanTrace::new(self.span_seed, self.settings.trace_jobs_rate,
                           servers.to_vec())
        });
        let progress = self.progress.as_ref().and_then(|_| {
            self.settings.progress_s.map(|p| {
                Progress::new(p, label, self.duration_s)
            })
        });
        Observer {
            timeline,
            spans,
            progress,
            settings: self.settings.clone(),
            duration_s: self.duration_s,
            span_seed: self.span_seed,
            ci_names: self.ci_names.clone(),
        }
    }

    /// Fold a shard observer back into the fleet-level one. Callers fold
    /// in ascending shard index; see the recorder merge rules.
    pub fn merge(&mut self, other: Observer) {
        if let (Some(tl), Some(other_tl)) = (self.timeline.as_mut(),
                                             other.timeline.as_ref()) {
            tl.merge(other_tl);
        }
        if let (Some(sp), Some(other_sp)) = (self.spans.as_mut(),
                                             other.spans) {
            sp.merge(other_sp);
        }
    }
}
