//! Production-trace replay: a chunked, O(1)-memory [`TraceSource`] that
//! feeds recorded request streams through the [`ArrivalSource`] trait.
//!
//! Real serving studies (EcoServe §6, GreenLLM, BurstGPT) ground their
//! claims in production traces; the synthetic generators in this crate
//! reproduce published summary statistics, but the burstiness claim should
//! be validated against reality. This module replays CSV traces in two
//! dialects — Azure LLM inference style (`timestamp, prompt_tokens,
//! output_tokens`) and BurstGPT style (`ts, model, request_tokens,
//! response_tokens`) — streaming line-by-line so a multi-million-request
//! day never materializes.
//!
//! Ingestion contract:
//! - **Error policy** is line-level: [`TraceErrorPolicy::Skip`] counts and
//!   drops malformed lines, [`TraceErrorPolicy::Fail`] rejects the file at
//!   open time with the first offending line. Replay itself never fails:
//!   [`TraceSource::open`] validates the whole file once (a streaming
//!   pass, still O(1) memory), so the simulator's pull loop stays
//!   infallible.
//! - **Monotonic repair**: out-of-order timestamps (clock skew, merged
//!   collector shards) are clamped up to the last seen timestamp and
//!   counted — never reordered, never dropped, under either policy.
//! - **Rescaling**: [`TraceRescale::fit_duration`] maps the trace's
//!   recorded span onto the run's `--duration` (arrivals cover the
//!   half-open `[0, duration)`), and [`TraceRescale::rate`] replicates or
//!   thins records through a deterministic credit accumulator, so a
//!   day-long trace can drive any duration at any load multiple without
//!   touching an RNG.
//!
//! Determinism: replay is a pure function of (file bytes, dialect, policy,
//! rescale, duration), so the streaming/materialized differential and the
//! shard-count invariance contracts hold exactly as they do for the
//! synthetic generators.

use std::fs::File;
use std::io::{BufRead, BufReader, Lines};

use anyhow::{anyhow, bail, ensure, Result};

use super::{ArrivalSource, Request, RequestClass};

/// CSV dialect of a request trace. The resolver is pluggable in the sense
/// that each dialect is a pure line parser behind one enum — adding a
/// format means one arm in [`TraceDialect::parse_line`] plus a sniffing
/// rule in [`sniff_dialect`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceDialect {
    /// Azure LLM inference style: `timestamp,prompt_tokens,output_tokens`
    /// (exactly 3 fields; timestamp in seconds from an arbitrary origin).
    Azure,
    /// BurstGPT style: `ts,model,request_tokens,response_tokens[,...]`
    /// (4+ fields; the model name and any trailing fields are ignored).
    BurstGpt,
}

impl TraceDialect {
    /// Parse a CLI flag value (`--trace-dialect azure|burstgpt`).
    pub fn from_flag(s: &str) -> Option<TraceDialect> {
        match s {
            "azure" => Some(TraceDialect::Azure),
            "burstgpt" => Some(TraceDialect::BurstGpt),
            _ => None,
        }
    }

    pub fn flag(&self) -> &'static str {
        match self {
            TraceDialect::Azure => "azure",
            TraceDialect::BurstGpt => "burstgpt",
        }
    }

    /// Parse one line. `Ok(None)` for blank lines and `#` comments;
    /// `Err(reason)` for malformed data lines (header detection is the
    /// cursor's job, not the parser's).
    fn parse_line(&self, line: &str) -> std::result::Result<Option<RawRecord>, String> {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            return Ok(None);
        }
        let fields: Vec<&str> = t.split(',').map(str::trim).collect();
        let (ts_f, p_f, o_f) = match self {
            TraceDialect::Azure => {
                if fields.len() != 3 {
                    return Err(format!(
                        "expected 3 fields (timestamp,prompt_tokens,\
                         output_tokens), got {}", fields.len()));
                }
                (fields[0], fields[1], fields[2])
            }
            TraceDialect::BurstGpt => {
                if fields.len() < 4 {
                    return Err(format!(
                        "expected >=4 fields (ts,model,request_tokens,\
                         response_tokens), got {}", fields.len()));
                }
                (fields[0], fields[2], fields[3])
            }
        };
        let ts: f64 = ts_f.parse()
            .map_err(|_| format!("bad timestamp '{ts_f}'"))?;
        if !ts.is_finite() || ts < 0.0 {
            return Err(format!("bad timestamp '{ts_f}'"));
        }
        let prompt = parse_tokens(p_f)?;
        let output = parse_tokens(o_f)?;
        Ok(Some(RawRecord { ts, prompt, output }))
    }
}

fn parse_tokens(s: &str) -> std::result::Result<usize, String> {
    let v: f64 = s.parse().map_err(|_| format!("bad token count '{s}'"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("bad token count '{s}'"));
    }
    // Zero-token records (logging artifacts) round up to one token.
    Ok((v as usize).max(1))
}

/// Guess the dialect from the first non-blank, non-comment line of the
/// file (header or data): 4+ comma-separated fields reads as BurstGPT,
/// exactly 3 as Azure.
pub fn sniff_dialect(path: &str) -> Result<TraceDialect> {
    let f = File::open(path).map_err(|e| anyhow!("trace {path}: {e}"))?;
    for line in BufReader::new(f).lines() {
        let line = line.map_err(|e| anyhow!("trace {path}: {e}"))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let n = t.split(',').count();
        return match n {
            3 => Ok(TraceDialect::Azure),
            _ if n >= 4 => Ok(TraceDialect::BurstGpt),
            _ => bail!("trace {path}: cannot sniff dialect from a \
                        {n}-field line; pass --trace-dialect"),
        };
    }
    bail!("trace {path}: empty file, cannot sniff dialect")
}

/// What to do with a malformed data line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceErrorPolicy {
    /// Drop the line and count it (`TraceStats::skipped_lines`).
    Skip,
    /// Reject the whole file at open time with the first offending line.
    Fail,
}

impl TraceErrorPolicy {
    /// Parse a CLI flag value (`--trace-errors skip|fail`).
    pub fn from_flag(s: &str) -> Option<TraceErrorPolicy> {
        match s {
            "skip" => Some(TraceErrorPolicy::Skip),
            "fail" => Some(TraceErrorPolicy::Fail),
            _ => None,
        }
    }
}

/// Time/load rescaling applied at replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRescale {
    /// Map the trace's recorded span onto the run duration (so a day-long
    /// trace drives any `--duration`). When off, timestamps replay
    /// natively relative to the first record and the run clips at
    /// `duration`.
    pub fit_duration: bool,
    /// Load multiplier: each record contributes `rate` arrivals through a
    /// deterministic credit accumulator (2.0 duplicates every record,
    /// 0.5 keeps every other one).
    pub rate: f64,
}

impl Default for TraceRescale {
    fn default() -> Self {
        TraceRescale { fit_duration: true, rate: 1.0 }
    }
}

/// Health counters from one pass over a trace file.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TraceStats {
    /// Parseable data records.
    pub records: u64,
    /// Malformed lines dropped under [`TraceErrorPolicy::Skip`].
    pub skipped_lines: u64,
    /// Out-of-order timestamps clamped up to the running maximum.
    pub repaired_timestamps: u64,
    /// Timestamp of the first record (trace origin).
    pub t0_s: f64,
    /// Recorded span: last (repaired) timestamp minus the first.
    pub span_s: f64,
}

struct RawRecord {
    ts: f64,
    prompt: usize,
    output: usize,
}

enum Step {
    /// A data record with its monotonic-repaired timestamp.
    Record { ts: f64, prompt: usize, output: usize, repaired: bool },
    /// Blank, comment, or leading header line.
    Ignore,
    /// Malformed data line.
    Bad(String),
}

/// Line-classification state machine shared by the validation and replay
/// passes, so both make byte-identical decisions (header detection and
/// monotonic repair are stateful).
struct LineCursor {
    dialect: TraceDialect,
    awaiting_first: bool,
    have_last: bool,
    last_ts: f64,
}

impl LineCursor {
    fn new(dialect: TraceDialect) -> LineCursor {
        LineCursor { dialect, awaiting_first: true, have_last: false,
                     last_ts: 0.0 }
    }

    fn step(&mut self, line: &str) -> Step {
        match self.dialect.parse_line(line) {
            Ok(None) => Step::Ignore,
            Ok(Some(rec)) => {
                self.awaiting_first = false;
                let repaired = self.have_last && rec.ts < self.last_ts;
                let ts = if repaired { self.last_ts } else { rec.ts };
                self.have_last = true;
                self.last_ts = ts;
                Step::Record { ts, prompt: rec.prompt, output: rec.output,
                               repaired }
            }
            Err(reason) => {
                // A leading line whose first field is alphabetic is a
                // header, not data gone bad.
                if self.awaiting_first && looks_like_header(line) {
                    self.awaiting_first = false;
                    Step::Ignore
                } else {
                    Step::Bad(reason)
                }
            }
        }
    }
}

fn looks_like_header(line: &str) -> bool {
    line.split(',').next().unwrap_or("")
        .chars().any(|c| c.is_ascii_alphabetic())
}

/// Validate a trace file in one streaming pass: parse every line, apply
/// the error policy, and return the health counters plus the time extent
/// the rescaler needs. O(1) memory at any file size.
pub fn probe(path: &str, dialect: TraceDialect, policy: TraceErrorPolicy)
    -> Result<TraceStats>
{
    let f = File::open(path).map_err(|e| anyhow!("trace {path}: {e}"))?;
    let mut cursor = LineCursor::new(dialect);
    let mut st = TraceStats::default();
    let mut line_no = 0u64;
    let (mut t0, mut last, mut have) = (0.0f64, 0.0f64, false);
    for line in BufReader::new(f).lines() {
        let line = line.map_err(|e| {
            anyhow!("trace {path}: line {}: {e}", line_no + 1)
        })?;
        line_no += 1;
        match cursor.step(&line) {
            Step::Record { ts, repaired, .. } => {
                st.records += 1;
                if repaired {
                    st.repaired_timestamps += 1;
                }
                if !have {
                    t0 = ts;
                    have = true;
                }
                last = ts;
            }
            Step::Ignore => {}
            Step::Bad(reason) => match policy {
                TraceErrorPolicy::Skip => st.skipped_lines += 1,
                TraceErrorPolicy::Fail => {
                    bail!("trace {path}: line {line_no}: {reason}")
                }
            },
        }
    }
    st.t0_s = t0;
    st.span_s = if have { last - t0 } else { 0.0 };
    Ok(st)
}

/// Streaming replay of a recorded request trace. See the module docs for
/// the ingestion contract; construction validates the whole file so the
/// [`ArrivalSource`] pull loop is infallible.
pub struct TraceSource {
    cursor: LineCursor,
    lines: Lines<BufReader<File>>,
    policy: TraceErrorPolicy,
    class: RequestClass,
    duration_s: f64,
    /// Trace origin (first record's repaired timestamp).
    t0: f64,
    /// Recorded seconds → simulated seconds.
    time_scale: f64,
    rate: f64,
    credit: f64,
    pending: (f64, usize, usize),
    pending_copies: u64,
    next_id: u64,
    done: bool,
    stats: TraceStats,
}

impl TraceSource {
    /// Open and validate `path`. Fails on I/O errors, on any malformed
    /// line under [`TraceErrorPolicy::Fail`], on an empty trace, and on a
    /// zero-span trace when `rescale.fit_duration` needs an extent to map.
    pub fn open(path: &str, dialect: TraceDialect, policy: TraceErrorPolicy,
                rescale: TraceRescale, class: RequestClass, duration_s: f64)
        -> Result<TraceSource>
    {
        ensure!(duration_s > 0.0,
                "trace {path}: replay duration must be positive");
        ensure!(rescale.rate.is_finite() && rescale.rate > 0.0,
                "trace {path}: rate multiplier must be finite and > 0, \
                 got {}", rescale.rate);
        let stats = probe(path, dialect, policy)?;
        ensure!(stats.records > 0, "trace {path}: no parseable records");
        let time_scale = if rescale.fit_duration {
            ensure!(stats.span_s > 0.0,
                    "trace {path}: zero recorded span, cannot fit to \
                     duration (need >=2 records with distinct timestamps)");
            duration_s / stats.span_s
        } else {
            1.0
        };
        let f = File::open(path).map_err(|e| anyhow!("trace {path}: {e}"))?;
        Ok(TraceSource {
            cursor: LineCursor::new(dialect),
            lines: BufReader::new(f).lines(),
            policy,
            class,
            duration_s,
            t0: stats.t0_s,
            time_scale,
            rate: rescale.rate,
            credit: 0.0,
            pending: (0.0, 0, 0),
            pending_copies: 0,
            next_id: 0,
            done: false,
            stats,
        })
    }

    /// Health counters from the validation pass.
    pub fn stats(&self) -> &TraceStats {
        &self.stats
    }
}

impl ArrivalSource for TraceSource {
    fn next_request(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        loop {
            if self.pending_copies > 0 {
                self.pending_copies -= 1;
                let (arrival_s, prompt_tokens, output_tokens) = self.pending;
                let id = self.next_id;
                self.next_id += 1;
                return Some(Request {
                    id,
                    arrival_s,
                    prompt_tokens,
                    output_tokens,
                    class: self.class,
                });
            }
            let line = match self.lines.next() {
                Some(Ok(l)) => l,
                // EOF, or an I/O error after the file already validated
                // (e.g. truncated between passes): end the stream.
                None | Some(Err(_)) => {
                    self.done = true;
                    return None;
                }
            };
            let (ts, prompt, output) = match self.cursor.step(&line) {
                Step::Record { ts, prompt, output, .. } => (ts, prompt, output),
                Step::Ignore => continue,
                // Malformed lines were counted (Skip) or rejected (Fail)
                // by the validation pass; replay just drops them.
                Step::Bad(_) => {
                    debug_assert!(self.policy == TraceErrorPolicy::Skip,
                                  "Fail-policy trace had a bad line past \
                                   open-time validation");
                    continue;
                }
            };
            let arrival = (ts - self.t0) * self.time_scale;
            if arrival >= self.duration_s {
                self.done = true;
                return None;
            }
            self.credit += self.rate;
            let copies = self.credit.floor();
            self.credit -= copies;
            if copies < 1.0 {
                continue;
            }
            self.pending = (arrival, prompt, output);
            self.pending_copies = copies as u64;
        }
    }
}

/// Windowed burstiness statistics of an arrival stream: the coefficient of
/// variation and peak-to-mean ratio of per-window arrival counts. This is
/// the number behind the "synthetic generators match production
/// burstiness" claim — computed on the replayed stream and on a
/// rate-matched synthetic generator, then reported side by side.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Burstiness {
    pub windows: usize,
    /// std/mean of per-window counts (0 for an empty stream).
    pub cv: f64,
    /// max/mean of per-window counts (0 for an empty stream).
    pub peak_to_mean: f64,
    pub total: u64,
}

/// Drain `src` and bucket arrivals into `windows` equal slices of
/// `[0, duration_s)`.
pub fn burstiness(src: &mut dyn ArrivalSource, duration_s: f64,
                  windows: usize) -> Burstiness {
    let windows = windows.max(1);
    let w = duration_s / windows as f64;
    let mut counts = vec![0u64; windows];
    let mut total = 0u64;
    while let Some(r) = src.next_request() {
        let i = if w > 0.0 {
            ((r.arrival_s / w) as usize).min(windows - 1)
        } else {
            0
        };
        counts[i] += 1;
        total += 1;
    }
    let n = windows as f64;
    let mean = total as f64 / n;
    if mean <= 0.0 {
        return Burstiness { windows, cv: 0.0, peak_to_mean: 0.0, total };
    }
    let var = counts.iter()
        .map(|&c| { let d = c as f64 - mean; d * d })
        .sum::<f64>() / n;
    let peak = counts.iter().copied().max().unwrap_or(0) as f64;
    Burstiness { windows, cv: var.sqrt() / mean, peak_to_mean: peak / mean,
                 total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &str) -> String {
        let path = std::env::temp_dir()
            .join(format!("ecoserve-trace-test-{name}-{}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path.to_string_lossy().into_owned()
    }

    #[test]
    fn azure_lines_parse_and_replay_in_order() {
        let p = tmp("azure-basic",
                    "timestamp,prompt_tokens,output_tokens\n\
                     0.0,100,50\n1.5,200,20\n3.0,50,10\n6.0,80,40\n");
        let mut s = TraceSource::open(
            &p, TraceDialect::Azure, TraceErrorPolicy::Fail,
            TraceRescale { fit_duration: false, rate: 1.0 },
            RequestClass::Online, 100.0).unwrap();
        let tr = s.materialize();
        // Native replay: last record at t=6.0 < 100 stays in.
        assert_eq!(tr.len(), 4);
        assert_eq!(tr[0].arrival_s, 0.0);
        assert_eq!(tr[1].arrival_s, 1.5);
        assert_eq!(tr[1].prompt_tokens, 200);
        assert_eq!(tr[1].output_tokens, 20);
        assert!(tr.iter().enumerate().all(|(i, r)| r.id == i as u64));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn burstgpt_lines_use_fields_two_and_three() {
        let p = tmp("burstgpt-basic",
                    "Timestamp,Model,Request tokens,Response tokens,Total\n\
                     0,model-a,120,60,180\n2,model-b,30,15,45\n4,model-a,10,5,15\n");
        let mut s = TraceSource::open(
            &p, TraceDialect::BurstGpt, TraceErrorPolicy::Fail,
            TraceRescale { fit_duration: false, rate: 1.0 },
            RequestClass::Offline, 100.0).unwrap();
        let tr = s.materialize();
        assert_eq!(tr.len(), 3);
        assert_eq!(tr[0].prompt_tokens, 120);
        assert_eq!(tr[0].output_tokens, 60);
        assert!(tr.iter().all(|r| r.class == RequestClass::Offline));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fit_duration_maps_span_onto_the_run() {
        // Span 0..10 mapped onto duration 40: arrivals at 0, 20, 30; the
        // final record lands exactly at 40 and the half-open window drops
        // it.
        let p = tmp("fit", "0,10,10\n5,10,10\n7.5,10,10\n10,10,10\n");
        let mut s = TraceSource::open(
            &p, TraceDialect::Azure, TraceErrorPolicy::Fail,
            TraceRescale::default(), RequestClass::Online, 40.0).unwrap();
        let tr = s.materialize();
        assert_eq!(tr.len(), 3);
        assert_eq!(tr[0].arrival_s, 0.0);
        assert_eq!(tr[1].arrival_s, 20.0);
        assert_eq!(tr[2].arrival_s, 30.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rate_multiplier_replicates_and_thins_exactly() {
        let body = "0,10,10\n1,10,10\n2,10,10\n3,10,10\n4,10,10\n";
        let p = tmp("rate", body);
        let count = |rate: f64| {
            TraceSource::open(
                &p, TraceDialect::Azure, TraceErrorPolicy::Fail,
                TraceRescale { fit_duration: true, rate },
                RequestClass::Online, 100.0).unwrap().materialize().len()
        };
        let base = count(1.0);
        assert_eq!(base, 4); // 5 records, last lands on duration and drops
        assert_eq!(count(2.0), 2 * base);
        assert_eq!(count(0.5), base / 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn monotonic_repair_counts_and_clamps() {
        let p = tmp("mono", "0,10,10\n5,10,10\n3,10,10\n8,10,10\n");
        let st = probe(&p, TraceDialect::Azure, TraceErrorPolicy::Fail)
            .unwrap();
        assert_eq!(st.records, 4);
        assert_eq!(st.repaired_timestamps, 1);
        let mut s = TraceSource::open(
            &p, TraceDialect::Azure, TraceErrorPolicy::Fail,
            TraceRescale { fit_duration: false, rate: 1.0 },
            RequestClass::Online, 100.0).unwrap();
        let tr = s.materialize();
        assert_eq!(tr.len(), 4);
        assert_eq!(tr[2].arrival_s, 5.0); // clamped up, not reordered
        assert!(tr.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn skip_policy_counts_and_fail_policy_rejects() {
        let p = tmp("bad", "0,10,10\n1,10\nnot,a,line\n2,10,10\n3,10,10\n");
        let st = probe(&p, TraceDialect::Azure, TraceErrorPolicy::Skip)
            .unwrap();
        assert_eq!(st.records, 3);
        assert_eq!(st.skipped_lines, 2);
        assert!(probe(&p, TraceDialect::Azure, TraceErrorPolicy::Fail)
                    .is_err());
        assert!(TraceSource::open(
            &p, TraceDialect::Azure, TraceErrorPolicy::Fail,
            TraceRescale::default(), RequestClass::Online, 60.0).is_err());
        // Skip-policy replay drops exactly the malformed lines.
        let tr = TraceSource::open(
            &p, TraceDialect::Azure, TraceErrorPolicy::Skip,
            TraceRescale { fit_duration: false, rate: 1.0 },
            RequestClass::Online, 60.0).unwrap().materialize();
        assert_eq!(tr.len(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn header_is_ignored_without_counting_a_skip() {
        let p = tmp("header", "timestamp,prompt_tokens,output_tokens\n\
                               0,10,10\n1,10,10\n");
        let st = probe(&p, TraceDialect::Azure, TraceErrorPolicy::Fail)
            .unwrap();
        assert_eq!(st.records, 2);
        assert_eq!(st.skipped_lines, 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dialect_sniffing_counts_fields() {
        let a = tmp("sniff-a", "0,10,10\n1,10,10\n");
        let b = tmp("sniff-b", "Timestamp,Model,Request tokens,Response tokens\n");
        assert_eq!(sniff_dialect(&a).unwrap(), TraceDialect::Azure);
        assert_eq!(sniff_dialect(&b).unwrap(), TraceDialect::BurstGpt);
        assert_eq!(TraceDialect::from_flag("azure"), Some(TraceDialect::Azure));
        assert_eq!(TraceDialect::from_flag("burstgpt"),
                   Some(TraceDialect::BurstGpt));
        assert!(TraceDialect::from_flag("csv").is_none());
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn burstiness_separates_uniform_from_clustered() {
        // 40 uniform arrivals vs 40 arrivals packed into one window.
        let uniform: Vec<Request> = (0..40).map(|i| Request {
            id: i, arrival_s: i as f64 * 0.25, prompt_tokens: 10,
            output_tokens: 10, class: RequestClass::Online,
        }).collect();
        let packed: Vec<Request> = (0..40).map(|i| Request {
            id: i, arrival_s: 0.1, prompt_tokens: 10, output_tokens: 10,
            class: RequestClass::Online,
        }).collect();
        let u = burstiness(&mut crate::workload::SliceSource::new(&uniform),
                           10.0, 10);
        let c = burstiness(&mut crate::workload::SliceSource::new(&packed),
                           10.0, 10);
        assert_eq!(u.total, 40);
        assert!(u.cv < 0.1, "uniform cv {}", u.cv);
        assert!(c.cv > 2.0, "clustered cv {}", c.cv);
        assert!((c.peak_to_mean - 10.0).abs() < 1e-9);
    }
}
