"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every test asserts allclose against ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.decode_attention import (decode_attention,
                                              vmem_bytes_per_program)
from compile.kernels.gemm import gemm, mxu_utilization_estimate
from compile.kernels.gemm import vmem_bytes_per_program as gemm_vmem
from compile.kernels.ref import decode_attention_ref, gemm_ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------- decode attn

@hypothesis.given(
    b=st.sampled_from([1, 2, 4]),
    h=st.sampled_from([1, 2, 4, 8]),
    kvh_div=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    s_chunks=st.integers(min_value=1, max_value=4),
    chunk=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, kvh_div, dh, s_chunks, chunk, seed):
    if h % kvh_div != 0:
        kvh_div = 1
    kvh = h // kvh_div
    s = s_chunks * chunk
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, h, dh))
    k = jax.random.normal(kk, (b, s, kvh, dh))
    v = jax.random.normal(kv, (b, s, kvh, dh))
    pos = jax.random.randint(kp, (b,), 0, s)
    got = decode_attention(q, k, v, pos, chunk=chunk)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_pos_zero():
    """pos=0: only slot 0 attended -> output equals v[:, 0] per kv group."""
    b, h, kvh, dh, s = 2, 4, 2, 16, 64
    q = rand(0, (b, h, dh))
    k = rand(1, (b, s, kvh, dh))
    v = rand(2, (b, s, kvh, dh))
    pos = jnp.zeros((b,), jnp.int32)
    got = decode_attention(q, k, v, pos, chunk=32)
    want = jnp.repeat(v[:, 0], h // kvh, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_full_context():
    """pos=S-1: equal to unmasked softmax attention over the whole cache."""
    b, h, kvh, dh, s = 1, 8, 2, 32, 128
    q, k, v = rand(3, (b, h, dh)), rand(4, (b, s, kvh, dh)), rand(5, (b, s, kvh, dh))
    pos = jnp.full((b,), s - 1, jnp.int32)
    got = decode_attention(q, k, v, pos, chunk=64)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_per_sequence_pos():
    """Mixed per-sequence positions (continuous batching) stay independent."""
    b, h, kvh, dh, s = 4, 4, 4, 16, 64
    q, k, v = rand(6, (b, h, dh)), rand(7, (b, s, kvh, dh)), rand(8, (b, s, kvh, dh))
    pos = jnp.array([0, 13, 31, 63], jnp.int32)
    got = decode_attention(q, k, v, pos, chunk=16)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # Changing cache content beyond a sequence's pos must not change it.
    k2 = k.at[1, 20:].set(99.0)
    got2 = decode_attention(q, k2, v, pos, chunk=16)
    np.testing.assert_allclose(np.asarray(got2[1]), np.asarray(got[1]),
                               rtol=1e-6, atol=1e-6)


def test_decode_attention_invariant_to_chunk():
    """Split-KV merge is exact: results identical across chunk sizes."""
    b, h, kvh, dh, s = 2, 8, 2, 32, 128
    q, k, v = rand(9, (b, h, dh)), rand(10, (b, s, kvh, dh)), rand(11, (b, s, kvh, dh))
    pos = jnp.array([100, 37], jnp.int32)
    outs = [decode_attention(q, k, v, pos, chunk=c) for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attention_softmax_scale_extremes():
    """Large-magnitude logits: the running-max merge must stay stable."""
    b, h, kvh, dh, s = 1, 2, 1, 16, 64
    q = rand(12, (b, h, dh)) * 30.0
    k = rand(13, (b, s, kvh, dh)) * 30.0
    v = rand(14, (b, s, kvh, dh))
    pos = jnp.array([s - 1], jnp.int32)
    got = decode_attention(q, k, v, pos, chunk=16)
    want = decode_attention_ref(q, k, v, pos)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_rejects_bad_shapes():
    q = jnp.zeros((1, 4, 16))
    k = jnp.zeros((1, 60, 2, 16))  # 60 not a multiple of 32
    v = jnp.zeros((1, 60, 2, 16))
    with pytest.raises(AssertionError):
        decode_attention(q, k, v, jnp.zeros((1,), jnp.int32), chunk=32)


def test_vmem_budget():
    """DESIGN.md §7: per-program footprint fits VMEM with double buffering."""
    assert vmem_bytes_per_program(dh=32, chunk=64) < 2 * 1024 * 1024
    assert gemm_vmem(128, 128, 128) * 2 < 16 * 1024 * 1024


# ----------------------------------------------------------------------- gemm

@hypothesis.given(
    mt=st.integers(min_value=1, max_value=3),
    nt=st.integers(min_value=1, max_value=3),
    kt=st.integers(min_value=1, max_value=3),
    bs=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gemm_matches_ref(mt, nt, kt, bs, seed):
    m, n, k = mt * bs, nt * bs, kt * bs
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (k, n))
    got = gemm(a, b, bm=bs, bn=bs, bk=bs)
    want = gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_identity():
    n = 64
    a = rand(20, (n, n))
    got = gemm(a, jnp.eye(n), bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a),
                               rtol=1e-6, atol=1e-6)


def test_gemm_tile_invariance():
    m = n = k = 128
    a, b = rand(21, (m, k)), rand(22, (k, n))
    o1 = gemm(a, b, bm=32, bn=32, bk=32)
    o2 = gemm(a, b, bm=64, bn=64, bk=64)
    o3 = gemm(a, b, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), rtol=1e-5, atol=1e-5)


def test_gemm_rejects_untileable():
    with pytest.raises(AssertionError):
        gemm(jnp.zeros((100, 128)), jnp.zeros((128, 128)))


def test_mxu_utilization_estimate():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 0.5
    assert mxu_utilization_estimate(32, 32, 32) == pytest.approx(1 / 64)
