//! Carbon-accounting invariants: the simulator's energy/carbon bookkeeping
//! and the operational/embodied task model stay self-consistent.

use ecoserve::carbon::operational::{amortized_emb_kg, device_power, idle_power,
                                    op_kg, op_kg_from_joules, op_kg_per_hr,
                                    task_carbon, GPU_POWER_GAMMA};
use ecoserve::models;
use ecoserve::sim::{homogeneous_fleet, simulate, Router, SimConfig, SimReport};
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, Request,
                         RequestClass};

fn run_sim(gpus: usize, rate: f64, ci: f64, class: RequestClass)
    -> (SimReport, Vec<Request>) {
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate }, LengthDist::ShareGpt,
                            class, 120.0, 99);
    let servers = homogeneous_fleet("A100-40", gpus, m, 2048);
    let n = servers.len();
    let cfg = SimConfig::flat(servers, Router::WorkloadAware, ci, vec![0.005; n]);
    let r = simulate(m, &tr, &cfg, 0.5, 0.1);
    (r, tr)
}

#[test]
fn sim_carbon_is_op_plus_embodied() {
    let (r, _) = run_sim(4, 3.0, 261.0, RequestClass::Online);
    assert!(r.op_kg > 0.0 && r.emb_kg > 0.0);
    assert!((r.carbon_kg() - (r.op_kg + r.emb_kg)).abs() < 1e-12,
            "carbon {} != {} + {}", r.carbon_kg(), r.op_kg, r.emb_kg);
    // Operational carbon is exactly energy × CI for a flat signal (the
    // meter sums linearly over busy/idle intervals, so the total must
    // match a single conversion of the total energy draw).
    let expect = op_kg_from_joules(r.energy_j, 261.0);
    assert!((r.op_kg - expect).abs() <= 1e-9 * expect.max(1e-12),
            "op {} vs energy-derived {}", r.op_kg, expect);
}

#[test]
fn sim_conserves_tokens_and_energy_is_non_negative() {
    let (r, tr) = run_sim(4, 3.0, 261.0, RequestClass::Online);
    assert_eq!(r.completed, tr.len(), "requests lost");
    let want: usize = tr.iter().map(|x| x.output_tokens.max(1)).sum();
    assert_eq!(r.generated_tokens, want, "token conservation violated");
    assert!(r.energy_j.is_finite() && r.energy_j > 0.0);
    assert!(r.sim_duration_s > 0.0);
    assert!(r.throughput_tok_s() > 0.0);
}

#[test]
fn slo_attainment_stays_in_unit_interval() {
    // Light load, overload, and offline-only (no online SLO samples).
    for (gpus, rate, class) in [(8, 0.5, RequestClass::Online),
                                (1, 12.0, RequestClass::Online),
                                (2, 2.0, RequestClass::Offline)] {
        let (r, _) = run_sim(gpus, rate, 261.0, class);
        assert!((0.0..=1.0).contains(&r.slo_attainment),
                "gpus={gpus} rate={rate}: slo {}", r.slo_attainment);
        if class == RequestClass::Offline {
            // No online requests -> attainment is vacuously perfect.
            assert_eq!(r.slo_attainment, 1.0);
        }
    }
}

#[test]
fn op_carbon_scales_linearly_with_ci() {
    let (lo, _) = run_sim(4, 2.0, 17.0, RequestClass::Online);
    let (hi, _) = run_sim(4, 2.0, 501.0, RequestClass::Online);
    // Same seed/fleet: identical energy, op ∝ CI, embodied unchanged.
    assert!((lo.energy_j - hi.energy_j).abs() < 1e-6);
    let ratio = hi.op_kg / lo.op_kg;
    assert!((ratio - 501.0 / 17.0).abs() < 1e-6, "ratio {ratio}");
    assert!((lo.emb_kg - hi.emb_kg).abs() < 1e-12);
}

#[test]
fn task_carbon_components_sum() {
    let tc = task_carbon(300.0, 400.0, 7200.0, 261.0, 800.0, 120.0, 9.0, 3.0);
    let total = tc.op_kg + tc.emb_host_kg + tc.emb_gpu_kg;
    assert!((tc.total() - total).abs() < 1e-12);
    assert!(tc.op_kg > 0.0 && tc.emb_host_kg > 0.0 && tc.emb_gpu_kg > 0.0);
    // Op term matches the closed form; embodied amortizes over lifetime.
    assert!((tc.op_kg - op_kg(700.0, 7200.0, 261.0)).abs() < 1e-12);
    let full_lt_s = 3.0 * 365.25 * 86_400.0;
    assert!((amortized_emb_kg(120.0, full_lt_s, 3.0) - 120.0).abs() < 1e-9);
}

#[test]
fn planner_idle_pricing_matches_the_sim_meter_on_flat_ci() {
    let m = models::llm("llama-8b").unwrap();
    let specs = homogeneous_fleet("A100-40", 4, m, 2048);

    // The planner's objective columns price idle per *individual GPU*
    // (idle_power(idle_w, 1), B_j counts GPUs); the sim meters idle per
    // tp-group server (idle_power(idle_w, tp)). Both are the one shared
    // function, and for any concrete fleet — where GPUs come in whole
    // tp-groups — the two views are bit-identical.
    let planner_idle_w: f64 = specs.iter()
        .map(|s| s.tp as f64 * idle_power(s.device.idle_w, 1))
        .sum();
    let sim_idle_w: f64 = specs.iter()
        .map(|s| idle_power(s.device.idle_w, s.tp))
        .sum();
    assert_eq!(planner_idle_w.to_bits(), sim_idle_w.to_bits());

    // Flat-CI run: the meter's fleet energy must reconstruct exactly from
    // the shared model — per-server busy draw plus idle seconds priced at
    // the planner's per-GPU floor.
    let (r, _) = run_sim(4, 0.3, 261.0, RequestClass::Online);
    let mut reconstructed = 0.0;
    for (u, s) in r.per_server.iter().zip(&specs) {
        let idle_s = (u.provisioned_s - u.busy_s).max(0.0);
        let busy_j = u.energy_j - idle_s * idle_power(s.device.idle_w, s.tp);
        assert!(busy_j >= -1e-6, "negative busy energy {busy_j}");
        reconstructed += busy_j
            + idle_s * (s.tp as f64 * idle_power(s.device.idle_w, 1));
    }
    assert!((reconstructed - r.energy_j).abs() <= 1e-9 * r.energy_j.max(1.0),
            "planner reconstruction {reconstructed} vs metered {}", r.energy_j);

    // And the op charge is that energy priced through the same W -> kg/hr
    // conversion (op_kg_per_hr) the planner's columns apply.
    let mean_w = r.energy_j / r.sim_duration_s.max(1e-9);
    let predicted_op = op_kg_per_hr(mean_w, 261.0) * (r.sim_duration_s / 3600.0);
    assert!((predicted_op - r.op_kg).abs() <= 1e-9 * r.op_kg.max(1e-12),
            "planner op pricing {predicted_op} vs metered {}", r.op_kg);
}

#[test]
fn device_power_bounded_by_idle_and_tdp() {
    for util in [0.0, 0.1, 0.5, 0.9, 1.0] {
        let p = device_power(50.0, 400.0, util, GPU_POWER_GAMMA);
        assert!((50.0..=400.0).contains(&p), "util {util}: {p}");
    }
    assert_eq!(device_power(50.0, 400.0, 0.0, GPU_POWER_GAMMA), 50.0);
    assert_eq!(device_power(50.0, 400.0, 1.0, GPU_POWER_GAMMA), 400.0);
}
