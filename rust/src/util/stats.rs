//! Summary-statistics substrate: means, percentiles, streaming accumulators.
//!
//! Used by the simulator's SLO accounting (TTFT/TPOT p50/p90/p99), the bench
//! harness, and experiment reports.

/// Streaming accumulator (Welford) for mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 { self.n }
    pub fn mean(&self) -> f64 { if self.n == 0 { f64::NAN } else { self.mean } }
    pub fn min(&self) -> f64 { self.min }
    pub fn max(&self) -> f64 { self.max }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 { self.variance().sqrt() }

    pub fn merge(&mut self, other: &Accum) {
        if other.n == 0 { return; }
        if self.n == 0 { *self = other.clone(); return; }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// A recorded sample set with percentile queries (sorts lazily).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self { Samples { xs: Vec::new(), sorted: true } }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.xs.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize { self.xs.len() }
    pub fn is_empty(&self) -> bool { self.xs.is_empty() }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 { self.xs.iter().sum() }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp: NaN sorts after +inf instead of panicking, so a
            // stray NaN sample degrades a percentile, never the process.
            self.xs.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi { return self.xs[lo]; }
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 { self.percentile(50.0) }
    pub fn p90(&mut self) -> f64 { self.percentile(90.0) }
    pub fn p99(&mut self) -> f64 { self.percentile(99.0) }
    pub fn max(&mut self) -> f64 { self.percentile(100.0) }
    pub fn min(&mut self) -> f64 { self.percentile(0.0) }

    /// Median absolute deviation — robust spread for outlier rejection.
    pub fn mad(&mut self) -> f64 {
        if self.xs.is_empty() { return f64::NAN; }
        let med = self.p50();
        let mut devs = Samples::new();
        let xs = self.xs.clone();
        for x in xs { devs.push((x - med).abs()); }
        devs.p50()
    }
}

/// Fixed-bin log-spaced histogram for latency metrics at production trace
/// scales: O(1) memory however many samples stream in, with deterministic
/// percentile queries (no per-sample vector, no lazy sort). Bins span
/// [`Histogram::LO`], 10^[`Histogram::DECADES`]·LO) at
/// [`Histogram::BINS_PER_DECADE`] bins per decade (~3.7% resolution);
/// percentiles interpolate geometrically inside a bin and clamp to the
/// exactly-tracked [min, max], so they are monotone in q and never leave
/// the observed range. Values at or below `LO` (e.g. a zero TPOT) land in
/// the first bin and report as ≤ `LO` after the min-clamp.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    /// Lazily allocated on first push so empty histograms stay tiny.
    bins: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Lower edge of the first bin, seconds (10 µs).
    pub const LO: f64 = 1e-5;
    pub const DECADES: usize = 9; // up to 10^4 s
    pub const BINS_PER_DECADE: usize = 64;
    const BINS: usize = Self::DECADES * Self::BINS_PER_DECADE;

    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bin_index(x: f64) -> usize {
        if x.is_nan() || x <= Self::LO {
            return 0; // underflow (and any NaN garbage) pools here
        }
        let i = ((x / Self::LO).log10() * Self::BINS_PER_DECADE as f64) as usize;
        i.min(Self::BINS - 1)
    }

    fn edges(i: usize) -> (f64, f64) {
        let b = Self::BINS_PER_DECADE as f64;
        let lo = Self::LO * 10f64.powf(i as f64 / b);
        let hi = Self::LO * 10f64.powf((i + 1) as f64 / b);
        (lo, hi)
    }

    pub fn push(&mut self, x: f64) {
        if self.bins.is_empty() {
            self.bins = vec![0u64; Self::BINS];
            self.min = f64::INFINITY;
            self.max = f64::NEG_INFINITY;
        }
        self.bins[Self::bin_index(x)] += 1;
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.sum / self.n as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }

    /// Percentile, q in [0, 100]: rank interpolation across the binned
    /// CDF (same rank convention as [`Samples::percentile`]), geometric
    /// interpolation within a bin, clamped to the exact [min, max].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        if self.n == 1 {
            return self.min;
        }
        let rank = (q / 100.0).clamp(0.0, 1.0) * (self.n - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let last_rank = (cum + c - 1) as f64;
            if rank <= last_rank {
                let frac = if c > 1 {
                    ((rank - cum as f64) / (c - 1) as f64).clamp(0.0, 1.0)
                } else {
                    0.5
                };
                let (lo, hi) = Self::edges(i);
                let v = lo * (hi / lo).powf(frac);
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 { self.percentile(50.0) }
    pub fn p90(&self) -> f64 { self.percentile(90.0) }
    pub fn p99(&self) -> f64 { self.percentile(99.0) }

    /// Fold `other` into `self` — the shard-merge primitive. Bin counts,
    /// sample count, and min/max are exact, so every percentile of a
    /// merged histogram is *bitwise* independent of merge order and
    /// grouping. The running `sum` (and hence `mean`) is an f64
    /// accumulation: commutative bitwise, associative only to rounding —
    /// which is why the sharded runtime always folds shards in ascending
    /// shard-index order (the merged report is then a pure function of
    /// the partition set, never of thread interleaving).
    pub fn merge(&mut self, other: &Histogram) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exponential moving average for runtime load tracking.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> { self.value }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_basic() {
        let mut a = Accum::new();
        for x in [1.0, 2.0, 3.0, 4.0] { a.push(x); }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn accum_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accum::new();
        for &x in &xs { whole.push(x); }
        let mut left = Accum::new();
        let mut right = Accum::new();
        for &x in &xs[..37] { left.push(x); }
        for &x in &xs[37..] { right.push(x); }
        left.merge(&right);
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
        assert!((left.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 { s.push(i as f64); }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn empty_samples_nan() {
        let mut s = Samples::new();
        assert!(s.p50().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn nan_sample_degrades_percentiles_without_panicking() {
        // Regression: the lazy sort used partial_cmp().unwrap(), so one
        // NaN sample aborted the whole run. total_cmp sorts NaN last.
        let mut s = Samples::new();
        s.extend(&[3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.p50(), 2.5);
        assert!(s.percentile(100.0).is_nan());
    }

    #[test]
    fn mad_robust() {
        let mut s = Samples::new();
        s.extend(&[1.0, 1.0, 1.0, 1.0, 1000.0]);
        assert_eq!(s.mad(), 0.0);
    }

    #[test]
    fn histogram_tracks_percentiles_within_bin_resolution() {
        let mut h = Histogram::new();
        let mut s = Samples::new();
        // Latency-shaped values across four decades.
        for i in 1..=1000 {
            let x = 1e-3 * (i as f64).powf(1.7);
            h.push(x);
            s.push(x);
        }
        assert_eq!(h.len(), 1000);
        for q in [10.0, 50.0, 90.0, 99.0] {
            let exact = s.percentile(q);
            let binned = h.percentile(q);
            assert!((binned / exact - 1.0).abs() < 0.05,
                    "q{q}: binned {binned} exact {exact}");
        }
        assert!((h.mean() - s.mean()).abs() < 1e-9 * s.mean());
        assert_eq!(h.min(), s.percentile(0.0));
        assert_eq!(h.max(), s.percentile(100.0));
    }

    #[test]
    fn histogram_percentiles_are_monotone_and_clamped() {
        let mut h = Histogram::new();
        for x in [0.0, 2e-6, 0.04, 0.04, 0.05, 3.0, 20000.0] {
            h.push(x);
        }
        let mut prev = f64::NEG_INFINITY;
        for q in 0..=100 {
            let v = h.percentile(q as f64);
            assert!(v >= prev, "q{q}: {v} < {prev}");
            assert!(v >= h.min() && v <= h.max(), "q{q}: {v} out of range");
            prev = v;
        }
        // Underflow and overflow stay inside the observed extremes.
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 20000.0);
    }

    #[test]
    fn histogram_merge_matches_sequential_pushes() {
        let xs: Vec<f64> = (1..=500)
            .map(|i| 1e-3 * (i as f64).powf(1.6))
            .collect();
        let mut whole = Histogram::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut left = Histogram::new();
        let mut right = Histogram::new();
        for &x in &xs[..201] {
            left.push(x);
        }
        for &x in &xs[201..] {
            right.push(x);
        }
        left.merge(&right);
        assert_eq!(left.len(), whole.len());
        assert_eq!(left.min().to_bits(), whole.min().to_bits());
        assert_eq!(left.max().to_bits(), whole.max().to_bits());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(left.percentile(q).to_bits(),
                       whole.percentile(q).to_bits(),
                       "q{q} diverged after merge");
        }
        // Sum is a float accumulation: equal to rounding, not bitwise.
        assert!((left.mean() - whole.mean()).abs() < 1e-12 * whole.mean());
        // Merging an empty histogram is the identity in both directions.
        let snap = left.percentile(50.0);
        left.merge(&Histogram::new());
        assert_eq!(left.percentile(50.0).to_bits(), snap.to_bits());
        let mut empty = Histogram::new();
        empty.merge(&whole);
        assert_eq!(empty.len(), whole.len());
        assert_eq!(empty.p90().to_bits(), whole.p90().to_bits());
    }

    #[test]
    fn histogram_empty_and_single() {
        let mut h = Histogram::new();
        assert!(h.p50().is_nan() && h.mean().is_nan());
        assert_eq!(h.len(), 0);
        h.push(0.25);
        assert_eq!(h.p50(), 0.25);
        assert_eq!(h.p99(), 0.25);
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(10.0), 10.0);
        let mut v = 0.0;
        for _ in 0..50 { v = e.push(20.0); }
        assert!((v - 20.0).abs() < 1e-6);
    }
}
