//! Fig 20: rightsizing vs Melange and single-hardware baselines
//! (Gemma-27B, online TPOT=100 ms / offline 24 h).
use ecoserve::models;
use ecoserve::planner::slicing::Slice;
use ecoserve::planner::{plan, PlanConfig};
use ecoserve::strategies::Strategy;
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::Slo;

fn main() {
    let m = models::llm("gemma-27b").unwrap();
    println!("== Fig 20: rightsizing vs Melange / single-HW (Gemma-27B) ==");
    for (setting, offline) in [("online", false), ("offline", true)] {
        println!("\n{setting} setting:");
        let mut t = Table::new(&["rate", "baseline", "carbon kg/hr", "energy-proxy",
                                 "eco improvement x"]);
        for &rate in &[1.0f64, 4.0, 16.0] {
            let slo = if offline {
                Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY }
            } else {
                Slo { ttft_s: 10.0, tpot_s: 0.1 }
            };
            let slices = vec![
                Slice { model: m, rate, prompt: 512, output: 256, slo, offline },
                Slice { model: m, rate: rate / 2.0, prompt: 4096, output: 256,
                        slo, offline },
            ];
            let eco = Strategy::EcoRightsize.plan(&slices, 420.0);
            let mut add = |name: &str, p: ecoserve::planner::Plan| {
                t.row(&[fnum(rate), name.into(), fnum(p.carbon_kg_per_hr()),
                        fnum(p.op_kg_per_hr),
                        fnum(p.carbon_kg_per_hr() / eco.carbon_kg_per_hr())]);
            };
            add("melange", Strategy::Melange.plan(&slices, 420.0));
            for hw in ["H100", "A100-80", "L4"] {
                let cfg = PlanConfig {
                    alpha: 0.0,
                    gpu_menu: vec![hw],
                    cpu_reuse: false,
                    reduce_host: false,
                    host_lifetime_y: 4.0,
                    gpu_lifetime_y: 4.0,
                    ..Default::default()
                };
                add(&format!("single-{hw}"), plan(&slices, &cfg));
            }
            add("eco-rightsize", eco.clone());
        }
        t.print();
    }
    println!("(ratios > 1: baseline emits more than rightsizing)");
}
