//! ASCII table renderer for experiment reports (benches print the paper's
//! tables/figure series through this).

/// Column-aligned table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn len(&self) -> usize { self.rows.len() }
    pub fn is_empty(&self) -> bool { self.rows.is_empty() }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep: String = widths.iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with engineering-style precision (3 significant-ish digits).
pub fn fnum(x: f64) -> String {
    if !x.is_finite() { return format!("{x}"); }
    let a = x.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 0.1 {
        format!("{x:.3}")
    } else {
        format!("{x:.3e}")
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn ftime(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(12345.6), "12346");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(0.5), "0.500");
        assert_eq!(fnum(0.00123), "1.230e-3");
    }

    #[test]
    fn ftime_ranges() {
        assert_eq!(ftime(2.5), "2.50s");
        assert_eq!(ftime(0.0025), "2.50ms");
        assert_eq!(ftime(2.5e-6), "2.5µs");
        assert_eq!(ftime(2.5e-9), "2.5ns");
    }
}
