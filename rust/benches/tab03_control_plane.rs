//! Table 3: ILP control-plane wall-clock vs cluster size and load.
use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::planner::{plan, PlanConfig};
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::Slo;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

fn main() {
    let m = models::llm("llama-8b").unwrap();
    println!("== Table 3: planner solve time (s) vs cluster size ==");
    let mut t = Table::new(&["cluster", "online (low)", "offline (low)",
                             "online (high)", "offline (high)"]);
    for &nodes in &[10usize, 20, 40, 80, 160] {
        let mut cells = vec![format!("{nodes}")];
        for (class, load) in [(RequestClass::Online, 0.3), (RequestClass::Offline, 0.3),
                              (RequestClass::Online, 0.8), (RequestClass::Offline, 0.8)] {
            // Rate scaled so the fleet lands near `nodes` devices at `load`.
            let rate = load * nodes as f64 * 1.2;
            let dist = if class == RequestClass::Offline {
                LengthDist::LongBench
            } else {
                LengthDist::ShareGpt
            };
            let tr = generate_trace(Arrivals::Poisson { rate }, dist, class,
                                    120.0, nodes as u64);
            let f = if load > 0.5 { 4 } else { 2 };
            let slices = cluster_slices(&slice_trace(
                m, &tr, 120.0, Slo { ttft_s: 0.5, tpot_s: 0.1 }, f));
            let cfg = PlanConfig::default();
            let p = plan(&slices, &cfg);
            cells.push(fnum(p.solve_s));
        }
        t.row(&cells);
    }
    t.print();
    println!("(clustered slices keep growth sub-linear; paper: <2 s at 160)");
}
