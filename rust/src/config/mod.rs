//! Deployment configuration: a JSON file describing the workloads, SLO
//! overrides, region, and strategy knobs that drive the planner — the
//! "framework ingests hardware specs, LLM characteristics, and production
//! traces alongside carbon intensity data" front door of Fig 7.
//!
//! Example (see `ecoserve plan --config deploy.json`):
//! ```json
//! {
//!   "region": "california",
//!   "strategy": {"reuse": true, "rightsize": true,
//!                "reduce": true, "recycle": true, "alpha": 1.0},
//!   "workloads": [
//!     {"model": "llama-8b", "rate": 20.0, "dataset": "sharegpt",
//!      "class": "online", "ttft_s": 0.5, "tpot_s": 0.1},
//!     {"model": "llama-8b", "rate": 8.0, "dataset": "longbench",
//!      "class": "offline"}
//!   ],
//!   "gpu_menu": ["L4", "A100-40", "A100-80", "H100"],
//!   "slice_factor": 2
//! }
//! ```

use crate::carbon::intensity::Region;
use crate::planner::PlanConfig;
use crate::util::json::Json;
use crate::workload::slo::{slo_for, Slo, OFFLINE_DEADLINE_S};
use crate::workload::{LengthDist, RequestClass};
use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone)]
pub struct WorkloadCfg {
    pub model: String,
    pub rate: f64,
    pub dataset: LengthDist,
    pub class: RequestClass,
    pub slo: Slo,
}

#[derive(Debug, Clone)]
pub struct DeployConfig {
    pub region: Region,
    pub workloads: Vec<WorkloadCfg>,
    pub plan: PlanConfig,
    pub slice_factor: usize,
}

pub fn parse_region(name: &str) -> Result<Region> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "sweden" | "se-north" | "low" => Region::SwedenNorth,
        "california" | "caiso" | "mid" => Region::California,
        "midcontinent" | "miso" | "high" => Region::Midcontinent,
        "us-east" => Region::UsEast,
        "europe" | "eu-central" => Region::Europe,
        "us-central" | "us-south" => Region::UsCentral,
        "renewable" | "hyperscale" => Region::HyperscaleRenewable,
        other => bail!("unknown region '{other}'"),
    })
}

fn parse_dataset(name: &str) -> Result<LengthDist> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "sharegpt" => LengthDist::ShareGpt,
        "longbench" => LengthDist::LongBench,
        "azure" | "aft" | "azurecode" => LengthDist::AzureCode,
        other => bail!("unknown dataset '{other}'"),
    })
}

impl DeployConfig {
    pub fn from_json(text: &str) -> Result<DeployConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let region = parse_region(
            j.get("region").and_then(|r| r.as_str()).unwrap_or("california"))?;

        let mut plan = PlanConfig::default();
        plan.ci = region.avg_ci();
        if let Some(s) = j.get("strategy") {
            let flag = |k: &str, d: bool| s.get(k).and_then(|v| v.as_bool()).unwrap_or(d);
            plan = PlanConfig::ecoserve(
                flag("reuse", true), flag("rightsize", true),
                flag("reduce", true), flag("recycle", true));
            plan.ci = region.avg_ci();
            if let Some(a) = s.get("alpha").and_then(|v| v.as_f64()) {
                if !(0.0..=1.0).contains(&a) {
                    bail!("alpha {a} out of [0,1]");
                }
                plan.alpha = a;
            }
        }
        if let Some(menu) = j.get("gpu_menu").and_then(|m| m.as_arr()) {
            let mut names = Vec::new();
            for g in menu {
                let n = g.as_str().ok_or_else(|| anyhow!("gpu_menu entry not a string"))?;
                let spec = crate::hw::gpu(n)
                    .ok_or_else(|| anyhow!("unknown GPU '{n}' in gpu_menu"))?;
                names.push(spec.name);
            }
            if names.is_empty() {
                bail!("gpu_menu is empty");
            }
            plan.gpu_menu = names;
        }

        let wl = j.get("workloads").and_then(|w| w.as_arr())
            .ok_or_else(|| anyhow!("missing 'workloads' array"))?;
        if wl.is_empty() {
            bail!("'workloads' is empty");
        }
        let mut workloads = Vec::new();
        for (i, w) in wl.iter().enumerate() {
            let ctx = || format!("workloads[{i}]");
            let model = w.get("model").and_then(|m| m.as_str())
                .ok_or_else(|| anyhow!("{}: missing model", ctx()))?.to_string();
            crate::models::llm(&model)
                .ok_or_else(|| anyhow!("{}: unknown model '{model}'", ctx()))?;
            let rate = w.get("rate").and_then(|r| r.as_f64())
                .ok_or_else(|| anyhow!("{}: missing rate", ctx()))?;
            if rate <= 0.0 {
                bail!("{}: rate must be positive", ctx());
            }
            let class = match w.get("class").and_then(|c| c.as_str()).unwrap_or("online") {
                "online" => RequestClass::Online,
                "offline" => RequestClass::Offline,
                other => bail!("{}: unknown class '{other}'", ctx()),
            };
            let dataset = parse_dataset(
                w.get("dataset").and_then(|d| d.as_str()).unwrap_or("sharegpt"))?;
            // SLO: explicit override > §5 table default > generic.
            let table = slo_for(&model, class == RequestClass::Offline).map(|t| t.slo);
            let default = if class == RequestClass::Offline {
                Slo { ttft_s: OFFLINE_DEADLINE_S, tpot_s: f64::INFINITY }
            } else {
                table.unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 })
            };
            let slo = Slo {
                ttft_s: w.get("ttft_s").and_then(|v| v.as_f64()).unwrap_or(default.ttft_s),
                tpot_s: w.get("tpot_s").and_then(|v| v.as_f64()).unwrap_or(default.tpot_s),
            };
            workloads.push(WorkloadCfg { model, rate, dataset, class, slo });
        }

        let slice_factor = j.get("slice_factor").and_then(|v| v.as_usize()).unwrap_or(1);
        if slice_factor == 0 {
            bail!("slice_factor must be >= 1");
        }
        Ok(DeployConfig { region, workloads, plan, slice_factor })
    }

    pub fn load(path: &std::path::Path) -> Result<DeployConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&text)
    }

    /// Expand workloads into planner slices via synthetic traces at each
    /// workload's rate/dataset (deterministic per seed).
    pub fn to_slices(&self, duration_s: f64, seed: u64)
        -> Vec<crate::planner::slicing::Slice> {
        use crate::planner::slicing::{cluster_slices, slice_trace};
        use crate::workload::{generate_trace, Arrivals};
        let mut all = Vec::new();
        for (i, w) in self.workloads.iter().enumerate() {
            let m = crate::models::llm(&w.model).unwrap();
            let tr = generate_trace(Arrivals::Poisson { rate: w.rate }, w.dataset,
                                    w.class, duration_s, seed ^ i as u64);
            let mut slices = slice_trace(m, &tr, duration_s, w.slo, self.slice_factor);
            // slice_trace derives offline SLOs itself; online keep w.slo.
            all.append(&mut slices);
        }
        cluster_slices(&all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
        "region": "california",
        "strategy": {"reuse": true, "rightsize": false, "reduce": true,
                     "recycle": true, "alpha": 0.8},
        "workloads": [
            {"model": "llama-8b", "rate": 10.0, "dataset": "sharegpt",
             "class": "online", "ttft_s": 0.4},
            {"model": "llama-8b", "rate": 4.0, "dataset": "longbench",
             "class": "offline"}
        ],
        "gpu_menu": ["L4", "H100"],
        "slice_factor": 2
    }"#;

    #[test]
    fn parses_full_config() {
        let c = DeployConfig::from_json(GOOD).unwrap();
        assert_eq!(c.region, Region::California);
        assert_eq!(c.plan.ci, 261.0);
        assert_eq!(c.plan.alpha, 0.8);
        assert!(c.plan.cpu_reuse && !c.plan.gpu_menu.contains(&"A100-40"));
        assert_eq!(c.plan.gpu_menu, vec!["L4", "H100"]);
        assert_eq!(c.workloads.len(), 2);
        assert_eq!(c.workloads[0].slo.ttft_s, 0.4);   // override
        assert_eq!(c.workloads[0].slo.tpot_s, 0.1);   // table default
        assert_eq!(c.workloads[1].slo.ttft_s, OFFLINE_DEADLINE_S);
        assert_eq!(c.slice_factor, 2);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(DeployConfig::from_json("{}").is_err());
        let bad_model = GOOD.replace("llama-8b", "gpt-9");
        assert!(DeployConfig::from_json(&bad_model).is_err());
        let bad_gpu = GOOD.replace("\"H100\"", "\"B200\"");
        assert!(DeployConfig::from_json(&bad_gpu).is_err());
        let bad_alpha = GOOD.replace("0.8", "1.8");
        assert!(DeployConfig::from_json(&bad_alpha).is_err());
        let bad_rate = GOOD.replace("10.0", "-1");
        assert!(DeployConfig::from_json(&bad_rate).is_err());
    }

    #[test]
    fn slices_and_plan_end_to_end() {
        let c = DeployConfig::from_json(GOOD).unwrap();
        let slices = c.to_slices(120.0, 42);
        assert!(!slices.is_empty());
        let total: f64 = slices.iter().map(|s| s.rate).sum();
        assert!(total > 5.0, "rate lost in slicing: {total}");
        let p = crate::planner::plan(&slices, &c.plan);
        assert!(p.total_gpus() > 0);
    }

    #[test]
    fn region_aliases() {
        assert_eq!(parse_region("LOW").unwrap(), Region::SwedenNorth);
        assert_eq!(parse_region("miso").unwrap(), Region::Midcontinent);
        assert!(parse_region("mars").is_err());
    }
}
