//! Fused single-pass demand analysis.
//!
//! Reprovision runs used to walk the arrival stream three times before the
//! simulator ever saw a request: once for the peak-window scan
//! ([`super::horizon::peak_window_over`]), once to re-materialize the peak
//! window into a [`SliceAccum`], and once inside
//! [`super::horizon::plan_schedule_stream`]'s sliding observation buffer.
//! A [`DemandProfile`] collapses all three into one streaming pass — one
//! `ArrivalSource` materialization per run — and can shard that pass
//! across worker threads the way `sim/shard.rs` shards the simulator,
//! with an order-fixed merge.
//!
//! Bitwise contract: every histogram in the profile is integer counts
//! accumulated under the *exact* float membership tests the separate
//! passes used (`t_k <= a && a < t_k + epoch` for grid windows,
//! `t_k - w <= a && a < t_k` for epoch windows, with `t_k = k as f64 *
//! epoch` and `w = window.min(t_k)` computed by the same expressions).
//! Window edges are never reconstructed from partial sums — a derived
//! edge like `fl(fl(k*q) + epoch)` can differ from `fl((k+4)*q)` by one
//! ulp, which would move boundary arrivals between windows. Because the
//! per-window contents are integers, merging modulo-partitioned partial
//! profiles in worker-index order reproduces the single-threaded profile
//! exactly, for any worker count.

use crate::planner::slicing::SliceAccum;
use crate::workload::{ArrivalSource, Request};

/// Quarter-epoch sliding peak grid: window `k` covers
/// `[k·q, k·q + epoch)` with `q = epoch/4`, so a burst straddling an
/// epoch-aligned boundary is never undercounted. Shared by
/// [`super::horizon::peak_window_over`] and [`DemandProfile`], so the
/// streaming, materialized, and fused paths cannot disagree — on ties the
/// first strictly-maximal window always wins.
#[derive(Debug, Clone)]
pub(crate) struct PeakGrid {
    epoch_s: f64,
    q: f64,
    counts: Vec<usize>,
}

impl PeakGrid {
    pub(crate) fn new(epoch_s: f64, duration_s: f64) -> PeakGrid {
        assert!(epoch_s > 0.0 && duration_s > 0.0);
        let q = epoch_s / 4.0;
        // Enumerate every k with k·q inside the trace. The effective epoch
        // is clamped to duration/96, so this is at most a few hundred
        // counters.
        let mut n_windows = 0usize;
        while (n_windows as f64) * q < duration_s {
            n_windows += 1;
        }
        PeakGrid { epoch_s, q, counts: vec![0usize; n_windows] }
    }

    pub(crate) fn len(&self) -> usize {
        self.counts.len()
    }

    /// Count arrival `a` into every grid window containing it, invoking
    /// `hit(k)` per member window (the fused pass hangs its per-window
    /// histograms off this callback; the plain peak scan passes a no-op).
    pub(crate) fn observe(&mut self, a: f64, mut hit: impl FnMut(usize)) {
        let n_windows = self.counts.len();
        // Guarded index range: derive candidates by division, confirm
        // membership against the exact k·q edges.
        let k_hi = ((a / self.q) as usize).min(n_windows.saturating_sub(1));
        let k_lo = (((a - self.epoch_s) / self.q).floor().max(0.0)) as usize;
        for k in k_lo.saturating_sub(1)..=(k_hi + 1).min(n_windows - 1) {
            let t_k = k as f64 * self.q;
            if t_k <= a && a < t_k + self.epoch_s {
                self.counts[k] += 1;
                hit(k);
            }
        }
    }

    /// First strictly-maximal window index and its count.
    pub(crate) fn best_index(&self) -> (usize, usize) {
        let mut best_k = 0usize;
        let mut best_n = 0usize;
        for (k, &n) in self.counts.iter().enumerate() {
            if n > best_n {
                best_n = n;
                best_k = k;
            }
        }
        (best_k, best_n)
    }

    /// First strictly-maximal window: `(t_lo, t_hi, count)`; `count == 0`
    /// means no arrivals were observed.
    pub(crate) fn best(&self) -> (f64, f64, usize) {
        let (best_k, best_n) = self.best_index();
        let t_lo = best_k as f64 * self.q;
        (t_lo, t_lo + self.epoch_s, best_n)
    }

    /// Sum the partial grid into this one (integer adds; order-free).
    pub(crate) fn merge(&mut self, other: &PeakGrid) {
        debug_assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }
}

/// Everything the planning layer needs from one walk of the demand
/// stream: the peak grid with per-window slice histograms, every schedule
/// epoch's trailing-window histogram, and plain quarter-epoch chunk
/// counts (the event resolution of the Benders interval sweep). Memory is
/// O(windows × buckets) — a few hundred KiB — independent of trace
/// length.
#[derive(Debug, Clone)]
pub struct DemandProfile {
    /// Effective re-plan period (already clamped by the caller).
    pub epoch_s: f64,
    /// Observation window (resolved: never 0).
    pub window_s: f64,
    pub duration_s: f64,
    grid: PeakGrid,
    /// Per grid-window slice histograms (same membership as `grid`).
    grid_accums: Vec<SliceAccum>,
    /// `epoch_accums[k-1]`: arrivals in `[t_k - w_k, t_k)` for schedule
    /// epoch `k`, under the old sliding-buffer float semantics.
    epoch_accums: Vec<SliceAccum>,
    /// Arrivals per quarter-epoch chunk `[j·q, (j+1)·q)` — the demand
    /// events the interval-cut sweep runs over.
    chunk_counts: Vec<usize>,
    total: usize,
}

impl DemandProfile {
    fn empty(epoch_s: f64, window_s: f64, duration_s: f64) -> DemandProfile {
        let grid = PeakGrid::new(epoch_s, duration_s);
        let n_windows = grid.len();
        // Schedule epochs: k = 1 while k·epoch < duration (same loop bound
        // as the rolling-horizon controller).
        let mut n_epochs = 0usize;
        while ((n_epochs + 1) as f64) * epoch_s < duration_s {
            n_epochs += 1;
        }
        DemandProfile {
            epoch_s,
            window_s,
            duration_s,
            grid,
            grid_accums: vec![SliceAccum::new(); n_windows],
            epoch_accums: vec![SliceAccum::new(); n_epochs],
            chunk_counts: vec![0usize; n_windows],
            total: 0,
        }
    }

    /// Build the profile in one pass over `source`. `window_s == 0` means
    /// one epoch, mirroring [`super::horizon::HorizonConfig::window_s`].
    pub fn build(source: &mut dyn ArrivalSource, epoch_s: f64,
                 window_s: f64, duration_s: f64) -> DemandProfile {
        let window_s = if window_s > 0.0 { window_s } else { epoch_s };
        let mut p = DemandProfile::empty(epoch_s, window_s, duration_s);
        while let Some(r) = source.next_request() {
            p.ingest(&r);
        }
        p
    }

    /// Build the profile sharded across up to `threads` worker threads.
    /// Worker `w` walks its own fresh stream and keeps arrivals with
    /// sequence index ≡ w (mod workers); the partial profiles merge in
    /// ascending worker index. Every histogram is integer counts, so the
    /// result is byte-identical to [`DemandProfile::build`] for any
    /// worker count.
    pub fn build_sharded<'a>(
        fresh: &(dyn Fn() -> Box<dyn ArrivalSource + 'a> + Sync),
        threads: usize, epoch_s: f64, window_s: f64, duration_s: f64,
    ) -> DemandProfile {
        let window_s = if window_s > 0.0 { window_s } else { epoch_s };
        let workers = threads.max(1);
        if workers == 1 {
            return DemandProfile::build(&mut *fresh(), epoch_s, window_s,
                                        duration_s);
        }
        let parts = crate::sim::shard::parallel_slots(workers, workers, |me| {
            let mut part = DemandProfile::empty(epoch_s, window_s, duration_s);
            let mut src = fresh();
            let mut seq = 0usize;
            while let Some(r) = src.next_request() {
                if seq % workers == me {
                    part.ingest(&r);
                }
                seq += 1;
            }
            part
        });
        let mut it = parts.into_iter();
        let mut merged = it.next().expect("at least one worker");
        for p in it {
            merged.merge(&p);
        }
        merged
    }

    fn ingest(&mut self, r: &Request) {
        let a = r.arrival_s;
        let (c, p, o) = SliceAccum::bucket(r);

        // 1. Peak grid + per-window histograms (shared membership).
        let accums = &mut self.grid_accums;
        self.grid.observe(a, |k| accums[k].push_bucket(c, p, o));

        // 2. Quarter-epoch chunk counts (guarded index).
        let q = self.epoch_s / 4.0;
        let n_chunks = self.chunk_counts.len();
        let mut j = ((a / q) as usize).min(n_chunks - 1);
        while j > 0 && (j as f64) * q > a {
            j -= 1;
        }
        while j + 1 < n_chunks && ((j + 1) as f64) * q <= a {
            j += 1;
        }
        self.chunk_counts[j] += 1;

        // 3. Schedule-epoch trailing windows. Epoch k observes
        // [t_k - w_k, t_k) with t_k = k·epoch and w_k = window.min(t_k);
        // an arrival near an ulp-misaligned boundary can fall in zero or
        // several epochs, and with window > epoch it falls in many. Find
        // the first epoch with t_k > a by guarded division, then walk
        // while the (nondecreasing) lower edge still admits `a`.
        let n_epochs = self.epoch_accums.len();
        let mut k = ((a / self.epoch_s) as usize).max(1);
        while k > 1 && ((k - 1) as f64) * self.epoch_s > a {
            k -= 1;
        }
        while k <= n_epochs && (k as f64) * self.epoch_s <= a {
            k += 1;
        }
        while k <= n_epochs {
            let t_k = k as f64 * self.epoch_s;
            let w = self.window_s.min(t_k);
            // Exact lower-edge expression of the old sliding buffer's pop
            // test (`arrival < t_k - w` evicted): admitted iff NOT below.
            if a < t_k - w {
                break;
            }
            self.epoch_accums[k - 1].push_bucket(c, p, o);
            k += 1;
        }

        self.total += 1;
    }

    /// Sum another (modulo-partitioned) partial profile into this one.
    pub fn merge(&mut self, other: &DemandProfile) {
        debug_assert_eq!(self.epoch_accums.len(), other.epoch_accums.len());
        self.grid.merge(&other.grid);
        for (a, b) in self.grid_accums.iter_mut().zip(&other.grid_accums) {
            a.merge(b);
        }
        for (a, b) in self.epoch_accums.iter_mut().zip(&other.epoch_accums) {
            a.merge(b);
        }
        for (a, b) in self.chunk_counts.iter_mut().zip(&other.chunk_counts) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Total arrivals observed.
    pub fn total(&self) -> usize {
        self.total
    }

    /// The busiest epoch-sized window: `(t_lo, t_hi, count)` — identical
    /// to what [`super::horizon::peak_window_over`] returns on the same
    /// stream (shared [`PeakGrid`], first strict max wins ties).
    pub fn peak(&self) -> (f64, f64, usize) {
        self.grid.best()
    }

    /// Slice histogram of the peak window (empty when the stream was).
    pub fn peak_accum(&self) -> SliceAccum {
        let (best_k, n) = self.grid.best_index();
        if n == 0 {
            return SliceAccum::new();
        }
        self.grid_accums[best_k].clone()
    }

    /// Number of schedule epochs (`k` runs `1..=epochs()`).
    pub fn epochs(&self) -> usize {
        self.epoch_accums.len()
    }

    /// Trailing-window histogram of schedule epoch `k` (1-based).
    pub fn epoch_accum(&self, k: usize) -> &SliceAccum {
        &self.epoch_accums[k - 1]
    }

    /// Quarter-epoch chunk arrival rates (req/s) overlapping
    /// `[t_lo, t_hi)`, as `(chunk_start_s, rate)` events for the interval
    /// sweep. Chunk resolution, not request resolution — the cut layer is
    /// a capacity model, not a bitwise one.
    pub fn chunk_rates(&self, t_lo: f64, t_hi: f64) -> Vec<(f64, f64)> {
        let q = self.epoch_s / 4.0;
        let mut out = Vec::new();
        for (j, &n) in self.chunk_counts.iter().enumerate() {
            let start = j as f64 * q;
            if start + q <= t_lo || start >= t_hi {
                continue;
            }
            out.push((start, n as f64 / q));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::horizon::peak_window_over;
    use crate::workload::{generate_trace, Arrivals, LengthDist, RequestClass,
                          SliceSource};

    fn trace(duration_s: f64, seed: u64) -> Vec<Request> {
        generate_trace(
            Arrivals::Step { base: 2.0, surge: 14.0, start_frac: 0.5,
                             end_frac: 0.7 },
            LengthDist::ShareGpt, RequestClass::Online, duration_s, seed)
    }

    /// The fused grid and the standalone peak scan share one PeakGrid, but
    /// pin the equality anyway — it is the contract the scenario layer
    /// relies on when it swaps three passes for one.
    #[test]
    fn fused_peak_matches_peak_window_over() {
        for seed in [3u64, 17, 40] {
            let tr = trace(300.0, seed);
            let p = DemandProfile::build(&mut SliceSource::new(&tr), 20.0,
                                         0.0, 300.0);
            let sep = peak_window_over(&mut SliceSource::new(&tr), 20.0, 300.0);
            let fused = p.peak();
            assert_eq!(fused.2, sep.2);
            assert_eq!(fused.0.to_bits(), sep.0.to_bits());
            assert_eq!(fused.1.to_bits(), sep.1.to_bits());
        }
    }

    /// Epoch histograms must match a literal re-implementation of the old
    /// sliding-buffer walk, byte for byte.
    #[test]
    fn fused_epoch_accums_match_sliding_buffer() {
        use std::collections::VecDeque;
        for (window_s, seed) in [(0.0, 5u64), (45.0, 6), (200.0, 7)] {
            let duration = 300.0;
            let epoch = 15.0;
            let tr = trace(duration, seed);
            let p = DemandProfile::build(&mut SliceSource::new(&tr), epoch,
                                         window_s, duration);
            let window = if window_s > 0.0 { window_s } else { epoch };

            let mut src = SliceSource::new(&tr);
            let mut buf: VecDeque<Request> = VecDeque::new();
            let mut lookahead = src.next_request();
            let mut k = 1usize;
            while (k as f64) * epoch < duration {
                let t_k = k as f64 * epoch;
                let w = window.min(t_k);
                while let Some(r) = lookahead.take() {
                    if r.arrival_s < t_k {
                        buf.push_back(r);
                        lookahead = src.next_request();
                    } else {
                        lookahead = Some(r);
                        break;
                    }
                }
                while buf.front().is_some_and(|r| r.arrival_s < t_k - w) {
                    buf.pop_front();
                }
                let mut acc = SliceAccum::new();
                for r in &buf {
                    acc.push(r);
                }
                assert_eq!(&acc, p.epoch_accum(k),
                           "epoch {k} diverged (window {window_s})");
                k += 1;
            }
            assert_eq!(p.epochs(), k - 1);
        }
    }

    /// Sharded build is byte-identical to the single-threaded build for
    /// any worker count.
    #[test]
    fn sharded_build_is_worker_count_invariant() {
        let tr = trace(300.0, 9);
        let single = DemandProfile::build(&mut SliceSource::new(&tr), 20.0,
                                          60.0, 300.0);
        for threads in [2usize, 3, 8] {
            let fresh = || {
                Box::new(SliceSource::new(&tr)) as Box<dyn ArrivalSource + '_>
            };
            let sharded = DemandProfile::build_sharded(&fresh, threads, 20.0,
                                                       60.0, 300.0);
            assert_eq!(sharded.total(), single.total());
            assert_eq!(sharded.peak(), single.peak());
            assert_eq!(sharded.peak_accum(), single.peak_accum());
            for k in 1..=single.epochs() {
                assert_eq!(sharded.epoch_accum(k), single.epoch_accum(k),
                           "epoch {k} diverged at {threads} workers");
            }
            assert_eq!(sharded.chunk_rates(0.0, 300.0),
                       single.chunk_rates(0.0, 300.0));
        }
    }

    #[test]
    fn chunk_rates_cover_the_surge() {
        let tr = trace(400.0, 11);
        let p = DemandProfile::build(&mut SliceSource::new(&tr), 20.0, 0.0,
                                     400.0);
        // Rates over the surge [200, 280) should dominate the quiet head.
        let quiet: f64 = p.chunk_rates(0.0, 100.0).iter()
            .map(|(_, r)| *r).sum::<f64>()
            / p.chunk_rates(0.0, 100.0).len() as f64;
        let surge: f64 = p.chunk_rates(210.0, 270.0).iter()
            .map(|(_, r)| *r).sum::<f64>()
            / p.chunk_rates(210.0, 270.0).len() as f64;
        assert!(surge > 3.0 * quiet, "surge {surge} quiet {quiet}");
    }
}
