//! Fig 14: effective component age vs deployment time at cloud utilization.
use ecoserve::carbon::reliability::{cpu_effective_age, max_safe_host_lifetime,
                                    ssd_effective_age};
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 14: effective age vs deployment time (20% utilization) ==");
    let mut t = Table::new(&["deployed years", "CPU eff. age", "SSD eff. age"]);
    for y in [1.0, 2.0, 3.0, 5.0, 7.0, 9.0] {
        t.row(&[fnum(y), fnum(cpu_effective_age(y, 0.2)),
                fnum(ssd_effective_age(y, 0.2))]);
    }
    t.print();
    println!("max safe host lifetime @20% util: {} years",
             fnum(max_safe_host_lifetime(0.2, 5.0, 2.5)));
    println!("(paper calibration: 5y @ 20% -> CPU ages 0.8y, SSD 1y)");
}
