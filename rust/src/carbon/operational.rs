//! Operational carbon: power × time × grid CI, plus task-level total-carbon
//! accounting (paper §3, the CF_task equation):
//!
//!   CF_task = (P_host + P_gpu)·t·CI + CF_emb_host·t/LT + CF_emb_gpu·t/LT

use super::intensity::CiTrace;

/// Joules → kWh.
pub fn j_to_kwh(joules: f64) -> f64 {
    joules / 3.6e6
}

/// Operational carbon (kgCO₂e) of drawing `power_w` for `dur_s` seconds at
/// a flat CI (gCO₂e/kWh).
pub fn op_kg(power_w: f64, dur_s: f64, ci_g_per_kwh: f64) -> f64 {
    op_kg_from_joules(power_w * dur_s, ci_g_per_kwh)
}

/// Operational carbon (kgCO₂e) of an energy draw at a flat CI — the
/// energy-first form of [`op_kg`] for accounting paths that track joules
/// directly (no fictitious `op_kg(1.0, e, ci)` power×time factoring).
pub fn op_kg_from_joules(energy_j: f64, ci_g_per_kwh: f64) -> f64 {
    j_to_kwh(energy_j) * ci_g_per_kwh / 1000.0
}

/// Operational carbon integrating a CI trace from `t0_s` for `dur_s`.
pub fn op_kg_traced(power_w: f64, t0_s: f64, dur_s: f64, trace: &CiTrace) -> f64 {
    if dur_s <= 0.0 {
        return 0.0;
    }
    // Integrate at the trace resolution.
    let step = trace.step_s.min(dur_s);
    let n = (dur_s / step).ceil() as usize;
    let mut kg = 0.0;
    for i in 0..n {
        let t = t0_s + i as f64 * step;
        let dt = step.min(dur_s - i as f64 * step);
        kg += op_kg(power_w, dt, trace.at(t));
    }
    kg
}

/// Amortized embodied carbon (kgCO₂e) attributed to a task of `dur_s`
/// seconds on hardware with total embodied `emb_kg` and lifetime `lt_years`.
pub fn amortized_emb_kg(emb_kg: f64, dur_s: f64, lt_years: f64) -> f64 {
    emb_kg * dur_s / (lt_years * 365.25 * 86_400.0)
}

/// Task-level total carbon (the paper's CF_task).
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskCarbon {
    pub op_kg: f64,
    pub emb_host_kg: f64,
    pub emb_gpu_kg: f64,
}

impl TaskCarbon {
    pub fn total(&self) -> f64 {
        self.op_kg + self.emb_host_kg + self.emb_gpu_kg
    }
}

/// Compute CF_task for a workload segment.
#[allow(clippy::too_many_arguments)]
pub fn task_carbon(
    p_host_w: f64,
    p_gpu_w: f64,
    dur_s: f64,
    ci: f64,
    emb_host_kg: f64,
    emb_gpu_kg: f64,
    lt_host_years: f64,
    lt_gpu_years: f64,
) -> TaskCarbon {
    TaskCarbon {
        op_kg: op_kg(p_host_w + p_gpu_w, dur_s, ci),
        emb_host_kg: amortized_emb_kg(emb_host_kg, dur_s, lt_host_years),
        emb_gpu_kg: amortized_emb_kg(emb_gpu_kg, dur_s, lt_gpu_years),
    }
}

/// Utilization-dependent device power: idle + (tdp − idle)·util^γ.
/// γ < 1 models poor energy proportionality (paper §6.3: "the CPU's lack of
/// energy proportionality"); γ = 1 is linear.
pub fn device_power(idle_w: f64, tdp_w: f64, util: f64, gamma: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    idle_w + (tdp_w - idle_w) * u.powf(gamma)
}

/// Default non-proportionality exponents.
pub const GPU_POWER_GAMMA: f64 = 0.85;
pub const CPU_POWER_GAMMA: f64 = 0.5;

// ---------------------------------------------------------------------------
// The one shared power model. Every operational-energy number in the
// system — the roofline's per-batch draw, the simulator's idle floor,
// the planner's marginal/idle objective columns — routes through the
// functions below, so the ILP optimizes the exact energy landscape the
// simulator meters. (`carbon` sits below `perf`/`planner`/`sim` in the
// module DAG, so the helpers take scalars, not device structs.)

/// Execution phase of an inference batch. Prefill is compute-bound,
/// decode memory-bound — the per-phase frequency knob exploits that
/// asymmetry ("Towards Sustainable LLM Serving").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// The utilization the planner prices capacity at: provisioned devices
/// are assumed to run at this operating point when loaded. Shared with
/// the parity tests so sim-vs-planner comparisons use one constant.
pub const PLANNING_UTIL: f64 = 0.8;

/// Dynamic (above-idle) device power at a utilization point.
pub fn dynamic_power(idle_w: f64, tdp_w: f64, util: f64, gamma: f64) -> f64 {
    device_power(idle_w, tdp_w, util, gamma) - idle_w
}

/// Idle floor of one server = one tensor-parallel group of `tp` devices.
/// The *only* idle-power formula in the system: the simulator's
/// provisioned-idle meter and the planner's idle objective columns both
/// call this, so tp>1 servers are charged identically on both sides.
pub fn idle_power(idle_w: f64, tp: usize) -> f64 {
    idle_w * tp as f64
}

/// Busy power of one server (`tp` devices) at utilization `util` with a
/// per-phase frequency scale. `freq_scale` models DVFS: dynamic power
/// scales ~f³ while (in the roofline) latency scales 1/f, so energy per
/// token moves ~f². `freq_scale = 1.0` is bit-identical to the unscaled
/// curve.
pub fn server_power(idle_w: f64, tdp_w: f64, util: f64, gamma: f64,
                    freq_scale: f64, tp: usize) -> f64 {
    (idle_w + (tdp_w - idle_w) * util.clamp(0.0, 1.0).powf(gamma)
         * freq_scale.powi(3))
        * tp as f64
}

/// Energy (J) of holding `power_w` for `dur_s` — the busy-period
/// integrand `begin_busy` meters, kept here so sim and planner share the
/// whole chain from curve to joules.
pub fn busy_energy_j(power_w: f64, dur_s: f64) -> f64 {
    power_w * dur_s
}

/// kgCO₂e per hour of drawing `power_w` at a flat CI — the planner's
/// objective-column unit (W → kW, g → kg).
pub fn op_kg_per_hr(power_w: f64, ci_g_per_kwh: f64) -> f64 {
    power_w / 1000.0 * ci_g_per_kwh / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::intensity::{CiTrace, Region};

    #[test]
    fn one_kwh_at_unit_ci() {
        // 1000 W for 1 hour at 1000 g/kWh = 1 kg.
        assert!((op_kg(1000.0, 3600.0, 1000.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn joules_form_matches_power_time_form() {
        assert!((op_kg_from_joules(3.6e6, 1000.0) - 1.0).abs() < 1e-12);
        let e = 12_345.6;
        assert!((op_kg_from_joules(e, 261.0) - op_kg(1.0, e, 261.0)).abs() < 1e-15);
    }

    #[test]
    fn traced_matches_flat_for_flat_trace() {
        let tr = CiTrace::flat(Region::California, 1, 900.0);
        let a = op_kg_traced(500.0, 0.0, 7200.0, &tr);
        let b = op_kg(500.0, 7200.0, 261.0);
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn amortization_full_lifetime() {
        // Using hardware for its whole lifetime attributes all of it.
        let lt_s = 4.0 * 365.25 * 86_400.0;
        assert!((amortized_emb_kg(100.0, lt_s, 4.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn task_carbon_totals() {
        let tc = task_carbon(300.0, 400.0, 3600.0, 261.0, 800.0, 120.0, 4.0, 4.0);
        assert!((tc.op_kg - op_kg(700.0, 3600.0, 261.0)).abs() < 1e-12);
        assert!(tc.emb_host_kg > tc.emb_gpu_kg); // 800 vs 120 kg amortized
        assert!(tc.total() > 0.0);
    }

    #[test]
    fn embodied_dominates_in_clean_grids() {
        // Fig 6: at low CI, embodied > operational; at high CI, reversed.
        let mk = |ci: f64| task_carbon(300.0, 400.0, 3600.0, ci, 800.0, 120.0, 4.0, 4.0);
        let clean = mk(17.0);
        let dirty = mk(501.0);
        assert!(clean.emb_host_kg + clean.emb_gpu_kg > clean.op_kg);
        assert!(dirty.op_kg > dirty.emb_host_kg + dirty.emb_gpu_kg);
    }

    #[test]
    fn server_power_reduces_to_device_power_at_defaults() {
        // freq_scale = 1.0, tp = 1 must be bit-identical to the bare
        // curve — this is what keeps every pre-existing golden stable.
        for util in [0.0, 0.13, 0.5, 0.97, 1.0] {
            let a = server_power(50.0, 400.0, util, GPU_POWER_GAMMA, 1.0, 1);
            let b = device_power(50.0, 400.0, util, GPU_POWER_GAMMA);
            assert_eq!(a.to_bits(), b.to_bits(), "util {util}");
        }
        // tp scales the whole server draw; idle_power is its util-0 line.
        let s4 = server_power(50.0, 400.0, 0.0, GPU_POWER_GAMMA, 1.0, 4);
        assert_eq!(s4.to_bits(), idle_power(50.0, 4).to_bits());
    }

    #[test]
    fn frequency_scaling_moves_only_the_dynamic_term() {
        let lo = server_power(50.0, 400.0, 0.8, GPU_POWER_GAMMA, 0.8, 1);
        let hi = server_power(50.0, 400.0, 0.8, GPU_POWER_GAMMA, 1.0, 1);
        assert!(lo < hi, "downclocking must cut power: {lo} vs {hi}");
        // The idle floor is frequency-independent.
        let idle_lo = server_power(50.0, 400.0, 0.0, GPU_POWER_GAMMA, 0.8, 1);
        assert!((idle_lo - 50.0).abs() < 1e-12);
        // f³ on the dynamic term exactly.
        let dyn_hi = hi - 50.0;
        let dyn_lo = lo - 50.0;
        assert!((dyn_lo - dyn_hi * 0.8f64.powi(3)).abs() < 1e-9);
    }

    #[test]
    fn planner_units_round_trip() {
        // 1 kW for an hour at CI 1000 g/kWh is 1 kg — and the kg/hr
        // column times hours equals the joules-form meter.
        assert!((op_kg_per_hr(1000.0, 1000.0) - 1.0).abs() < 1e-12);
        let p = 732.5;
        let hr = op_kg_per_hr(p, 261.0) * 2.0;
        let metered = op_kg_from_joules(busy_energy_j(p, 7200.0), 261.0);
        assert!((hr - metered).abs() < 1e-12, "{hr} vs {metered}");
        assert!((dynamic_power(50.0, 400.0, 1.0, GPU_POWER_GAMMA) - 350.0)
                    .abs() < 1e-12);
        assert!(PLANNING_UTIL > 0.0 && PLANNING_UTIL <= 1.0);
    }

    #[test]
    fn power_model_monotone_and_bounded() {
        for util in [0.0, 0.2, 0.5, 1.0] {
            let p = device_power(50.0, 400.0, util, GPU_POWER_GAMMA);
            assert!(p >= 50.0 && p <= 400.0);
        }
        // Non-proportionality: 20% util costs far more than 20% of dynamic.
        let p20 = device_power(100.0, 700.0, 0.2, CPU_POWER_GAMMA);
        assert!(p20 - 100.0 > 0.2 * 600.0);
    }
}
