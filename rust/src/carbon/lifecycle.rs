//! Hardware lifecycle / upgrade-schedule modeling (paper §4.1.4 "Recycle",
//! Figs 13/14/21).
//!
//! Models cumulative (embodied + operational) carbon under replacement
//! schedules where hosts and GPUs upgrade on *different* cadences, with GPU
//! energy efficiency doubling every `eff_doubling_years` (paper: 3.5, citing
//! product-data trends).

/// Parameters for an upgrade-schedule study (Fig 21 defaults).
#[derive(Debug, Clone)]
pub struct LifecycleParams {
    /// Host embodied per replacement, kgCO₂e (paper baseline: 800).
    pub host_emb_kg: f64,
    /// GPU embodied per replacement, kgCO₂e (paper baseline: 120).
    pub gpu_emb_kg: f64,
    /// Yearly operational emissions with a generation-0 GPU, kgCO₂e
    /// (paper baseline: 600 total).
    pub op_kg_per_year: f64,
    /// Fraction of operational emissions attributable to the GPU (which
    /// improves with upgrades); the host share stays flat.
    pub gpu_op_fraction: f64,
    /// Years for GPU energy efficiency to double.
    pub eff_doubling_years: f64,
}

impl Default for LifecycleParams {
    fn default() -> Self {
        LifecycleParams {
            host_emb_kg: 800.0,
            gpu_emb_kg: 120.0,
            op_kg_per_year: 600.0,
            gpu_op_fraction: 0.85,
            eff_doubling_years: 3.5,
        }
    }
}

/// Year-by-year carbon under a (host every `host_period`, GPU every
/// `gpu_period`) replacement schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub years: usize,
    pub host_period: usize,
    pub gpu_period: usize,
    /// Per-year embodied emissions (replacement charges), kgCO₂e.
    pub emb_by_year: Vec<f64>,
    /// Per-year operational emissions, kgCO₂e.
    pub op_by_year: Vec<f64>,
}

impl Schedule {
    pub fn cumulative_total(&self) -> f64 {
        self.emb_by_year.iter().sum::<f64>() + self.op_by_year.iter().sum::<f64>()
    }

    pub fn total_by_year(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.emb_by_year
            .iter()
            .zip(&self.op_by_year)
            .map(|(e, o)| {
                acc += e + o;
                acc
            })
            .collect()
    }
}

/// Simulate a replacement schedule over `years`.
pub fn simulate_schedule(
    p: &LifecycleParams,
    years: usize,
    host_period: usize,
    gpu_period: usize,
) -> Schedule {
    assert!(host_period > 0 && gpu_period > 0);
    let op_host = p.op_kg_per_year * (1.0 - p.gpu_op_fraction);
    let op_gpu0 = p.op_kg_per_year * p.gpu_op_fraction;
    let mut emb = vec![0.0; years];
    let mut op = vec![0.0; years];
    let mut gpu_gen_year = 0usize;
    for (y, (e, o)) in emb.iter_mut().zip(op.iter_mut()).enumerate() {
        if y % host_period == 0 {
            *e += p.host_emb_kg;
        }
        if y % gpu_period == 0 {
            *e += p.gpu_emb_kg;
            gpu_gen_year = y;
        }
        // GPU bought in year g is 2^(g/T) more efficient than gen-0.
        let eff = 2f64.powf(gpu_gen_year as f64 / p.eff_doubling_years);
        *o = op_host + op_gpu0 / eff;
    }
    Schedule { years, host_period, gpu_period, emb_by_year: emb, op_by_year: op }
}

/// Fig 21: baseline (both every 4y) vs EcoServe (host 9y, GPU 3y).
pub fn fig21_comparison(p: &LifecycleParams, years: usize) -> (Schedule, Schedule) {
    (
        simulate_schedule(p, years, 4, 4),
        simulate_schedule(p, years, 9, 3),
    )
}

/// Optimal GPU usage duration (years) before an upgrade pays back, as a
/// function of CI — the Fig 13 question. A replacement's embodied cost
/// `gpu_emb_kg` is recouped by the op savings of a 2^(T/3.5)× more
/// efficient card; returns the break-even holding time.
pub fn optimal_gpu_holding_years(p: &LifecycleParams, ci_scale: f64) -> f64 {
    // Search holding periods 1..=12y for min average yearly carbon.
    let op_gpu0 = p.op_kg_per_year * p.gpu_op_fraction * ci_scale;
    let mut best = (f64::INFINITY, 1usize);
    for hold in 1..=12usize {
        // Steady-state: each generation is 2^(hold/T) better than the last;
        // geometric improvement means long-run average per-cycle op equals
        // op of the current gen; approximate with first two cycles.
        let eff1 = 2f64.powf(hold as f64 / p.eff_doubling_years);
        let cycle_op = (0..hold).map(|_| op_gpu0).sum::<f64>()
            + (0..hold).map(|_| op_gpu0 / eff1).sum::<f64>();
        let avg = (2.0 * p.gpu_emb_kg + cycle_op) / (2.0 * hold as f64);
        if avg < best.0 {
            best = (avg, hold);
        }
    }
    best.1 as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig21_savings_band() {
        // Paper: asymmetric (host 9y / GPU 3y) saves ≈16% cumulative over
        // 10 years vs fixed 4y/4y.
        let p = LifecycleParams::default();
        let (base, eco) = fig21_comparison(&p, 10);
        let savings = 1.0 - eco.cumulative_total() / base.cumulative_total();
        assert!(savings > 0.10 && savings < 0.25, "savings {savings}");
    }

    #[test]
    fn schedule_charges_on_period() {
        let p = LifecycleParams::default();
        let s = simulate_schedule(&p, 10, 4, 4);
        // Replacements at years 0, 4, 8.
        assert!(s.emb_by_year[0] > 0.0 && s.emb_by_year[4] > 0.0 && s.emb_by_year[8] > 0.0);
        assert_eq!(s.emb_by_year[1], 0.0);
    }

    #[test]
    fn op_decreases_after_gpu_upgrade() {
        let p = LifecycleParams::default();
        let s = simulate_schedule(&p, 10, 9, 3);
        assert!(s.op_by_year[3] < s.op_by_year[2]);
        assert!(s.op_by_year[6] < s.op_by_year[3]);
    }

    #[test]
    fn cumulative_monotone() {
        let p = LifecycleParams::default();
        let s = simulate_schedule(&p, 10, 4, 4);
        let cum = s.total_by_year();
        assert!(cum.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn high_ci_shortens_gpu_holding() {
        // Fig 13: at high CI (operational dominates) upgrades pay back
        // sooner than at low CI.
        let p = LifecycleParams::default();
        let hold_low = optimal_gpu_holding_years(&p, 50.0 / 400.0);
        let hold_high = optimal_gpu_holding_years(&p, 400.0 / 400.0);
        assert!(hold_high <= hold_low, "high {hold_high} low {hold_low}");
        assert!(hold_high >= 2.0 && hold_low <= 12.0);
    }
}
