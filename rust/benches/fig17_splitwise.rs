//! Fig 17: EcoServe vs Splitwise on iso-power deployments across carbon
//! intensity and load (Bloom-176B and Llama-70B).
use ecoserve::carbon::intensity::Region;
use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::sim::{simulate, Router};
use ecoserve::strategies::{fleet_from_plan, sim_config, splitwise_fleet, Strategy};
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::{slo_for, Slo};
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

fn main() {
    println!("== Fig 17: iso-power EcoServe vs Splitwise (2-min traces) ==");
    let mut t = Table::new(&["model", "CI", "load", "splitwise kg", "ecoserve kg",
                             "saving %", "eco TTFT p90", "sw TTFT p90"]);
    for model_name in ["llama-70b", "bloom-176b"] {
        let m = models::llm(model_name).unwrap();
        let slo = slo_for(model_name, false).map(|w| w.slo)
            .unwrap_or(Slo { ttft_s: 20.0, tpot_s: 0.27 });
        for region in Region::low_mid_high() {
            for &(label, rate) in &[("low", 0.4f64), ("high", 1.2)] {
                let tr = generate_trace(Arrivals::Poisson { rate },
                                        LengthDist::AzureCode,
                                        RequestClass::Online, 120.0, 17);
                let slices = cluster_slices(&slice_trace(m, &tr, 120.0, slo, 1));
                let ci = region.avg_ci();
                let eco_plan = Strategy::EcoFull.plan(&slices, ci);
                let eco_fleet = fleet_from_plan(&eco_plan, m, 2048);
                let mut eco_cfg = sim_config(eco_fleet, &eco_plan, ci);
                let eco = simulate(m, &tr, &eco_cfg, slo.ttft_s, slo.tpot_s);

                // Splitwise: iso-power H100 fleet, fixed 3:1 PD split, JSQ.
                let total = eco_plan.total_gpus().max(4);
                let np = (total * 3 / 4).max(1);
                let sw_fleet = splitwise_fleet(m, np, (total - np).max(1), 2048);
                let sw_plan = Strategy::Splitwise.plan(&slices, ci);
                let mut sw_cfg = sim_config(sw_fleet, &sw_plan, ci);
                sw_cfg.router = Router::Jsq;
                let sw = simulate(m, &tr, &sw_cfg, slo.ttft_s, slo.tpot_s);

                eco_cfg.servers.clear();
                sw_cfg.servers.clear();
                t.row(&[model_name.into(), fnum(ci), label.into(),
                        fnum(sw.carbon_kg()), fnum(eco.carbon_kg()),
                        fnum(100.0 * (1.0 - eco.carbon_kg() / sw.carbon_kg())),
                        fnum(eco.ttft.p90()), fnum(sw.ttft.p90())]);
            }
        }
    }
    t.print();
    println!("(gap widens at lower request rate and higher CI — paper §6.2.1)");
}
