//! Metrics collection for the simulator: one [`MetricsSink`] accumulates
//! TTFT/TPOT samples and completion/SLO/deadline counters as the core
//! raises events (instead of the old 13-`&mut`-argument threading), then
//! folds into the final [`SimReport`].
//!
//! Latency percentiles accumulate into fixed-bin log-spaced
//! [`Histogram`]s, not per-sample vectors — O(1) memory at any trace
//! scale, which is what lets the streaming core hold a multi-million
//! request production day without the metrics sink growing with it.

use crate::util::stats::Histogram;

/// Streaming collector the event core and server stepping write into.
#[derive(Debug, Default)]
pub struct MetricsSink {
    pub ttft: Histogram,
    pub tpot: Histogram,
    /// Requests pulled from the arrival stream.
    pub arrivals: usize,
    pub completed: usize,
    pub generated_tokens: usize,
    pub slo_ok: usize,
    pub online_done: usize,
    pub offline_done: usize,
    pub offline_on_time: usize,
    /// Offline requests temporally shifted by the deferral policy.
    pub deferred: usize,
    /// Requests whose prompts were clipped to the sim's context cap.
    pub truncated_prompts: usize,
    /// Discrete events processed (the core's perf currency).
    pub events: usize,
    /// Servers brought online by provisioning events (excludes the
    /// initially-active fleet).
    pub provision_events: usize,
    /// Draining servers that emptied and were decommissioned.
    pub decommission_events: usize,
    /// High-water mark of concurrently live jobs in the arena — the
    /// streaming core's memory bound (set at finish).
    pub peak_live_jobs: usize,
    /// Injected faults that actually hit a live (or booting) server.
    pub faults_injected: usize,
    /// Jobs displaced off a killed server and re-routed or parked.
    pub jobs_rescheduled: usize,
    /// Jobs drained out of the recovery queue after capacity returned.
    pub jobs_recovered: usize,
    /// Total seconds recovered jobs spent parked waiting for capacity.
    pub recovery_wait_s: f64,
}

impl MetricsSink {
    /// Record a finished request.
    pub(crate) fn complete(&mut self, online: bool, slo_hit: bool,
                           on_time: bool, tpot_s: f64) {
        self.tpot.push(tpot_s);
        self.completed += 1;
        if online {
            self.online_done += 1;
            if slo_hit {
                self.slo_ok += 1;
            }
        } else {
            self.offline_done += 1;
            if on_time {
                self.offline_on_time += 1;
            }
        }
    }

    /// Fraction of online requests meeting TTFT+TPOT SLOs (vacuously 1).
    pub fn slo_attainment(&self) -> f64 {
        if self.online_done == 0 {
            1.0
        } else {
            self.slo_ok as f64 / self.online_done as f64
        }
    }

    /// Fraction of offline requests finishing by their deadline
    /// (vacuously 1 when none carry a deadline or none completed).
    pub fn offline_deadline_attainment(&self) -> f64 {
        if self.offline_done == 0 {
            1.0
        } else {
            self.offline_on_time as f64 / self.offline_done as f64
        }
    }

    pub(crate) fn into_report(mut self, sim_duration_s: f64, energy_j: f64,
                              op_kg: f64, emb_kg: f64,
                              per_server: Vec<ServerUsage>) -> SimReport {
        let slo_attainment = self.slo_attainment();
        let offline_deadline_attainment = self.offline_deadline_attainment();
        let provisioned_server_hours =
            per_server.iter().map(|u| u.provisioned_s).sum::<f64>() / 3600.0;
        SimReport {
            ttft: std::mem::take(&mut self.ttft),
            tpot: std::mem::take(&mut self.tpot),
            arrivals: self.arrivals,
            completed: self.completed,
            generated_tokens: self.generated_tokens,
            sim_duration_s,
            energy_j,
            op_kg,
            emb_kg,
            slo_attainment,
            offline_deadline_attainment,
            online_done: self.online_done,
            slo_ok: self.slo_ok,
            offline_done: self.offline_done,
            offline_on_time: self.offline_on_time,
            deferred_requests: self.deferred,
            truncated_prompts: self.truncated_prompts,
            events: self.events,
            provision_events: self.provision_events,
            decommission_events: self.decommission_events,
            peak_live_jobs: self.peak_live_jobs,
            faults_injected: self.faults_injected,
            jobs_rescheduled: self.jobs_rescheduled,
            jobs_recovered: self.jobs_recovered,
            recovery_wait_s: self.recovery_wait_s,
            provisioned_server_hours,
            per_server,
        }
    }
}

/// Per-server usage, for fleet-elasticity observability: how long each
/// server was provisioned (embodied + idle are charged only over this)
/// and how much of that it spent busy.
#[derive(Debug, Clone, Default)]
pub struct ServerUsage {
    pub busy_s: f64,
    pub energy_j: f64,
    /// Total provisioned seconds (sum of provision→decommission
    /// intervals, open intervals closed at the sim horizon).
    pub provisioned_s: f64,
}

/// Simulation outcome.
#[derive(Debug)]
pub struct SimReport {
    pub ttft: Histogram,
    pub tpot: Histogram,
    /// Requests pulled from the arrival stream (== trace length once the
    /// queue drains).
    pub arrivals: usize,
    pub completed: usize,
    pub generated_tokens: usize,
    pub sim_duration_s: f64,
    pub energy_j: f64,
    pub op_kg: f64,
    pub emb_kg: f64,
    /// Fraction of online requests whose TTFT/TPOT met the SLO.
    pub slo_attainment: f64,
    /// Fraction of deadline-carrying offline requests finishing on time
    /// (1.0 when no deadlines are tracked).
    pub offline_deadline_attainment: f64,
    /// Raw attainment counters — kept alongside the ratios so shard
    /// merging recomputes attainment from exact sums instead of averaging
    /// per-shard fractions.
    pub online_done: usize,
    pub slo_ok: usize,
    pub offline_done: usize,
    pub offline_on_time: usize,
    /// Offline requests shifted into a later low-CI release slot.
    pub deferred_requests: usize,
    /// Requests whose prompts were silently clipped to the context cap —
    /// surfaced so sweeps can warn instead of hiding the truncation.
    pub truncated_prompts: usize,
    /// Discrete events processed by the core.
    pub events: usize,
    /// Servers brought online by provisioning events.
    pub provision_events: usize,
    /// Draining servers that emptied and were decommissioned.
    pub decommission_events: usize,
    /// High-water mark of concurrently live jobs — memory is bounded by
    /// this (plus the fleet), never by `arrivals`.
    pub peak_live_jobs: usize,
    /// Injected faults ([`crate::sim::fault`]) that hit a live or booting
    /// server (deaths aimed past the fleet edge or at already-dead
    /// servers don't count).
    pub faults_injected: usize,
    /// Jobs displaced off killed servers and re-routed to survivors (or
    /// parked, when no survivor existed).
    pub jobs_rescheduled: usize,
    /// Jobs that sat in the recovery queue and drained once capacity
    /// returned.
    pub jobs_recovered: usize,
    /// Total seconds recovered jobs spent parked — the latency price of
    /// degrading gracefully instead of dropping work.
    pub recovery_wait_s: f64,
    /// Fleet-wide provisioned server-hours — the base embodied and idle
    /// carbon amortize over (static fleets: n_servers · duration).
    pub provisioned_server_hours: f64,
    /// Per-server busy/energy/provisioned breakdown.
    pub per_server: Vec<ServerUsage>,
}

impl SimReport {
    pub fn carbon_kg(&self) -> f64 {
        self.op_kg + self.emb_kg
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.generated_tokens as f64 / self.sim_duration_s.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainments_are_vacuously_perfect_when_empty() {
        let m = MetricsSink::default();
        assert_eq!(m.slo_attainment(), 1.0);
        assert_eq!(m.offline_deadline_attainment(), 1.0);
    }

    #[test]
    fn complete_routes_counters_by_class() {
        let mut m = MetricsSink::default();
        m.complete(true, true, true, 0.05);
        m.complete(true, false, true, 0.2);
        m.complete(false, false, true, 0.1);
        m.complete(false, false, false, 0.1);
        assert_eq!(m.completed, 4);
        assert_eq!(m.online_done, 2);
        assert_eq!(m.offline_done, 2);
        assert!((m.slo_attainment() - 0.5).abs() < 1e-12);
        assert!((m.offline_deadline_attainment() - 0.5).abs() < 1e-12);
        let usage = vec![
            ServerUsage { busy_s: 4.0, energy_j: 60.0, provisioned_s: 7200.0 },
            ServerUsage { busy_s: 1.0, energy_j: 40.0, provisioned_s: 3600.0 },
        ];
        let r = m.into_report(10.0, 100.0, 0.1, 0.2, usage);
        assert_eq!(r.completed, 4);
        assert!((r.carbon_kg() - 0.3).abs() < 1e-12);
        assert_eq!(r.tpot.len(), 4);
        assert!((r.provisioned_server_hours - 3.0).abs() < 1e-12);
        assert_eq!(r.per_server.len(), 2);
    }
}
