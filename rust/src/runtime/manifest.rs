//! Artifact manifest: parses artifacts/model_config.json (written by
//! aot.py) — model dims, parameter order, available prefill/decode buckets.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub max_seq: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub params: Vec<ParamInfo>,
    /// (batch, seq) prefill buckets, ascending.
    pub prefill_buckets: Vec<(usize, usize)>,
    /// decode batch buckets, ascending.
    pub decode_buckets: Vec<usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let m = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let get = |k: &str| -> Result<usize> {
            m.get(k).and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("missing model.{k}"))
        };
        let model = ModelDims {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            ffn_hidden: get("ffn_hidden")?,
            max_seq: get("max_seq")?,
            pad: get("pad")? as i32,
            bos: get("bos")? as i32,
            eos: get("eos")? as i32,
        };
        let params = j.get("params").and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(|p| -> Result<ParamInfo> {
                Ok(ParamInfo {
                    name: p.get("name").and_then(|n| n.as_str())
                        .ok_or_else(|| anyhow!("param missing name"))?.to_string(),
                    shape: p.get("shape").and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut prefill_buckets: Vec<(usize, usize)> = j.get("prefill_buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| anyhow!("missing prefill_buckets"))?
            .iter()
            .map(|b| -> Result<(usize, usize)> {
                Ok((
                    b.idx(0).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("bad bucket"))?,
                    b.idx(1).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("bad bucket"))?,
                ))
            })
            .collect::<Result<_>>()?;
        prefill_buckets.sort_unstable();
        let mut decode_buckets: Vec<usize> = j.get("decode_buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| anyhow!("missing decode_buckets"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad decode bucket")))
            .collect::<Result<_>>()?;
        decode_buckets.sort_unstable();
        Ok(Manifest { dir: dir.to_path_buf(), model, params, prefill_buckets, decode_buckets })
    }

    pub fn prefill_path(&self, batch: usize, seq: usize) -> PathBuf {
        self.dir.join(format!("prefill_b{batch}_s{seq}.hlo.txt"))
    }

    pub fn decode_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("decode_b{batch}.hlo.txt"))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("weights.bin")
    }

    /// Smallest prefill bucket that fits (batch, prompt_len), if any.
    pub fn pick_prefill_bucket(&self, batch: usize, prompt: usize) -> Option<(usize, usize)> {
        self.prefill_buckets
            .iter()
            .copied()
            .filter(|&(b, s)| b >= batch && s >= prompt)
            .min_by_key(|&(b, s)| (s, b))
    }

    /// KV-cache element count for a decode bucket.
    pub fn kv_numel(&self, batch: usize) -> usize {
        self.model.n_layers * batch * self.model.max_seq
            * self.model.n_kv_heads * self.model.head_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(dir: &Path) {
        std::fs::write(dir.join("model_config.json"), r#"{
            "model": {"vocab":259,"d_model":256,"n_layers":4,"n_heads":8,
                      "n_kv_heads":2,"head_dim":32,"ffn_hidden":512,
                      "max_seq":512,"pad":0,"bos":1,"eos":2},
            "params": [{"name":"embed","shape":[259,256]}],
            "prefill_buckets": [[4,32],[1,32],[1,128]],
            "decode_buckets": [8,1]
        }"#).unwrap();
    }

    #[test]
    fn parses_and_sorts() {
        let dir = std::env::temp_dir().join("ecoserve_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 259);
        assert_eq!(m.prefill_buckets, vec![(1, 32), (1, 128), (4, 32)]);
        assert_eq!(m.decode_buckets, vec![1, 8]);
        assert_eq!(m.kv_numel(8), 4 * 8 * 512 * 2 * 32);
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("ecoserve_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_prefill_bucket(1, 20), Some((1, 32)));
        assert_eq!(m.pick_prefill_bucket(1, 100), Some((1, 128)));
        assert_eq!(m.pick_prefill_bucket(2, 20), Some((4, 32)));
        assert_eq!(m.pick_prefill_bucket(1, 4000), None);
        assert_eq!(m.pick_prefill_bucket(8, 20), None);
    }
}
