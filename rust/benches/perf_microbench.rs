//! Performance microbenches for the real serving stack (EXPERIMENTS.md
//! §Perf): engine prefill/decode step latency, batched-vs-single decode
//! amortization, Pallas-vs-XLA GEMM artifacts, solver and simulator speed.
use ecoserve::bench::{run, BenchConfig};
use ecoserve::runtime::engine::Engine;
use ecoserve::runtime::tokenizer;
use std::path::PathBuf;

fn main() {
    let cfg = BenchConfig::quick();

    // Solver microbench.
    let r = run("milp_assignment_20x6", &cfg, || {
        use ecoserve::solver::*;
        let mut pb = ProblemBuilder::new();
        let bs: Vec<Var> = (0..6).map(|j| pb.var(&format!("b{j}"), 1.0, true)).collect();
        for s in 0..20 {
            let avars: Vec<Var> = (0..6)
                .map(|j| pb.binary(&format!("a{s}_{j}"), (s * j) as f64 * 0.01))
                .collect();
            let terms: Vec<(Var, f64)> = avars.iter().map(|v| (*v, 1.0)).collect();
            pb.eq(&terms, 1.0);
            for (j, a) in avars.iter().enumerate() {
                pb.le(&[(*a, 0.4), (bs[j], -1.0)], 0.0);
            }
        }
        std::hint::black_box(pb.solve(&MilpConfig::default()));
    });
    println!("{}", r.report());

    // Simulator throughput.
    let r = run("sim_2min_trace_8gpus", &cfg, || {
        use ecoserve::models;
        use ecoserve::sim::*;
        use ecoserve::workload::*;
        let m = models::llm("llama-8b").unwrap();
        let tr = generate_trace(Arrivals::Poisson { rate: 4.0 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                120.0, 1);
        let servers = homogeneous_fleet("A100-40", 8, m, 2048);
        let cfg2 = SimConfig::flat(servers, Router::WorkloadAware, 261.0,
                                   vec![0.005; 8]);
        std::hint::black_box(simulate(m, &tr, &cfg2, 0.5, 0.1));
    });
    println!("{}", r.report());

    // Engine benches require artifacts.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("model_config.json").exists() {
        println!("SKIP engine benches: run `make artifacts`");
        return;
    }
    let eng = Engine::load(&dir).expect("engine");
    let prompt = tokenizer::encode("a benchmark prompt for ecoserve");

    let r = run("prefill_b1_s32", &cfg, || {
        std::hint::black_box(eng.prefill(std::slice::from_ref(&prompt)).unwrap());
    });
    println!("{}", r.report());

    for b in eng.decode_buckets().to_vec() {
        let mut cache = eng.empty_cache(b);
        let toks = vec![5i32; b];
        let pos: Vec<i32> = (0..b as i32).map(|i| 40 + i).collect();
        let r = run(&format!("decode_step_b{b}"), &cfg, || {
            std::hint::black_box(
                eng.decode_step(&mut cache, &toks, &pos).unwrap());
        });
        println!("{} | per-seq {}", r.report(),
                 ecoserve::util::table::ftime(r.mean_s / b as f64));
    }
}
