//! Rolling-horizon re-provisioning: the controller that closes the loop
//! between the allocation ILP and the cluster simulator (the paper's
//! periodic pool management, §4.2.2's planner run "at every epoch").
//!
//! At every epoch boundary the controller looks at the demand *observed*
//! over the trailing window (it is causal: nothing ahead of the boundary
//! is visible), re-solves the allocation ILP restricted to the SKUs of
//! the provisioned template fleet with the CI-signal forecast for the
//! next epoch as the planning carbon intensity, and converts the solved
//! fleet into [`FleetSchedule`] provisioning events: servers the new plan
//! no longer needs are drained (they finish in-flight batches, then
//! decommission), previously drained servers are re-provisioned when
//! demand returns (the 4R "Recycle" of still-amortizing hardware).
//!
//! Embodied carbon is charged per provisioned-hour in the simulator, so a
//! right-sized elastic fleet is *visibly* cheaper in total kgCO₂e than a
//! static peak-provisioned one — the cross-stack claim this module exists
//! to reproduce.

use crate::carbon::intensity::CiSignal;
use crate::models::LlmSpec;
use crate::planner::slicing::{cluster_slices, SliceAccum};
use crate::planner::{self, PlanConfig};
use crate::sim::{FleetAction, FleetEvent, FleetSchedule, Role, ServerSpec};
use crate::workload::slo::Slo;
use crate::workload::{ArrivalSource, Request, SliceSource};
use std::collections::{BTreeMap, VecDeque};

/// Controller knobs. All durations are simulated seconds (a compressed
/// trace maps "every 15 real minutes" onto its own time scale).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HorizonConfig {
    /// Re-plan period. Clamped at run time to `[duration/96, duration/2]`
    /// so a schedule always has between 1 and 95 re-plan boundaries.
    pub epoch_s: f64,
    /// Demand observation window; `0` means one epoch.
    pub window_s: f64,
    /// Capacity margin over observed demand (provisioning for the mean of
    /// a window invites SLO misses on its peaks).
    pub headroom: f64,
    /// Never drain the fleet below this many active servers.
    pub min_active: usize,
    /// Branch-and-bound node budget per epoch solve (node-bound, never
    /// wall-clock-bound, to keep schedules deterministic).
    pub milp_nodes: usize,
}

impl Default for HorizonConfig {
    fn default() -> Self {
        HorizonConfig {
            epoch_s: 15.0,
            window_s: 0.0,
            headroom: 1.3,
            min_active: 1,
            milp_nodes: 200,
        }
    }
}

impl HorizonConfig {
    /// The epoch actually used against a trace of `duration_s` seconds.
    pub fn effective_epoch(&self, duration_s: f64) -> f64 {
        assert!(self.epoch_s > 0.0 && duration_s > 0.0,
                "epoch and duration must be positive");
        self.epoch_s.clamp(duration_s / 96.0, duration_s / 2.0)
    }
}

/// The busiest epoch-sized demand window over an arrival stream, found in
/// one pass and O(windows) memory: windows slide at quarter-epoch steps
/// (so a burst straddling an epoch-aligned boundary is not undercounted)
/// and the first strictly-maximal window wins. Returns the window's
/// `(t_lo, t_hi, count)`; `count == 0` means the stream was empty.
pub fn peak_window_over(source: &mut dyn ArrivalSource, epoch_s: f64,
                        duration_s: f64) -> (f64, f64, usize) {
    assert!(epoch_s > 0.0 && duration_s > 0.0);
    let q = epoch_s / 4.0;
    // Window k covers [k·q, k·q + epoch); enumerate every k with k·q
    // inside the trace. The effective epoch is clamped to duration/96, so
    // this is at most a few hundred counters.
    let mut n_windows = 0usize;
    while (n_windows as f64) * q < duration_s {
        n_windows += 1;
    }
    let mut counts = vec![0usize; n_windows];
    while let Some(r) = source.next_request() {
        let a = r.arrival_s;
        // Guarded index range: derive candidates by division, confirm
        // membership against the exact k·q edges.
        let k_hi = ((a / q) as usize).min(n_windows.saturating_sub(1));
        let k_lo = (((a - epoch_s) / q).floor().max(0.0)) as usize;
        for k in k_lo.saturating_sub(1)..=(k_hi + 1).min(n_windows - 1) {
            let t_k = k as f64 * q;
            if t_k <= a && a < t_k + epoch_s {
                counts[k] += 1;
            }
        }
    }
    let mut best_k = 0usize;
    let mut best_n = 0usize;
    for (k, &n) in counts.iter().enumerate() {
        if n > best_n {
            best_n = n;
            best_k = k;
        }
    }
    let t_lo = best_k as f64 * q;
    (t_lo, t_lo + epoch_s, best_n)
}

/// Index range (into an arrival-sorted trace) of the busiest epoch-sized
/// window — what "peak-provisioned" means for the static baseline and for
/// sizing the elastic template fleet. Materialized adapter over
/// [`peak_window_over`]; `(0, len)` when the trace is empty.
pub fn peak_epoch_window(trace: &[Request], epoch_s: f64, duration_s: f64)
    -> (usize, usize) {
    let (t_lo, t_hi, n) = peak_window_over(&mut SliceSource::new(trace),
                                           epoch_s, duration_s);
    if n == 0 {
        return (0, trace.len());
    }
    let lo = trace.partition_point(|r| r.arrival_s < t_lo);
    let hi = trace.partition_point(|r| r.arrival_s < t_hi);
    (lo, hi)
}

/// Build the provisioning schedule for `template` over a materialized
/// trace — a thin adapter over [`plan_schedule_stream`].
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule(model: &'static LlmSpec, trace: &[Request],
                     template: &[ServerSpec], base: &PlanConfig,
                     ci: &CiSignal, slo: Slo, h: &HorizonConfig,
                     duration_s: f64) -> FleetSchedule {
    plan_schedule_stream(model, &mut SliceSource::new(trace), template, base,
                         ci, slo, h, duration_s)
}

/// Build the provisioning schedule for `template` over a streaming
/// arrival source.
///
/// The template is the peak-provisioned fleet (every server the schedule
/// may ever use); the whole template starts active, and from the first
/// epoch boundary on, the observed-demand ILP decides how much of it
/// stays up. The stream is consumed forward, holding only the trailing
/// observation window in memory (≤ rate·window requests — never the whole
/// trace). Deterministic: same inputs, same schedule, independent of
/// thread count (the per-epoch MILP is node-bounded).
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule_stream(model: &'static LlmSpec,
                            source: &mut dyn ArrivalSource,
                            template: &[ServerSpec], base: &PlanConfig,
                            ci: &CiSignal, slo: Slo, h: &HorizonConfig,
                            duration_s: f64) -> FleetSchedule {
    assert!(!template.is_empty(), "empty template fleet");
    let epoch = h.effective_epoch(duration_s);
    let window = if h.window_s > 0.0 { h.window_s } else { epoch };

    // Template servers grouped by SKU (BTreeMap: deterministic order).
    // Within a group, low indices activate first and high indices drain
    // first, so server identity is stable across epochs.
    let mut groups: BTreeMap<&'static str, Vec<usize>> = BTreeMap::new();
    for (i, s) in template.iter().enumerate() {
        if let Some(g) = crate::hw::gpu(&s.device.name) {
            groups.entry(g.name).or_default().push(i);
        }
    }
    assert!(!groups.is_empty(), "template has no catalog GPUs");
    let menu: Vec<&'static str> = groups.keys().copied().collect();

    // Sliding observation window: arrivals in [t_k - w, t_k), ingested
    // forward with one request of lookahead.
    let mut buf: VecDeque<Request> = VecDeque::new();
    let mut lookahead = source.next_request();

    let mut active: Vec<bool> = vec![true; template.len()];
    let mut events = Vec::new();
    let mut k = 1usize;
    while (k as f64) * epoch < duration_s {
        let t_k = k as f64 * epoch;
        k += 1;

        // Observed demand: arrivals in the trailing window (clipped to
        // the elapsed trace so early epochs don't dilute their rates),
        // scaled by the headroom margin.
        let w = window.min(t_k);
        while let Some(r) = lookahead.take() {
            if r.arrival_s < t_k {
                buf.push_back(r);
                lookahead = source.next_request();
            } else {
                lookahead = Some(r);
                break;
            }
        }
        while buf.front().is_some_and(|r| r.arrival_s < t_k - w) {
            buf.pop_front();
        }
        let mut desired: BTreeMap<&'static str, usize> =
            menu.iter().map(|n| (*n, 0)).collect();
        if !buf.is_empty() {
            let mut acc = SliceAccum::new();
            for r in &buf {
                acc.push(r);
            }
            let mut slices = cluster_slices(&acc.slices(model, w, slo, 1));
            for s in &mut slices {
                s.rate *= h.headroom;
            }
            let mut cfg = base.clone();
            cfg.gpu_menu = menu.clone();
            cfg.milp.max_nodes = h.milp_nodes;
            cfg.milp.time_limit = std::time::Duration::from_secs(3600);
            // CI forecast for the next epoch: the planning carbon price.
            cfg.ci = ci.mean_over(t_k, (t_k + epoch).min(duration_s));
            let plan = planner::plan(&slices, &cfg);
            for (name, &gpus) in &plan.counts {
                let Some((sku, idxs)) = groups.get_key_value(name.as_str()) else {
                    continue; // cpu-host reuse consumes no template server
                };
                let tp = template[idxs[0]].tp.max(1);
                desired.insert(*sku, gpus.div_ceil(tp).min(idxs.len()));
            }
        }

        // Desired active set: the first `n` servers of each SKU group.
        let mut want = vec![false; template.len()];
        for (name, idxs) in &groups {
            let n = desired.get(name).copied().unwrap_or(0);
            for &i in idxs.iter().take(n) {
                want[i] = true;
            }
        }
        // Floors: total active count, and at least one prompt-capable
        // server so the routing invariant can never be violated.
        let floor = h.min_active.max(1);
        let mut n_active = want.iter().filter(|w| **w).count();
        for w in want.iter_mut() {
            if n_active >= floor {
                break;
            }
            if !*w {
                *w = true;
                n_active += 1;
            }
        }
        if !want.iter().zip(template).any(|(w, s)| *w && s.role != Role::Decode) {
            let i = template.iter().position(|s| s.role != Role::Decode)
                .expect("template has no prompt-capable server");
            want[i] = true;
        }
        // Symmetric guard for disaggregated templates: prefill handoffs
        // need a decode-capable server too, or decode batches would fall
        // back onto prompt-role hardware.
        if !want.iter().zip(template).any(|(w, s)| *w && s.role != Role::Prompt) {
            if let Some(i) = template.iter().position(|s| s.role != Role::Prompt) {
                want[i] = true;
            }
        }

        // Diff against the running fleet → provisioning events.
        for i in 0..template.len() {
            if want[i] && !active[i] {
                events.push(FleetEvent {
                    t: t_k, server: i, action: FleetAction::Provision,
                });
            } else if !want[i] && active[i] {
                events.push(FleetEvent {
                    t: t_k, server: i, action: FleetAction::Drain,
                });
            }
        }
        active = want;
    }
    FleetSchedule { initially_active: Vec::new(), events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sim::homogeneous_fleet;
    use crate::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

    fn diurnal_trace(duration_s: f64, seed: u64) -> Vec<Request> {
        generate_trace(
            Arrivals::CompressedDiurnal { rate: 10.0, amplitude: 0.7, period_s: 0.0 },
            LengthDist::ShareGpt, RequestClass::Online, duration_s, seed)
    }

    fn controller_inputs() -> (&'static LlmSpec, Vec<ServerSpec>, PlanConfig, Slo) {
        let m = models::llm("llama-8b").unwrap();
        let template = homogeneous_fleet("A100-40", 6, m, 2048);
        let cfg = PlanConfig { cpu_reuse: false, ..Default::default() };
        (m, template, cfg, Slo { ttft_s: 2.0, tpot_s: 0.2 })
    }

    /// Replay a schedule and return the active-server count over time.
    fn replay(template_len: usize, sched: &FleetSchedule) -> Vec<(f64, usize)> {
        let mut active = vec![true; template_len];
        if !sched.initially_active.is_empty() {
            active = sched.initially_active.clone();
        }
        let mut out = vec![(0.0, active.iter().filter(|a| **a).count())];
        for e in &sched.events {
            active[e.server] = e.action == FleetAction::Provision;
            out.push((e.t, active.iter().filter(|a| **a).count()));
        }
        out
    }

    #[test]
    fn peak_window_finds_the_surge() {
        let tr = generate_trace(
            Arrivals::Step { base: 1.0, surge: 20.0, start_frac: 0.5, end_frac: 0.7 },
            LengthDist::ShareGpt, RequestClass::Online, 200.0, 3);
        let (lo, hi) = peak_epoch_window(&tr, 20.0, 200.0);
        assert!(hi > lo);
        // The densest 20 s window lies inside the surge [100, 140).
        assert!(tr[lo].arrival_s >= 100.0 - 1e-9 && tr[hi - 1].arrival_s < 140.0,
                "peak window [{}, {})", tr[lo].arrival_s, tr[hi - 1].arrival_s);
    }

    #[test]
    fn schedule_is_deterministic_and_time_ordered() {
        let (m, template, cfg, slo) = controller_inputs();
        let tr = diurnal_trace(240.0, 11);
        let h = HorizonConfig::default();
        let ci = CiSignal::flat(261.0);
        let a = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        let b = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        assert_eq!(a, b, "same inputs must give the same schedule");
        assert!(a.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn diurnal_demand_scales_the_fleet_down_and_back() {
        let (m, template, cfg, slo) = controller_inputs();
        let tr = diurnal_trace(240.0, 12);
        let h = HorizonConfig { epoch_s: 20.0, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let sched = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        assert!(sched.events.iter().any(|e| e.action == FleetAction::Drain),
                "a 0.7-amplitude diurnal load should shed servers off-peak");
        let counts = replay(template.len(), &sched);
        let min = counts.iter().map(|(_, n)| *n).min().unwrap();
        let max = counts.iter().map(|(_, n)| *n).max().unwrap();
        assert!(min < max, "fleet never resized: min {min} max {max}");
    }

    #[test]
    fn floor_is_never_violated() {
        let (m, template, cfg, slo) = controller_inputs();
        // Nearly idle trace: without the floor the ILP would drain to 0.
        let tr = generate_trace(Arrivals::Poisson { rate: 0.02 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                240.0, 13);
        let h = HorizonConfig { min_active: 2, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let sched = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        for (t, n) in replay(template.len(), &sched) {
            assert!(n >= 2, "active fleet fell to {n} at t={t}");
        }
    }

    #[test]
    fn effective_epoch_clamps() {
        let h = HorizonConfig { epoch_s: 1000.0, ..Default::default() };
        assert_eq!(h.effective_epoch(100.0), 50.0);
        let h = HorizonConfig { epoch_s: 0.1, ..Default::default() };
        assert_eq!(h.effective_epoch(960.0), 10.0);
        let h = HorizonConfig { epoch_s: 15.0, ..Default::default() };
        assert_eq!(h.effective_epoch(180.0), 15.0);
    }
}
