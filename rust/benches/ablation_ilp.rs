//! Ablation (DESIGN.md design-choice): ILP branch-and-bound vs the greedy
//! warm start — solution quality and solve time — and the slice-factor f
//! sweep (finer slices = finer allocation at higher control-plane cost).
use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::planner::{plan, PlanConfig};
use ecoserve::solver::MilpConfig;
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::Slo;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

fn main() {
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate: 20.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            300.0, 21);
    let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };

    println!("== Ablation A: branch-and-bound vs greedy-only ==");
    let slices = cluster_slices(&slice_trace(m, &tr, 300.0, slo, 1));
    let mut t = Table::new(&["solver", "carbon kg/hr", "cost $/hr", "solve s",
                             "nodes"]);
    let full = plan(&slices, &PlanConfig::default());
    t.row(&["greedy+B&B".into(), fnum(full.carbon_kg_per_hr()),
            fnum(full.cost_hr), fnum(full.solve_s), format!("{}", full.nodes)]);
    let greedy_only = plan(&slices, &PlanConfig {
        milp: MilpConfig { max_nodes: 0, ..Default::default() },
        ..Default::default()
    });
    t.row(&["greedy only".into(), fnum(greedy_only.carbon_kg_per_hr()),
            fnum(greedy_only.cost_hr), fnum(greedy_only.solve_s), "0".into()]);
    t.print();
    println!("gap closed by B&B: {:.2}%",
             100.0 * (1.0 - full.carbon_kg_per_hr()
                 / greedy_only.carbon_kg_per_hr()));

    println!("\n== Ablation B: slice factor f (finer-grained allocation) ==");
    let mut t = Table::new(&["f", "slices", "carbon kg/hr", "solve s"]);
    for f in [1usize, 2, 4, 8] {
        let s = slice_trace(m, &tr, 300.0, slo, f);
        let p = plan(&s, &PlanConfig::default());
        t.row(&[format!("{f}"), format!("{}", s.len()),
                fnum(p.carbon_kg_per_hr()), fnum(p.solve_s)]);
    }
    t.print();
    println!("(f>1 buys little here because identical slices cluster; the\n\
              paper uses f for heterogeneous-SLO mixes)");
}
