//! Reuse deep-dive: when should offline decode move to host CPUs?
//! Sweeps model × context × CI and reports the planner's choice plus the
//! CPU-vs-GPU throughput/carbon arithmetic behind it (paper §4.1.1, §6.3).
//!
//! Run: `cargo run --release --example offline_cpu_reuse`

use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::cpu::{decode_throughput, max_batch, CpuStrategy};
use ecoserve::perf::roofline::{decode_throughput as gpu_tput, Device};
use ecoserve::planner::slicing::Slice;
use ecoserve::planner::{plan, Phase, PlanConfig};
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::Slo;

fn main() {
    let spr = hw::cpu("SPR-112").unwrap();
    println!("== CPU-vs-GPU offline decode arithmetic ==");
    let mut t = Table::new(&["model", "ctx", "cpu tok/s (opt)", "gpu tok/s (A100)",
                             "ratio"]);
    for model_name in ["gemma-2b", "llama-8b", "gemma-27b"] {
        let m = models::llm(model_name).unwrap();
        let dev = Device::from_gpu(hw::gpu("A100-40").unwrap());
        for &ctx in &[512usize, 2048, 8192] {
            let cb = max_batch(m, 512.0, ctx).clamp(1, 512);
            let cpu = decode_throughput(m, spr, cb, ctx, CpuStrategy::Optimized);
            let mut tp = 1usize;
            while m.max_batch(dev.mem_gb, ctx, tp) == 0 && tp < 8 { tp *= 2; }
            let gb = m.max_batch(dev.mem_gb, ctx, tp).max(1);
            let gpu = gpu_tput(m, &dev, gb, ctx, tp);
            t.row(&[model_name.into(), format!("{ctx}"), fnum(cpu), fnum(gpu),
                    fnum(cpu / gpu)]);
        }
    }
    t.print();

    println!("\n== planner decisions: offline decode placement ==");
    let m = models::llm("llama-8b").unwrap();
    let mut t = Table::new(&["ctx", "CI", "decode device", "carbon kg/hr"]);
    for &ctx in &[512usize, 2048, 8192] {
        for &ci in &[17.0f64, 261.0, 501.0] {
            let slices = vec![
                Slice { model: m, rate: 4.0, prompt: 256, output: 128,
                        slo: Slo { ttft_s: 0.5, tpot_s: 0.1 }, offline: false },
                Slice { model: m, rate: 2.0, prompt: ctx, output: 256,
                        slo: Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY },
                        offline: true },
            ];
            let p = plan(&slices, &PlanConfig { ci, ..Default::default() });
            let dev = p.assignments.iter()
                .find(|a| a.slice_idx == 1 && a.phase == Phase::Decode)
                .map(|a| a.device.clone()).unwrap_or_default();
            t.row(&[format!("{ctx}"), fnum(ci), dev, fnum(p.carbon_kg_per_hr())]);
        }
    }
    t.print();
    println!("(long context + clean grid -> host-CPU reuse)");
}
