//! # EcoServe — carbon-aware AI inference systems
//!
//! Reproduction of "EcoServe: Designing Carbon-Aware AI Inference Systems"
//! (CS.DC 2025) as a three-layer Rust + JAX + Pallas serving stack:
//! Layer 1/2 (Pallas kernels + JAX model) are AOT-lowered to HLO text at
//! build time; Layer 3 (this crate) owns the request path, the carbon and
//! performance models, the 4R strategies, the ILP planner, and the cluster
//! simulator. See DESIGN.md for the system inventory and experiment index.

pub mod bench;
pub mod carbon;
pub mod config;
pub mod coordinator;
pub mod runtime;
pub mod hw;
pub mod models;
pub mod obs;
pub mod planner;
pub mod perf;
pub mod scenarios;
pub mod workload;
pub mod sim;
pub mod solver;
pub mod strategies;
pub mod testkit;
pub mod util;

pub fn version() -> &'static str { env!("CARGO_PKG_VERSION") }
