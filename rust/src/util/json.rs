//! Minimal JSON substrate (no serde in the offline vendor set).
//!
//! Parses the artifact manifest (`model_config.json`), serving configs, and
//! serializes experiment reports. Supports the full JSON grammar; numbers
//! are f64 (i64-exact integers round-trip via `as_i64`).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 2f64.powi(53) => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ------------------------------------------------------

    pub fn obj() -> Json { Json::Obj(BTreeMap::new()) }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }
}

impl From<f64> for Json { fn from(x: f64) -> Json { Json::Num(x) } }
impl From<i64> for Json { fn from(x: i64) -> Json { Json::Num(x as f64) } }
impl From<usize> for Json { fn from(x: usize) -> Json { Json::Num(x as f64) } }
impl From<bool> for Json { fn from(x: bool) -> Json { Json::Bool(x) } }
impl From<&str> for Json { fn from(x: &str) -> Json { Json::Str(x.to_string()) } }
impl From<String> for Json { fn from(x: String) -> Json { Json::Str(x) } }
impl From<Vec<Json>> for Json { fn from(x: Vec<Json>) -> Json { Json::Arr(x) } }

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 { write!(f, ",")?; }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 { write!(f, ",")?; }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> { self.b.get(self.pos).copied() }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') { self.pos += 1; }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) { self.pos += 1; }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) { self.pos += 1; }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
                   Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("x", 1i64).set("y", "z");
        assert_eq!(j.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn parses_real_model_config_shape() {
        let src = r#"{"model":{"vocab":259,"d_model":256},
                      "params":[{"name":"embed","shape":[259,256]}],
                      "decode_buckets":[1,4,8]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("model").unwrap().get("vocab").unwrap().as_usize(), Some(259));
        let p = j.get("params").unwrap().idx(0).unwrap();
        assert_eq!(p.get("shape").unwrap().idx(0).unwrap().as_usize(), Some(259));
    }
}
