//! Tiny CLI argument parser substrate (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args,
//! and subcommands (first positional). Typed getters with defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit argv slice (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn positional(&self) -> &[String] { &self.positional }

    pub fn has(&self, key: &str) -> bool { self.flags.contains_key(key) }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_kv_both_styles() {
        let a = args(&["--rate", "2.5", "--model=llama-8b"]);
        assert_eq!(a.f64("rate", 0.0), 2.5);
        assert_eq!(a.str("model", ""), "llama-8b");
    }

    #[test]
    fn bool_flags() {
        let a = args(&["--verbose", "--offline"]);
        assert!(a.bool("verbose") && a.bool("offline"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn subcommand_and_positional() {
        let a = args(&["serve", "--port", "8080", "extra"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.positional(), &["serve".to_string(), "extra".to_string()]);
        assert_eq!(a.usize("port", 0), 8080);
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.f64("x", 1.25), 1.25);
        assert_eq!(a.str("y", "d"), "d");
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = args(&["--a", "--b", "v"]);
        assert!(a.bool("a"));
        assert_eq!(a.str("b", ""), "v");
    }
}
