//! Periodic pool management (paper §4.2.1): EcoServe "maintains separate
//! resource pools for online, mixed and offline inference ... pool sizes
//! automatically adjust via periodically triggered ILP based on workload
//! demands and carbon intensity."
//!
//! `PoolManager` walks a demand trace (workload::demand) at a fixed
//! reallocation interval (paper: 4 hours), re-solves the allocation for the
//! current online/offline mix, and tracks how much GPU capacity the CPU
//! reuse pool absorbs — the machinery behind Figs 10/11.

use super::slicing::Slice;
use super::{plan_warm, Phase, Plan, PlanConfig, WarmStart};
use crate::models::LlmSpec;
use crate::workload::demand::DemandPoint;
use crate::workload::slo::{Slo, OFFLINE_DEADLINE_S};

/// Pool sizing decision for one reallocation window.
#[derive(Debug, Clone)]
pub struct PoolDecision {
    pub t_s: f64,
    /// Demand (normalized units) in this window.
    pub online_demand: f64,
    pub offline_demand: f64,
    /// Provisioned GPUs by pool.
    pub online_gpus: usize,
    pub offline_gpus: usize,
    /// Raw GPU load (device-equivalents) by pool.
    pub online_gpu_load: f64,
    pub offline_gpu_load: f64,
    /// Offline decode load absorbed by host CPUs (device-equivalents).
    pub cpu_absorbed: f64,
    pub carbon_kg_per_hr: f64,
}

/// Configuration of the periodic re-planner.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Reallocation interval, seconds (paper: 4 h).
    pub interval_s: f64,
    /// Requests/s corresponding to demand 1.0.
    pub rate_scale: f64,
    pub online_slo: Slo,
    /// Representative lengths per class.
    pub online_prompt: usize,
    pub online_output: usize,
    pub offline_prompt: usize,
    pub offline_output: usize,
    /// Slice factor f (paper §4.2.2): subdividing each class's rate lets
    /// the binary assignment put *part* of the offline demand on host CPUs
    /// while the remainder stays on GPUs.
    pub slice_factor: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            interval_s: 4.0 * 3600.0,
            rate_scale: 20.0,
            online_slo: Slo { ttft_s: 0.5, tpot_s: 0.1 },
            online_prompt: 256,
            online_output: 256,
            offline_prompt: 4096,
            offline_output: 512,
            slice_factor: 4,
        }
    }
}

/// Re-plan pools across a demand trace. One ILP solve per window.
pub fn manage_pools(
    model: &'static LlmSpec,
    demand: &[DemandPoint],
    pool_cfg: &PoolConfig,
    plan_cfg: &PlanConfig,
) -> Vec<PoolDecision> {
    let mut out = Vec::new();
    if demand.is_empty() {
        return out;
    }
    let step = demand.get(1).map(|p| p.t_s - demand[0].t_s).unwrap_or(1.0).max(1.0);
    let per_window = (pool_cfg.interval_s / step).ceil() as usize;
    // Consecutive windows often see the same peak demand (flat stretches
    // of the diurnal curve); carry the previous solve across windows so
    // those re-plans are memoized instead of re-solved. Bitwise-neutral:
    // plan_warm reuses only on an exact input match.
    let mut warm: Option<WarmStart> = None;
    for window in demand.chunks(per_window.max(1)) {
        // Plan for the window's PEAK demand (capacity must cover it).
        let online = window.iter().map(|p| p.online).fold(0.0, f64::max);
        let offline = window.iter().map(|p| p.offline).fold(0.0, f64::max);
        let f = pool_cfg.slice_factor.max(1);
        let mut slices = Vec::with_capacity(2 * f);
        for _ in 0..f {
            slices.push(Slice {
                model,
                rate: online * pool_cfg.rate_scale / f as f64,
                prompt: pool_cfg.online_prompt,
                output: pool_cfg.online_output,
                slo: pool_cfg.online_slo,
                offline: false,
            });
            slices.push(Slice {
                model,
                rate: offline * pool_cfg.rate_scale / f as f64,
                prompt: pool_cfg.offline_prompt,
                output: pool_cfg.offline_output,
                slo: Slo { ttft_s: OFFLINE_DEADLINE_S, tpot_s: f64::INFINITY },
                offline: true,
            });
        }
        let p = plan_warm(&slices, plan_cfg, warm.as_ref());
        out.push(decision_from_plan(window[0].t_s, online, offline, &p, &slices));
        warm = Some(WarmStart::new(&slices, plan_cfg, p));
    }
    out
}

fn decision_from_plan(t_s: f64, online: f64, offline: f64, p: &Plan,
                      slices: &[Slice]) -> PoolDecision {
    // Attribute GPUs to pools by each class's share of GPU load.
    let mut online_load = 0.0;
    let mut offline_load = 0.0;
    let mut cpu_absorbed = 0.0;
    for a in &p.assignments {
        if a.device == "cpu-host" {
            cpu_absorbed += a.load;
        } else if slices[a.slice_idx].offline {
            offline_load += a.load;
        } else {
            online_load += a.load;
        }
    }
    let total_load = (online_load + offline_load).max(1e-9);
    let gpus = p.total_gpus();
    let online_gpus = ((online_load / total_load) * gpus as f64).round() as usize;
    PoolDecision {
        t_s,
        online_demand: online,
        offline_demand: offline,
        online_gpus,
        offline_gpus: gpus - online_gpus.min(gpus),
        online_gpu_load: online_load,
        offline_gpu_load: offline_load,
        cpu_absorbed,
        carbon_kg_per_hr: p.carbon_kg_per_hr(),
    }
}

/// Peak offline GPU-pool size across decisions — Fig 11's headline metric:
/// compare with `cpu_reuse` disabled to get the capacity-reduction factor.
pub fn peak_offline_gpus(decisions: &[PoolDecision]) -> usize {
    decisions.iter().map(|d| d.offline_gpus).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::demand::{demand_trace, Service};

    fn run(reuse: bool) -> Vec<PoolDecision> {
        let m = models::llm("llama-8b").unwrap();
        let demand = demand_trace(Service::B, 2, 3600.0, 42);
        let plan_cfg = PlanConfig {
            cpu_reuse: reuse,
            ci: 17.0, // low-CI regime where reuse pays (Fig 16)
            ..PlanConfig::ecoserve(reuse, true, true, true)
        };
        let pool_cfg = PoolConfig {
            offline_prompt: 8192, // long-context offline: the reuse target
            ..Default::default()
        };
        manage_pools(m, &demand, &pool_cfg, &plan_cfg)
    }

    #[test]
    fn windows_cover_trace() {
        let d = run(true);
        // 2 days at 4-hour windows = 12 decisions.
        assert_eq!(d.len(), 12);
        assert!(d.windows(2).all(|w| w[1].t_s > w[0].t_s));
        assert!(d.iter().all(|x| x.carbon_kg_per_hr > 0.0));
    }

    #[test]
    fn pools_track_demand() {
        let d = run(true);
        // The window with the highest online demand carries at least as
        // much online GPU load as the one with the lowest.
        let hi = d.iter().max_by(|a, b| a.online_demand.partial_cmp(&b.online_demand).unwrap()).unwrap();
        let lo = d.iter().min_by(|a, b| a.online_demand.partial_cmp(&b.online_demand).unwrap()).unwrap();
        assert!(hi.online_gpu_load >= lo.online_gpu_load - 1e-9,
                "hi {:?} lo {:?}", hi, lo);
    }

    #[test]
    fn reuse_absorbs_offline_capacity() {
        // Fig 11: with CPU reuse the offline GPU pool shrinks at low CI.
        let with = run(true);
        let without = run(false);
        let absorbed: f64 = with.iter().map(|d| d.cpu_absorbed).sum();
        assert!(absorbed > 0.0, "reuse never engaged");
        // Compare GPU *load* (robust to solver time-limit nondeterminism
        // and integer attribution rounding): reuse must shift offline work
        // off the GPUs.
        let load = |ds: &[PoolDecision]| -> f64 {
            ds.iter().map(|d| d.offline_gpu_load).sum()
        };
        assert!(load(&with) < load(&without) - 1e-6,
                "offline GPU load with {} vs without {}",
                load(&with), load(&without));
        assert!(peak_offline_gpus(&with) <= peak_offline_gpus(&without) + 1,
                "with {} without {}", peak_offline_gpus(&with),
                peak_offline_gpus(&without));
    }
}
