//! Carbon-meter observer: integrates operational carbon against the
//! deployment's time-varying CI signal as the simulation runs, instead of
//! multiplying total energy by a scalar CI after the fact. Multi-region
//! fleets attach per-server flat overrides (a server's grid does not move
//! with the primary region's trace).
//!
//! The meter also keeps each server's **provisioned intervals** — opened
//! by `Provision`, closed by `Decommission` events — so embodied carbon
//! amortizes per provisioned-hour (the 4R Rightsize/Recycle accounting: a
//! decommissioned server stops accruing embodied and idle carbon) rather
//! than being charged for the whole sim horizon regardless of fleet size.

use crate::carbon::intensity::CiSignal;
use crate::carbon::operational::op_kg_from_joules;

use super::core::SimConfig;

#[derive(Debug)]
pub struct CarbonMeter {
    primary: CiSignal,
    /// Per-server flat CI overrides (multi-region fleets), indexed like
    /// `SimConfig::servers`.
    overrides: Vec<Option<f64>>,
    op_kg: f64,
    /// Closed provisioned intervals per server, in time order (consulted
    /// only for traced signals when pricing idle energy).
    intervals: Vec<Vec<(f64, f64)>>,
    /// Start of each server's currently open provisioned interval.
    open_since: Vec<Option<f64>>,
    /// Running per-server provisioned-second totals, maintained at
    /// decommission time so [`CarbonMeter::provisioned_s`] is O(1) on the
    /// per-server finish path instead of re-summing interval lists.
    total_s: Vec<f64>,
}

impl CarbonMeter {
    pub fn new(cfg: &SimConfig) -> CarbonMeter {
        let n = cfg.servers.len();
        CarbonMeter {
            primary: cfg.ci.clone(),
            overrides: cfg.servers.iter()
                .map(|s| s.region.map(|r| r.avg_ci()))
                .collect(),
            op_kg: 0.0,
            intervals: vec![Vec::new(); n],
            open_since: vec![None; n],
            total_s: vec![0.0; n],
        }
    }

    /// Open a provisioned interval for `server` at `t_s` (idempotent
    /// while an interval is already open).
    pub(crate) fn provision(&mut self, server: usize, t_s: f64) {
        if self.open_since[server].is_none() {
            self.open_since[server] = Some(t_s);
        }
    }

    /// Close `server`'s open provisioned interval at `t_s`.
    pub(crate) fn decommission(&mut self, server: usize, t_s: f64) {
        if let Some(t0) = self.open_since[server].take() {
            let t1 = t_s.max(t0);
            self.intervals[server].push((t0, t1));
            self.total_s[server] += t1 - t0;
        }
    }

    /// Close every still-open interval at the end of the sim horizon.
    pub(crate) fn finalize(&mut self, horizon_s: f64) {
        for i in 0..self.open_since.len() {
            self.decommission(i, horizon_s);
        }
    }

    /// Total provisioned seconds accumulated by `server` so far (open
    /// intervals count only after [`CarbonMeter::finalize`]). O(1).
    pub fn provisioned_s(&self, server: usize) -> f64 {
        self.total_s[server]
    }

    /// Mean CI over `server`'s provisioned intervals, weighted by
    /// interval length — what idle draw should be priced at (an elastic
    /// server is only idle while it is provisioned). Falls back to the
    /// horizon mean for a never-provisioned server (its idle energy is
    /// zero anyway).
    fn provisioned_mean_ci(&self, server: usize, horizon_s: f64) -> f64 {
        if let CiSignal::Flat(ci) = &self.primary {
            return *ci; // interval weighting is moot for a flat signal
        }
        let iv = &self.intervals[server];
        let total: f64 = iv.iter().map(|(a, b)| b - a).sum();
        if total <= 0.0 {
            return self.primary.mean_over(0.0, horizon_s);
        }
        iv.iter()
            .map(|(a, b)| self.primary.mean_over(*a, *b) * (b - a))
            .sum::<f64>()
            / total
    }

    /// The deployment's primary CI signal (drives deferral decisions).
    pub fn primary(&self) -> &CiSignal {
        &self.primary
    }

    /// Grid CI seen by `server` at time `t`.
    pub fn ci_at(&self, server: usize, t_s: f64) -> f64 {
        match self.overrides.get(server).copied().flatten() {
            Some(ci) => ci,
            None => self.primary.at(t_s),
        }
    }

    /// Charge a busy interval's energy at the mean CI over the interval.
    /// Called once per busy period — the meter's hot path — so the flat
    /// signal skips the interval-integration machinery entirely.
    pub fn record(&mut self, server: usize, t0_s: f64, dur_s: f64, energy_j: f64) {
        let ci = match self.overrides.get(server).copied().flatten() {
            Some(ci) => ci,
            None => match &self.primary {
                CiSignal::Flat(ci) => *ci,
                sig => sig.mean_over(t0_s, t0_s + dur_s.max(0.0)),
            },
        };
        self.op_kg += op_kg_from_joules(energy_j, ci);
    }

    /// Charge idle-floor energy at the signal's mean over the server's
    /// provisioned intervals (idle draw is spread across the time the
    /// server was actually up — the whole run for a static fleet).
    pub fn record_idle(&mut self, server: usize, energy_j: f64, dur_s: f64) {
        let ci = match self.overrides.get(server).copied().flatten() {
            Some(ci) => ci,
            None => self.provisioned_mean_ci(server, dur_s),
        };
        self.op_kg += op_kg_from_joules(energy_j, ci);
    }

    /// Accumulated operational carbon, kgCO₂e.
    pub fn op_kg(&self) -> f64 {
        self.op_kg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::intensity::{CiTrace, Region};
    use crate::models;
    use crate::sim::policy::Router;
    use crate::sim::server::homogeneous_fleet;

    fn cfg(ci: CiSignal, regions: &[Option<Region>]) -> SimConfig {
        let m = models::llm("llama-8b").unwrap();
        let mut fleet = homogeneous_fleet("A100-40", regions.len(), m, 2048);
        for (s, r) in fleet.iter_mut().zip(regions) {
            s.region = *r;
        }
        let n = fleet.len();
        let mut c = SimConfig::flat(fleet, Router::Jsq, 0.0, vec![0.005; n]);
        c.ci = ci;
        c
    }

    #[test]
    fn flat_meter_matches_closed_form() {
        let mut m = CarbonMeter::new(&cfg(CiSignal::flat(261.0), &[None, None]));
        m.record(0, 0.0, 10.0, 3.6e6);
        m.record_idle(1, 3.6e6, 100.0);
        // 2 kWh at 261 g/kWh = 0.522 kg.
        assert!((m.op_kg() - 2.0 * 261.0 / 1000.0).abs() < 1e-12);
        assert_eq!(m.ci_at(0, 55.0), 261.0);
    }

    #[test]
    fn overrides_pin_a_server_to_its_region() {
        let m = CarbonMeter::new(&cfg(
            CiSignal::flat(261.0),
            &[Some(Region::SwedenNorth), None],
        ));
        assert_eq!(m.ci_at(0, 0.0), 17.0);
        assert_eq!(m.ci_at(1, 0.0), 261.0);
        let mut m2 = CarbonMeter::new(&cfg(
            CiSignal::flat(261.0),
            &[Some(Region::SwedenNorth), None],
        ));
        m2.record(0, 0.0, 1.0, 3.6e6);
        assert!((m2.op_kg() - 17.0 / 1000.0).abs() < 1e-12);
    }

    #[test]
    fn provisioned_intervals_accumulate_and_close() {
        let mut m = CarbonMeter::new(&cfg(CiSignal::flat(261.0), &[None, None]));
        m.provision(0, 0.0);
        m.provision(0, 5.0); // idempotent while open
        m.decommission(0, 10.0);
        m.provision(0, 20.0); // re-provision opens a second interval
        m.provision(1, 0.0);
        m.finalize(30.0);
        assert!((m.provisioned_s(0) - 20.0).abs() < 1e-12,
                "server 0: {}", m.provisioned_s(0));
        assert!((m.provisioned_s(1) - 30.0).abs() < 1e-12,
                "server 1: {}", m.provisioned_s(1));
        // Closing an already-closed interval is a no-op.
        m.decommission(0, 40.0);
        assert!((m.provisioned_s(0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn traced_meter_charges_less_in_the_dip() {
        let tr = CiTrace::compressed_diurnal(Region::California, 240.0, 1, 96, 3);
        let sig = CiSignal::Trace(tr);
        let dip_t = 13.0 / 24.0 * 240.0;
        let night_t = 3.0 / 24.0 * 240.0;
        let mk = |t0: f64| {
            let mut m = CarbonMeter::new(&cfg(sig.clone(), &[None]));
            m.record(0, t0, 2.0, 1e6);
            m.op_kg()
        };
        assert!(mk(dip_t) < mk(night_t),
                "dip {} night {}", mk(dip_t), mk(night_t));
    }
}
