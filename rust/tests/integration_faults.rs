//! Determinism and graceful-degradation suite for the fault-injection
//! pack: every failure-pack scenario must be byte-identical across shard
//! worker-thread counts and between the streaming and materialized
//! arrival paths, the engine must survive total fleet death without
//! panicking, and the recovery accounting must surface in extras.

use ecoserve::scenarios::{catalog, registry, run_spec, run_spec_sharded,
                          run_spec_sharded_materialized, scenario_seed, Pack};

#[test]
fn failure_pack_is_byte_identical_across_shard_counts() {
    // The acceptance gate: injected faults ride the ordinary event queue,
    // so a fault scenario's outcome bytes are invariant in the shard
    // thread budget — and identical between arrival paths.
    for s in registry().iter().filter(|s| s.pack() == Pack::Failure) {
        let name = s.name();
        let seed = scenario_seed(47, name);
        let runs: Vec<String> = [1usize, 2, 4]
            .iter()
            .map(|&n| run_spec_sharded(name, &s.spec(), seed, 40.0, n)
                .to_json()
                .to_string())
            .collect();
        assert_eq!(runs[0], runs[1], "{name}: 1 vs 2 shards diverged");
        assert_eq!(runs[1], runs[2], "{name}: 2 vs 4 shards diverged");
        let materialized =
            run_spec_sharded_materialized(name, &s.spec(), seed, 40.0, 2)
                .to_json()
                .to_string();
        assert_eq!(runs[1], materialized,
                   "{name}: streaming vs materialized diverged");
    }
}

#[test]
fn failure_storm_reroutes_and_reports_fault_metrics() {
    let s = catalog::by_names(&["failure-storm"]).unwrap().remove(0);
    let seed = scenario_seed(13, "failure-storm");
    let out = run_spec("failure-storm", &s.spec(), seed, 60.0);
    // Orphaned work finishes on the survivors — nothing is dropped.
    assert_eq!(out.completed, out.requests,
               "killed servers' jobs must finish elsewhere");
    if out.fleet_servers > 1 {
        assert!(out.extras["faults_injected"] >= 1.0,
                "a multi-server fleet must take at least one death");
    }
    for k in ["faults_injected", "jobs_rescheduled", "jobs_recovered",
              "recovery_wait_s", "op_kg_nofault", "carbon_kg_nofault",
              "slo_attainment_nofault", "ttft_p90_s_nofault"] {
        assert!(out.extras.contains_key(k), "missing extras key {k}");
    }
}

#[test]
fn region_outage_recovers_and_completes() {
    let s = catalog::by_names(&["region-outage"]).unwrap().remove(0);
    let seed = scenario_seed(29, "region-outage");
    let out = run_spec("region-outage", &s.spec(), seed, 60.0);
    // Capacity returns at 55% of the trace, so everything drains.
    assert_eq!(out.completed, out.requests);
    // Server 0 is always pinned to the outage region (i % 2 == 0), so at
    // least one death lands whatever the planner provisioned.
    assert!(out.extras["faults_injected"] >= 1.0);
    // Losing half the fleet cannot *improve* attainment over the twin.
    assert!(out.slo_attainment
                <= out.extras["slo_attainment_nofault"] + 1e-9);
}

#[test]
fn total_fleet_death_does_not_panic_at_the_scenario_layer() {
    use ecoserve::sim::FaultPlan;
    let s = catalog::by_names(&["failure-storm"]).unwrap().remove(0);
    let mut spec = s.spec();
    // Kill every server the planner could possibly provision, with no
    // recovery: the run must close its books instead of panicking, with
    // the post-death arrivals stranded (arrived, never completed).
    let mut plan = FaultPlan::new();
    for i in 0..64 {
        plan = plan.server_death(0.5, i);
    }
    spec.faults = plan;
    let seed = scenario_seed(17, "failure-storm");
    let out = run_spec("failure-storm", &spec, seed, 45.0);
    assert!(out.completed < out.requests,
            "killing the whole fleet must strand the post-death tail");
    assert!(out.extras["faults_injected"] >= 1.0);
}

#[test]
fn hetero_disaggregation_serves_with_a_recycled_decode_tier() {
    let s = catalog::by_names(&["hetero-disaggregation"]).unwrap().remove(0);
    let seed = scenario_seed(19, "hetero-disaggregation");
    let out = run_spec("hetero-disaggregation", &s.spec(), seed, 45.0);
    assert_eq!(out.completed, out.requests);
    assert!(out.generated_tokens > 0);
    // No faults in this design point: the fault extras must be absent so
    // the pack's byte-neutrality contract stays visible in reports.
    assert!(!out.extras.contains_key("faults_injected"));
}
