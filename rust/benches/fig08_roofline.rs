//! Fig 8: roofline comparison, SPR-112 CPU vs A100-40 GPU, with prefill
//! and decode operating points for Llama-3-8B at ctx 2048.
use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::cpu as cpuperf;
use ecoserve::perf::roofline::{knee_intensity, Device};
use ecoserve::util::table::{fnum, Table};

fn main() {
    let m = models::llm("llama-8b").unwrap();
    let a100 = Device::from_gpu(hw::gpu("A100-40").unwrap());
    let spr = Device::from_cpu(hw::cpu("SPR-112").unwrap(), 512.0);
    println!("== Fig 8: rooflines (Llama-8B, ctx 2048) ==");
    let mut t = Table::new(&["device", "peak TF/s", "bw GB/s", "knee FLOP/B",
                             "max batch @2048"]);
    t.row(&["A100-40".into(), fnum(a100.peak_flops / 1e12), fnum(a100.mem_bw / 1e9),
            fnum(knee_intensity(&a100)), format!("{}", m.max_batch(40.0, 2048, 1))]);
    t.row(&["SPR-112".into(), fnum(spr.peak_flops / 1e12), fnum(spr.mem_bw / 1e9),
            fnum(knee_intensity(&spr)),
            format!("{}", cpuperf::max_batch(m, 512.0, 2048))]);
    t.print();
    println!("\noperating points (arithmetic intensity, FLOP/byte):");
    let mut t = Table::new(&["op", "batch", "AI", "A100 bound", "CPU bound"]);
    for (name, b) in [("decode", 1), ("decode", 16), ("decode", 512)] {
        let ai = m.decode_intensity(b, 2048);
        let bound = |d: &Device| if ai < knee_intensity(d) { "memory" } else { "compute" };
        t.row(&[name.into(), format!("{b}"), fnum(ai),
                bound(&a100).into(), bound(&spr).into()]);
    }
    let pf_ai = m.prefill_flops(1, 2048) / m.prefill_bytes(1, 2048);
    t.row(&["prefill".into(), "1".into(), fnum(pf_ai), "compute".into(),
            "compute".into()]);
    t.print();
    println!("(low-AI decode fits the CPU; GPU is capacity-bound at large batch)");
}
