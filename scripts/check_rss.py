#!/usr/bin/env python3
"""Assert the peak RSS recorded by `/usr/bin/time -v` stays under a cap.

Usage: check_rss.py TIME_V_FILE MAX_RSS_KB

Shared by the scale-smoke, scale-matrix, and replay-determinism CI jobs:
each wraps the binary under test in `/usr/bin/time -v`, captures stderr,
and hands the transcript here. Exits nonzero (with the offending numbers)
when the "Maximum resident set size" line is missing or over the cap, so
the memory promise of the streaming core is a hard gate, not a log line.
"""

import sys


def main(argv):
    if len(argv) != 3:
        sys.exit(f"usage: {argv[0]} TIME_V_FILE MAX_RSS_KB")
    path, cap_kb = argv[1], int(argv[2])
    rss_kb = None
    with open(path) as f:
        for line in f:
            if "Maximum resident set size" in line:
                rss_kb = int(line.rsplit(":", 1)[1].strip())
    if rss_kb is None:
        sys.exit(f"{path}: no 'Maximum resident set size' line — "
                 "was the command wrapped in /usr/bin/time -v?")
    print(f"peak RSS: {rss_kb} KB (cap {cap_kb} KB)")
    if rss_kb > cap_kb:
        sys.exit(f"peak RSS {rss_kb} KB exceeds cap {cap_kb} KB")


if __name__ == "__main__":
    main(sys.argv)
