//! Event-driven cluster simulator (the Splitwise-simulator substitute,
//! paper §5/§6.2): prefill/decode disaggregation, iteration-level
//! continuous batching, KV-transfer costs, JSQ vs workload-aware routing,
//! and energy/carbon accounting.
//!
//! Drives Figs 15/17 (end-to-end carbon vs TTFT/TPOT under load) on top of
//! the same roofline models the planner uses, so provisioning decisions and
//! runtime behaviour stay consistent — the paper's cross-layer point.

use crate::carbon::operational::op_kg;
use crate::models::LlmSpec;
use crate::perf::roofline::{self, Device};
use crate::util::stats::Samples;
use crate::workload::{Request, RequestClass};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Server role in a (possibly disaggregated) deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Prompt,
    Decode,
    Mixed,
}

/// One provisioned server (a TP group acts as one server).
#[derive(Debug, Clone)]
pub struct ServerSpec {
    pub device: Device,
    pub role: Role,
    pub tp: usize,
    /// Max concurrent decode sequences (KV capacity at typical ctx).
    pub max_batch: usize,
    /// Max prompts per prefill batch.
    pub prefill_batch: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Router {
    /// Join-shortest-queue over eligible servers (Splitwise's policy).
    Jsq,
    /// Workload-aware: long prompts to high-memory servers, short to lean
    /// ones (EcoServe's runtime component).
    WorkloadAware,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub servers: Vec<ServerSpec>,
    pub router: Router,
    /// Grid carbon intensity, gCO₂e/kWh.
    pub ci: f64,
    /// Per-server embodied amortization, kgCO₂e per server-hour.
    pub emb_kg_per_hr: Vec<f64>,
    /// KV transfer bandwidth between prefill and decode servers, B/s.
    pub kv_transfer_bw: f64,
}

/// Simulation outcome.
#[derive(Debug)]
pub struct SimReport {
    pub ttft: Samples,
    pub tpot: Samples,
    pub completed: usize,
    pub generated_tokens: usize,
    pub sim_duration_s: f64,
    pub energy_j: f64,
    pub op_kg: f64,
    pub emb_kg: f64,
    /// Fraction of online requests whose TTFT/TPOT met the SLO.
    pub slo_attainment: f64,
}

impl SimReport {
    pub fn carbon_kg(&self) -> f64 {
        self.op_kg + self.emb_kg
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.generated_tokens as f64 / self.sim_duration_s.max(1e-9)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    Wake(usize),
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by time.
        other.t.partial_cmp(&self.t).unwrap_or(Ordering::Equal)
    }
}

#[derive(Debug, Clone)]
struct Job {
    arrival: f64,
    prompt: usize,
    output: usize,
    class: RequestClass,
    slo_ttft: f64,
    slo_tpot: f64,
    first_token_t: Option<f64>,
    decoded: usize,
}

struct Server {
    spec: ServerSpec,
    prompt_q: VecDeque<usize>,
    decode_q: VecDeque<usize>,
    active: Vec<usize>,
    busy_until: f64,
    busy_s: f64,
    energy_j: f64,
}

/// Run the simulator over a trace for a model.
pub fn simulate(model: &LlmSpec, trace: &[Request], cfg: &SimConfig,
                slo_ttft: f64, slo_tpot: f64) -> SimReport {
    assert_eq!(cfg.servers.len(), cfg.emb_kg_per_hr.len());
    let mut jobs: Vec<Job> = trace.iter().map(|r| Job {
        arrival: r.arrival_s,
        prompt: r.prompt_tokens.min(8192),
        output: r.output_tokens.max(1),
        class: r.class,
        slo_ttft,
        slo_tpot,
        first_token_t: None,
        decoded: 0,
    }).collect();

    let mut servers: Vec<Server> = cfg.servers.iter().map(|s| Server {
        spec: s.clone(),
        prompt_q: VecDeque::new(),
        decode_q: VecDeque::new(),
        active: Vec::new(),
        busy_until: 0.0,
        busy_s: 0.0,
        energy_j: 0.0,
    }).collect();

    let mut heap = BinaryHeap::new();
    for (i, j) in jobs.iter().enumerate() {
        heap.push(Event { t: j.arrival, kind: EventKind::Arrival(i) });
    }

    let mut ttft = Samples::new();
    let mut tpot = Samples::new();
    let mut completed = 0usize;
    let mut generated = 0usize;
    let mut slo_ok = 0usize;
    let mut online_done = 0usize;
    let mut now = 0.0f64;

    let prompt_eligible: Vec<usize> = servers.iter().enumerate()
        .filter(|(_, s)| s.spec.role != Role::Decode)
        .map(|(i, _)| i)
        .collect();
    assert!(!prompt_eligible.is_empty(), "no prompt-capable servers");

    while let Some(ev) = heap.pop() {
        now = ev.t;
        match ev.kind {
            EventKind::Arrival(ji) => {
                let sid = route(&servers, &prompt_eligible, &jobs[ji], cfg.router);
                servers[sid].prompt_q.push_back(ji);
                heap.push(Event { t: now, kind: EventKind::Wake(sid) });
            }
            EventKind::Wake(sid) => {
                if servers[sid].busy_until > now + 1e-12 {
                    continue; // stale wake; the busy completion re-wakes.
                }
                if let Some(next) = step_server(
                    sid, &mut servers, &mut jobs, model, cfg, now,
                    &mut ttft, &mut tpot, &mut completed, &mut generated,
                    &mut slo_ok, &mut online_done, &mut heap,
                ) {
                    heap.push(Event { t: next, kind: EventKind::Wake(sid) });
                }
            }
        }
    }

    let dur = now.max(trace.last().map(|r| r.arrival_s).unwrap_or(0.0));
    let mut energy = 0.0;
    let mut op = 0.0;
    let mut emb = 0.0;
    for (s, emb_rate) in servers.iter().zip(&cfg.emb_kg_per_hr) {
        let tpf = s.spec.tp as f64;
        let idle_s = (dur - s.busy_s).max(0.0);
        let e = s.energy_j + idle_s * s.spec.device.idle_w * tpf;
        energy += e;
        op += op_kg(1.0, e, cfg.ci); // op_kg(P,t,ci) with P·t == e joules
        emb += emb_rate * dur / 3600.0;
    }

    SimReport {
        ttft,
        tpot,
        completed,
        generated_tokens: generated,
        sim_duration_s: dur,
        energy_j: energy,
        op_kg: op,
        emb_kg: emb,
        slo_attainment: if online_done == 0 { 1.0 } else {
            slo_ok as f64 / online_done as f64
        },
    }
}

fn route(servers: &[Server], eligible: &[usize], job: &Job, policy: Router) -> usize {
    match policy {
        Router::Jsq => *eligible.iter()
            .min_by_key(|&&i| servers[i].prompt_q.len() + servers[i].active.len())
            .unwrap(),
        Router::WorkloadAware => {
            // Long prompts → largest-memory eligible server pool; short →
            // smallest that still fits; ties by queue depth.
            let long = job.prompt >= 1024;
            *eligible.iter()
                .min_by(|&&a, &&b| {
                    let ka = wa_key(&servers[a], long);
                    let kb = wa_key(&servers[b], long);
                    ka.partial_cmp(&kb).unwrap()
                })
                .unwrap()
        }
    }
}

fn wa_key(s: &Server, long: bool) -> (f64, usize) {
    let mem = s.spec.device.mem_gb;
    let pref = if long { -mem } else { mem };
    (pref, s.prompt_q.len() + s.active.len())
}

/// Execute one scheduling iteration on a server; returns the wall-clock of
/// the next wake, or None if idle (a future arrival will wake it).
#[allow(clippy::too_many_arguments)]
fn step_server(
    sid: usize,
    servers: &mut [Server],
    jobs: &mut [Job],
    model: &LlmSpec,
    cfg: &SimConfig,
    now: f64,
    ttft: &mut Samples,
    tpot: &mut Samples,
    completed: &mut usize,
    generated: &mut usize,
    slo_ok: &mut usize,
    online_done: &mut usize,
    heap: &mut BinaryHeap<Event>,
) -> Option<f64> {
    // Prefill first (prompt servers drain their queue; mixed servers give
    // prefill priority — chunked-prefill-style).
    let (do_prefill, batch_ids): (bool, Vec<usize>) = {
        let s = &mut servers[sid];
        if s.spec.role != Role::Decode && !s.prompt_q.is_empty() {
            let n = s.spec.prefill_batch.min(s.prompt_q.len());
            let ids: Vec<usize> = (0..n).map(|_| s.prompt_q.pop_front().unwrap()).collect();
            (true, ids)
        } else {
            (false, Vec::new())
        }
    };

    if do_prefill {
        let max_prompt = batch_ids.iter().map(|&j| jobs[j].prompt).max().unwrap();
        let spec_tp = servers[sid].spec.tp;
        let perf = roofline::prefill_perf(model, &servers[sid].spec.device,
                                          batch_ids.len(), max_prompt, spec_tp);
        let done_t = now + perf.latency_s;
        {
            let s = &mut servers[sid];
            s.busy_until = done_t;
            s.busy_s += perf.latency_s;
            s.energy_j += perf.energy_j;
        }
        // First token is produced by prefill.
        for &ji in &batch_ids {
            let j = &mut jobs[ji];
            j.first_token_t = Some(done_t);
            ttft.push(done_t - j.arrival);
        }
        // Hand sequences to a decode server (KV transfer if remote).
        let decode_sid = pick_decode_server(servers, sid);
        let kv_bytes = batch_ids.iter()
            .map(|&j| jobs[j].prompt as f64 * model.kv_bytes_per_token())
            .sum::<f64>();
        let xfer = if decode_sid == sid { 0.0 } else { kv_bytes / cfg.kv_transfer_bw };
        for &ji in &batch_ids {
            servers[decode_sid].decode_q.push_back(ji);
        }
        heap.push(Event { t: done_t + xfer, kind: EventKind::Wake(decode_sid) });
        return Some(done_t);
    }

    // Decode iteration.
    {
        let s = &mut servers[sid];
        while s.active.len() < s.spec.max_batch {
            let Some(ji) = s.decode_q.pop_front() else { break };
            s.active.push(ji);
        }
    }
    let active = servers[sid].active.clone();
    if active.is_empty() {
        return None;
    }
    let mean_ctx = (active.iter()
        .map(|&j| jobs[j].prompt + jobs[j].decoded)
        .sum::<usize>() / active.len()).max(1);
    let spec_tp = servers[sid].spec.tp;
    let perf = roofline::decode_step_perf(model, &servers[sid].spec.device,
                                          active.len(), mean_ctx, spec_tp);
    let done_t = now + perf.latency_s;
    {
        let s = &mut servers[sid];
        s.busy_until = done_t;
        s.busy_s += perf.latency_s;
        s.energy_j += perf.energy_j;
    }
    let mut still = Vec::with_capacity(active.len());
    for ji in active {
        let j = &mut jobs[ji];
        j.decoded += 1;
        *generated += 1;
        if j.decoded >= j.output {
            let first = j.first_token_t.unwrap_or(j.arrival);
            let t = if j.decoded > 1 {
                (done_t - first) / (j.decoded - 1) as f64
            } else {
                0.0
            };
            tpot.push(t);
            *completed += 1;
            if j.class == RequestClass::Online {
                *online_done += 1;
                if (first - j.arrival) <= j.slo_ttft && t <= j.slo_tpot {
                    *slo_ok += 1;
                }
            }
        } else {
            still.push(ji);
        }
    }
    servers[sid].active = still;
    Some(done_t)
}

fn pick_decode_server(servers: &[Server], from: usize) -> usize {
    if servers[from].spec.role == Role::Mixed {
        return from;
    }
    // JSQ over decode-capable servers.
    servers.iter().enumerate()
        .filter(|(_, s)| s.spec.role != Role::Prompt)
        .min_by_key(|(_, s)| s.decode_q.len() + s.active.len())
        .map(|(i, _)| i)
        .unwrap_or(from)
}

/// Convenience: n identical mixed servers of a GPU SKU.
pub fn homogeneous_fleet(gpu: &str, n: usize, model: &LlmSpec, ctx: usize)
    -> Vec<ServerSpec> {
    let g = crate::hw::gpu(gpu).unwrap_or_else(|| panic!("unknown gpu {gpu}"));
    let dev = Device::from_gpu(g);
    let mut tp = 1usize;
    while model.weight_gb() >= 0.45 * dev.mem_gb * tp as f64 && tp < 8 {
        tp *= 2;
    }
    let max_batch = model.max_batch(dev.mem_gb, ctx, tp).clamp(1, 64);
    (0..n)
        .map(|_| ServerSpec {
            device: dev.clone(),
            role: Role::Mixed,
            tp,
            max_batch,
            prefill_batch: 4,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::{generate_trace, Arrivals, LengthDist};

    fn small_trace(rate: f64, seed: u64) -> Vec<Request> {
        generate_trace(Arrivals::Poisson { rate }, LengthDist::ShareGpt,
                       RequestClass::Online, 120.0, seed)
    }

    fn cfg_for(servers: Vec<ServerSpec>, router: Router) -> SimConfig {
        let n = servers.len();
        SimConfig {
            servers,
            router,
            ci: 261.0,
            emb_kg_per_hr: vec![0.005; n],
            kv_transfer_bw: 64e9,
        }
    }

    #[test]
    fn completes_all_requests() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 1);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 4, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert!(r.generated_tokens > 0);
        assert!(r.op_kg > 0.0 && r.emb_kg > 0.0);
    }

    #[test]
    fn overload_degrades_ttft() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let mut light = simulate(m, &small_trace(0.5, 2), &cfg, 0.5, 0.1);
        let mut heavy = simulate(m, &small_trace(12.0, 2), &cfg, 0.5, 0.1);
        assert!(heavy.ttft.p90() > light.ttft.p90(),
                "heavy {} vs light {}", heavy.ttft.p90(), light.ttft.p90());
    }

    #[test]
    fn more_servers_more_throughput_headroom() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(8.0, 3);
        let small = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let big = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let mut r_small = simulate(m, &tr, &small, 0.5, 0.1);
        let mut r_big = simulate(m, &tr, &big, 0.5, 0.1);
        // More servers relieve queueing (p90 within noise of batched
        // prefill saturation effects) and never hurt SLO attainment.
        assert!(r_big.ttft.p90() <= r_small.ttft.p90() * 1.1 + 1e-9,
                "big {} small {}", r_big.ttft.p90(), r_small.ttft.p90());
        assert!(r_big.slo_attainment >= r_small.slo_attainment);
    }

    #[test]
    fn disaggregated_pd_split_works() {
        let m = models::llm("llama-8b").unwrap();
        let mut servers = homogeneous_fleet("H100", 2, m, 2048);
        servers[0].role = Role::Prompt;
        servers[1].role = Role::Decode;
        let cfg = cfg_for(servers, Router::Jsq);
        let r = simulate(m, &small_trace(1.0, 4), &cfg, 0.5, 0.1);
        assert_eq!(r.completed, simulate(m, &small_trace(1.0, 4),
            &cfg_for(homogeneous_fleet("H100", 2, m, 2048), Router::Jsq),
            0.5, 0.1).completed);
        assert!(r.ttft.len() > 0 && r.tpot.len() > 0);
    }

    #[test]
    fn workload_aware_router_helps_mixed_lengths() {
        let m = models::llm("gemma-27b").unwrap();
        // Heterogeneous fleet: one big-memory A100-80, one lean L4 pair.
        let mut servers = homogeneous_fleet("A100-80", 1, m, 2048);
        servers.extend(homogeneous_fleet("A100-40", 1, m, 2048));
        let tr = generate_trace(Arrivals::Poisson { rate: 1.0 },
                                LengthDist::AzureCode, RequestClass::Online,
                                240.0, 5);
        let mut jsq = simulate(m, &tr, &cfg_for(servers.clone(), Router::Jsq),
                               10.0, 0.2);
        let mut wa = simulate(m, &tr, &cfg_for(servers, Router::WorkloadAware),
                              10.0, 0.2);
        // Workload-aware must not be worse on p90 TTFT (usually better).
        assert!(wa.ttft.p90() <= jsq.ttft.p90() * 1.35,
                "wa {} jsq {}", wa.ttft.p90(), jsq.ttft.p90());
    }

    #[test]
    fn energy_includes_idle_floor() {
        let m = models::llm("llama-8b").unwrap();
        // One request on a big fleet: idle power dominates.
        let tr = small_trace(0.05, 6);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        let idle_j = r.sim_duration_s * 8.0 * 50.0; // 8x idle 50 W
        assert!(r.energy_j > 0.8 * idle_j, "energy {} idle floor {idle_j}", r.energy_j);
    }
}
