//! Carbon-aware runtime policies end to end: carbon-greedy routing on a
//! two-grid fleet, and the diurnal-shift scenario's temporal shifting of
//! offline work (deferred work meets its deadline with lower operational
//! carbon than run-immediately, without hurting the online SLO).

use ecoserve::carbon::intensity::Region;
use ecoserve::models;
use ecoserve::scenarios::catalog;
use ecoserve::scenarios::{run_sweep, SweepConfig};
use ecoserve::sim::{homogeneous_fleet, simulate, Router, SimConfig};
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

#[test]
fn carbon_greedy_weakly_lowers_op_carbon_on_a_two_region_fleet() {
    let m = models::llm("llama-8b").unwrap();
    let mut servers = homogeneous_fleet("A100-40", 4, m, 2048);
    for (i, s) in servers.iter_mut().enumerate() {
        s.region = Some(if i < 2 { Region::SwedenNorth } else { Region::Midcontinent });
    }
    let tr = generate_trace(Arrivals::Poisson { rate: 0.8 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            120.0, 21);
    let mk = |router: Router| {
        let cfg = SimConfig::flat(servers.clone(), router, 261.0,
                                  vec![0.004; 4]);
        simulate(m, &tr, &cfg, 0.5, 0.1)
    };
    let cg = mk(Router::CarbonGreedy);
    let jsq = mk(Router::Jsq);
    assert_eq!(cg.completed, jsq.completed);
    assert_eq!(cg.completed, tr.len());
    // Same fleet, same work: steering busy energy onto the clean grid can
    // only lower (never raise) operational carbon at this load.
    assert!(cg.op_kg <= jsq.op_kg * (1.0 + 1e-9),
            "carbon-greedy op {} vs jsq op {}", cg.op_kg, jsq.op_kg);
    assert!((cg.emb_kg - jsq.emb_kg).abs() < 1e-12);
}

#[test]
fn carbon_router_scenario_beats_its_jsq_baseline() {
    let sel = catalog::by_names(&["carbon-router"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 7, duration_s: 60.0,
                            ..Default::default() };
    let r = run_sweep(&sel, &cfg);
    let o = &r.outcomes[0];
    assert_eq!(o.completed, o.requests, "requests lost");
    let jsq_op = o.extras["op_kg_jsq"];
    assert!(o.op_kg <= jsq_op * (1.0 + 1e-9),
            "carbon-greedy op {} vs jsq {}", o.op_kg, jsq_op);
}

#[test]
fn diurnal_shift_defers_into_low_ci_and_meets_deadlines() {
    let sel = catalog::by_names(&["diurnal-shift"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 7, duration_s: 120.0,
                            ..Default::default() };
    let r = run_sweep(&sel, &cfg);
    let o = &r.outcomes[0];
    assert_eq!(o.completed, o.requests, "requests lost");
    assert!(o.deferred > 0, "no offline work was deferred");
    // Every deferred job still lands inside its deadline.
    assert_eq!(o.offline_deadline_attainment, 1.0,
               "deadline attainment {}", o.offline_deadline_attainment);
    // Temporal shifting strictly lowers operational carbon vs the
    // run-immediately baseline on the same trace/fleet/CI signal.
    let op_base = o.extras["op_kg_immediate"];
    assert!(o.op_kg < op_base,
            "deferred op {} !< immediate op {}", o.op_kg, op_base);
    // Online-first batching keeps the online SLO essentially unchanged.
    let slo_base = o.extras["slo_attainment_immediate"];
    assert!(o.slo_attainment >= slo_base - 0.05,
            "online SLO degraded: {} vs {}", o.slo_attainment, slo_base);
}

#[test]
fn diurnal_shift_is_deterministic_and_offline_work_is_conserved() {
    let sel1 = catalog::by_names(&["diurnal-shift"]).unwrap();
    let sel2 = catalog::by_names(&["diurnal-shift"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 3, duration_s: 60.0,
                            ..Default::default() };
    let a = run_sweep(&sel1, &cfg).to_json().to_string();
    let b = run_sweep(&sel2, &cfg).to_json().to_string();
    assert_eq!(a, b, "deferral queue must be deterministic");
}
