//! Full inference platforms: host system + attached GPUs.
//!
//! Mirrors the cloud offerings the paper analyzes in Fig 5 (Azure /
//! LambdaLabs instances with 1-8 GPUs) and the lean "Reduce" SKUs EcoServe
//! proposes (§4.1.3).

use super::{CpuSpec, GpuSpec, MemTech, cpu, gpu};

/// Host-side configuration (everything that is not the accelerator).
#[derive(Debug, Clone)]
pub struct HostSpec {
    pub cpu: CpuSpec,
    pub dram_gb: f64,
    pub dram_tech: MemTech,
    pub ssd_gb: f64,
    pub hdd_count: usize,
    pub nic_count: usize,
    /// Mainboard printed-wiring-board area, cm² (Dell R740: 1925).
    pub pcb_cm2: f64,
}

impl HostSpec {
    /// DRAM+SSD idle draw (paper: SSD ≈ 2.8 W/TB idle; DRAM ≈ 0.375 W/GB
    /// self-refresh+background, a standard DDR4/5 figure).
    pub fn mem_idle_w(&self) -> f64 {
        self.ssd_gb / 1000.0 * 2.8 + self.dram_gb * 0.375
    }

    pub fn idle_w(&self) -> f64 {
        self.cpu.idle_w + self.mem_idle_w()
    }

    pub fn tdp_w(&self) -> f64 {
        self.cpu.tdp_w + self.mem_idle_w() * 2.0
    }
}

/// A complete platform: one host + `gpu_count` × `gpu`.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub host: HostSpec,
    pub gpu: GpuSpec,
    pub gpu_count: usize,
}

impl Platform {
    pub fn tdp_w(&self) -> f64 {
        self.host.tdp_w() + self.gpu.tdp_w * self.gpu_count as f64
    }

    pub fn idle_w(&self) -> f64 {
        self.host.idle_w() + self.gpu.idle_w * self.gpu_count as f64
    }
}

/// Azure ND96asr-A100-v4-like: 8×A100-40, ~900 GB DRAM, 6.5 TB NVMe.
pub fn azure_nd96_a100() -> Platform {
    Platform {
        name: "ND96asr-A100-v4".into(),
        host: HostSpec {
            cpu: cpu("SPR-112").unwrap().clone(),
            dram_gb: 900.0,
            dram_tech: MemTech::Ddr4,
            ssd_gb: 6500.0,
            hdd_count: 1,
            nic_count: 2,
            pcb_cm2: 1925.0,
        },
        gpu: gpu("A100-40").unwrap().clone(),
        gpu_count: 8,
    }
}

/// A standard host scaled to the number/size of the attached GPUs — how
/// cloud SKUs are actually provisioned (host memory ≈ 2× aggregate HBM,
/// SSD ≈ 10× HBM for model/dataset staging).
pub fn standard_platform(gpu_name: &str, gpu_count: usize) -> Platform {
    let g = gpu(gpu_name).unwrap_or_else(|| panic!("unknown gpu {gpu_name}")).clone();
    let hbm_total = g.mem_gb * gpu_count as f64;
    let host_cpu = if gpu_count > 4 { "SPR-112" } else { "SPR-56" };
    Platform {
        name: format!("{gpu_name}x{gpu_count}"),
        host: HostSpec {
            cpu: cpu(host_cpu).unwrap().clone(),
            dram_gb: (2.0 * hbm_total).max(128.0),
            dram_tech: MemTech::Ddr4,
            ssd_gb: (10.0 * hbm_total).max(1000.0),
            hdd_count: 1,
            nic_count: if gpu_count > 4 { 2 } else { 1 },
            pcb_cm2: if gpu_count > 4 { 1925.0 } else { 1200.0 },
        },
        gpu: g,
        gpu_count,
    }
}

/// EcoServe "Reduce" SKU (§4.1.3): DRAM sized by Eq. 1 (KV working set, not
/// 2× HBM), SSD sized by Eq. 2 (1.2× GPU memory), no HDD, single NIC.
///
/// `kv_working_set_gb` is the P90 aggregated-context KV footprint the
/// planner profiles per workload (models::LlmSpec::kv_bytes_per_token).
pub fn reduced_platform(gpu_name: &str, gpu_count: usize,
                        model_weight_gb: f64, kv_working_set_gb: f64) -> Platform {
    let mut p = standard_platform(gpu_name, gpu_count);
    let hbm_total = p.gpu.mem_gb * gpu_count as f64;
    p.name = format!("{gpu_name}x{gpu_count}-reduced");
    // Eq 1: weights (one layer pinned is enough for streaming, but keep the
    // full model resident for robustness) + KV offload working set.
    p.host.dram_gb = (model_weight_gb + kv_working_set_gb).max(32.0);
    // Eq 2: min SSD = 1.2 x GPU memory.
    p.host.ssd_gb = 1.2 * hbm_total;
    p.host.hdd_count = 0;
    p.host.nic_count = 1;
    p.host.pcb_cm2 *= 0.85; // fewer DIMM slots / drive bays
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn azure_instance_shape() {
        let p = azure_nd96_a100();
        assert_eq!(p.gpu_count, 8);
        assert!(p.host.dram_gb >= 900.0);
        assert!(p.tdp_w() > 8.0 * 400.0);
    }

    #[test]
    fn standard_scales_with_gpus() {
        let small = standard_platform("L4", 1);
        let big = standard_platform("H100", 8);
        assert!(big.host.dram_gb > small.host.dram_gb);
        assert!(big.host.ssd_gb > small.host.ssd_gb);
    }

    #[test]
    fn reduce_shrinks_memory_subsystem() {
        let std = standard_platform("A100-80", 8);
        let red = reduced_platform("A100-80", 8, 140.0, 80.0);
        assert!(red.host.dram_gb < std.host.dram_gb);
        assert!(red.host.ssd_gb < std.host.ssd_gb);
        assert_eq!(red.host.hdd_count, 0);
        // Eq 2: 1.2 x 640 GB HBM.
        assert!((red.host.ssd_gb - 768.0).abs() < 1e-9);
    }

    #[test]
    fn idle_power_accounts_for_memory() {
        let p = azure_nd96_a100();
        // 6.5 TB SSD alone is ~18 W idle; with 900 GB DRAM the host memory
        // subsystem must dominate CPU idle.
        assert!(p.host.mem_idle_w() > 300.0);
    }
}
