"""L1 Pallas kernel: split-KV (flash-decoding style) decode attention.

This is the TPU-side expression of EcoServe's CPU decode optimization
(paper §4.1.1, Figs 9/18/19): the paper parallelizes decode attention along
the KV *sequence-length* dimension (in addition to batch) to saturate memory
bandwidth across all cores. Here the same insight maps onto the Pallas grid:
the third grid axis iterates over KV chunks, each program reduces one
(batch, head, kv-chunk) tile held in VMEM, and partial softmax results are
merged with a numerically stable running-max rescale.

The kernel supports grouped-query attention (GQA): ``n_heads`` query heads
share ``n_kv_heads`` KV heads via the BlockSpec index map.

Kernels are lowered with ``interpret=True`` — CPU PJRT cannot execute Mosaic
custom-calls; correctness is validated against ``ref.decode_attention_ref``
and real-TPU efficiency is estimated analytically (DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Large-but-finite mask value: using -inf produces NaNs in fully-masked
# chunks (exp(-inf - -inf)); -1e30 underflows to exactly 0 after the
# running-max rescale, which is what we want.
NEG_MASK = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref, *,
                        chunk: int, scale: float, num_chunks: int):
    """One (batch, q-head, kv-chunk) grid step of split-KV decode attention.

    Running state lives in the output refs (same block for every chunk of a
    given (b, h)): ``o_ref`` holds the *unnormalized* accumulator until the
    final chunk, ``m_ref``/``l_ref`` hold the running max / normalizer.
    """
    c = pl.program_id(2)

    q = q_ref[0, 0, :]        # [Dh]
    k = k_ref[0, :, 0, :]     # [chunk, Dh]
    v = v_ref[0, :, 0, :]     # [chunk, Dh]

    s = jnp.dot(k, q) * scale                                    # [chunk]
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0) + c * chunk
    s = jnp.where(idx <= pos_ref[0], s, NEG_MASK)

    m_c = jnp.maximum(jnp.max(s), NEG_MASK)
    p_c = jnp.exp(s - m_c)                                       # [chunk]
    # Zero out fully-masked lanes (where s == NEG_MASK == m_c → exp(0) == 1).
    p_c = jnp.where(idx <= pos_ref[0], p_c, 0.0)
    l_c = jnp.sum(p_c)
    acc_c = jnp.dot(p_c, v)                                      # [Dh]

    @pl.when(c == 0)
    def _init():
        m_ref[0, 0] = m_c
        l_ref[0, 0] = l_c
        o_ref[0, 0, :] = acc_c

    @pl.when(c > 0)
    def _merge():
        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, m_c)
        alpha = jnp.exp(m_prev - m_new)
        beta = jnp.exp(m_c - m_new)
        m_ref[0, 0] = m_new
        l_ref[0, 0] = alpha * l_prev + beta * l_c
        o_ref[0, 0, :] = alpha * o_ref[0, 0, :] + beta * acc_c

    @pl.when(c == num_chunks - 1)
    def _finalize():
        # Every position <= pos is live, so l >= exp(0) > 0 when pos >= 0.
        o_ref[0, 0, :] = o_ref[0, 0, :] / jnp.maximum(l_ref[0, 0], 1e-30)


@functools.partial(jax.jit, static_argnames=("chunk",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, chunk: int = 64) -> jax.Array:
    """Split-KV decode attention.

    Args:
      q:   [B, H, Dh]        query for the current token.
      k:   [B, S, KVH, Dh]   key cache (S must be a multiple of ``chunk``).
      v:   [B, S, KVH, Dh]   value cache.
      pos: [B] int32         index of the current token; positions > pos
                             are masked out (cache slot ``pos`` must already
                             hold the current token's K/V).
      chunk: KV-chunk size — the sequence-dimension parallelism degree.

    Returns:
      [B, H, Dh] attention output.
    """
    b, h, dh = q.shape
    _, s, kvh, _ = k.shape
    assert s % chunk == 0, f"seq len {s} not a multiple of chunk {chunk}"
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    num_chunks = s // chunk
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _decode_attn_kernel, chunk=chunk, scale=scale, num_chunks=num_chunks)

    out, _, _ = pl.pallas_call(
        kernel,
        grid=(b, h, num_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ci: (bi, hi, 0)),
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda bi, hi, ci: (bi, ci, hi // group, 0)),
            pl.BlockSpec((1, chunk, 1, dh),
                         lambda bi, hi, ci: (bi, ci, hi // group, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ci: (bi, hi, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (bi, hi)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dh), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
        ],
        interpret=True,
    )(q, k, v, pos)
    return out


def vmem_bytes_per_program(dh: int, chunk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid program (DESIGN.md §7).

    q tile + K tile + V tile + output/merge state. Used by vmem_report.py to
    check the double-buffered footprint stays within a 16 MiB VMEM budget.
    """
    q_t = dh * dtype_bytes
    kv_t = 2 * chunk * dh * dtype_bytes
    out_t = (dh + 2) * dtype_bytes
    return q_t + kv_t + out_t
