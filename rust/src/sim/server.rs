//! Server state and the prefill/decode stepping logic, driven by the
//! event core. Batch formation and routing are delegated to the policy
//! traits in `policy.rs`; energy goes to the server ledger and the
//! carbon meter; latency/SLO samples go to the metrics sink.
//!
//! Job state lives in a [`JobArena`]: a compact slot arena that recycles
//! retired jobs' slots, so the sim's memory footprint follows the number
//! of *in-flight* jobs (fleet-bounded in steady state) rather than the
//! trace length — the invariant that lets a multi-million-request
//! production day stream through the core.

use crate::carbon::intensity::Region;
use crate::models::LlmSpec;
use crate::perf::roofline::{self, Device};
use crate::workload::RequestClass;
use std::collections::VecDeque;
use std::ops::{Index, IndexMut};

use super::core::{EventKind, Sim};

/// Prompts are clipped to this many tokens (the sim's context cap);
/// clipped requests are counted in `SimReport::truncated_prompts`.
pub const MAX_PROMPT_TOKENS: usize = 8192;

/// Server role in a (possibly disaggregated) deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Prompt,
    Decode,
    Mixed,
}

/// Provisioning lifecycle of a server under fleet elasticity. Static
/// fleets stay `Active` for the whole run; a rolling-horizon schedule
/// walks servers `Pending → Active → Draining → Retired` (and possibly
/// back to `Active` on re-provision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Not yet provisioned: invisible to routing, charged nothing.
    Pending,
    /// Provisioned and admitting work.
    Active,
    /// Finishing in-flight batches; admits nothing new. Still charged
    /// embodied + idle carbon until it empties and retires.
    Draining,
    /// Decommissioned: no work, no further embodied/idle accrual.
    Retired,
}

/// One provisioned server (a TP group acts as one server).
#[derive(Debug, Clone)]
pub struct ServerSpec {
    pub device: Device,
    pub role: Role,
    pub tp: usize,
    /// Max concurrent decode sequences (KV capacity at typical ctx).
    pub max_batch: usize,
    /// Max prompts per prefill batch.
    pub prefill_batch: usize,
    /// Grid region override for multi-region fleets; `None` means the
    /// deployment's primary CI signal applies.
    pub region: Option<Region>,
}

/// A request as the simulator tracks it.
#[derive(Debug, Clone)]
pub struct Job {
    pub arrival: f64,
    pub prompt: usize,
    pub output: usize,
    pub class: RequestClass,
    pub slo_ttft: f64,
    pub slo_tpot: f64,
    /// Completion deadline (offline temporal shifting); ∞ when untracked.
    pub deadline: f64,
    /// When the request was handed to the routers — equals `arrival`
    /// unless the deferral policy shifted it. TTFT measures from here so
    /// intentional temporal shifting doesn't masquerade as serving
    /// latency (deadline attainment still measures from `arrival`).
    pub dispatched_t: f64,
    pub first_token_t: Option<f64>,
    pub decoded: usize,
}

/// Compact slot arena for job state. `alloc` reuses the slot of the most
/// recently retired job before growing, so capacity tracks the *peak
/// concurrent* job count, not the trace length. An `occupied` bitmap makes
/// double-free and use-after-free structural errors rather than silent
/// aliasing (`tests/prop_sim_core.rs` holds the recycler to that).
#[derive(Debug, Default)]
pub struct JobArena {
    slots: Vec<Job>,
    free: Vec<usize>,
    occupied: Vec<bool>,
    live: usize,
    peak_live: usize,
}

impl JobArena {
    pub fn new() -> JobArena {
        JobArena::default()
    }

    /// Store `job`, returning its slot id (stable until [`JobArena::free`]).
    pub fn alloc(&mut self, job: Job) -> usize {
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        match self.free.pop() {
            Some(i) => {
                debug_assert!(!self.occupied[i], "free list held a live slot");
                self.slots[i] = job;
                self.occupied[i] = true;
                i
            }
            None => {
                self.slots.push(job);
                self.occupied.push(true);
                self.slots.len() - 1
            }
        }
    }

    /// Retire a job, recycling its slot for a future [`JobArena::alloc`].
    pub fn free(&mut self, i: usize) {
        assert!(self.occupied[i], "double free of job slot {i}");
        self.occupied[i] = false;
        self.live -= 1;
        self.free.push(i);
    }

    /// Currently live jobs.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of concurrently live jobs — the sim's memory bound.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Slots ever allocated (equals `peak_live` up to free-list reuse
    /// order; always ≪ trace length for a streaming run).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn is_live(&self, i: usize) -> bool {
        self.occupied.get(i).copied().unwrap_or(false)
    }

    /// Raw slot view for read-only policy context. Freed slots hold stale
    /// jobs; callers must only index ids they were handed for live work.
    pub fn as_slice(&self) -> &[Job] {
        &self.slots
    }
}

impl Index<usize> for JobArena {
    type Output = Job;

    fn index(&self, i: usize) -> &Job {
        debug_assert!(self.occupied[i], "read of freed job slot {i}");
        &self.slots[i]
    }
}

impl IndexMut<usize> for JobArena {
    fn index_mut(&mut self, i: usize) -> &mut Job {
        debug_assert!(self.occupied[i], "write to freed job slot {i}");
        &mut self.slots[i]
    }
}

/// A per-class FIFO queue with global arrival sequencing: batch policies
/// take strict-FIFO or class-priority prefixes in O(batch) — no queue
/// scans — and removal is a front pop into a caller-owned scratch buffer,
/// so the hot path neither scans nor allocates.
#[derive(Debug, Default)]
pub struct ClassQueue {
    online: VecDeque<(u64, usize)>,
    offline: VecDeque<(u64, usize)>,
    next_seq: u64,
}

impl ClassQueue {
    pub fn push(&mut self, job: usize, class: RequestClass) {
        let seq = self.next_seq;
        self.next_seq += 1;
        match class {
            RequestClass::Online => self.online.push_back((seq, job)),
            RequestClass::Offline => self.offline.push_back((seq, job)),
        }
    }

    pub fn len(&self) -> usize {
        self.online.len() + self.offline.len()
    }

    pub fn is_empty(&self) -> bool {
        self.online.is_empty() && self.offline.is_empty()
    }

    /// Online-class depth (fleet-timeline sampling).
    pub fn len_online(&self) -> usize {
        self.online.len()
    }

    /// Offline-class depth (fleet-timeline sampling).
    pub fn len_offline(&self) -> usize {
        self.offline.len()
    }

    /// Remove up to `max` job ids in strict arrival order (classes
    /// interleaved by enqueue sequence), appending to `out`.
    pub fn pop_fifo_into(&mut self, max: usize, out: &mut Vec<usize>) {
        let target = out.len() + max.min(self.len());
        while out.len() < target {
            let take_online = match (self.online.front(), self.offline.front()) {
                (Some(&(a, _)), Some(&(b, _))) => a < b,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            let q = if take_online { &mut self.online } else { &mut self.offline };
            out.push(q.pop_front().unwrap().1);
        }
    }

    /// Remove up to `max` job ids, online class first (each class in
    /// arrival order), appending to `out`.
    pub fn pop_online_first_into(&mut self, max: usize, out: &mut Vec<usize>) {
        let target = out.len() + max.min(self.len());
        while out.len() < target {
            let Some((_, j)) = self.online.pop_front() else { break };
            out.push(j);
        }
        while out.len() < target {
            let Some((_, j)) = self.offline.pop_front() else { break };
            out.push(j);
        }
    }

    /// Vec-returning convenience over [`ClassQueue::pop_fifo_into`].
    pub fn pop_fifo(&mut self, max: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(max.min(self.len()));
        self.pop_fifo_into(max, &mut out);
        out
    }

    /// Vec-returning convenience over [`ClassQueue::pop_online_first_into`].
    pub fn pop_online_first(&mut self, max: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(max.min(self.len()));
        self.pop_online_first_into(max, &mut out);
        out
    }
}

/// Runtime server state. Fields are crate-private; policies observe
/// servers through the accessor methods.
#[derive(Debug)]
pub struct Server {
    pub(crate) spec: ServerSpec,
    pub(crate) lifecycle: Lifecycle,
    pub(crate) prompt_q: ClassQueue,
    pub(crate) decode_q: ClassQueue,
    pub(crate) active: Vec<usize>,
    /// Count of busy periods started; a `Complete { gen }` event ends the
    /// period it names, making stale wakes structurally impossible.
    pub(crate) busy_gen: u64,
    pub(crate) in_flight: bool,
    pub(crate) busy_s: f64,
    /// When the in-flight busy period ends — lets a mid-batch kill trim
    /// the unserved remainder out of `busy_s` (the energy, already spent,
    /// stays charged).
    pub(crate) busy_until: f64,
    /// A `Drain` arrived while this server was still cold-starting
    /// (`Pending`): apply it the moment the boot completes instead of
    /// silently dropping it. Cleared by a later `Provision` — the newest
    /// scheduling intent wins.
    pub(crate) drain_pending: bool,
    pub(crate) energy_j: f64,
    /// When this draining server last went idle-empty (warm, awaiting
    /// either reuse or its keep-alive window expiring).
    pub(crate) warm_since: Option<f64>,
    /// Earliest time a pending `Decommission` may actually retire this
    /// server — re-arming the keep-alive window invalidates stale events.
    pub(crate) retire_at: f64,
    /// Per-server idle-before-reuse histogram for the hybrid-histogram
    /// keep-alive policy. Per-server (not per-sim) so shard partitioning
    /// cannot change what any server has observed.
    pub(crate) ka_hist: Vec<u64>,
    pub(crate) ka_obs: u64,
    /// Power draw (W) of the most recent busy period — the figure the
    /// fleet timeline samples while `busy_until > t`. Written on every
    /// busy period, read only by the observer; simulation logic never
    /// consults it, so it is byte-neutral with observers off.
    pub(crate) last_power_w: f64,
}

/// Histogram bins are capped so a pathological idle duration cannot grow
/// the vector without bound.
pub(crate) const KA_MAX_BINS: usize = 4096;

impl Server {
    pub(crate) fn new(spec: &ServerSpec) -> Server {
        Server {
            spec: spec.clone(),
            lifecycle: Lifecycle::Active,
            prompt_q: ClassQueue::default(),
            decode_q: ClassQueue::default(),
            active: Vec::new(),
            busy_gen: 0,
            in_flight: false,
            busy_s: 0.0,
            busy_until: 0.0,
            drain_pending: false,
            energy_j: 0.0,
            warm_since: None,
            retire_at: 0.0,
            ka_hist: Vec::new(),
            ka_obs: 0,
            last_power_w: 0.0,
        }
    }

    /// Record that this server sat warm for `idle_s` before being reused
    /// (a `Provision` cancelled its drain). Feeds the hybrid-histogram
    /// keep-alive window.
    pub(crate) fn record_warm_reuse(&mut self, idle_s: f64, bin_s: f64) {
        let bin = ((idle_s / bin_s.max(1e-9)) as usize).min(KA_MAX_BINS - 1);
        if self.ka_hist.len() <= bin {
            self.ka_hist.resize(bin + 1, 0);
        }
        self.ka_hist[bin] += 1;
        self.ka_obs += 1;
    }

    /// Load the routing policies see: waiting prompts + running decodes.
    pub fn depth(&self) -> usize {
        self.prompt_q.len() + self.active.len()
    }

    pub fn spec(&self) -> &ServerSpec {
        &self.spec
    }

    pub fn lifecycle(&self) -> Lifecycle {
        self.lifecycle
    }

    /// Whether routing may send *new* work here. Draining servers finish
    /// what they hold but never admit.
    pub fn is_admitting(&self) -> bool {
        self.lifecycle == Lifecycle::Active
    }

    /// No queued, admitted, or in-flight work of any kind.
    pub(crate) fn is_idle_empty(&self) -> bool {
        !self.in_flight
            && self.prompt_q.is_empty()
            && self.decode_q.is_empty()
            && self.active.is_empty()
    }
}

impl<'a> Sim<'a> {
    /// One scheduling iteration: prefill first (prompt servers drain their
    /// queue; mixed servers give prefill priority, chunked-prefill-style),
    /// else a decode step. Work schedules its own `Complete` event.
    /// Draining servers still step (they must finish in-flight batches);
    /// pending/retired servers hold no work and never run.
    pub(crate) fn step(&mut self, sid: usize) {
        match self.servers[sid].lifecycle {
            Lifecycle::Pending | Lifecycle::Retired => {
                debug_assert!(self.servers[sid].is_idle_empty(),
                              "unprovisioned server holds work");
                return;
            }
            Lifecycle::Active | Lifecycle::Draining => {}
        }
        if self.try_prefill(sid) {
            return;
        }
        self.try_decode(sid);
    }

    fn try_prefill(&mut self, sid: usize) -> bool {
        if self.servers[sid].spec.role == Role::Decode
            || self.servers[sid].prompt_q.is_empty()
        {
            return false;
        }
        let cap = self.servers[sid].spec.prefill_batch;
        let mut picks = std::mem::take(&mut self.batch_scratch);
        picks.clear();
        self.batch.select_prefill(&mut self.servers[sid].prompt_q,
                                  self.jobs.as_slice(), cap, &mut picks);
        if picks.is_empty() {
            self.batch_scratch = picks;
            return false;
        }

        let max_prompt = picks.iter().map(|&j| self.jobs[j].prompt).max().unwrap();
        let tp = self.servers[sid].spec.tp;
        let perf = roofline::prefill_perf(self.model, &self.servers[sid].spec.device,
                                          picks.len(), max_prompt, tp);
        let done_t = self.begin_busy(sid, perf.latency_s, perf.power_w);

        // First token is produced by prefill. TTFT is measured from the
        // dispatch time (== arrival unless the job was deferred).
        for &ji in &picks {
            self.jobs[ji].first_token_t = Some(done_t);
            let ttft = done_t - self.jobs[ji].dispatched_t;
            self.metrics.ttft.push(ttft);
        }
        let t0 = self.now;
        if let Some(sp) = self.spans_mut() {
            for &ji in &picks {
                sp.on_prefill(ji, sid, t0, done_t);
            }
        }

        // Hand sequences to a decode server (KV transfer if remote). The
        // Handoff event lands the KV at done_t + xfer — the decode side
        // cannot admit a sequence before its prefill (and transfer) ends.
        let decode_sid = self.pick_decode_server(sid);
        let kv_bytes: f64 = picks.iter()
            .map(|&j| self.jobs[j].prompt as f64 * self.model.kv_bytes_per_token())
            .sum();
        let xfer = if decode_sid == sid { 0.0 } else { kv_bytes / self.cfg.kv_transfer_bw };
        for &ji in &picks {
            self.queue.push(done_t + xfer,
                            EventKind::Handoff { job: ji, server: decode_sid });
        }
        picks.clear();
        self.batch_scratch = picks;
        true
    }

    fn try_decode(&mut self, sid: usize) {
        let slots = {
            let s = &self.servers[sid];
            s.spec.max_batch.saturating_sub(s.active.len())
        };
        if slots > 0 && !self.servers[sid].decode_q.is_empty() {
            let mut picks = std::mem::take(&mut self.batch_scratch);
            picks.clear();
            self.batch.select_decode(&mut self.servers[sid].decode_q,
                                     self.jobs.as_slice(), slots, &mut picks);
            self.servers[sid].active.extend_from_slice(&picks);
            let now = self.now;
            if let Some(sp) = self.spans_mut() {
                for &ji in &picks {
                    sp.on_decode_start(ji, now, sid);
                }
            }
            picks.clear();
            self.batch_scratch = picks;
        }

        if self.servers[sid].active.is_empty() {
            return;
        }
        let (n_active, ctx_sum) = {
            let s = &self.servers[sid];
            (s.active.len(),
             s.active.iter()
                 .map(|&j| self.jobs[j].prompt + self.jobs[j].decoded)
                 .sum::<usize>())
        };
        let mean_ctx = (ctx_sum / n_active).max(1);
        let tp = self.servers[sid].spec.tp;
        let perf = roofline::decode_step_perf(self.model, &self.servers[sid].spec.device,
                                              n_active, mean_ctx, tp);
        let done_t = self.begin_busy(sid, perf.latency_s, perf.power_w);

        // Retain survivors in place: no per-step allocation, and finished
        // jobs hand their arena slots back for recycling.
        let mut active = std::mem::take(&mut self.servers[sid].active);
        active.retain(|&ji| {
            self.jobs[ji].decoded += 1;
            self.metrics.generated_tokens += 1;
            let j = &self.jobs[ji];
            if j.decoded >= j.output {
                let first = j.first_token_t.unwrap_or(j.dispatched_t);
                let tpot = if j.decoded > 1 {
                    (done_t - first) / (j.decoded - 1) as f64
                } else {
                    0.0
                };
                let online = j.class == RequestClass::Online;
                let slo_hit = (first - j.dispatched_t) <= j.slo_ttft
                    && tpot <= j.slo_tpot;
                let on_time = done_t <= j.deadline;
                self.metrics.complete(online, slo_hit, on_time, tpot);
                if let Some(sp) = self.spans_mut() {
                    sp.on_complete(ji, done_t);
                }
                self.jobs.free(ji);
                false
            } else {
                true
            }
        });
        self.servers[sid].active = active;
    }

    /// Start a busy period ending at `now + latency_s`: bump the server's
    /// generation, charge the meter, and schedule the matching `Complete`.
    /// The meter integrates the shared power curve directly — energy is
    /// `busy_energy_j(power_w, latency_s)`, not a precomputed figure, so
    /// the simulator and planner price the same curve.
    fn begin_busy(&mut self, sid: usize, latency_s: f64, power_w: f64) -> f64 {
        let energy_j = crate::carbon::operational::busy_energy_j(power_w, latency_s);
        let done_t = self.now + latency_s;
        let s = &mut self.servers[sid];
        s.busy_gen += 1;
        s.in_flight = true;
        s.busy_s += latency_s;
        s.busy_until = done_t;
        s.energy_j += energy_j;
        s.last_power_w = power_w;
        let gen = s.busy_gen;
        self.meter.record(sid, self.now, latency_s, energy_j);
        self.queue.push(done_t, EventKind::Complete { server: sid, gen });
        done_t
    }

    /// JSQ over decode-capable servers; live mixed servers keep their own
    /// KV. Preference order: admitting decode-capable, then draining
    /// decode-capable (so in-flight prefills still land somewhere when
    /// the whole decode side is winding down), then any live server at
    /// all — never a pending or retired one.
    pub(crate) fn pick_decode_server(&self, from: usize) -> usize {
        let alive = |s: &Server| {
            matches!(s.lifecycle, Lifecycle::Active | Lifecycle::Draining)
        };
        if self.servers[from].spec.role == Role::Mixed && alive(&self.servers[from]) {
            return from;
        }
        self.best_decode_target().unwrap_or(from)
    }

    /// The JSQ ladder behind [`Sim::pick_decode_server`], without the
    /// keep-your-own-KV shortcut: `None` only when the whole fleet is
    /// dead — the signal for the fault path to park the job in the
    /// recovery queue instead of stranding it on a retired server.
    pub(crate) fn best_decode_target(&self) -> Option<usize> {
        let alive = |s: &Server| {
            matches!(s.lifecycle, Lifecycle::Active | Lifecycle::Draining)
        };
        let best = |decode_only: bool, admitting_only: bool| {
            self.servers.iter().enumerate()
                .filter(|(_, s)| !decode_only || s.spec.role != Role::Prompt)
                .filter(|(_, s)| if admitting_only { s.is_admitting() } else { alive(s) })
                .min_by_key(|(_, s)| s.decode_q.len() + s.active.len())
                .map(|(i, _)| i)
        };
        best(true, true)
            .or_else(|| best(true, false))
            .or_else(|| best(false, true))
            .or_else(|| best(false, false))
    }
}

/// Convenience: n identical mixed servers of a GPU SKU.
pub fn homogeneous_fleet(gpu: &str, n: usize, model: &LlmSpec, ctx: usize)
    -> Vec<ServerSpec> {
    let g = crate::hw::gpu(gpu).unwrap_or_else(|| panic!("unknown gpu {gpu}"));
    let dev = Device::from_gpu(g);
    let mut tp = 1usize;
    while model.weight_gb() >= 0.45 * dev.mem_gb * tp as f64 && tp < 8 {
        tp *= 2;
    }
    let max_batch = model.max_batch(dev.mem_gb, ctx, tp).clamp(1, 64);
    (0..n)
        .map(|_| ServerSpec {
            device: dev.clone(),
            role: Role::Mixed,
            tp,
            max_batch,
            prefill_batch: 4,
            region: None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_queue_fifo_interleaves_by_arrival() {
        let mut q = ClassQueue::default();
        q.push(10, RequestClass::Online);
        q.push(11, RequestClass::Offline);
        q.push(12, RequestClass::Online);
        q.push(13, RequestClass::Offline);
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop_fifo(3), vec![10, 11, 12]);
        assert_eq!(q.pop_fifo(3), vec![13]);
        assert!(q.is_empty());
    }

    #[test]
    fn class_queue_online_first_pads_with_offline() {
        let mut q = ClassQueue::default();
        for (j, class) in [(0, RequestClass::Online), (1, RequestClass::Offline),
                           (2, RequestClass::Offline), (3, RequestClass::Online)] {
            q.push(j, class);
        }
        assert_eq!(q.pop_online_first(3), vec![0, 3, 1]);
        assert_eq!(q.pop_online_first(3), vec![2]);
        assert!(q.is_empty());
    }

    #[test]
    fn class_queue_pop_into_appends_without_clearing() {
        let mut q = ClassQueue::default();
        for j in 0..5 {
            q.push(j, RequestClass::Online);
        }
        let mut out = vec![99];
        q.pop_fifo_into(2, &mut out);
        assert_eq!(out, vec![99, 0, 1]);
        q.pop_online_first_into(10, &mut out);
        assert_eq!(out, vec![99, 0, 1, 2, 3, 4]);
    }

    fn job_with_tag(tag: f64) -> Job {
        Job {
            arrival: tag,
            prompt: 8,
            output: 4,
            class: RequestClass::Online,
            slo_ttft: 1.0,
            slo_tpot: 0.1,
            deadline: f64::INFINITY,
            dispatched_t: tag,
            first_token_t: None,
            decoded: 0,
        }
    }

    #[test]
    fn arena_recycles_slots_and_tracks_peak() {
        let mut a = JobArena::new();
        let s0 = a.alloc(job_with_tag(0.0));
        let s1 = a.alloc(job_with_tag(1.0));
        assert_ne!(s0, s1);
        assert_eq!(a.live(), 2);
        a.free(s0);
        assert_eq!(a.live(), 1);
        // The freed slot is reused before the arena grows.
        let s2 = a.alloc(job_with_tag(2.0));
        assert_eq!(s2, s0);
        assert_eq!(a[s2].arrival, 2.0);
        assert_eq!(a[s1].arrival, 1.0, "live neighbor must be untouched");
        assert_eq!(a.capacity(), 2);
        assert_eq!(a.peak_live(), 2);
        a.free(s1);
        a.free(s2);
        assert_eq!(a.live(), 0);
        assert_eq!(a.peak_live(), 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn arena_double_free_panics() {
        let mut a = JobArena::new();
        let s = a.alloc(job_with_tag(0.0));
        a.free(s);
        a.free(s);
    }
}
