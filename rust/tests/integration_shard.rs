//! Determinism suite for the sharded multi-region runtime: the outcome
//! JSON of a sharded scenario run must be byte-identical for any shard
//! worker-thread count (the partition is a pure function of the fleet),
//! stable across repeated runs (thread interleavings), and identical
//! between the lazy-generator and materialized-trace arrival paths.

use ecoserve::scenarios::{catalog, registry, run_spec_sharded,
                          run_spec_sharded_materialized, run_sweep,
                          scenario_seed, SweepConfig};

fn sharded_json(name: &str, seed_master: u64, duration_s: f64, shards: usize)
    -> String {
    let sc = catalog::by_names(&[name]).unwrap().remove(0);
    let seed = scenario_seed(seed_master, name);
    run_spec_sharded(name, &sc.spec(), seed, duration_s, shards)
        .to_json()
        .to_string()
}

#[test]
fn production_day_is_byte_identical_across_shard_counts() {
    // The acceptance gate: --shards N ∈ {1, 2, 4} on production-day must
    // produce identical outcome bytes — N buys wall-clock, never a
    // different answer. A repeated 4-shard run covers interleaving
    // nondeterminism within one count.
    let runs: Vec<String> = [1usize, 2, 4]
        .iter()
        .map(|&n| sharded_json("production-day", 31, 45.0, n))
        .collect();
    assert_eq!(runs[0], runs[1], "1-shard vs 2-shard runs diverged");
    assert_eq!(runs[1], runs[2], "2-shard vs 4-shard runs diverged");
    assert_eq!(runs[2], sharded_json("production-day", 31, 45.0, 4),
               "repeated 4-shard run diverged (interleaving leak)");
}

#[test]
fn sharded_streaming_matches_materialized() {
    for name in ["carbon-router", "production-day", "nonlinear-power"] {
        let sc = catalog::by_names(&[name]).unwrap().remove(0);
        let seed = scenario_seed(61, name);
        let streamed = run_spec_sharded(name, &sc.spec(), seed, 24.0, 2)
            .to_json()
            .to_string();
        let materialized =
            run_spec_sharded_materialized(name, &sc.spec(), seed, 24.0, 2)
                .to_json()
                .to_string();
        assert_eq!(streamed, materialized,
                   "{name}: sharded streaming and materialized diverge");
    }
}

#[test]
fn cold_start_and_keepalive_keep_shard_byte_identity() {
    // The honest-energy knobs ride the same determinism contract: a boot
    // delay plus each keep-alive policy — including the per-server hybrid
    // histogram, whose reuse observations must not depend on how the
    // fleet was partitioned — cannot change a byte across shard counts,
    // nor between the streaming and materialized arrival paths.
    use ecoserve::sim::KeepAlivePolicy;
    let sc = catalog::by_names(&["keepalive-surge"]).unwrap().remove(0);
    let seed = scenario_seed(53, "keepalive-surge");
    for keepalive in [
        KeepAlivePolicy::Fixed { window_s: 30.0 },
        KeepAlivePolicy::HybridHistogram {
            bin_s: 10.0, percentile: 0.9, max_window_s: 60.0,
        },
    ] {
        let mut spec = sc.spec();
        spec.keepalive = keepalive;
        let runs: Vec<String> = [1usize, 2, 4]
            .iter()
            .map(|&n| run_spec_sharded("keepalive-surge", &spec, seed, 40.0, n)
                .to_json()
                .to_string())
            .collect();
        assert_eq!(runs[0], runs[1], "{keepalive:?}: 1 vs 2 shards diverged");
        assert_eq!(runs[1], runs[2], "{keepalive:?}: 2 vs 4 shards diverged");
        let materialized = run_spec_sharded_materialized(
            "keepalive-surge", &spec, seed, 40.0, 2)
            .to_json()
            .to_string();
        assert_eq!(runs[1], materialized,
                   "{keepalive:?}: streaming vs materialized diverged");
    }
}

#[test]
fn every_registry_scenario_runs_sharded() {
    // Sharding is a total function over the registry: every design point
    // (elastic, disaggregated, deferred, multi-region) partitions into
    // servable shards, loses no requests, and keeps its baseline extras.
    for sc in registry() {
        let seed = scenario_seed(77, sc.name());
        let o = run_spec_sharded(sc.name(), &sc.spec(), seed, 24.0, 2);
        assert_eq!(o.completed, o.requests,
                   "{}: requests lost under sharding", sc.name());
        assert!(o.events > 0, "{}: no events", sc.name());
    }
}

#[test]
fn sharded_sweep_report_is_invariant_in_threads_and_shard_budget() {
    let sel = ["carbon-router", "autoscale-diurnal"];
    let mk = |threads: usize, shards: usize| {
        let scenarios = catalog::by_names(&sel).unwrap();
        let cfg = SweepConfig { threads, seed: 19, duration_s: 24.0,
                                shards: Some(shards),
                                ..Default::default() };
        run_sweep(&scenarios, &cfg).to_json().to_string()
    };
    let a = mk(1, 1);
    assert_eq!(a, mk(2, 3), "sweep --shards bytes depend on the budget");
    assert_eq!(a, mk(4, 8), "sweep --shards bytes depend on thread count");
}

#[test]
fn sharded_production_day_smoke_flexes_and_stays_bounded() {
    let sc = catalog::by_names(&["production-day"]).unwrap().remove(0);
    let seed = scenario_seed(7, "production-day");
    let o = run_spec_sharded("production-day", &sc.spec(), seed, 60.0, 4);
    assert!(o.requests > 10_000, "day too quiet: {}", o.requests);
    assert_eq!(o.completed, o.requests, "requests lost");
    // The merged arena bound (sum of shard peaks) must still be a sliver
    // of the trace — sharding cannot silently break the streaming-memory
    // contract.
    assert!(o.peak_live_jobs * 2 < o.requests,
            "peak live jobs {} vs {} requests", o.peak_live_jobs, o.requests);
    assert!(o.extras.contains_key("op_kg_jsq"),
            "missing routing baseline under sharding");
    assert!(o.extras.contains_key("carbon_kg_static"),
            "missing static provisioning baseline under sharding");
}
