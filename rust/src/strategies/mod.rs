//! Named provisioning strategies: EcoServe's 4R combinations and the
//! paper's baselines (perf-opt, energy-opt, Melange, Splitwise), all
//! evaluated through the same planner + simulator (Fig 15 / 17 / 20).

use crate::models::LlmSpec;
use crate::planner::{self, Plan, PlanConfig};
use crate::planner::slicing::Slice;
use crate::sim::{Role, Router, ServerSpec, SimConfig};
use crate::perf::roofline::Device;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    PerfOpt,
    EnergyOpt,
    Melange,
    Splitwise,
    EcoReuse,
    EcoRightsize,
    EcoReduce,
    EcoRecycle,
    EcoFull,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::PerfOpt => "perf-opt",
            Strategy::EnergyOpt => "energy-opt",
            Strategy::Melange => "melange",
            Strategy::Splitwise => "splitwise",
            Strategy::EcoReuse => "eco-reuse",
            Strategy::EcoRightsize => "eco-rightsize",
            Strategy::EcoReduce => "eco-reduce",
            Strategy::EcoRecycle => "eco-recycle",
            Strategy::EcoFull => "ecoserve",
        }
    }

    pub fn all() -> &'static [Strategy] {
        &[
            Strategy::PerfOpt, Strategy::EnergyOpt, Strategy::Melange,
            Strategy::Splitwise, Strategy::EcoReuse, Strategy::EcoRightsize,
            Strategy::EcoReduce, Strategy::EcoRecycle, Strategy::EcoFull,
        ]
    }

    /// Planner configuration for this strategy at a grid CI.
    pub fn plan_config(&self, ci: f64) -> PlanConfig {
        let mut cfg = match self {
            Strategy::PerfOpt => PlanConfig::perf_opt(),
            Strategy::EnergyOpt => PlanConfig::energy_opt(),
            Strategy::Melange => PlanConfig::melange(),
            // Splitwise restricts to its two SKUs; we model its fixed PD
            // split in the simulator (splitwise_fleet).
            Strategy::Splitwise => PlanConfig {
                alpha: 0.0,
                gpu_menu: vec!["H100", "A100-40"],
                cpu_reuse: false,
                reduce_host: false,
                host_lifetime_y: 4.0,
                gpu_lifetime_y: 4.0,
                ..Default::default()
            },
            Strategy::EcoReuse => PlanConfig::ecoserve(true, false, false, false),
            Strategy::EcoRightsize => PlanConfig::ecoserve(false, true, false, false),
            Strategy::EcoReduce => PlanConfig::ecoserve(false, false, true, false),
            Strategy::EcoRecycle => PlanConfig::ecoserve(false, false, false, true),
            Strategy::EcoFull => PlanConfig::ecoserve(true, true, true, true),
        };
        if *self != Strategy::EnergyOpt {
            cfg.ci = ci;
        }
        cfg
    }

    /// Plan under this strategy's objective, then report carbon under the
    /// *true* grid CI so strategies are comparable. Energy-opt plans at
    /// CI=1 with embodied ignored (its objective), so its operational term
    /// is rescaled and its embodied recomputed at standard 4y/4y rates.
    pub fn plan(&self, slices: &[Slice], ci: f64) -> Plan {
        let cfg = self.plan_config(ci);
        let mut p = planner::plan(slices, &cfg);
        if *self == Strategy::EnergyOpt {
            p.op_kg_per_hr *= ci / cfg.ci;
            let acct = PlanConfig {
                reduce_host: false,
                host_lifetime_y: 4.0,
                gpu_lifetime_y: 4.0,
                ..Default::default()
            };
            let opts = planner::device_options(&acct, slices[0].model);
            p.emb_kg_per_hr = p.counts.iter()
                .filter_map(|(name, &n)| {
                    opts.iter().find(|o| &o.name == name)
                        .map(|o| o.emb_kg_per_hr * n as f64)
                })
                .sum();
        }
        p
    }
}

/// Build a simulator fleet from a plan: per device type, create mixed
/// servers; if the plan split a slice's phases across types, mark the
/// prompt-heavy types as Prompt servers and decode-heavy as Decode.
pub fn fleet_from_plan(plan: &Plan, model: &LlmSpec, ctx: usize) -> Vec<ServerSpec> {
    let mut out = Vec::new();
    for (name, &count) in &plan.counts {
        if name == "cpu-host" {
            continue; // CPU offload handled by capacity reduction
        }
        // Plan counts are GPUs; a simulator server is one TP group.
        let g = crate::hw::gpu(name).unwrap();
        let dev = Device::from_gpu(g);
        let mut tp = 1usize;
        while model.weight_gb() >= 0.45 * dev.mem_gb * tp as f64 && tp < 8 {
            tp *= 2;
        }
        let n_servers = count.div_ceil(tp).max(1);
        let mut base = crate::sim::homogeneous_fleet(name, n_servers, model, ctx);
        // Role from the plan's phase loads on this type.
        let ploads: f64 = plan.assignments.iter()
            .filter(|a| &a.device == name && a.phase == planner::Phase::Prompt)
            .map(|a| a.load)
            .sum();
        let dloads: f64 = plan.assignments.iter()
            .filter(|a| &a.device == name && a.phase == planner::Phase::Decode)
            .map(|a| a.load)
            .sum();
        let role = if ploads > 4.0 * dloads {
            Role::Prompt
        } else if dloads > 4.0 * ploads {
            Role::Decode
        } else {
            Role::Mixed
        };
        for s in &mut base {
            s.role = role;
        }
        out.extend(base);
    }
    // A fleet must always be able to prefill and decode; degenerate plans
    // (e.g. everything shed or CPU-only) get one mixed fallback server.
    if out.is_empty() {
        out = crate::sim::homogeneous_fleet("A100-80", 1, model, ctx);
    }
    if !out.iter().any(|s| s.role != Role::Decode) {
        out[0].role = Role::Mixed;
    }
    out
}

/// Splitwise-style fixed partition: `n_prompt` H100 prompt machines and
/// `n_token` token machines (paper §6.2.1 uses 35P/8T at 40-H100-equiv).
pub fn splitwise_fleet(model: &LlmSpec, n_prompt: usize, n_token: usize,
                       ctx: usize) -> Vec<ServerSpec> {
    let mut fleet = crate::sim::homogeneous_fleet("H100", n_prompt + n_token, model, ctx);
    for (i, s) in fleet.iter_mut().enumerate() {
        s.role = if i < n_prompt { Role::Prompt } else { Role::Decode };
    }
    fleet
}

/// Deployment reference year for lifecycle screening — the simulator has
/// no wall clock, so deployed hardware ages are measured against this
/// fixed anchor (keeps fleet selection deterministic run-to-run).
pub const FLEET_YEAR: u32 = 2026;

/// Utilization assumed when reliability-screening recycled decode gear:
/// the decode tier is bandwidth-bound and batch-limited, so it runs well
/// below prefill duty.
const DECODE_TIER_UTIL: f64 = 0.4;

/// Oldest catalog GPU that still clears the component-reliability screens
/// ([`crate::carbon::reliability`]) at decode-tier utilization and can
/// hold the model at TP ≤ 8. Decode is bandwidth-bound, so near-wearout
/// generations stay useful there long after prefill outgrows them — the
/// 4R Recycle lever applied to accelerators, not just hosts.
pub fn oldest_safe_decode_gpu(model: &LlmSpec) -> &'static crate::hw::GpuSpec {
    use crate::carbon::reliability::{cpu_effective_age, dram_is_safe};
    crate::hw::gpu_catalog()
        .iter()
        .filter(|g| {
            let age = FLEET_YEAR.saturating_sub(g.year) as f64;
            // DRAM retention and host-aging budgets both must hold for the
            // recycled board to be worth racking (CPU budget ≈ 5 design
            // years, matching max_safe_host_lifetime's convention).
            dram_is_safe(age, DECODE_TIER_UTIL)
                && cpu_effective_age(age, DECODE_TIER_UTIL) <= 5.0
                && model.weight_gb() < 0.45 * g.mem_gb * 8.0
        })
        .min_by_key(|g| g.year)
        .expect("catalog always holds a reliability-safe decode GPU")
}

/// GreenLLM-style heterogeneous PD split: current-generation H100 prompt
/// servers in front of a decode tier built from the oldest reliability-
/// safe GPU in the catalog ([`oldest_safe_decode_gpu`]).
pub fn hetero_pd_fleet(model: &LlmSpec, n_prompt: usize, n_token: usize,
                       ctx: usize) -> Vec<ServerSpec> {
    let old = oldest_safe_decode_gpu(model);
    let mut fleet = crate::sim::homogeneous_fleet("H100", n_prompt, model, ctx);
    for s in &mut fleet {
        s.role = Role::Prompt;
    }
    let mut decode = crate::sim::homogeneous_fleet(old.name, n_token, model, ctx);
    for s in &mut decode {
        s.role = Role::Decode;
    }
    fleet.extend(decode);
    fleet
}

/// SimConfig for a fleet under a strategy's carbon accounting: flat CI at
/// the planning value, workload-aware routing, online-first batching.
/// Callers swap `cfg.ci` for a [`crate::carbon::intensity::CiSignal`]
/// trace or set `cfg.deferral` for temporal-shifting studies.
pub fn sim_config(fleet: Vec<ServerSpec>, plan: &Plan, ci: f64) -> SimConfig {
    let n = fleet.len().max(1);
    // Spread the plan's embodied rate across servers.
    let per_server = plan.emb_kg_per_hr / n as f64;
    let emb = vec![per_server; fleet.len()];
    SimConfig::flat(fleet, Router::WorkloadAware, ci, emb)
}

/// Iso-power fleet sizing: how many of `gpu` fit the power envelope of
/// `n_ref` × `ref_gpu` (Fig 17's "iso-power deployment").
pub fn iso_power_count(ref_gpu: &str, n_ref: usize, gpu: &str) -> usize {
    let r = crate::hw::gpu(ref_gpu).unwrap().tdp_w;
    let g = crate::hw::gpu(gpu).unwrap().tdp_w;
    ((n_ref as f64 * r) / g).floor() as usize
}

/// TP-scaling desiderata (Table 2): relative metrics when doubling n → 2n.
pub struct TpScaling {
    pub power_ratio: f64,
    pub latency_ratio: f64,
    pub cost_ratio: f64,
    pub carbon_ratio: f64,
    pub energy_ratio: f64,
}

pub fn tp_scaling(model: &LlmSpec, dev: &Device, n: usize, p_cpu: f64,
                  emb_cpu: f64, emb_gpu_each: f64, comm_overhead: f64) -> TpScaling {
    let nf = n as f64;
    let p_gpu = dev.tdp_w;
    // Paper Table 2 formulas.
    let power_ratio = (2.0 * nf * p_gpu + p_cpu) / (nf * p_gpu + p_cpu);
    let latency_ratio = 0.5 + comm_overhead;
    let cost_ratio = 1.0;
    let carbon_ratio = (emb_cpu + 2.0 * nf * emb_gpu_each)
        / (emb_cpu + nf * emb_gpu_each)
        * latency_ratio;
    let energy_ratio = power_ratio * latency_ratio;
    let _ = model;
    TpScaling { power_ratio, latency_ratio, cost_ratio, carbon_ratio, energy_ratio }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::slo::Slo;

    fn slices(model: &'static LlmSpec) -> Vec<Slice> {
        // Production-ish scale: integer fleet quantization is small
        // relative to the totals (the paper's savings are fleet-scale).
        vec![
            Slice { model, rate: 30.0, prompt: 256, output: 128,
                    slo: Slo { ttft_s: 1.0, tpot_s: 0.15 }, offline: false },
            Slice { model, rate: 10.0, prompt: 2048, output: 256,
                    slo: Slo { ttft_s: 2.0, tpot_s: 0.2 }, offline: false },
            Slice { model, rate: 12.0, prompt: 4096, output: 256,
                    slo: Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY },
                    offline: true },
        ]
    }

    #[test]
    fn all_strategies_plan() {
        let m = models::llm("llama-8b").unwrap();
        let s = slices(m);
        for strat in Strategy::all() {
            let p = strat.plan(&s, 261.0);
            assert!(p.total_gpus() > 0, "{} provisioned nothing", strat.name());
        }
    }

    #[test]
    fn ecoserve_dominates_on_carbon() {
        // Fig 15's headline: EcoServe-full beats every baseline on carbon.
        let m = models::llm("llama-8b").unwrap();
        let s = slices(m);
        let eco = Strategy::EcoFull.plan(&s, 261.0).carbon_kg_per_hr();
        for strat in [Strategy::PerfOpt, Strategy::Melange] {
            let c = strat.plan(&s, 261.0).carbon_kg_per_hr();
            assert!(eco <= c * 1.001, "{}: eco {eco} vs {c}", strat.name());
        }
    }

    #[test]
    fn savings_band_vs_perf_opt() {
        // Paper: combined strategies ≈ 1.4–2.2x total-carbon reduction.
        let m = models::llm("llama-8b").unwrap();
        let s = slices(m);
        let eco = Strategy::EcoFull.plan(&s, 261.0).carbon_kg_per_hr();
        let perf = Strategy::PerfOpt.plan(&s, 261.0).carbon_kg_per_hr();
        let ratio = perf / eco;
        assert!(ratio > 1.05 && ratio < 3.5, "reduction ratio {ratio}");
        // Savings widen at low CI where embodied dominates (Fig 16).
        let eco_lo = Strategy::EcoFull.plan(&s, 17.0).carbon_kg_per_hr();
        let perf_lo = Strategy::PerfOpt.plan(&s, 17.0).carbon_kg_per_hr();
        assert!(perf_lo / eco_lo > ratio, "low-CI ratio {} vs mid {}",
                perf_lo / eco_lo, ratio);
    }

    #[test]
    fn fleet_from_plan_nonempty_and_serves() {
        let m = models::llm("llama-8b").unwrap();
        let plan = Strategy::EcoFull.plan(&slices(m), 261.0);
        let fleet = fleet_from_plan(&plan, m, 2048);
        assert!(!fleet.is_empty());
        assert!(fleet.iter().any(|s| s.role != Role::Decode));
    }

    #[test]
    fn hetero_fleet_pairs_new_prefill_with_old_safe_decode() {
        let m = models::llm("llama-8b").unwrap();
        let old = oldest_safe_decode_gpu(m);
        let age = (FLEET_YEAR - old.year) as f64;
        assert!(crate::carbon::reliability::dram_is_safe(age, 0.4),
                "{} at {age}y fails its own screen", old.name);
        // Strictly older than the prefill tier's gear.
        assert!(old.year < crate::hw::gpu("H100").unwrap().year);
        let fleet = hetero_pd_fleet(m, 3, 2, 2048);
        assert_eq!(fleet.len(), 5);
        assert!(fleet[..3].iter()
            .all(|s| s.role == Role::Prompt && s.device.name == "H100"));
        assert!(fleet[3..].iter()
            .all(|s| s.role == Role::Decode && s.device.name == old.name));
    }

    #[test]
    fn iso_power_math() {
        // 40 H100 (350 W) ≈ 35 A100-40 (400 W).
        assert_eq!(iso_power_count("H100", 40, "A100-40"), 35);
        assert_eq!(iso_power_count("H100", 40, "H100"), 40);
    }

    #[test]
    fn tp_scaling_table2_shape() {
        let m = models::llm("llama-70b").unwrap();
        let dev = Device::from_gpu(crate::hw::gpu("A100-80").unwrap());
        let s = tp_scaling(m, &dev, 2, 700.0, 800.0, 119.0, 0.1);
        assert!(s.power_ratio > 1.0 && s.power_ratio < 2.0);
        assert!(s.latency_ratio < 1.0); // TP halves latency minus comm
        assert!((s.cost_ratio - 1.0).abs() < 1e-9);
        assert!(s.energy_ratio < 1.0); // energy improves with TP at fixed CI
    }
}
