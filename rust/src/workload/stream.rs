//! Streaming arrival sources: the workload layer as lazy generators.
//!
//! The materializing path (`generate_trace` → `Vec<Request>` →
//! `merge_traces`) costs memory and startup time linear in the trace
//! length, which caps the simulator at toy scales. An [`ArrivalSource`]
//! yields requests one at a time in nondecreasing arrival order, so a
//! multi-million-request production day streams through the discrete-event
//! core with memory bounded by the fleet and the in-flight jobs — never by
//! the trace length.
//!
//! Determinism contract: [`GeneratorSource`] consumes its RNG stream in
//! exactly the order `generate_trace` does, and [`MergedSource`] merges
//! component streams exactly as the stable sort in `merge_traces` would
//! (ties at equal timestamps resolve to the earlier component). The
//! differential suite (`tests/integration_streaming.rs`) holds every
//! registry scenario to byte-identical outcomes across the two paths.

use crate::util::rng::Rng;

use super::{Arrivals, LengthDist, Request, RequestClass};

/// A time-ordered stream of requests. `next_request` returns `None` once
/// the trace is exhausted (sources are fused: further calls keep returning
/// `None`). Arrival times must be nondecreasing.
pub trait ArrivalSource {
    fn next_request(&mut self) -> Option<Request>;

    /// Drain the source into a vector — the bridge back to code that
    /// still wants a materialized trace (tests, small planning windows).
    fn materialize(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.next_request() {
            out.push(r);
        }
        out
    }
}

/// Lazy single-class generator: the streaming equivalent of
/// [`super::generate_trace`], same seed, same RNG draw order, same
/// requests.
#[derive(Debug, Clone)]
pub struct GeneratorSource {
    arrivals: Arrivals,
    lengths: LengthDist,
    class: RequestClass,
    duration_s: f64,
    rng: Rng,
    t: f64,
    next_id: u64,
    done: bool,
}

impl GeneratorSource {
    pub fn new(arrivals: Arrivals, lengths: LengthDist, class: RequestClass,
               duration_s: f64, seed: u64) -> GeneratorSource {
        assert!(!matches!(arrivals, Arrivals::Trace { .. }),
                "Arrivals::Trace replays through TraceSource, not a \
                 generator (see workload::trace)");
        GeneratorSource {
            arrivals,
            lengths,
            class,
            duration_s,
            rng: Rng::new(seed),
            t: 0.0,
            next_id: 0,
            done: false,
        }
    }
}

impl ArrivalSource for GeneratorSource {
    fn next_request(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        self.t += self.arrivals.next_gap(&mut self.rng, self.t, self.duration_s);
        if self.t >= self.duration_s {
            self.done = true;
            return None;
        }
        let (p, o) = self.lengths.sample(&mut self.rng);
        let id = self.next_id;
        self.next_id += 1;
        Some(Request {
            id,
            arrival_s: self.t,
            prompt_tokens: p,
            output_tokens: o,
            class: self.class,
        })
    }
}

/// K-way merge of component sources into one time-ordered multi-class
/// stream, re-assigning ids in pop order — the streaming equivalent of
/// [`super::merge_traces`]. Ties at equal arrival times resolve to the
/// lowest component index, matching the stable sort over concatenated
/// traces.
#[derive(Debug)]
pub struct MergedSource<S: ArrivalSource> {
    sources: Vec<S>,
    heads: Vec<Option<Request>>,
    next_id: u64,
}

impl<S: ArrivalSource> MergedSource<S> {
    pub fn new(mut sources: Vec<S>) -> MergedSource<S> {
        let heads = sources.iter_mut().map(|s| s.next_request()).collect();
        MergedSource { sources, heads, next_id: 0 }
    }
}

/// Forwarding impl so heterogeneous component sets (generators mixed with
/// trace replays) can run through [`MergedSource<Box<dyn ArrivalSource>>`].
impl ArrivalSource for Box<dyn ArrivalSource + '_> {
    fn next_request(&mut self) -> Option<Request> {
        (**self).next_request()
    }
}

impl<S: ArrivalSource> ArrivalSource for MergedSource<S> {
    fn next_request(&mut self) -> Option<Request> {
        let mut best: Option<(usize, f64)> = None;
        for (i, h) in self.heads.iter().enumerate() {
            if let Some(r) = h {
                // Strict `<` keeps the first (lowest-index) head on ties —
                // exactly the stable-sort order of `merge_traces`.
                let better = match best {
                    None => true,
                    Some((_, bt)) => r.arrival_s < bt,
                };
                if better {
                    best = Some((i, r.arrival_s));
                }
            }
        }
        let (i, _) = best?;
        let mut r = self.heads[i].take().unwrap();
        self.heads[i] = self.sources[i].next_request();
        r.id = self.next_id;
        self.next_id += 1;
        Some(r)
    }
}

/// Deterministic shard filter over a full arrival stream: every shard
/// walks its own copy of the complete stream through the *same*
/// deterministic assignment function and keeps only the requests assigned
/// to it. Because the assigner is a pure state machine over the request
/// sequence (no execution-time inputs), all shards agree on the partition
/// without any cross-thread coordination, and each shard's substream is a
/// time-ordered subsequence of a time-ordered stream — exactly what the
/// discrete-event core's arrival contract requires. Memory stays O(1):
/// filtered-out requests are dropped, never buffered.
pub struct PartitionSource<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    assign: Box<dyn FnMut(&Request) -> usize + 'a>,
    shard: usize,
}

impl<'a> PartitionSource<'a> {
    /// `assign` must be deterministic over the request sequence alone and
    /// must agree across all shards of one partition (each shard builds
    /// its own instance from the same initial state).
    pub fn new(inner: Box<dyn ArrivalSource + 'a>, shard: usize,
               assign: Box<dyn FnMut(&Request) -> usize + 'a>)
        -> PartitionSource<'a> {
        PartitionSource { inner, assign, shard }
    }
}

impl ArrivalSource for PartitionSource<'_> {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            let r = self.inner.next_request()?;
            if (self.assign)(&r) == self.shard {
                return Some(r);
            }
        }
    }
}

/// Adapter over a materialized, arrival-sorted trace — the reference
/// implementation the differential tests compare the lazy generators
/// against, and the bridge for callers that already hold a `Vec<Request>`.
#[derive(Debug)]
pub struct SliceSource<'a> {
    trace: &'a [Request],
    i: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(trace: &'a [Request]) -> SliceSource<'a> {
        debug_assert!(trace.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s),
                      "SliceSource requires an arrival-sorted trace");
        SliceSource { trace, i: 0 }
    }
}

impl ArrivalSource for SliceSource<'_> {
    fn next_request(&mut self) -> Option<Request> {
        let r = self.trace.get(self.i)?.clone();
        self.i += 1;
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, merge_traces};

    fn eq_requests(a: &Request, b: &Request) -> bool {
        a.id == b.id
            && a.arrival_s.to_bits() == b.arrival_s.to_bits()
            && a.prompt_tokens == b.prompt_tokens
            && a.output_tokens == b.output_tokens
            && a.class == b.class
    }

    #[test]
    fn generator_source_matches_generate_trace_bit_for_bit() {
        for (arrivals, seed) in [
            (Arrivals::Poisson { rate: 6.0 }, 3u64),
            (Arrivals::Bursty { rate: 4.0, cv: 2.5 }, 4),
            (Arrivals::CompressedDiurnal { rate: 10.0, amplitude: 0.7,
                                           period_s: 0.0 }, 5),
            (Arrivals::Step { base: 2.0, surge: 10.0, start_frac: 0.3,
                              end_frac: 0.5 }, 6),
            (Arrivals::Week { rate: 8.0, amplitude: 0.6,
                              weekend_factor: 0.5 }, 7),
        ] {
            let eager = generate_trace(arrivals.clone(), LengthDist::ShareGpt,
                                       RequestClass::Online, 90.0, seed);
            let lazy = GeneratorSource::new(arrivals.clone(),
                                            LengthDist::ShareGpt,
                                            RequestClass::Online, 90.0, seed)
                .materialize();
            assert_eq!(eager.len(), lazy.len(), "{arrivals:?}");
            assert!(eager.iter().zip(&lazy).all(|(a, b)| eq_requests(a, b)),
                    "{arrivals:?}: stream diverged from the eager trace");
        }
    }

    #[test]
    fn merged_source_matches_merge_traces() {
        let mk = |seed| (
            generate_trace(Arrivals::Poisson { rate: 3.0 },
                           LengthDist::ShareGpt, RequestClass::Online,
                           60.0, seed),
            GeneratorSource::new(Arrivals::Poisson { rate: 3.0 },
                                 LengthDist::ShareGpt, RequestClass::Online,
                                 60.0, seed),
        );
        let mk_off = |seed| (
            generate_trace(Arrivals::Bursty { rate: 2.0, cv: 2.0 },
                           LengthDist::LongBench, RequestClass::Offline,
                           60.0, seed),
            GeneratorSource::new(Arrivals::Bursty { rate: 2.0, cv: 2.0 },
                                 LengthDist::LongBench, RequestClass::Offline,
                                 60.0, seed),
        );
        let (ea, la) = mk(11);
        let (eb, lb) = mk_off(12);
        let eager = merge_traces(vec![ea, eb]);
        let lazy = MergedSource::new(vec![la, lb]).materialize();
        assert_eq!(eager.len(), lazy.len());
        assert!(eager.iter().zip(&lazy).all(|(a, b)| eq_requests(a, b)),
                "merged stream diverged from merge_traces");
        assert!(lazy.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
    }

    #[test]
    fn sources_are_fused() {
        let mut s = GeneratorSource::new(Arrivals::Poisson { rate: 5.0 },
                                         LengthDist::ShareGpt,
                                         RequestClass::Online, 10.0, 1);
        while s.next_request().is_some() {}
        assert!(s.next_request().is_none());
        assert!(s.next_request().is_none());
        let mut m: MergedSource<GeneratorSource> = MergedSource::new(vec![]);
        assert!(m.next_request().is_none());
    }

    #[test]
    fn partition_sources_cover_the_stream_exactly_once() {
        let mk = || {
            Box::new(GeneratorSource::new(Arrivals::Poisson { rate: 6.0 },
                                          LengthDist::ShareGpt,
                                          RequestClass::Online, 60.0, 21))
                as Box<dyn ArrivalSource>
        };
        let whole = mk().materialize();
        // Deterministic round-robin assigner, rebuilt per shard.
        let assigner = || {
            let mut i = 0usize;
            Box::new(move |_: &Request| {
                let s = i % 3;
                i += 1;
                s
            }) as Box<dyn FnMut(&Request) -> usize>
        };
        let parts: Vec<Vec<Request>> = (0..3)
            .map(|k| PartitionSource::new(mk(), k, assigner()).materialize())
            .collect();
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), whole.len());
        // Each substream is time-ordered, and a k-way id-merge over the
        // parts reproduces the full stream's request ids exactly once.
        let mut ids: Vec<u64> = parts.iter().flatten().map(|r| r.id).collect();
        ids.sort_unstable();
        let want: Vec<u64> = whole.iter().map(|r| r.id).collect();
        assert_eq!(ids, want);
        for p in &parts {
            assert!(p.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        }
    }

    #[test]
    fn slice_source_round_trips() {
        let tr = generate_trace(Arrivals::Poisson { rate: 4.0 },
                                LengthDist::AzureCode, RequestClass::Online,
                                40.0, 9);
        let back = SliceSource::new(&tr).materialize();
        assert_eq!(tr.len(), back.len());
        assert!(tr.iter().zip(&back).all(|(a, b)| eq_requests(a, b)));
    }
}
