//! Std-only substrates: PRNG, statistics, JSON, tables, CLI parsing.
//! These exist because the offline vendor set has no rand/serde/clap.

pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod table;
