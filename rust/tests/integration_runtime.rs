//! End-to-end runtime integration: load real AOT artifacts, run prefill +
//! batched decode through the coordinator, and check determinism/metrics.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use ecoserve::coordinator::{Coordinator, CoordinatorConfig, FinishReason, ServeRequest};
use ecoserve::runtime::engine::Engine;
use ecoserve::runtime::tokenizer;
use ecoserve::workload::RequestClass;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("model_config.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn engine() -> Option<Engine> {
    artifacts_dir().map(|d| Engine::load(&d).expect("engine load"))
}

#[test]
fn prefill_deterministic_across_buckets() {
    let Some(eng) = engine() else { return };
    let prompt = tokenizer::encode("the quick brown fox");
    let a = eng.prefill(std::slice::from_ref(&prompt)).unwrap();
    let b = eng.prefill(std::slice::from_ref(&prompt)).unwrap();
    assert_eq!(a.logits[0], b.logits[0], "prefill must be deterministic");
    // The same prompt through a larger bucket yields the same logits:
    // bucket padding must not leak into the live sequence.
    let two = eng.prefill(&[prompt.clone(), tokenizer::encode("x")]).unwrap();
    assert_ne!(a.bucket, two.bucket);
    let max_abs: f32 = a.logits[0].iter().zip(&two.logits[0])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(max_abs < 1e-3, "bucket-invariance violated: {max_abs}");
}

#[test]
fn decode_chain_matches_prefill() {
    // Teacher-forcing consistency: prefill(p + t) last logits must match
    // decoding token t after prefill(p) — the same invariant the python
    // tests check, but through the compiled artifacts and rust KV plumbing.
    let Some(eng) = engine() else { return };
    let full = tokenizer::encode("carbon");
    let p = full[..full.len() - 1].to_vec();
    let t = full[full.len() - 1];

    let pre_full = eng.prefill(std::slice::from_ref(&full)).unwrap();

    let pre = eng.prefill(std::slice::from_ref(&p)).unwrap();
    let mut cache = eng.empty_cache(1);
    cache.copy_slot_from(0, &pre.cache, 0);
    let (logits, _) = eng
        .decode_step(&mut cache, &[t], &[p.len() as i32])
        .unwrap();

    let max_abs: f32 = logits[0].iter().zip(&pre_full.logits[0])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(max_abs < 1e-3, "decode/prefill mismatch: {max_abs}");
}

#[test]
fn batched_decode_matches_single() {
    // A sequence decoded in a shared batch must produce the same tokens as
    // alone — KV-slot isolation through the compiled decode path.
    let Some(eng) = engine() else { return };
    let run = |batch: usize, prompt: &str| -> Vec<i32> {
        let mut c = Coordinator::new(&eng, CoordinatorConfig {
            decode_batch: batch, ..Default::default()
        }).unwrap();
        c.submit(ServeRequest {
            id: 0,
            tokens: tokenizer::encode(prompt),
            max_new_tokens: 12,
            class: RequestClass::Online,
        });
        if batch > 1 {
            for i in 1..3 {
                c.submit(ServeRequest {
                    id: i,
                    tokens: tokenizer::encode(&format!("other prompt {i}")),
                    max_new_tokens: 12,
                    class: RequestClass::Online,
                });
            }
        }
        let done = c.run_to_completion().unwrap();
        done.into_iter().find(|c| c.id == 0).unwrap().output
    };
    let solo = run(1, "green computing");
    let batched = run(8, "green computing");
    assert_eq!(solo, batched, "batch neighbours changed generation");
}

#[test]
fn coordinator_serves_mixed_load() {
    let Some(eng) = engine() else { return };
    let mut c = Coordinator::new(&eng, CoordinatorConfig::default()).unwrap();
    let n = 12;
    for i in 0..n {
        c.submit(ServeRequest {
            id: i,
            tokens: tokenizer::encode(&format!("request number {i}")),
            max_new_tokens: 8 + (i as usize % 5),
            class: if i % 3 == 0 { RequestClass::Offline } else { RequestClass::Online },
        });
    }
    let done = c.run_to_completion().unwrap();
    assert_eq!(done.len(), n as usize);
    for comp in &done {
        assert!(comp.finish != FinishReason::Rejected);
        assert!(!comp.output.is_empty());
        assert!(comp.ttft_s >= 0.0 && comp.e2e_s >= comp.ttft_s);
    }
    assert!(c.stats.mean_batch_occupancy() > 1.0,
            "continuous batching never overlapped: {}", c.stats.mean_batch_occupancy());
    assert_eq!(c.stats.completed, n as usize);
}

#[test]
fn long_prompt_rejected_cleanly() {
    let Some(eng) = engine() else { return };
    let mut c = Coordinator::new(&eng, CoordinatorConfig::default()).unwrap();
    c.submit(ServeRequest {
        id: 7,
        tokens: vec![tokenizer::BOS; 4096],
        max_new_tokens: 4,
        class: RequestClass::Online,
    });
    let done = c.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish, FinishReason::Rejected);
}
