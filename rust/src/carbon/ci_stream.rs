//! File-backed grid-CI signals: a chunked [`CiStream`] reader that serves
//! `at`/`mean_over` lookups from a sliding window over a CSV trace, so a
//! year of 5-minute grid data feeds the planner's epoch-aligned forecast
//! without materializing 100k+ samples per shard. The in-memory
//! [`CiTrace`] stays the representation for synthetic profiles —
//! bitwise-unchanged — and [`CiTrace::from_file`] materializes the same
//! file through the same parser, which is exactly what the
//! streaming-vs-materialized parity test leans on.
//!
//! File schema: CSV lines `t_seconds,ci_g_per_kwh` with optional `#`
//! comments and an optional alphabetic header. Timestamps must be strictly
//! increasing on a uniform step; the file's recorded span is mapped onto
//! the run duration (`step_s = duration / n`), mirroring how
//! `CompressedDiurnal` compresses a solar day onto a short trace and how
//! `TraceRescale::fit_duration` maps request traces. CI files are curated
//! inputs, not noisy production logs, so any malformed line fails the open
//! — there is no skip-and-count mode on the carbon side.
//!
//! Concurrency: the window sits behind a `Mutex` because `&SimConfig`
//! (which owns the `CiSignal`) is shared across shard worker threads;
//! cloning a `CiStream` (as `sub_config` does per shard) shares the
//! immutable metadata but gives the clone a fresh window, so shards never
//! contend on one reader.
//!
//! Determinism: every query is answered with arithmetic identical to
//! [`CiTrace`]'s (same index clamps, same overlap-weight loop, same
//! in-order mean fold), so `CiSignal::Streaming` and a materialized
//! `CiSignal::Trace` over the same file agree bitwise.

use std::fs::File;
use std::io::{BufRead, BufReader, Lines};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Result};

use super::intensity::{CiTrace, Region};

/// Immutable facts about a validated CI file, shared by all clones of a
/// [`CiStream`].
#[derive(Debug)]
pub struct CiFileMeta {
    pub path: String,
    pub region: Region,
    /// Effective sample step in *simulation* seconds: `duration / n`.
    pub step_s: f64,
    /// Native step recorded in the file, seconds.
    pub raw_step_s: f64,
    /// Number of samples in the file.
    pub n: usize,
    /// Mean CI over the file (in-order fold, matching [`CiTrace::mean`]).
    pub mean: f64,
}

/// Summary of one validating scan over a CI file.
struct CiScan {
    raw_step_s: f64,
    n: usize,
    mean: f64,
}

/// Stream every sample of the file through `sink` while validating the
/// schema (strictly increasing timestamps, uniform step, finite
/// non-negative CI). O(1) memory — the probe passes a no-op sink, the
/// materializer pushes into a `Vec`.
fn scan_ci_file<F: FnMut(f64)>(path: &str, mut sink: F) -> Result<CiScan> {
    let f = File::open(path).map_err(|e| anyhow!("ci file {path}: {e}"))?;
    let mut awaiting_first = true;
    let mut line_no = 0u64;
    let mut prev_t: Option<f64> = None;
    let mut step: Option<f64> = None;
    let mut n = 0usize;
    let mut sum = 0.0f64;
    for line in BufReader::new(f).lines() {
        let line = line.map_err(|e| {
            anyhow!("ci file {path}: line {}: {e}", line_no + 1)
        })?;
        line_no += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut fields = t.split(',').map(str::trim);
        let (tf, cf) = (fields.next().unwrap_or(""),
                        fields.next().unwrap_or(""));
        let ts: f64 = match tf.parse() {
            Ok(v) => v,
            Err(_) if awaiting_first
                && tf.chars().any(|c| c.is_ascii_alphabetic()) => {
                awaiting_first = false;
                continue; // header row
            }
            Err(_) => bail!("ci file {path}: line {line_no}: bad \
                             timestamp '{tf}'"),
        };
        awaiting_first = false;
        let ci: f64 = cf.parse().map_err(|_| {
            anyhow!("ci file {path}: line {line_no}: bad ci value '{cf}'")
        })?;
        ensure!(ts.is_finite() && ci.is_finite() && ci >= 0.0,
                "ci file {path}: line {line_no}: non-finite or negative \
                 sample");
        if let Some(p) = prev_t {
            let gap = ts - p;
            ensure!(gap > 0.0,
                    "ci file {path}: line {line_no}: timestamps must be \
                     strictly increasing");
            match step {
                None => step = Some(gap),
                Some(s) => ensure!(
                    (gap - s).abs() <= s * 1e-6,
                    "ci file {path}: line {line_no}: non-uniform step \
                     ({gap} vs {s})"),
            }
        }
        prev_t = Some(ts);
        n += 1;
        sum += ci;
        sink(ci);
    }
    ensure!(n >= 2, "ci file {path}: needs >= 2 samples, got {n}");
    Ok(CiScan { raw_step_s: step.unwrap(), n, mean: sum / n as f64 })
}

/// Materialize a CI file into an in-memory [`CiTrace`], mapping the file's
/// extent onto `duration_s` exactly as [`CiStream::open`] does — the
/// reference the parity test compares the chunked reader against, and a
/// convenient bridge for small files.
impl CiTrace {
    pub fn from_file(path: &str, region: Region, duration_s: f64)
        -> Result<CiTrace>
    {
        ensure!(duration_s > 0.0,
                "ci file {path}: duration must be positive");
        let mut values = Vec::new();
        let scan = scan_ci_file(path, |v| values.push(v))?;
        Ok(CiTrace { region, step_s: duration_s / scan.n as f64, values })
    }
}

/// Sliding-window state over the file: `values` caches samples
/// `[start, start + values.len())` and the reader (when open) is
/// positioned to yield sample `next_idx == start + values.len()`.
struct CiWindow {
    start: usize,
    values: Vec<f64>,
    reader: Option<CiRecords>,
    next_idx: usize,
}

/// Forward-only sample iterator over the file, skipping the same
/// non-sample lines the validating scan does.
struct CiRecords {
    lines: Lines<BufReader<File>>,
    awaiting_first: bool,
}

impl CiRecords {
    fn open(path: &str) -> CiRecords {
        let f = File::open(path).unwrap_or_else(|e| {
            panic!("ci file {path}: vanished after validation: {e}")
        });
        CiRecords { lines: BufReader::new(f).lines(), awaiting_first: true }
    }

    /// Next CI sample. The file validated at open time, so running out of
    /// lines or failing to parse mid-run means the file changed under us —
    /// a caller error worth a loud panic, not a silent fallback.
    fn next_ci(&mut self, path: &str) -> f64 {
        loop {
            let line = match self.lines.next() {
                Some(Ok(l)) => l,
                Some(Err(e)) => panic!(
                    "ci file {path}: unreadable after validation: {e}"),
                None => panic!(
                    "ci file {path}: truncated after validation"),
            };
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let mut fields = t.split(',').map(str::trim);
            let tf = fields.next().unwrap_or("");
            if self.awaiting_first && tf.parse::<f64>().is_err() {
                self.awaiting_first = false;
                continue; // header row
            }
            self.awaiting_first = false;
            let cf = fields.next().unwrap_or("");
            return cf.parse().unwrap_or_else(|_| {
                panic!("ci file {path}: sample changed after validation")
            });
        }
    }
}

/// Chunked file-backed CI signal. See the module docs.
pub struct CiStream {
    meta: Arc<CiFileMeta>,
    win: Mutex<CiWindow>,
}

impl CiStream {
    /// Validate `path` and build a stream whose file extent maps onto
    /// `duration_s` (`step_s = duration / n`, matching
    /// [`CiTrace::from_file`] on the same arguments bitwise).
    pub fn open(path: &str, region: Region, duration_s: f64)
        -> Result<CiStream>
    {
        ensure!(duration_s > 0.0,
                "ci file {path}: duration must be positive");
        let scan = scan_ci_file(path, |_| {})?;
        let meta = CiFileMeta {
            path: path.to_string(),
            region,
            step_s: duration_s / scan.n as f64,
            raw_step_s: scan.raw_step_s,
            n: scan.n,
            mean: scan.mean,
        };
        Ok(CiStream {
            meta: Arc::new(meta),
            win: Mutex::new(CiWindow {
                start: 0,
                values: Vec::new(),
                reader: None,
                next_idx: 0,
            }),
        })
    }

    pub fn meta(&self) -> &CiFileMeta {
        &self.meta
    }

    /// Run `f` over the cached samples `[lo, hi]` (inclusive, already
    /// clamped to the file extent by the callers). Forward queries advance
    /// the persistent reader; a backward query rewinds to the file head
    /// and skips forward — O(file) only on rewind, O(1) amortized for the
    /// sim/planner's monotone scans.
    fn with_range<R>(&self, lo: usize, hi: usize,
                     f: impl FnOnce(&[f64]) -> R) -> R {
        debug_assert!(lo <= hi && hi < self.meta.n);
        let mut w = self.win.lock().unwrap();
        if w.reader.is_none() || lo < w.start {
            w.reader = Some(CiRecords::open(&self.meta.path));
            w.next_idx = 0;
            w.start = 0;
            w.values.clear();
        }
        // Drop cached samples below lo; skip-read if the cache runs dry
        // before reaching it.
        if w.start < lo {
            let cached_drop = (lo - w.start).min(w.values.len());
            w.values.drain(..cached_drop);
            w.start += cached_drop;
            if w.values.is_empty() {
                while w.next_idx < lo {
                    let reader = w.reader.as_mut().unwrap();
                    reader.next_ci(&self.meta.path);
                    w.next_idx += 1;
                }
                w.start = w.next_idx;
            }
        }
        // Extend the cache through hi.
        while w.start + w.values.len() <= hi {
            let reader = w.reader.as_mut().unwrap();
            let v = reader.next_ci(&self.meta.path);
            w.values.push(v);
            w.next_idx += 1;
        }
        f(&w.values[..=(hi - w.start)])
    }

    /// CI at time t — arithmetic identical to [`CiTrace::at`].
    pub fn at(&self, t_s: f64) -> f64 {
        let idx = ((t_s / self.meta.step_s) as usize).min(self.meta.n - 1);
        self.with_range(idx, idx, |v| v[0])
    }

    /// Mean CI over the whole file, precomputed at open.
    pub fn mean(&self) -> f64 {
        self.meta.mean
    }

    /// Length-weighted mean over `[t0, t1]` — arithmetic identical to
    /// [`CiTrace::mean_over`], served from the window.
    pub fn mean_over(&self, t0_s: f64, t1_s: f64) -> f64 {
        if t1_s <= t0_s {
            return self.at(t0_s);
        }
        let step_s = self.meta.step_s;
        let last = self.meta.n - 1;
        let lo = ((t0_s / step_s) as usize).min(last);
        let hi = ((t1_s / step_s) as usize).min(last).max(lo);
        self.with_range(lo, hi, |vals| {
            let mut weighted = 0.0;
            for (k, &v) in vals.iter().enumerate() {
                let i = lo + k;
                let s0 = i as f64 * step_s;
                let s1 = if i == last { f64::INFINITY } else { s0 + step_s };
                let w = (t1_s.min(s1) - t0_s.max(s0)).max(0.0);
                weighted += w * v;
            }
            weighted / (t1_s - t0_s)
        })
    }

    pub fn step_s(&self) -> f64 {
        self.meta.step_s
    }
}

impl Clone for CiStream {
    /// Clones share the immutable metadata but get a fresh window — each
    /// shard's `sub_config` reads the file through its own descriptor.
    fn clone(&self) -> CiStream {
        CiStream {
            meta: Arc::clone(&self.meta),
            win: Mutex::new(CiWindow {
                start: 0,
                values: Vec::new(),
                reader: None,
                next_idx: 0,
            }),
        }
    }
}

impl std::fmt::Debug for CiStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CiStream")
            .field("path", &self.meta.path)
            .field("n", &self.meta.n)
            .field("step_s", &self.meta.step_s)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &str) -> String {
        let path = std::env::temp_dir()
            .join(format!("ecoserve-ci-test-{name}-{}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path.to_string_lossy().into_owned()
    }

    fn sample_file(name: &str) -> String {
        let mut s = String::from("# synthetic duck curve\nt_s,ci\n");
        for i in 0..96 {
            let hour = i as f64 * 0.25;
            let ci = 300.0 - 120.0
                * (-((hour - 13.0) / 3.5).powi(2)).exp();
            s.push_str(&format!("{},{ci}\n", i * 900));
        }
        tmp(name, &s)
    }

    #[test]
    fn stream_matches_materialized_trace_bitwise() {
        let p = sample_file("parity");
        let dur = 240.0;
        let tr = CiTrace::from_file(&p, Region::California, dur).unwrap();
        let st = CiStream::open(&p, Region::California, dur).unwrap();
        assert_eq!(st.meta().n, 96);
        assert_eq!(st.step_s().to_bits(), tr.step_s.to_bits());
        assert_eq!(st.mean().to_bits(), tr.mean().to_bits());
        // Forward scan, point lookups past the extent, backward seeks,
        // and overlap-weighted windows all agree bitwise.
        for k in 0..30 {
            let t = k as f64 * 9.7;
            assert_eq!(st.at(t).to_bits(), tr.at(t).to_bits(), "at({t})");
        }
        assert_eq!(st.at(1e9).to_bits(), tr.at(1e9).to_bits());
        assert_eq!(st.at(3.0).to_bits(), tr.at(3.0).to_bits()); // rewind
        for (a, b) in [(0.0, 240.0), (10.0, 20.0), (117.3, 119.9),
                       (230.0, 500.0), (42.0, 42.0)] {
            assert_eq!(st.mean_over(a, b).to_bits(),
                       tr.mean_over(a, b).to_bits(), "mean_over({a},{b})");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn clones_get_independent_windows() {
        let p = sample_file("clone");
        let a = CiStream::open(&p, Region::California, 100.0).unwrap();
        let _ = a.at(90.0); // advance a's window to the tail
        let b = a.clone();
        // The clone starts cold and still answers head-of-file queries.
        assert_eq!(b.at(0.0).to_bits(), a.at(0.0).to_bits());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn malformed_ci_files_fail_at_open() {
        for (name, body) in [
            ("short", "t,ci\n0,200\n"),
            ("nonuniform", "0,200\n900,210\n2700,220\n"),
            ("backwards", "0,200\n900,210\n450,220\n"),
            ("garbage", "0,200\n900,duck\n1800,220\n"),
            ("negative", "0,200\n900,-5\n1800,220\n"),
        ] {
            let p = tmp(name, body);
            assert!(CiStream::open(&p, Region::California, 100.0).is_err(),
                    "{name} should fail validation");
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn header_and_comments_are_skipped() {
        let p = tmp("hdr", "# provenance note\nt_s,ci_g_per_kwh\n\
                            0,100\n900,200\n1800,300\n");
        let st = CiStream::open(&p, Region::California, 90.0).unwrap();
        assert_eq!(st.meta().n, 3);
        assert_eq!(st.meta().raw_step_s, 900.0);
        assert_eq!(st.step_s(), 30.0);
        assert_eq!(st.at(0.0), 100.0);
        assert_eq!(st.at(89.0), 300.0);
        std::fs::remove_file(&p).ok();
    }
}
