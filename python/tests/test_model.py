"""L2 model correctness: shapes, prefill/decode-chain consistency, AOT lowering."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import aot

TINY = M.ModelCfg(vocab=37, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                  head_dim=8, ffn_hidden=48, max_seq=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(TINY, seed=7)


def make_tokens(key, batch, lengths, seq):
    toks = jax.random.randint(key, (batch, seq), 3, TINY.vocab)
    pos = jnp.arange(seq)[None, :]
    return jnp.where(pos < jnp.asarray(lengths)[:, None], toks, M.PAD)


def test_prefill_shapes(params):
    tokens = make_tokens(jax.random.PRNGKey(0), 2, [5, 8], 16)
    logits, kc, vc = M.prefill(TINY, params, tokens, jnp.array([5, 8]))
    assert logits.shape == (2, TINY.vocab)
    assert kc.shape == (TINY.n_layers, 2, TINY.max_seq, TINY.n_kv_heads,
                        TINY.head_dim)
    assert vc.shape == kc.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_cache_zero_beyond_length(params):
    tokens = make_tokens(jax.random.PRNGKey(1), 2, [5, 8], 16)
    _, kc, vc = M.prefill(TINY, params, tokens, jnp.array([5, 8]))
    assert np.allclose(np.asarray(kc[:, 0, 5:]), 0.0)
    assert np.allclose(np.asarray(vc[:, 1, 8:]), 0.0)


def test_prefill_logits_match_full_forward(params):
    lengths = jnp.array([5, 12])
    tokens = make_tokens(jax.random.PRNGKey(2), 2, [5, 12], 16)
    logits, _, _ = M.prefill(TINY, params, tokens, lengths)
    all_logits = M.full_forward_ref(TINY, params, tokens, lengths)
    want = jnp.stack([all_logits[0, 4], all_logits[1, 11]])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("use_pallas", [True, False])
def test_decode_chain_matches_full_forward(params, use_pallas):
    """Teacher-forced decode after prefill reproduces full-forward logits."""
    batch, plen, total = 2, 6, 12
    lengths = jnp.array([plen] * batch)
    tokens_all = make_tokens(jax.random.PRNGKey(3), batch, [total] * batch, total)
    logits, kc, vc = M.prefill(TINY, params, tokens_all[:, :plen], lengths)
    full = M.full_forward_ref(TINY, params, tokens_all,
                              jnp.array([total] * batch))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, plen - 1]),
                               rtol=3e-5, atol=3e-5)
    for t in range(plen, total):
        tok = tokens_all[:, t]
        pos = jnp.full((batch,), t, jnp.int32)
        logits, kc, vc = M.decode_step(TINY, params, kc, vc, tok, pos,
                                       use_pallas=use_pallas)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=1e-4, atol=1e-4,
            err_msg=f"step {t} (pallas={use_pallas})")


def test_decode_pallas_matches_ref_attention(params):
    """The Pallas and jnp decode paths agree step-by-step."""
    batch = 2
    kc, vc = M.empty_cache(TINY, batch)
    kc2, vc2 = M.empty_cache(TINY, batch)
    tok = jnp.array([M.BOS, M.BOS], jnp.int32)
    for t in range(4):
        pos = jnp.full((batch,), t, jnp.int32)
        l1, kc, vc = M.decode_step(TINY, params, kc, vc, tok, pos, True)
        l2, kc2, vc2 = M.decode_step(TINY, params, kc2, vc2, tok, pos, False)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-4)
        tok = jnp.argmax(l1, axis=-1).astype(jnp.int32)


def test_batch_independence(params):
    """A sequence's logits must not depend on its batch neighbours."""
    tokens = make_tokens(jax.random.PRNGKey(4), 2, [7, 3], 16)
    lengths = jnp.array([7, 3])
    both, _, _ = M.prefill(TINY, params, tokens, lengths)
    solo, _, _ = M.prefill(TINY, params, tokens[:1], lengths[:1])
    np.testing.assert_allclose(np.asarray(both[0]), np.asarray(solo[0]),
                               rtol=3e-5, atol=3e-5)


def test_rope_positions_distinguish(params):
    """Same token at different positions yields different K."""
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 8))
    r0 = M.rope(x, jnp.array([0]))
    r5 = M.rope(x, jnp.array([5]))
    assert not np.allclose(np.asarray(r0), np.asarray(r5))
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(r0)),
                               np.asarray(jnp.linalg.norm(r5)), rtol=1e-5)


def test_flatten_params_deterministic(params):
    n1 = [n for n, _ in aot.flatten_params(params)]
    n2 = [n for n, _ in aot.flatten_params(M.init_params(TINY, seed=7))]
    assert n1 == n2
    assert len(n1) == 3 + 9 * TINY.n_layers
    assert "layers.0.w_q" in n1 and "embed" in n1


def test_weights_bin_roundtrip(params):
    """ECOW format parses back to identical tensors (mirror of weights.rs)."""
    named = aot.flatten_params(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        aot.write_weights(path, named)
        import struct
        with open(path, "rb") as f:
            assert f.read(4) == b"ECOW"
            ver, cnt = struct.unpack("<II", f.read(8))
            assert ver == 1 and cnt == len(named)
            for name, leaf in named:
                nlen = struct.unpack("<H", f.read(2))[0]
                assert f.read(nlen).decode() == name
                dt, nd = struct.unpack("<BB", f.read(2))
                assert dt == 0 and nd == leaf.ndim
                dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
                assert tuple(dims) == leaf.shape
                data = np.frombuffer(f.read(4 * int(leaf.size)), dtype="<f4")
                np.testing.assert_array_equal(
                    data.reshape(leaf.shape), np.asarray(leaf))
            assert f.read() == b""


def test_aot_lowering_smoke(params):
    """Prefill + decode lower to HLO text with the expected parameter count."""
    text = aot.to_hlo_text(aot.lower_decode(TINY, params, batch=2))
    assert "ENTRY" in text
    nparams = len(aot.flatten_params(params)) + 4  # kc, vc, token, pos
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == nparams
    text_p = aot.to_hlo_text(aot.lower_prefill(TINY, params, batch=1, seq=16))
    assert "ENTRY" in text_p
