//! Component aging / reliability models (paper §4.1.4, Fig 14).
//!
//! The paper's CPU model is a confidential 7 nm foundry composite; we use a
//! published-parameter surrogate calibrated to its one disclosed datapoint:
//! at 20% utilization over 5 years the CPU ages only 0.8 effective years.
//! SSD wear follows P/E-cycle proportionality (ages 1 year per 5 calendar
//! years at 20% duty), and DRAM follows the cited retention studies (no
//! meaningful error-rate increase before ~10 years).

/// Effective CPU age (years) after `years` deployed at `util` (0..1).
///
/// Aging rate = static (NBTI-ish baseline at nominal voltage) + a
/// utilization-proportional dynamic term (electromigration / hot-carrier):
/// rate = 0.08 + 0.4·util, so 5y @ 20% → (0.08 + 0.08)·5 = 0.8y.
pub fn cpu_effective_age(years: f64, util: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    years * (0.08 + 0.4 * u)
}

/// Effective SSD age (years): proportional to write duty. The paper's
/// bound assumes the SSD writes whenever the CPU is active, so duty = util.
pub fn ssd_effective_age(years: f64, write_duty: f64) -> f64 {
    years * write_duty.clamp(0.0, 1.0)
}

/// DRAM wear-out onset (years of *intense* use before retention errors
/// meaningfully increase) per the cited IRPS/Cielo studies.
pub const DRAM_WEAROUT_YEARS: f64 = 10.0;

/// Deployed years at `util` before DRAM retention errors meaningfully
/// increase. Retention aging scales with activity (half-weighted, floored
/// at 10% to keep near-idle hosts finite) — the single source of truth for
/// both [`dram_is_safe`] and [`max_safe_host_lifetime`], which previously
/// duplicated (and could drift on) this formula.
pub fn dram_safe_lifetime_years(util: f64) -> f64 {
    DRAM_WEAROUT_YEARS * 0.5 / util.clamp(0.0, 1.0).max(0.1)
}

/// Whether DRAM at `util` remains reliability-safe after `years`.
pub fn dram_is_safe(years: f64, util: f64) -> bool {
    years < dram_safe_lifetime_years(util)
}

/// Max host lifetime (years) such that every component stays within its
/// effective-age budget (CPU budget ≈ 5 design-years, SSD endurance-years).
pub fn max_safe_host_lifetime(util: f64, cpu_budget_years: f64,
                              ssd_budget_years: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    let cpu_lt = cpu_budget_years / (0.08 + 0.4 * u);
    let ssd_lt = if u <= 0.0 { f64::INFINITY } else { ssd_budget_years / u };
    cpu_lt.min(ssd_lt).min(dram_safe_lifetime_years(u))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_point() {
        // 5 years at 20% utilization → 0.8 effective years (Fig 14).
        assert!((cpu_effective_age(5.0, 0.2) - 0.8).abs() < 1e-12);
        // SSD: 1 year effective over the same span.
        assert!((ssd_effective_age(5.0, 0.2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn aging_monotone_in_util() {
        assert!(cpu_effective_age(5.0, 0.8) > cpu_effective_age(5.0, 0.2));
        assert!(cpu_effective_age(5.0, 1.0) <= 5.0 * 0.48 + 1e-12);
    }

    #[test]
    fn nine_year_recycle_is_safe() {
        // EcoServe's Recycle extends hosts to 9 years at low AI-inference
        // utilization; the model must allow it.
        let lt = max_safe_host_lifetime(0.2, 5.0, 2.5);
        assert!(lt > 9.0, "max lifetime {lt}");
        assert!(dram_is_safe(9.0, 0.2));
    }

    #[test]
    fn heavy_use_limits_lifetime() {
        let lt = max_safe_host_lifetime(1.0, 5.0, 2.5);
        assert!(lt < 6.0, "max lifetime {lt}");
    }

    #[test]
    fn dram_safety_check_and_lifetime_bound_agree() {
        // Both callers must sit on the same wear formula: safe strictly
        // below the bound, unsafe at and beyond it.
        for util in [0.0, 0.05, 0.2, 0.5, 1.0] {
            let lt = dram_safe_lifetime_years(util);
            assert!(dram_is_safe(lt - 1e-9, util), "util {util}");
            assert!(!dram_is_safe(lt, util), "util {util}");
            assert!(max_safe_host_lifetime(util, 1e9, 1e9) <= lt + 1e-12);
        }
    }
}
