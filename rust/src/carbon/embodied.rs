//! Embodied-carbon model (paper §3.1, Table 1, Figs 1/3/4/5).
//!
//! Implements the paper's component-level coefficients exactly:
//!
//! | component      | kgCO₂e                         | source (per paper)   |
//! |----------------|--------------------------------|----------------------|
//! | SoC            | tech & area dependent          | ACT / iMec           |
//! | DDR4/LPDDR5    | 0.29 / GB                      | TechInsights         |
//! | GDDR6          | 0.36 / GB                      | TechInsights         |
//! | HBM2           | 0.28 / GB                      | TechInsights         |
//! | HBM3e          | 0.24 / GB                      | TechInsights         |
//! | SSD            | 0.110 / GB                     | Dell R740 LCA+SCARIF |
//! | PCB            | 0.048 / cm² (12 layer)         | Dell R740 LCA        |
//! | Ethernet card  | 4.91                           | Dell R740 LCA        |
//! | HDD controller | 5.136                          | Dell R740 LCA        |
//! | Cooling        | 7.877 / 100 W TDP              | scaled w/ TDP        |
//! | PDN / PSU      | 3.27 / 100 W TDP               | Schneider            |
//!
//! The SoC die model follows ACT's structure (carbon-per-area by process
//! node, yield-adjusted); per-node CPA values are calibrated to ACT/iMec
//! trends such that an A100-class 7 nm 826 mm² die lands near 25 kgCO₂e —
//! reproducing Fig 4's "ACT SoC ≈ 20% of GPU total" observation.

use crate::hw::{GpuSpec, MemTech};
use crate::hw::platform::{HostSpec, Platform};

/// kgCO₂e per GB of memory by technology (Table 1; GDDR5/DDR5/HBM2e/HBM3
/// interpolated from the published bit-density trend, Fig 3).
pub fn mem_kg_per_gb(tech: MemTech) -> f64 {
    match tech {
        MemTech::Ddr4 | MemTech::Lpddr5 => 0.29,
        MemTech::Ddr5 => 0.27,
        MemTech::Gddr5 => 0.40,
        MemTech::Gddr6 => 0.36,
        MemTech::Hbm2 => 0.28,
        MemTech::Hbm2e => 0.27,
        MemTech::Hbm3 => 0.26,
        MemTech::Hbm3e => 0.24,
    }
}

/// SSD: 0.110 kgCO₂e/GB (conservative vs the 0.160 academic estimate).
pub const SSD_KG_PER_GB: f64 = 0.110;
/// Mainboard PWB: 0.048 kgCO₂e/cm² at 12 layers (Dell R740: 1925 cm² → 92 kg...
/// the paper quotes the R740 total LCA; the per-cm² coefficient is theirs).
pub const PCB_KG_PER_CM2: f64 = 0.048;
pub const NIC_KG: f64 = 4.91;
pub const HDD_CONTROLLER_KG: f64 = 5.136;
pub const COOLING_KG_PER_100W: f64 = 7.877;
pub const PDN_KG_PER_100W: f64 = 3.27;

/// ACT-style carbon-per-area (kgCO₂e per cm² of *good* die) by node.
/// Values rise toward advanced nodes (more masks/EUV energy, lower yield),
/// matching ACT/iMec PPACE trends.
pub fn die_cpa_kg_per_cm2(process_nm: f64) -> f64 {
    // Piecewise-linear over the calibration points.
    const PTS: &[(f64, f64)] = &[
        (28.0, 1.2), (16.0, 1.6), (14.0, 1.65), (12.0, 1.7),
        (8.0, 2.0), (7.0, 2.5), (5.0, 3.0), (4.0, 3.3), (3.0, 3.8),
    ];
    if process_nm >= PTS[0].0 {
        return PTS[0].1;
    }
    for w in PTS.windows(2) {
        let (n0, c0) = w[0];
        let (n1, c1) = w[1];
        if process_nm <= n0 && process_nm >= n1 {
            let t = (n0 - process_nm) / (n0 - n1);
            return c0 + t * (c1 - c0);
        }
    }
    PTS.last().unwrap().1
}

/// Embodied carbon of a logic die.
pub fn die_kg(area_mm2: f64, process_nm: f64) -> f64 {
    area_mm2 / 100.0 * die_cpa_kg_per_cm2(process_nm)
}

pub fn cooling_kg(tdp_w: f64) -> f64 {
    tdp_w / 100.0 * COOLING_KG_PER_100W
}

pub fn pdn_kg(tdp_w: f64) -> f64 {
    tdp_w / 100.0 * PDN_KG_PER_100W
}

/// Component-wise embodied breakdown (kgCO₂e). Rendered by Figs 1/4/5.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub soc: f64,
    pub memory: f64,
    pub storage: f64,
    pub pcb: f64,
    pub cooling: f64,
    pub pdn: f64,
    pub nic: f64,
    pub hdd_controller: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.soc + self.memory + self.storage + self.pcb + self.cooling
            + self.pdn + self.nic + self.hdd_controller
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.soc += other.soc;
        self.memory += other.memory;
        self.storage += other.storage;
        self.pcb += other.pcb;
        self.cooling += other.cooling;
        self.pdn += other.pdn;
        self.nic += other.nic;
        self.hdd_controller += other.hdd_controller;
    }

    pub fn scaled(&self, f: f64) -> Breakdown {
        Breakdown {
            soc: self.soc * f,
            memory: self.memory * f,
            storage: self.storage * f,
            pcb: self.pcb * f,
            cooling: self.cooling * f,
            pdn: self.pdn * f,
            nic: self.nic * f,
            hdd_controller: self.hdd_controller * f,
        }
    }
}

/// Embodied breakdown of one GPU board (Fig 4).
pub fn gpu_embodied(g: &GpuSpec) -> Breakdown {
    Breakdown {
        soc: die_kg(g.die_mm2, g.process_nm),
        memory: g.mem_gb * mem_kg_per_gb(g.mem_tech),
        pcb: g.pcb_cm2 * PCB_KG_PER_CM2,
        cooling: cooling_kg(g.tdp_w),
        pdn: pdn_kg(g.tdp_w),
        ..Default::default()
    }
}

/// Embodied breakdown of a host system (Fig 5's "host" share).
pub fn host_embodied(h: &HostSpec) -> Breakdown {
    Breakdown {
        soc: die_kg(h.cpu.die_mm2, h.cpu.process_nm),
        memory: h.dram_gb * mem_kg_per_gb(h.dram_tech),
        storage: h.ssd_gb * SSD_KG_PER_GB,
        pcb: h.pcb_cm2 * PCB_KG_PER_CM2,
        cooling: cooling_kg(h.tdp_w()),
        pdn: pdn_kg(h.tdp_w()),
        nic: h.nic_count as f64 * NIC_KG,
        hdd_controller: h.hdd_count as f64 * HDD_CONTROLLER_KG,
    }
}

/// Whole-platform embodied carbon split into (host, gpus) (Figs 1/5/6).
pub fn platform_embodied(p: &Platform) -> (Breakdown, Breakdown) {
    let host = host_embodied(&p.host);
    let gpus = gpu_embodied(&p.gpu).scaled(p.gpu_count as f64);
    (host, gpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{self, platform};

    #[test]
    fn table1_coefficients() {
        assert_eq!(mem_kg_per_gb(MemTech::Ddr4), 0.29);
        assert_eq!(mem_kg_per_gb(MemTech::Gddr6), 0.36);
        assert_eq!(mem_kg_per_gb(MemTech::Hbm2), 0.28);
        assert_eq!(mem_kg_per_gb(MemTech::Hbm3e), 0.24);
        assert_eq!(SSD_KG_PER_GB, 0.110);
        assert_eq!(PCB_KG_PER_CM2, 0.048);
    }

    #[test]
    fn newer_dram_is_cleaner_per_gb() {
        // Fig 3: higher bit-density tech → lower kg/GB.
        assert!(mem_kg_per_gb(MemTech::Hbm3e) < mem_kg_per_gb(MemTech::Hbm2));
        assert!(mem_kg_per_gb(MemTech::Gddr6) < mem_kg_per_gb(MemTech::Gddr5));
    }

    #[test]
    fn cpa_monotone_toward_advanced_nodes() {
        assert!(die_cpa_kg_per_cm2(5.0) > die_cpa_kg_per_cm2(7.0));
        assert!(die_cpa_kg_per_cm2(7.0) > die_cpa_kg_per_cm2(16.0));
        // Interpolation stays within calibration endpoints.
        let c6 = die_cpa_kg_per_cm2(6.0);
        assert!(c6 > 2.5 && c6 < 3.0);
    }

    #[test]
    fn a100_calibration() {
        // DESIGN.md: A100 die ≈ 25 kg, board total ≈ 120 kg (Fig 21's
        // baseline GPU embodied figure).
        let a100 = hw::gpu("A100-40").unwrap();
        let b = gpu_embodied(a100);
        assert!((b.soc - 20.65).abs() < 1.0, "soc {}", b.soc);
        assert!(b.total() > 95.0 && b.total() < 135.0, "total {}", b.total());
        // SoC ≈ 20% of board total (Fig 4's observation about ACT).
        let frac = b.soc / b.total();
        assert!(frac > 0.12 && frac < 0.30, "soc frac {frac}");
    }

    #[test]
    fn l4_vs_h100_ratio() {
        // Paper: "an NVIDIA L4 incurs 3× lower embodied carbon" than H100.
        let l4 = gpu_embodied(hw::gpu("L4").unwrap()).total();
        let h100 = gpu_embodied(hw::gpu("H100").unwrap()).total();
        let ratio = h100 / l4;
        assert!(ratio > 2.3 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn host_dominates_instance_embodied() {
        // Fig 5: host-processing systems account for over half of the
        // embodied carbon of the 8xA100 Azure instance.
        let p = platform::azure_nd96_a100();
        let (host, gpus) = platform_embodied(&p);
        let frac = host.total() / (host.total() + gpus.total());
        assert!(frac > 0.5, "host frac {frac}");
        // Memory + storage ≈ 36% of instance embodied (paper §4.1.3 fn 1).
        let ms = (host.memory + host.storage)
            / (host.total() + gpus.total());
        assert!(ms > 0.25 && ms < 0.50, "mem+storage frac {ms}");
    }

    #[test]
    fn gpu_generations_trend() {
        // Fig 4: embodied carbon rises across generations.
        let names = ["K80", "V100", "A100-40", "H100"];
        let totals: Vec<f64> = names.iter()
            .map(|n| gpu_embodied(hw::gpu(n).unwrap()).total())
            .collect();
        for w in totals.windows(2) {
            assert!(w[1] > w[0] * 0.85, "non-rising: {totals:?}");
        }
        assert!(totals[3] > totals[0]);
    }

    #[test]
    fn breakdown_add_and_scale() {
        let a100 = hw::gpu("A100-40").unwrap();
        let b = gpu_embodied(a100);
        let mut two = b.clone();
        two.add(&b);
        assert!((two.total() - b.scaled(2.0).total()).abs() < 1e-9);
    }
}
