//! Parallel sweep runner: executes a set of scenarios over std::thread
//! scoped workers with deterministic per-scenario seeds, and renders the
//! combined [`SweepReport`] as machine-readable JSON (util::json) and a
//! human summary table (util::table).
//!
//! Re-provisioning scenarios run one fused demand pass per design point
//! (`planner::fused::DemandProfile`) that feeds both the peak-window plan
//! and the rolling-horizon controller, which itself re-solves the epoch
//! ILP only when the demand histogram actually moved
//! (`planner::horizon::IncrementalPlanner`) — the sweep stays
//! byte-identical while planning cost scales with demand *change*, not
//! epoch count.
//!
//! Stderr is deterministic too: each worker brackets its scenario with
//! `log::capture_begin`/`capture_end`, and the buffered lines replay in
//! scenario-selection order after the parallel scope — the same sweep at
//! 1 and 8 threads prints byte-identical warnings. Only the opt-in
//! `--progress` heartbeat bypasses the buffer (it is wall-clock-driven
//! and excluded from every determinism gate).

use super::{scenario_seed, CiProfile, Overrides, Scenario, ScenarioOutcome,
            TraceOverride};
use crate::obs::{ObsArtifacts, ObsSettings};
use crate::util::json::Json;
use crate::util::log;
use crate::util::table::{fnum, Table};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Sweep execution parameters.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker threads; 0 = one per available core (capped to the number
    /// of scenarios). Thread count never affects the report bytes.
    pub threads: usize,
    /// Master seed; per-scenario seeds derive from it and the name.
    pub seed: u64,
    /// Trace duration per scenario, seconds.
    pub duration_s: f64,
    /// Force a CI-signal shape on every scenario (the `--ci-trace` knob);
    /// `None` keeps each scenario's own profile.
    pub ci_profile: Option<CiProfile>,
    /// Override the re-provisioning epoch for rolling-horizon scenarios
    /// (the `--epoch` knob); `None` keeps each scenario's own epoch.
    pub epoch_s: Option<f64>,
    /// Run every scenario on the sharded runtime with up to N shard
    /// worker threads (the `--shards` knob); `None` keeps the unsharded
    /// engine. Outcome bytes are invariant in N.
    pub shards: Option<usize>,
    /// Force a provisioning cold-start delay in seconds on every scenario
    /// (the `--coldstart` knob); `None` keeps each scenario's own delay.
    pub coldstart_s: Option<f64>,
    /// Force a keep-alive policy on every scenario (the `--keepalive`
    /// knob); `None` keeps each scenario's own policy.
    pub keepalive: Option<crate::sim::KeepAlivePolicy>,
    /// Replace every scenario's workload mix with a single replayed
    /// request trace (the `--trace` knob); `None` keeps each scenario's
    /// own workloads.
    pub trace: Option<TraceOverride>,
    /// Replace every scenario's CI profile with a streamed grid-CI file
    /// (the `--ci-file` knob); wins over `ci_profile` when both are set.
    pub ci_file: Option<String>,
    /// Write observability artifacts (`<name>.timeline.csv`,
    /// `<name>.spans.json`, `<name>.profile.json`) into this directory
    /// (the `--obs-dir` knob); `None` keeps the recorders detached and
    /// the engine byte-identical to an unobserved run.
    pub obs_dir: Option<String>,
    /// Fleet-timeline sample interval, seconds (`--obs-interval`).
    pub obs_interval_s: f64,
    /// Span-sampling rate in [0, 1] (`--trace-jobs-rate`).
    pub trace_jobs_rate: f64,
    /// Wall-clock progress heartbeat period, seconds (`--progress`);
    /// works with or without `obs_dir`.
    pub progress_s: Option<f64>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { threads: 0, seed: 42, duration_s: 180.0,
                      ci_profile: None, epoch_s: None, shards: None,
                      coldstart_s: None, keepalive: None, trace: None,
                      ci_file: None, obs_dir: None, obs_interval_s: 60.0,
                      trace_jobs_rate: 0.05, progress_s: None }
    }
}

/// Combined result of one sweep invocation.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub seed: u64,
    pub duration_s: f64,
    /// Outcomes sorted by scenario name (stable across thread counts).
    pub outcomes: Vec<ScenarioOutcome>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        let scenarios: Vec<Json> =
            self.outcomes.iter().map(|o| o.to_json()).collect();
        Json::obj()
            .set("master_seed", format!("{:#018x}", self.seed))
            .set("duration_s", self.duration_s)
            .set("scenarios", scenarios)
    }

    /// Human-readable summary (latency in ms, SLO in %). The `trunc`
    /// column surfaces context-cap prompt clipping; pair the table with
    /// [`SweepReport::truncation_warnings`]. `peak-jobs` is the streaming
    /// core's arena high-water mark — at production trace lengths it
    /// should sit orders of magnitude below `req`.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(&[
            "scenario", "carbon kg", "op kg", "emb kg", "TTFT p50 ms",
            "TTFT p90 ms", "TPOT p50 ms", "SLO %", "util %", "gpus",
            "srv-hrs", "req", "peak-jobs", "trunc",
        ]);
        for o in &self.outcomes {
            t.row(&[
                o.name.clone(),
                fnum(o.carbon_kg()),
                fnum(o.op_kg),
                fnum(o.emb_kg),
                fnum(o.ttft_p50_s * 1e3),
                fnum(o.ttft_p90_s * 1e3),
                fnum(o.tpot_p50_s * 1e3),
                fnum(100.0 * o.slo_attainment),
                fnum(100.0 * o.extras.get("util_fleet_mean")
                                     .copied().unwrap_or(0.0)),
                format!("{}", o.fleet_gpus),
                fnum(o.provisioned_server_hours),
                format!("{}", o.requests),
                format!("{}", o.peak_live_jobs),
                format!("{}", o.truncated_prompts),
            ]);
        }
        t
    }

    /// One warning line per scenario that silently clipped prompts to the
    /// simulator's context cap.
    pub fn truncation_warnings(&self) -> Vec<String> {
        self.outcomes.iter()
            .filter(|o| o.truncated_prompts > 0)
            .map(|o| format!(
                "warning: {}: {} of {} prompts clipped to {} tokens \
                 (sim context cap)",
                o.name, o.truncated_prompts, o.requests,
                crate::sim::MAX_PROMPT_TOKENS))
            .collect()
    }
}

fn resolve_threads(requested: usize, jobs: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let n = if requested == 0 { auto } else { requested };
    n.clamp(1, jobs.max(1))
}

/// Resolve the sweep's observability knobs into recorder settings;
/// `None` when nothing is recorded (the byte-neutral default).
fn obs_settings(cfg: &SweepConfig) -> Option<ObsSettings> {
    match (&cfg.obs_dir, cfg.progress_s) {
        (Some(_), _) => Some(ObsSettings {
            timeline_interval_s: Some(cfg.obs_interval_s.max(1e-3)),
            trace_jobs_rate: cfg.trace_jobs_rate.clamp(0.0, 1.0),
            profile: true,
            progress_s: cfg.progress_s,
        }),
        (None, Some(p)) => Some(ObsSettings::progress_only(p)),
        (None, None) => None,
    }
}

/// Best-effort artifact writes: a full disk or bad permission degrades to
/// a buffered warning, never a lost sweep.
fn write_artifacts(dir: &str, name: &str, art: &ObsArtifacts) {
    let files = [("timeline.csv", &art.timeline_csv),
                 ("spans.json", &art.spans_json),
                 ("profile.json", &art.profile_json)];
    for (ext, body) in files {
        if let Some(body) = body {
            let path = format!("{dir}/{name}.{ext}");
            if let Err(e) = std::fs::write(&path, body) {
                log::warn(&format!("warning: cannot write {path}: {e}"));
            }
        }
    }
}

/// Run scenarios in parallel. Results are slotted by scenario index and
/// then sorted by name, so the report is byte-identical for any thread
/// count; per-scenario seeds come from [`scenario_seed`]. Log lines are
/// buffered per scenario and replayed in selection order, so stderr is
/// deterministic across thread counts too.
pub fn run_sweep(scenarios: &[Box<dyn Scenario>], cfg: &SweepConfig) -> SweepReport {
    let n = scenarios.len();
    let threads = resolve_threads(cfg.threads, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(ScenarioOutcome, Vec<String>)>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let obs = obs_settings(cfg);
    if let Some(dir) = &cfg.obs_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            log::warn(&format!("warning: cannot create obs dir {dir}: {e}"));
        }
    }

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let sc = &scenarios[i];
                let seed = scenario_seed(cfg.seed, sc.name());
                let ov = Overrides {
                    ci_profile: cfg.ci_profile.clone(),
                    epoch_s: cfg.epoch_s,
                    shards: cfg.shards,
                    coldstart_s: cfg.coldstart_s,
                    keepalive: cfg.keepalive,
                    trace: cfg.trace.clone(),
                    ci_file: cfg.ci_file.clone(),
                };
                log::capture_begin();
                let outcome = match &obs {
                    None => sc.run_with(seed, cfg.duration_s, &ov),
                    Some(settings) => {
                        let (outcome, art) =
                            sc.run_observed(seed, cfg.duration_s, &ov,
                                            settings);
                        if let Some(dir) = &cfg.obs_dir {
                            write_artifacts(dir, sc.name(), &art);
                        }
                        outcome
                    }
                };
                let lines = log::capture_end();
                *slots[i].lock().unwrap() = Some((outcome, lines));
            });
        }
    });

    let mut outcomes: Vec<ScenarioOutcome> = Vec::with_capacity(n);
    for m in slots {
        let (outcome, lines) = m
            .into_inner()
            .expect("sweep worker poisoned a result slot")
            .expect("sweep worker skipped a scenario");
        log::replay(&lines);
        outcomes.push(outcome);
    }
    outcomes.sort_by(|a, b| a.name.cmp(&b.name));
    SweepReport { seed: cfg.seed, duration_s: cfg.duration_s, outcomes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_resolution() {
        assert_eq!(resolve_threads(4, 6), 4);
        assert_eq!(resolve_threads(16, 6), 6);
        assert!(resolve_threads(0, 6) >= 1);
        assert_eq!(resolve_threads(3, 0), 1);
    }

    #[test]
    fn single_scenario_sweep_produces_table_and_json() {
        let scenarios = super::super::catalog::by_names(&["online-latency"]).unwrap();
        let cfg = SweepConfig { threads: 2, seed: 11, duration_s: 30.0,
                                ..Default::default() };
        let r = run_sweep(&scenarios, &cfg);
        assert_eq!(r.outcomes.len(), 1);
        let o = &r.outcomes[0];
        assert_eq!(o.name, "online-latency");
        assert!(o.requests > 0 && o.completed <= o.requests);
        assert!((0.0..=1.0).contains(&o.slo_attainment));
        let table = r.summary_table().render();
        assert!(table.contains("online-latency"));
        let json = r.to_json().to_string();
        assert!(json.contains("\"scenarios\""));
        assert!(Json::parse(&json).is_ok());
    }

    #[test]
    fn truncation_is_surfaced_for_long_context_scenarios() {
        // LongBench prompts exceed the sim's 8192-token cap often; the
        // clipping must be counted and warned about, not silent.
        let scenarios = super::super::catalog::by_names(&["offline-batch"]).unwrap();
        let cfg = SweepConfig { threads: 1, seed: 3, duration_s: 30.0,
                                ..Default::default() };
        let r = run_sweep(&scenarios, &cfg);
        assert!(r.outcomes[0].truncated_prompts > 0,
                "expected clipped LongBench prompts");
        let w = r.truncation_warnings();
        assert_eq!(w.len(), 1);
        assert!(w[0].contains("offline-batch") && w[0].contains("8192"));
        assert!(r.summary_table().render().contains("trunc"));
    }
}
