//! Roofline performance / energy model (paper Fig 8, §4.1.2).
//!
//! The paper's planner consumes *offline profiling models* of per-phase
//! latency and energy; with no fleet available these are analytical
//! rooflines over the hw catalog: time = max(compute, memory) with
//! device-and-phase efficiency caps, plus a TP communication term for
//! PCIe-attached GPUs. Calibrated to the published shape: prefill is
//! compute-bound, decode is bandwidth-bound, H100 wins large prompts,
//! A100 wins decode carbon (Fig 12), CPUs are decode-viable (Fig 8).

use crate::carbon::operational::{busy_energy_j, server_power, Phase,
                                 CPU_POWER_GAMMA, GPU_POWER_GAMMA};
use crate::hw::{CpuSpec, GpuSpec};
use crate::models::LlmSpec;

/// Which roofline limb binds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

/// Device abstraction shared by GPUs and CPUs.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    /// Peak dense FP16/BF16, FLOP/s.
    pub peak_flops: f64,
    /// Peak memory bandwidth, B/s.
    pub mem_bw: f64,
    pub mem_gb: f64,
    pub tdp_w: f64,
    pub idle_w: f64,
    /// Achievable fraction of peak FLOPs (prefill-like GEMMs).
    pub mfu_cap: f64,
    /// Achievable fraction of peak bandwidth (decode-like streaming).
    pub mbu_cap: f64,
    pub power_gamma: f64,
    /// Per-phase DVFS operating points: clock scale applied during
    /// prefill (compute-bound) and decode (memory-bound). 1.0 = stock
    /// clocks, bit-identical to the unscaled model. Decode is the natural
    /// downclock target — bandwidth-bound work loses little latency while
    /// dynamic power falls ~f³ ("Towards Sustainable LLM Serving").
    pub prefill_freq: f64,
    pub decode_freq: f64,
}

impl Device {
    pub fn from_gpu(g: &GpuSpec) -> Device {
        // H100's HBM3 at low arithmetic intensity sustains a smaller
        // fraction of peak than A100's HBM2 (the paper's "low MBU"
        // observation, Fig 12); leaner GDDR cards sit lower still.
        let (mfu, mbu) = match g.name {
            "H100" => (0.60, 0.55),
            "GH200" => (0.62, 0.60),
            "A100-40" | "A100-80" => (0.55, 0.70),
            "L4" | "T4" => (0.45, 0.60),
            _ => (0.50, 0.65),
        };
        Device {
            name: g.name.to_string(),
            peak_flops: g.fp16_tflops * 1e12,
            mem_bw: g.mem_bw_gbs * 1e9,
            mem_gb: g.mem_gb,
            tdp_w: g.tdp_w,
            idle_w: g.idle_w,
            mfu_cap: mfu,
            mbu_cap: mbu,
            power_gamma: GPU_POWER_GAMMA,
            prefill_freq: 1.0,
            decode_freq: 1.0,
        }
    }

    pub fn from_cpu(c: &CpuSpec, dram_gb: f64) -> Device {
        Device {
            name: c.name.to_string(),
            peak_flops: c.bf16_tflops * 1e12,
            mem_bw: c.mem_bw_gbs * 1e9,
            mem_gb: dram_gb,
            tdp_w: c.tdp_w,
            idle_w: c.idle_w,
            mfu_cap: 0.65,
            mbu_cap: 0.80,
            power_gamma: CPU_POWER_GAMMA,
            prefill_freq: 1.0,
            decode_freq: 1.0,
        }
    }

    /// The DVFS clock scale for a phase.
    pub fn freq_scale(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Prefill => self.prefill_freq,
            Phase::Decode => self.decode_freq,
        }
    }
}

/// Performance of one phase execution.
#[derive(Debug, Clone, Copy)]
pub struct PhasePerf {
    pub latency_s: f64,
    /// Whole-server draw (all `tp` devices) over the phase, from the
    /// shared `carbon::operational` power curve at the achieved
    /// utilization — the number the simulator's meter integrates.
    pub power_w: f64,
    pub energy_j: f64,
    /// Achieved fraction of device peak FLOPs.
    pub mfu: f64,
    /// Achieved fraction of device peak bandwidth.
    pub mbu: f64,
    pub bound: Bound,
}

/// PCIe interconnect bandwidth for TP collectives (paper uses PCIe GPUs).
pub const TP_LINK_BW: f64 = 64e9;
/// Saturation constant: tokens needed to reach peak MFU grow roughly
/// quadratically with chip size (tile + wave quantization on more SMs) —
/// this is what makes the A100 preferable for small prompts and the H100
/// for large ones (paper Fig 12).
pub const SAT_TOKENS_PER_TFLOP2: f64 = 0.014;

/// Fraction of the MFU cap achievable with `tokens` of prefill work.
pub fn prefill_saturation(dev: &Device, tokens: usize) -> f64 {
    let t0 = SAT_TOKENS_PER_TFLOP2 * (dev.peak_flops / 1e12).powi(2);
    tokens as f64 / (tokens as f64 + t0)
}
/// Fixed per-kernel-launch / framework overhead.
pub const DISPATCH_OVERHEAD_S: f64 = 40e-6;

/// Core roofline: time for (flops, bytes) on `dev`, with TP sharding and
/// an all-reduce term of `comm_bytes` per device pair hop.
pub fn phase_time(dev: &Device, flops: f64, bytes: f64, tp: usize,
                  comm_bytes: f64) -> (f64, Bound) {
    let tp_f = tp as f64;
    let t_compute = flops / tp_f / (dev.peak_flops * dev.mfu_cap);
    let t_memory = bytes / tp_f / (dev.mem_bw * dev.mbu_cap);
    let t_comm = if tp > 1 {
        2.0 * comm_bytes * (tp_f - 1.0) / tp_f / TP_LINK_BW
    } else {
        0.0
    };
    let bound = if t_compute >= t_memory { Bound::Compute } else { Bound::Memory };
    (t_compute.max(t_memory) + t_comm + DISPATCH_OVERHEAD_S, bound)
}

fn perf(dev: &Device, phase: Phase, flops: f64, bytes: f64, tp: usize,
        comm_bytes: f64) -> PhasePerf {
    let (raw_latency, bound) = phase_time(dev, flops, bytes, tp, comm_bytes);
    let tp_f = tp as f64;
    let mfu = flops / tp_f / raw_latency / dev.peak_flops;
    let mbu = bytes / tp_f / raw_latency / dev.mem_bw;
    let util = (mfu / dev.mfu_cap).max(mbu / dev.mbu_cap).min(1.0);
    // The one shared power curve (carbon::operational::server_power):
    // idle floor + nonlinear dynamic term × f³ at the phase's DVFS point,
    // across all tp devices. Downclocking stretches latency by 1/f.
    let freq = dev.freq_scale(phase);
    let power = server_power(dev.idle_w, dev.tdp_w, util, dev.power_gamma,
                             freq, tp);
    let latency = raw_latency / freq;
    PhasePerf { latency_s: latency, power_w: power,
                energy_j: busy_energy_j(power, latency), mfu, mbu, bound }
}

/// TTFT-phase performance: prefill a batch of prompts.
pub fn prefill_perf(m: &LlmSpec, dev: &Device, batch: usize, prompt: usize,
                    tp: usize) -> PhasePerf {
    let comm = m.n_layers as f64 * 2.0 * (batch * prompt * m.d_model) as f64
        * m.dtype_bytes;
    let sat = prefill_saturation(dev, batch * prompt);
    let mut sat_dev = dev.clone();
    sat_dev.mfu_cap = dev.mfu_cap * sat;
    perf(&sat_dev, Phase::Prefill, m.prefill_flops(batch, prompt),
         m.prefill_bytes(batch, prompt), tp, comm)
}

/// One decode step across the batch (TPOT when divided by 1).
pub fn decode_step_perf(m: &LlmSpec, dev: &Device, batch: usize, ctx: usize,
                        tp: usize) -> PhasePerf {
    let comm = m.n_layers as f64 * 2.0 * (batch * m.d_model) as f64 * m.dtype_bytes;
    perf(dev, Phase::Decode, m.decode_step_flops(batch, ctx),
         m.decode_step_bytes(batch, ctx), tp, comm)
}

/// Decode throughput, tokens/s, at a steady context length.
pub fn decode_throughput(m: &LlmSpec, dev: &Device, batch: usize, ctx: usize,
                         tp: usize) -> f64 {
    let p = decode_step_perf(m, dev, batch, ctx, tp);
    batch as f64 / p.latency_s
}

/// Prefill throughput, prompt tokens/s.
pub fn prefill_throughput(m: &LlmSpec, dev: &Device, batch: usize, prompt: usize,
                          tp: usize) -> f64 {
    let p = prefill_perf(m, dev, batch, prompt, tp);
    (batch * prompt) as f64 / p.latency_s
}

/// Energy per generated token (J/token) at steady state.
pub fn decode_energy_per_token(m: &LlmSpec, dev: &Device, batch: usize,
                               ctx: usize, tp: usize) -> f64 {
    let p = decode_step_perf(m, dev, batch, ctx, tp);
    p.energy_j / batch as f64
}

/// Roofline "knee": arithmetic intensity where compute == memory limb.
pub fn knee_intensity(dev: &Device) -> f64 {
    (dev.peak_flops * dev.mfu_cap) / (dev.mem_bw * dev.mbu_cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw;
    use crate::models;

    fn a100() -> Device { Device::from_gpu(hw::gpu("A100-40").unwrap()) }
    fn h100() -> Device { Device::from_gpu(hw::gpu("H100").unwrap()) }
    fn spr() -> Device { Device::from_cpu(hw::cpu("SPR-112").unwrap(), 512.0) }

    #[test]
    fn prefill_compute_bound_decode_memory_bound() {
        let m = models::llm("llama-8b").unwrap();
        let pf = prefill_perf(m, &a100(), 4, 2048, 1);
        let dc = decode_step_perf(m, &a100(), 4, 2048, 1);
        assert_eq!(pf.bound, Bound::Compute);
        assert_eq!(dc.bound, Bound::Memory);
        assert!(pf.mfu > 0.3, "prefill mfu {}", pf.mfu);
        assert!(dc.mbu > 0.3, "decode mbu {}", dc.mbu);
    }

    #[test]
    fn latencies_in_published_ballpark() {
        // llama-8b on A100-40: decode TPOT at batch 1 ≈ weights/bw
        // = 16 GB / (1555·0.7 GB/s) ≈ 15 ms.
        let m = models::llm("llama-8b").unwrap();
        let d = decode_step_perf(m, &a100(), 1, 512, 1);
        assert!(d.latency_s > 0.008 && d.latency_s < 0.03, "{}", d.latency_s);
        // Prefill 2048 tokens ≈ 2·8e9·2048 / (312e12·0.55) ≈ 0.19 s.
        let p = prefill_perf(m, &a100(), 1, 2048, 1);
        assert!(p.latency_s > 0.1 && p.latency_s < 0.4, "{}", p.latency_s);
    }

    #[test]
    fn h100_wins_prefill_a100_wins_decode_carbon_shape() {
        // Fig 12's crossover: H100 clearly faster on large prompts; on
        // decode the speedup is much smaller than its TDP/embodied premium.
        let m = models::llm("gemma-27b").unwrap();
        let pf_a = prefill_perf(m, &a100(), 8, 4096, 2).latency_s;
        let pf_h = prefill_perf(m, &h100(), 8, 4096, 2).latency_s;
        assert!(pf_a / pf_h > 1.8, "prefill speedup {}", pf_a / pf_h);
        let dc_a = decode_step_perf(m, &a100(), 8, 1024, 2).latency_s;
        let dc_h = decode_step_perf(m, &h100(), 8, 1024, 2).latency_s;
        let decode_speedup = dc_a / dc_h;
        assert!(decode_speedup < 1.3, "decode speedup {decode_speedup}");
    }

    #[test]
    fn cpu_decode_viable_gpu_prefill_dominates() {
        // Fig 8: CPU within ~4x of GPU on decode (bw-bound), but an order
        // of magnitude off on prefill (compute-bound).
        let m = models::llm("llama-8b").unwrap();
        let gpu_tput = decode_throughput(m, &a100(), 16, 2048, 1);
        let cpu_tput = decode_throughput(m, &spr(), 16, 2048, 1);
        let decode_gap = gpu_tput / cpu_tput;
        assert!(decode_gap < 4.0, "decode gap {decode_gap}");
        // At saturating prefill work the GPU's compute advantage shows.
        let gpu_pf = prefill_throughput(m, &a100(), 8, 2048, 1);
        let cpu_pf = prefill_throughput(m, &spr(), 8, 2048, 1);
        assert!(gpu_pf / cpu_pf > 5.0, "prefill gap {}", gpu_pf / cpu_pf);
    }

    #[test]
    fn tp_reduces_latency_with_overhead() {
        let m = models::llm("llama-70b").unwrap();
        let t1 = decode_step_perf(m, &a100(), 8, 1024, 4).latency_s;
        let t2 = decode_step_perf(m, &a100(), 8, 1024, 8).latency_s;
        assert!(t2 < t1);
        // Sub-linear: 2x devices must give < 2x speedup (Table 2).
        assert!(t1 / t2 < 2.0);
    }

    #[test]
    fn energy_positive_and_batch_efficient() {
        let m = models::llm("llama-8b").unwrap();
        let e1 = decode_energy_per_token(m, &a100(), 1, 512, 1);
        let e32 = decode_energy_per_token(m, &a100(), 32, 512, 1);
        assert!(e32 < e1, "batching must amortize energy: {e1} vs {e32}");
        assert!(e1 > 0.0);
    }

    #[test]
    fn energy_flows_through_the_shared_power_curve() {
        let m = models::llm("llama-8b").unwrap();
        let dev = a100();
        let p = decode_step_perf(m, &dev, 8, 1024, 1);
        // energy is exactly the metered integral of the reported power.
        assert_eq!(p.energy_j.to_bits(),
                   busy_energy_j(p.power_w, p.latency_s).to_bits());
        assert!(p.power_w >= dev.idle_w && p.power_w <= dev.tdp_w + 1e-9,
                "power {} outside [{}, {}]", p.power_w, dev.idle_w, dev.tdp_w);
        // Decode downclock: bandwidth-bound work pays latency 1/f but the
        // f³ dynamic term wins — energy per step drops.
        let mut slow = dev.clone();
        slow.decode_freq = 0.7;
        let q = decode_step_perf(m, &slow, 8, 1024, 1);
        assert!(q.latency_s > p.latency_s);
        assert!(q.energy_j < p.energy_j,
                "downclock energy {} vs {}", q.energy_j, p.energy_j);
        // Prefill clocks untouched by the decode knob.
        let pf_stock = prefill_perf(m, &dev, 4, 1024, 1);
        let pf_slow = prefill_perf(m, &slow, 4, 1024, 1);
        assert_eq!(pf_stock.latency_s.to_bits(), pf_slow.latency_s.to_bits());
    }

    #[test]
    fn knee_between_decode_and_prefill_intensity() {
        let m = models::llm("llama-8b").unwrap();
        let dev = a100();
        let knee = knee_intensity(&dev);
        assert!(m.decode_intensity(1, 2048) < knee);
        // Prefill AI ≈ params·2/bytes ≈ large.
        let pf_ai = m.prefill_flops(1, 2048) / m.prefill_bytes(1, 2048);
        assert!(pf_ai > knee);
    }
}
