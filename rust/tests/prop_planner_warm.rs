//! Property suite for the incremental rolling-horizon planner: for all
//! epoch sequences, (1) the warm-started planner at default knobs is
//! bitwise-identical to cold full re-solves, and (2) the drift early-out
//! never skips a re-solve the tolerance does not license — an independent
//! replay of the decision ladder over the demand profile must predict the
//! planner's epoch accounting exactly.

use ecoserve::carbon::intensity::CiSignal;
use ecoserve::planner::fused::DemandProfile;
use ecoserve::planner::horizon::{plan_schedule_from_profile, HorizonConfig,
                                 IncrementalPlanner};
use ecoserve::planner::slicing::SliceAccum;
use ecoserve::planner::PlanConfig;
use ecoserve::sim::homogeneous_fleet;
use ecoserve::testkit::{forall, PropConfig};
use ecoserve::workload::slo::Slo;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, Request,
                         RequestClass, SliceSource};

#[derive(Debug, Clone)]
struct Case {
    seed: u64,
    duration_s: f64,
    epoch_s: f64,
    /// 0.0 = one epoch (the config default); otherwise an explicit window.
    window_s: f64,
    pattern: u8,
    rate: f64,
    drift_tol: f64,
}

fn gen_case(r: &mut ecoserve::util::rng::Rng) -> Case {
    let epoch_s = 8.0 + r.f64() * 24.0;
    let window_s = match r.below(3) {
        0 => 0.0,
        1 => epoch_s * 0.5,
        _ => epoch_s * 2.0,
    };
    Case {
        seed: r.next_u64(),
        duration_s: 120.0 + r.f64() * 200.0,
        epoch_s,
        window_s,
        pattern: r.below(3) as u8,
        rate: 0.5 + r.f64() * 6.0,
        drift_tol: 0.02 + r.f64() * 0.3,
    }
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    if c.duration_s > 120.0 {
        out.push(Case { duration_s: 120.0, ..c.clone() });
    }
    if c.rate > 0.5 {
        out.push(Case { rate: c.rate / 2.0, ..c.clone() });
    }
    if c.pattern != 0 {
        out.push(Case { pattern: 0, ..c.clone() });
    }
    if c.window_s != 0.0 {
        out.push(Case { window_s: 0.0, ..c.clone() });
    }
    out
}

fn trace_for(c: &Case) -> Vec<Request> {
    let arrivals = match c.pattern {
        0 => Arrivals::Poisson { rate: c.rate },
        1 => Arrivals::Step { base: c.rate, surge: 4.0 * c.rate,
                              start_frac: 0.5, end_frac: 0.75 },
        _ => Arrivals::CompressedDiurnal { rate: c.rate, amplitude: 0.7,
                                           period_s: 0.0 },
    };
    generate_trace(arrivals, LengthDist::ShareGpt, RequestClass::Online,
                   c.duration_s, c.seed)
}

struct Setup {
    h: HorizonConfig,
    profile: DemandProfile,
    template: Vec<ecoserve::sim::ServerSpec>,
    cfg: PlanConfig,
    ci: CiSignal,
    slo: Slo,
}

fn setup(c: &Case, drift_tol: f64) -> Setup {
    let m = ecoserve::models::llm("llama-8b").unwrap();
    let h = HorizonConfig { epoch_s: c.epoch_s, window_s: c.window_s,
                            drift_tol, ..Default::default() };
    let epoch = h.effective_epoch(c.duration_s);
    let tr = trace_for(c);
    let profile = DemandProfile::build(&mut SliceSource::new(&tr), epoch,
                                       h.window_s, c.duration_s);
    Setup {
        h,
        profile,
        template: homogeneous_fleet("A100-40", 5, m, 2048),
        cfg: PlanConfig { cpu_reuse: false, ..Default::default() },
        ci: CiSignal::flat(261.0),
        slo: Slo { ttft_s: 2.0, tpot_s: 0.2 },
    }
}

/// For all epoch sequences: the memoizing warm planner at the default
/// knobs (`drift_tol = 0`, cuts off) produces a bitwise-identical
/// [`ecoserve::sim::FleetSchedule`] to cold per-epoch re-solves.
#[test]
fn warm_schedule_is_bitwise_cold_for_all_epoch_sequences() {
    let m = ecoserve::models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 40, ..Default::default() },
        gen_case,
        shrink_case,
        |c| {
            let s = setup(c, 0.0);
            let mut cold = IncrementalPlanner::disabled();
            let a = plan_schedule_from_profile(m, &s.profile, &s.template,
                                               &s.cfg, &s.ci, s.slo, &s.h,
                                               c.duration_s, &mut cold);
            let mut warm = IncrementalPlanner::from_horizon(&s.h);
            let b = plan_schedule_from_profile(m, &s.profile, &s.template,
                                               &s.cfg, &s.ci, s.slo, &s.h,
                                               c.duration_s, &mut warm);
            if a != b {
                return Err(format!(
                    "warm schedule diverged from cold ({} vs {} events, \
                     stats {:?})",
                    b.events.len(), a.events.len(), warm.stats()));
            }
            let ws = warm.stats();
            if ws.full_solves + ws.warm_hits != ws.epochs
                || ws.drift_skips != 0 || ws.cut_patches != 0 {
                return Err(format!("default-knob epochs leaked into a \
                                    tolerance path: {ws:?}"));
            }
            if cold.stats().full_solves != cold.stats().epochs {
                return Err(format!("cold planner reused a solve: {:?}",
                                   cold.stats()));
            }
            Ok(())
        },
    );
}

/// For all epoch sequences and tolerances: an independent replay of the
/// decision ladder over the same [`DemandProfile`] predicts the planner's
/// epoch accounting exactly — in particular, every drift skip it takes is
/// one the replay licenses (relative L1 within tolerance of the *anchor*
/// demand, the histogram the plan was last solved for), and every epoch
/// the replay says drifted past the tolerance is a real re-solve.
#[test]
fn drift_early_out_never_skips_past_the_tolerance() {
    let m = ecoserve::models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 40, ..Default::default() },
        gen_case,
        shrink_case,
        |c| {
            let s = setup(c, c.drift_tol);
            let mut warm = IncrementalPlanner::from_horizon(&s.h);
            let sched = plan_schedule_from_profile(m, &s.profile, &s.template,
                                                   &s.cfg, &s.ci, s.slo, &s.h,
                                                   c.duration_s, &mut warm);
            if !sched.events.windows(2).all(|w| w[0].t <= w[1].t) {
                return Err("schedule events out of order".into());
            }

            // Independent ladder replay (flat CI, cuts off): exact match
            // -> hit; within-tolerance L1 drift vs the anchor -> skip
            // (anchor unchanged); anything else -> full solve, re-anchor.
            let epoch = s.h.effective_epoch(c.duration_s);
            let window = if s.h.window_s > 0.0 { s.h.window_s } else { epoch };
            let mut anchor: Option<(u64, SliceAccum)> = None;
            let mut epochs = 0usize;
            let mut full = 0usize;
            let mut hits = 0usize;
            let mut skips = 0usize;
            for k in 1..=s.profile.epochs() {
                let acc = s.profile.epoch_accum(k);
                if acc.total() == 0 {
                    continue; // scheduler plans nothing on an empty window
                }
                epochs += 1;
                let w_bits = window.min(k as f64 * epoch).to_bits();
                let licensed = match &anchor {
                    Some((aw, aacc)) if *aw == w_bits && aacc == acc => {
                        hits += 1;
                        true
                    }
                    Some((aw, aacc)) if *aw == w_bits && {
                        let denom =
                            aacc.total().max(acc.total()).max(1) as f64;
                        aacc.l1_delta(acc) as f64 / denom <= c.drift_tol
                    } => {
                        skips += 1;
                        true
                    }
                    _ => false,
                };
                if !licensed {
                    full += 1;
                    anchor = Some((w_bits, acc.clone()));
                }
            }
            let ws = warm.stats();
            if (ws.epochs, ws.full_solves, ws.warm_hits, ws.drift_skips)
                != (epochs, full, hits, skips) || ws.cut_patches != 0 {
                return Err(format!(
                    "ladder mismatch: planner {ws:?} vs replay (epochs \
                     {epochs}, full {full}, hits {hits}, skips {skips})"));
            }
            Ok(())
        },
    );
}
