//! Fig 21: asymmetric host/GPU replacement schedules over 10 years.
use ecoserve::carbon::lifecycle::{fig21_comparison, LifecycleParams};
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 21: fixed 4y/4y vs EcoServe host-9y/GPU-3y ==");
    let p = LifecycleParams::default();
    let (base, eco) = fig21_comparison(&p, 10);
    let (bc, ec) = (base.total_by_year(), eco.total_by_year());
    let mut t = Table::new(&["year", "base emb", "base op", "eco emb", "eco op",
                             "cum saving %"]);
    for y in 0..10 {
        t.row(&[format!("{y}"), fnum(base.emb_by_year[y]), fnum(base.op_by_year[y]),
                fnum(eco.emb_by_year[y]), fnum(eco.op_by_year[y]),
                fnum(100.0 * (1.0 - ec[y] / bc[y]))]);
    }
    t.print();
    println!("10-year cumulative saving: {:.1}% (paper: ~16%)",
             100.0 * (1.0 - eco.cumulative_total() / base.cumulative_total()));
}
