//! Fleet timeline: fixed-interval series sampled from the event core as
//! it runs — server lifecycle counts, queue depths by request class,
//! recovery-queue depth, instantaneous fleet power from the shared
//! nonlinear model, per-region grid CI, cumulative operational/embodied
//! carbon, and rolling SLO attainment. Memory is O(duration / interval),
//! independent of trace length, matching the streaming core's contract.
//!
//! Determinism and shard merging follow the `Histogram::merge`
//! discipline: every shard emits a sample at exactly the same grid
//! instants `t_i = i · interval` (the engine flushes the tail with
//! `upto = ∞` at finish, so each shard produces the full grid even if
//! its events end early), and [`Timeline::merge`] folds shards in
//! ascending shard index — counts and power/carbon sum elementwise; CI
//! columns take the first fold's values, which are identical in every
//! shard because `ShardPlan::sub_config` clones the full primary and
//! region signals into each shard config. The merged CSV is therefore
//! byte-identical for any shard-thread budget.

/// One sampled grid instant. Counts are instantaneous (state just before
/// the first event at `t > t_s` is processed); `op_kg`/`emb_kg`/
/// `online_done`/`slo_ok` are cumulative since t = 0.
#[derive(Debug, Clone)]
pub struct TimelineSample {
    pub t_s: f64,
    pub pending: usize,
    pub active: usize,
    pub draining: usize,
    pub retired: usize,
    pub q_prompt_online: usize,
    pub q_prompt_offline: usize,
    pub q_decode_online: usize,
    pub q_decode_offline: usize,
    /// Jobs parked in the recovery queue (prompt + decode).
    pub recovery: usize,
    /// Instantaneous fleet draw: busy servers at their last busy-period
    /// power, idle provisioned servers at the shared idle floor.
    pub power_w: f64,
    /// Cumulative busy-interval operational carbon metered so far (idle
    /// op-carbon is priced once at finalize and is not in this column).
    pub op_kg: f64,
    /// Cumulative embodied carbon amortized over provisioned seconds
    /// through `t_s`.
    pub emb_kg: f64,
    pub online_done: usize,
    pub slo_ok: usize,
    /// Grid CI at `t_s`: primary signal first, then one entry per
    /// configured region signal (config order).
    pub ci: Vec<f64>,
}

/// The fixed-interval fleet series. See the module docs for the grid and
/// merge rules.
#[derive(Debug, Clone)]
pub struct Timeline {
    interval_s: f64,
    /// Grid size: `floor(duration / interval) + 1` instants.
    n_samples: usize,
    /// Next grid index this recorder owes a sample for.
    next_idx: usize,
    samples: Vec<TimelineSample>,
    /// CSV column names for the per-region CI tail (`ci_primary`, …).
    ci_names: Vec<String>,
}

/// Fixed (non-CI) CSV columns, in order. The golden header test pins the
/// rendered form.
const FIXED_COLUMNS: &[&str] = &[
    "t_s", "pending", "active", "draining", "retired", "q_prompt_online",
    "q_prompt_offline", "q_decode_online", "q_decode_offline", "recovery",
    "power_w", "op_kg", "emb_kg", "online_done", "slo_ok", "slo_window",
];

impl Timeline {
    pub fn new(interval_s: f64, duration_s: f64, ci_names: Vec<String>)
        -> Timeline {
        let interval_s = interval_s.max(1e-9);
        let n_samples = (duration_s.max(0.0) / interval_s) as usize + 1;
        Timeline {
            interval_s,
            n_samples,
            next_idx: 0,
            samples: Vec::with_capacity(n_samples),
            ci_names,
        }
    }

    /// The next grid instant due at or before `upto`, if any. The engine
    /// calls this before processing each event (and with `upto = ∞` at
    /// finish), sampling state for every due instant in order.
    pub fn due(&self, upto: f64) -> Option<f64> {
        if self.next_idx >= self.n_samples {
            return None;
        }
        let t = self.next_idx as f64 * self.interval_s;
        (t <= upto).then_some(t)
    }

    /// Append the sample for the instant [`Timeline::due`] returned.
    pub fn push(&mut self, sample: TimelineSample) {
        debug_assert!(self.next_idx < self.n_samples, "sample past the grid");
        debug_assert_eq!(sample.ci.len(), self.ci_names.len(),
                         "CI column count mismatch");
        self.samples.push(sample);
        self.next_idx += 1;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Fold a shard's timeline into this one (ascending shard index, the
    /// `Histogram::merge` discipline). Counts and power/carbon sums add
    /// elementwise; CI columns keep the first fold's values (identical in
    /// every shard — each shard config clones the full signals). An empty
    /// parent (the fleet-level recorder never ticks when the run is
    /// sharded) adopts the first shard's rows wholesale.
    pub fn merge(&mut self, other: &Timeline) {
        assert_eq!(self.n_samples, other.n_samples,
                   "timeline grids differ: {} vs {}",
                   self.n_samples, other.n_samples);
        assert_eq!(self.interval_s.to_bits(), other.interval_s.to_bits(),
                   "timeline intervals differ");
        if self.samples.is_empty() {
            self.samples = other.samples.clone();
            self.next_idx = other.next_idx;
            return;
        }
        assert_eq!(self.samples.len(), other.samples.len(),
                   "timeline row counts differ");
        for (a, b) in self.samples.iter_mut().zip(&other.samples) {
            debug_assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
            a.pending += b.pending;
            a.active += b.active;
            a.draining += b.draining;
            a.retired += b.retired;
            a.q_prompt_online += b.q_prompt_online;
            a.q_prompt_offline += b.q_prompt_offline;
            a.q_decode_online += b.q_decode_online;
            a.q_decode_offline += b.q_decode_offline;
            a.recovery += b.recovery;
            a.power_w += b.power_w;
            a.op_kg += b.op_kg;
            a.emb_kg += b.emb_kg;
            a.online_done += b.online_done;
            a.slo_ok += b.slo_ok;
            // CI columns: first-fold values stand (identical per shard).
        }
    }

    /// Render the series as CSV. `slo_window` is the per-interval SLO
    /// attainment (delta of the cumulative counters between consecutive
    /// rows; an interval with no online completions reports 1, matching
    /// the sink's vacuous-attainment convention).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for (i, c) in FIXED_COLUMNS.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(c);
        }
        for name in &self.ci_names {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        let mut prev_done = 0usize;
        let mut prev_ok = 0usize;
        for s in &self.samples {
            let w_done = s.online_done - prev_done;
            let w_ok = s.slo_ok - prev_ok;
            let slo_window = if w_done == 0 {
                1.0
            } else {
                w_ok as f64 / w_done as f64
            };
            prev_done = s.online_done;
            prev_ok = s.slo_ok;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.t_s, s.pending, s.active, s.draining, s.retired,
                s.q_prompt_online, s.q_prompt_offline, s.q_decode_online,
                s.q_decode_offline, s.recovery, s.power_w, s.op_kg, s.emb_kg,
                s.online_done, s.slo_ok, slo_window));
            for ci in &s.ci {
                out.push_str(&format!(",{ci}"));
            }
            out.push('\n');
        }
        out
    }

    /// The golden CSV header for this timeline's CI columns.
    pub fn csv_header(&self) -> String {
        self.to_csv().lines().next().unwrap_or_default().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, active: usize, done: usize, ok: usize)
        -> TimelineSample {
        TimelineSample {
            t_s: t,
            pending: 0,
            active,
            draining: 0,
            retired: 0,
            q_prompt_online: 1,
            q_prompt_offline: 0,
            q_decode_online: 2,
            q_decode_offline: 0,
            recovery: 0,
            power_w: 100.0,
            op_kg: 0.5,
            emb_kg: 0.25,
            online_done: done,
            slo_ok: ok,
            ci: vec![261.0],
        }
    }

    #[test]
    fn grid_emits_every_instant_through_flush() {
        let mut tl = Timeline::new(10.0, 35.0, vec!["ci_primary".into()]);
        assert_eq!(tl.n_samples, 4); // 0, 10, 20, 30
        assert_eq!(tl.due(9.0), Some(0.0));
        tl.push(sample(0.0, 1, 0, 0));
        assert_eq!(tl.due(9.0), None);
        assert_eq!(tl.due(10.0), Some(10.0)); // boundary instant is due
        tl.push(sample(10.0, 2, 4, 3));
        // Flush with ∞ drains the remaining grid.
        while let Some(t) = tl.due(f64::INFINITY) {
            tl.push(sample(t, 2, 8, 6));
        }
        assert_eq!(tl.len(), 4);
        assert_eq!(tl.due(f64::INFINITY), None);
    }

    #[test]
    fn csv_reports_windowed_slo_and_golden_header() {
        let mut tl = Timeline::new(10.0, 20.0, vec!["ci_primary".into()]);
        tl.push(sample(0.0, 1, 0, 0));
        tl.push(sample(10.0, 1, 4, 3));
        tl.push(sample(20.0, 1, 4, 3)); // empty window: vacuous 1
        let csv = tl.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0],
                   "t_s,pending,active,draining,retired,q_prompt_online,\
                    q_prompt_offline,q_decode_online,q_decode_offline,\
                    recovery,power_w,op_kg,emb_kg,online_done,slo_ok,\
                    slo_window,ci_primary");
        assert!(lines[2].contains(",0.75,"), "windowed slo: {}", lines[2]);
        assert!(lines[3].ends_with(",1,261"), "vacuous window: {}", lines[3]);
    }

    #[test]
    fn merge_sums_counts_and_keeps_first_fold_ci() {
        let mut parent = Timeline::new(10.0, 10.0, vec!["ci_primary".into()]);
        let mut a = Timeline::new(10.0, 10.0, vec!["ci_primary".into()]);
        let mut b = Timeline::new(10.0, 10.0, vec!["ci_primary".into()]);
        for tl in [&mut a, &mut b] {
            tl.push(sample(0.0, 1, 2, 1));
            tl.push(sample(10.0, 1, 3, 2));
        }
        parent.merge(&a);
        parent.merge(&b);
        assert_eq!(parent.samples[1].active, 2);
        assert_eq!(parent.samples[1].online_done, 6);
        assert_eq!(parent.samples[1].ci, vec![261.0]);
        assert!((parent.samples[1].power_w - 200.0).abs() < 1e-12);
    }
}
