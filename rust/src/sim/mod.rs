//! Event-driven cluster simulator (the Splitwise-simulator substitute,
//! paper §5/§6.2), decomposed into a pluggable discrete-event core:
//!
//! - [`core`] — sequence-numbered total-order event queue + engine loop;
//! - [`server`] — server state and prefill/decode stepping;
//! - [`policy`] — [`RoutePolicy`]/[`BatchPolicy`] traits (JSQ,
//!   workload-aware, carbon-greedy; FIFO, online-first) and the offline
//!   [`DeferralPolicy`] (temporal shifting into low-CI windows);
//! - [`metrics`] — the [`MetricsSink`] collecting TTFT/TPOT/SLO/deadline
//!   counters into a [`SimReport`];
//! - [`carbon_meter`] — operational-carbon observer integrating energy
//!   against a time-varying [`crate::carbon::intensity::CiSignal`], plus
//!   per-server provisioned intervals for amortized embodied carbon;
//! - [`fault`] — deterministic fault injection ([`FaultPlan`]: server
//!   death mid-batch, grid CI spikes, region outages) expanded into
//!   ordinary queue events, with recovery-queue parking instead of
//!   panics when a fault removes the last live server.
//!
//! Fleets may be *elastic*: a [`FleetSchedule`] (typically produced by the
//! rolling-horizon controller in [`crate::planner::horizon`]) provisions
//! and drains servers mid-run. Draining servers finish in-flight batches
//! but admit nothing; they decommission once empty, and embodied + idle
//! carbon is charged per provisioned-hour — the 4R Rightsize/Recycle
//! accounting.
//!
//! Provisioning (planner ILP) and runtime behaviour see the *same* carbon
//! signal — the paper's cross-layer point — and every policy is a trait
//! impl, so runtime experiments never fork the core.
//!
//! The core is *streaming*: arrivals pull lazily from a
//! [`crate::workload::ArrivalSource`] (one pending `Arrival` in the heap,
//! job slots recycled by a [`JobArena`], latency percentiles in fixed-bin
//! histograms), so a multi-million-request production day runs in memory
//! bounded by the fleet and the in-flight jobs, not the trace length.
//!
//! The core is also *shardable* ([`shard`]): a fleet partitions into
//! per-region/per-cluster shards that run on scoped threads over
//! deterministic substreams and merge order-invariantly back into one
//! [`SimReport`] — wall-clock scaling with a byte-identical report for
//! any shard-thread count.

pub mod carbon_meter;
pub mod core;
pub mod fault;
pub mod metrics;
pub mod policy;
pub mod server;
pub mod shard;

pub use self::carbon_meter::CarbonMeter;
pub use self::core::{histogram_window, Event, EventKind, EventQueue,
                     FleetAction, FleetEvent, FleetSchedule, KeepAlivePolicy,
                     SimConfig};
pub use self::fault::{apply_ci_spikes, Fault, FaultPlan};
pub use self::shard::{simulate_sharded, simulate_sharded_observed, ShardPlan,
                      ShardSpec, ShardSplitter, MAX_SHARD_SERVERS};
pub use self::metrics::{MetricsSink, ServerUsage, SimReport};
pub use self::policy::{BatchPolicy, Batcher, CarbonGreedy, DeferralPolicy,
                       FifoBatch, Jsq, OnlineFirstBatch, RouteCtx, RoutePolicy,
                       Router, WorkloadAware, LONG_PROMPT_TOKENS};
pub use self::server::{homogeneous_fleet, ClassQueue, Job, JobArena, Lifecycle,
                       Role, Server, ServerSpec, MAX_PROMPT_TOKENS};

use crate::models::LlmSpec;
use crate::workload::{ArrivalSource, Request, SliceSource};

/// Run the simulator over a materialized trace — a thin adapter over the
/// streaming path ([`simulate_stream`]); the two are byte-identical by
/// construction and the differential suite keeps them that way.
pub fn simulate(model: &LlmSpec, trace: &[Request], cfg: &SimConfig,
                slo_ttft: f64, slo_tpot: f64) -> SimReport {
    let mut src = SliceSource::new(trace);
    simulate_stream(model, &mut src, cfg, slo_ttft, slo_tpot)
}

/// Run the simulator over a streaming [`ArrivalSource`] with the config's
/// selected policies. Exactly one pending arrival lives in the event heap
/// at a time and job slots recycle, so memory is bounded by the fleet and
/// the in-flight work — this is the production-scale entry point.
pub fn simulate_stream(model: &LlmSpec, source: &mut dyn ArrivalSource,
                       cfg: &SimConfig, slo_ttft: f64, slo_tpot: f64)
    -> SimReport {
    simulate_stream_with(model, source, cfg, slo_ttft, slo_tpot,
                         cfg.router.policy(), cfg.batcher.policy())
}

/// [`simulate`] with explicit policy objects — the extension point for
/// custom routing/batching studies that are not in the
/// [`Router`]/[`Batcher`] registries.
pub fn simulate_with(model: &LlmSpec, trace: &[Request], cfg: &SimConfig,
                     slo_ttft: f64, slo_tpot: f64, route: &dyn RoutePolicy,
                     batch: &dyn BatchPolicy) -> SimReport {
    let mut src = SliceSource::new(trace);
    simulate_stream_with(model, &mut src, cfg, slo_ttft, slo_tpot, route, batch)
}

/// [`simulate_stream`] with explicit policy objects.
pub fn simulate_stream_with(model: &LlmSpec, source: &mut dyn ArrivalSource,
                            cfg: &SimConfig, slo_ttft: f64, slo_tpot: f64,
                            route: &dyn RoutePolicy, batch: &dyn BatchPolicy)
    -> SimReport {
    simulate_stream_observed(model, source, cfg, slo_ttft, slo_tpot,
                             route, batch, None)
}

/// [`simulate_stream_with`] with the passive observability recorders of
/// [`crate::obs`] attached: the engine drives the observer's timeline,
/// span, and progress hooks as it runs and flushes them on finish.
/// `None` is byte-identical to the unobserved path — the hooks are
/// `Option`-gated reads that never touch simulation state.
pub fn simulate_stream_observed(model: &LlmSpec,
                                source: &mut dyn ArrivalSource,
                                cfg: &SimConfig, slo_ttft: f64, slo_tpot: f64,
                                route: &dyn RoutePolicy,
                                batch: &dyn BatchPolicy,
                                obs: Option<&mut crate::obs::Observer>)
    -> SimReport {
    let mut sim = self::core::Sim::new(model, source, cfg, slo_ttft, slo_tpot,
                                       route, batch);
    if let Some(o) = obs {
        sim.attach_observer(o);
    }
    sim.run();
    sim.finish()
}
