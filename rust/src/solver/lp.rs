//! Dense two-phase primal simplex.
//!
//! The LP core under the MILP branch-and-bound (solver/milp.rs) that
//! implements EcoServe's allocation ILP (planner/). Scale target is the
//! paper's control plane (Table 3): a few hundred columns / constraints per
//! solve, well inside dense-tableau territory.
//!
//! Variables are x >= 0 with optional upper bounds (handled as rows by the
//! builder in solver/mod.rs). Anti-cycling: Dantzig rule with a Bland
//! fallback after a degeneracy streak.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
    IterLimit,
}

#[derive(Debug, Clone)]
pub struct LpSolution {
    pub status: LpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
}

/// A constraint row in sparse form.
#[derive(Debug, Clone)]
pub struct Row {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

const EPS: f64 = 1e-9;

/// Solve: minimize c·x  s.t. rows, x >= 0.
pub fn solve(ncols: usize, c: &[f64], rows: &[Row]) -> LpSolution {
    assert_eq!(c.len(), ncols);
    let m = rows.len();
    // Column layout: [structural 0..n) [slack/surplus n..n+m) [artificial ...]
    // plus RHS last. Artificial columns are allocated only where needed.
    let mut need_artificial = vec![false; m];
    let mut slack_sign = vec![0.0f64; m];
    for (i, r) in rows.iter().enumerate() {
        let flip = r.rhs < 0.0;
        let cmp = if flip {
            match r.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            }
        } else {
            r.cmp
        };
        match cmp {
            Cmp::Le => slack_sign[i] = 1.0,
            Cmp::Ge => {
                slack_sign[i] = -1.0;
                need_artificial[i] = true;
            }
            Cmp::Eq => need_artificial[i] = true,
        }
    }
    let n_art: usize = need_artificial.iter().filter(|&&b| b).count();
    let width = ncols + m + n_art + 1; // + RHS
    let rhs_col = width - 1;

    let mut t = vec![vec![0.0f64; width]; m];
    let mut basis = vec![usize::MAX; m];
    let mut art_idx = ncols + m;
    for (i, r) in rows.iter().enumerate() {
        let flip = if r.rhs < 0.0 { -1.0 } else { 1.0 };
        for &(j, v) in &r.coeffs {
            assert!(j < ncols, "coefficient for unknown var {j}");
            t[i][j] += flip * v;
        }
        t[i][rhs_col] = flip * r.rhs;
        if slack_sign[i] != 0.0 {
            t[i][ncols + i] = flip.signum() * slack_sign[i];
            // After flipping the row the slack sign logic above already
            // accounted for sense inversion; normalize:
            t[i][ncols + i] = slack_sign[i];
        }
        if need_artificial[i] {
            t[i][art_idx] = 1.0;
            basis[i] = art_idx;
            art_idx += 1;
        } else {
            basis[i] = ncols + i; // slack is basic
        }
    }

    // Phase 1: minimize sum of artificials.
    if n_art > 0 {
        let mut obj = vec![0.0f64; width];
        for j in ncols + m..ncols + m + n_art {
            obj[j] = 1.0;
        }
        // Price out basic artificials.
        for i in 0..m {
            if basis[i] >= ncols + m {
                for j in 0..width {
                    obj[j] -= t[i][j];
                }
            }
        }
        let status = run_simplex(&mut t, &mut obj, &mut basis, ncols + m, rhs_col);
        if status == LpStatus::IterLimit {
            return LpSolution { status, x: vec![0.0; ncols], objective: f64::NAN };
        }
        let phase1_obj = -obj[rhs_col];
        if phase1_obj > 1e-7 {
            return LpSolution {
                status: LpStatus::Infeasible,
                x: vec![0.0; ncols],
                objective: f64::NAN,
            };
        }
        // Drive any artificials still basic (at zero) out of the basis.
        for i in 0..m {
            if basis[i] >= ncols + m {
                if let Some(j) = (0..ncols + m).find(|&j| t[i][j].abs() > EPS) {
                    pivot(&mut t, &mut basis, i, j, rhs_col);
                } // else: redundant row, leave it (all-zero).
            }
        }
    }

    // Phase 2: minimize c over structural columns; artificial columns are
    // barred from entering (treated as absent).
    let mut obj = vec![0.0f64; width];
    obj[..ncols].copy_from_slice(c);
    for i in 0..m {
        let b = basis[i];
        if b < ncols + m && obj[b].abs() > 0.0 {
            let coef = obj[b];
            for j in 0..width {
                obj[j] -= coef * t[i][j];
            }
        }
    }
    let status = run_simplex(&mut t, &mut obj, &mut basis, ncols + m, rhs_col);

    let mut x = vec![0.0f64; ncols];
    for i in 0..m {
        if basis[i] < ncols {
            x[basis[i]] = t[i][rhs_col];
        }
    }
    let objective = c.iter().zip(&x).map(|(ci, xi)| ci * xi).sum();
    LpSolution { status, x, objective }
}

/// Run simplex until optimal / unbounded / iteration cap. `limit_cols`
/// bounds the entering-column search (to bar artificials in phase 2).
fn run_simplex(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    limit_cols: usize,
    rhs_col: usize,
) -> LpStatus {
    let m = t.len();
    let max_iters = 200 + 50 * (m + limit_cols);
    let mut degenerate_streak = 0usize;
    for _ in 0..max_iters {
        // Entering column: Dantzig (most negative), Bland under degeneracy.
        let entering = if degenerate_streak < 12 {
            let mut best = None;
            let mut best_v = -EPS * 10.0;
            for j in 0..limit_cols {
                if obj[j] < best_v {
                    best_v = obj[j];
                    best = Some(j);
                }
            }
            best
        } else {
            (0..limit_cols).find(|&j| obj[j] < -EPS * 10.0)
        };
        let Some(e) = entering else { return LpStatus::Optimal };

        // Ratio test (Bland tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let ratio = t[i][rhs_col] / t[i][e];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else { return LpStatus::Unbounded };
        if best_ratio < EPS {
            degenerate_streak += 1;
        } else {
            degenerate_streak = 0;
        }

        // Pivot, including the objective row.
        pivot_with_obj(t, obj, basis, l, e, rhs_col);
    }
    LpStatus::IterLimit
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], l: usize, e: usize, rhs_col: usize) {
    let piv = t[l][e];
    debug_assert!(piv.abs() > EPS);
    let inv = 1.0 / piv;
    for v in t[l].iter_mut() {
        *v *= inv;
    }
    let lrow = t[l].clone();
    for (i, row) in t.iter_mut().enumerate() {
        if i != l && row[e].abs() > EPS {
            let f = row[e];
            for (v, lv) in row.iter_mut().zip(&lrow) {
                *v -= f * lv;
            }
            row[e] = 0.0;
        }
    }
    let _ = rhs_col;
    basis[l] = e;
}

fn pivot_with_obj(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    l: usize,
    e: usize,
    rhs_col: usize,
) {
    pivot(t, basis, l, e, rhs_col);
    if obj[e].abs() > EPS {
        let f = obj[e];
        for (v, lv) in obj.iter_mut().zip(&t[l]) {
            *v -= f * lv;
        }
        obj[e] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(coeffs: &[(usize, f64)], cmp: Cmp, rhs: f64) -> Row {
        Row { coeffs: coeffs.to_vec(), cmp, rhs }
    }

    #[test]
    fn simple_min() {
        // min x0 + x1 s.t. x0 + x1 >= 2, x0 >= 0.5 → obj 2
        let s = solve(2, &[1.0, 1.0], &[
            row(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 2.0),
            row(&[(0, 1.0)], Cmp::Ge, 0.5),
        ]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective - 2.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn max_via_negation() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6 → x=4, y=0, obj 12.
        let s = solve(2, &[-3.0, -2.0], &[
            row(&[(0, 1.0), (1, 1.0)], Cmp::Le, 4.0),
            row(&[(0, 1.0), (1, 3.0)], Cmp::Le, 6.0),
        ]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective + 12.0).abs() < 1e-6, "{s:?}");
        assert!((s.x[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn equality_constraint() {
        // min x + 2y s.t. x + y = 3, y >= 1 → x=2, y=1, obj 4.
        let s = solve(2, &[1.0, 2.0], &[
            row(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0),
            row(&[(1, 1.0)], Cmp::Ge, 1.0),
        ]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective - 4.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn infeasible_detected() {
        let s = solve(1, &[1.0], &[
            row(&[(0, 1.0)], Cmp::Le, 1.0),
            row(&[(0, 1.0)], Cmp::Ge, 2.0),
        ]);
        assert_eq!(s.status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x with no upper bound on x.
        let s = solve(1, &[-1.0], &[row(&[(0, 1.0)], Cmp::Ge, 0.0)]);
        assert_eq!(s.status, LpStatus::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // x0 - x1 <= -1  ⇔  x1 - x0 >= 1; min x1 → x1 = 1 (x0 = 0).
        let s = solve(2, &[0.0, 1.0], &[row(&[(0, 1.0), (1, -1.0)], Cmp::Le, -1.0)]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective - 1.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Klee–Minty-flavoured degenerate LP; just require termination.
        let s = solve(3, &[-100.0, -10.0, -1.0], &[
            row(&[(0, 1.0)], Cmp::Le, 1.0),
            row(&[(0, 20.0), (1, 1.0)], Cmp::Le, 100.0),
            row(&[(0, 200.0), (1, 20.0), (2, 1.0)], Cmp::Le, 10000.0),
        ]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective + 10000.0).abs() < 1e-4, "{s:?}");
    }

    #[test]
    fn redundant_equalities() {
        let s = solve(2, &[1.0, 1.0], &[
            row(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 2.0),
            row(&[(0, 2.0), (1, 2.0)], Cmp::Eq, 4.0),
        ]);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective - 2.0).abs() < 1e-6, "{s:?}");
    }
}
