//! perf_sim: throughput of the refactored discrete-event core on a
//! 50k-request trace — reported as events/sec and persisted to
//! `BENCH_sim.json` so sim-core perf regressions are visible across PRs.
use ecoserve::bench::{run, BenchConfig};
use ecoserve::models;
use ecoserve::sim::{homogeneous_fleet, simulate, Router, SimConfig};
use ecoserve::util::json::Json;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};
use std::time::Duration;

fn main() {
    let m = models::llm("llama-8b").unwrap();
    // ~50k requests (Poisson 250/s over 200 s) on a 32-server fleet near
    // its saturation point — the regime where event pressure is highest.
    let tr = generate_trace(Arrivals::Poisson { rate: 250.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            200.0, 42);
    let servers = homogeneous_fleet("A100-40", 32, m, 2048);
    let n = servers.len();
    let cfg = SimConfig::flat(servers, Router::Jsq, 261.0, vec![0.005; n]);

    // One probe run pins down the (deterministic) event count.
    let probe = simulate(m, &tr, &cfg, 0.5, 0.1);
    assert_eq!(probe.completed, tr.len());

    let bcfg = BenchConfig {
        warmup: Duration::from_millis(200),
        measure: Duration::from_secs(2),
        min_samples: 3,
        max_samples: 50,
    };
    let r = run("sim_50k_requests_32_servers", &bcfg, || {
        std::hint::black_box(simulate(m, &tr, &cfg, 0.5, 0.1));
    });
    println!("{}", r.report());
    let events_per_sec = probe.events as f64 / r.mean_s;
    println!("events/sec: {events_per_sec:.0}  ({} events, {} requests, {} tokens)",
             probe.events, tr.len(), probe.generated_tokens);

    let j = Json::obj()
        .set("bench", "perf_sim")
        .set("requests", tr.len())
        .set("servers", n)
        .set("events", probe.events)
        .set("generated_tokens", probe.generated_tokens)
        .set("mean_s", r.mean_s)
        .set("p50_s", r.p50_s)
        .set("events_per_sec", events_per_sec);
    std::fs::write("BENCH_sim.json", j.to_string().as_bytes())
        .expect("write BENCH_sim.json");
    eprintln!("wrote BENCH_sim.json");
}
