//! Golden schema for the sweep report: the exact top-level key set of a
//! `ScenarioOutcome`, the baseline `extras` keys of the carbon-aware
//! scenarios, and the summary-table columns. Refactors may *add* report
//! fields (update the goldens deliberately), but nothing can silently
//! vanish.

use ecoserve::scenarios::{catalog, run_sweep, SweepConfig};
use ecoserve::util::json::Json;

/// Every top-level key a scenario outcome must carry, sorted.
const OUTCOME_KEYS: &[&str] = &[
    "carbon_kg",
    "ci_g_per_kwh",
    "completed",
    "decommission_events",
    "deferred_requests",
    "emb_kg",
    "energy_j",
    "events",
    "extras",
    "fleet_counts",
    "fleet_gpus",
    "fleet_servers",
    "generated_tokens",
    "model",
    "name",
    "offline_deadline_attainment",
    "op_kg",
    "peak_live_jobs",
    "plan_cost_hr",
    "plan_emb_kg_per_hr",
    "plan_op_kg_per_hr",
    "provision_events",
    "provisioned_server_hours",
    "region",
    "requests",
    "seed",
    "slo_attainment",
    "throughput_tok_s",
    "tpot_p50_s",
    "tpot_p90_s",
    "tpot_p99_s",
    "truncated_prompts",
    "ttft_p50_s",
    "ttft_p90_s",
    "ttft_p99_s",
];

/// Summary-table columns, in order.
const TABLE_COLUMNS: &[&str] = &[
    "scenario", "carbon kg", "op kg", "emb kg", "TTFT p50 ms", "TTFT p90 ms",
    "TPOT p50 ms", "SLO %", "util %", "gpus", "srv-hrs", "req", "peak-jobs",
    "trunc",
];

fn sweep_json() -> Json {
    let sel = catalog::by_names(&["diurnal-shift", "carbon-router",
                                  "autoscale-diurnal"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 5, duration_s: 40.0,
                            ..Default::default() };
    let report = run_sweep(&sel, &cfg);
    Json::parse(&report.to_json().to_string()).expect("report must parse")
}

#[test]
fn outcome_json_carries_the_exact_golden_key_set() {
    let j = sweep_json();
    assert!(j.get("master_seed").is_some() && j.get("duration_s").is_some(),
            "report-level keys missing");
    let scenarios = j.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    assert_eq!(scenarios.len(), 3);
    for s in scenarios {
        let name = s.get("name").unwrap().as_str().unwrap();
        let keys: Vec<&str> = s.as_obj().unwrap().keys()
            .map(|k| k.as_str())
            .collect();
        assert_eq!(keys, OUTCOME_KEYS,
                   "{name}: outcome key set drifted from the golden schema");
    }
}

#[test]
fn baseline_extras_cannot_silently_vanish() {
    let j = sweep_json();
    let scenarios = j.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    let extras_of = |name: &str| -> Vec<String> {
        let s = scenarios.iter()
            .find(|s| s.get("name").unwrap().as_str() == Some(name))
            .unwrap_or_else(|| panic!("scenario {name} missing from report"));
        s.get("extras").and_then(|e| e.as_obj()).unwrap()
            .keys().cloned().collect()
    };
    // Every scenario reports the fleet-utilization trio (busy seconds
    // over provisioned seconds); the "util_" prefix sorts last.
    // Temporal shifting reports the run-immediately baseline.
    assert_eq!(extras_of("diurnal-shift"),
               vec!["carbon_kg_immediate", "op_kg_immediate",
                    "slo_attainment_immediate", "ttft_p90_s_immediate",
                    "util_fleet_mean", "util_server_max", "util_server_min"]);
    // Carbon-greedy routing reports the carbon-blind JSQ baseline.
    assert_eq!(extras_of("carbon-router"),
               vec!["carbon_kg_jsq", "op_kg_jsq", "ttft_p90_s_jsq",
                    "util_fleet_mean", "util_server_max", "util_server_min"]);
    // Rolling-horizon elasticity reports the static peak-provisioned
    // baseline.
    assert_eq!(extras_of("autoscale-diurnal"),
               vec!["carbon_kg_static", "emb_kg_static", "op_kg_static",
                    "provisioned_server_hours_static", "slo_attainment_static",
                    "ttft_p90_s_static", "util_fleet_mean", "util_server_max",
                    "util_server_min"]);
}

#[test]
fn honest_energy_extras_cannot_silently_vanish() {
    // The honest-energy pair: keepalive-surge reports the keep-alive
    // policy panel next to its static baseline; nonlinear-power reports
    // the stock-clock baseline for its decode DVFS point.
    let sel = catalog::by_names(&["keepalive-surge", "nonlinear-power"])
        .unwrap();
    let cfg = SweepConfig { threads: 1, seed: 5, duration_s: 40.0,
                            ..Default::default() };
    let report = run_sweep(&sel, &cfg);
    let j = Json::parse(&report.to_json().to_string()).expect("must parse");
    let scenarios = j.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    let extras_of = |name: &str| -> Vec<String> {
        let s = scenarios.iter()
            .find(|s| s.get("name").unwrap().as_str() == Some(name))
            .unwrap_or_else(|| panic!("scenario {name} missing from report"));
        s.get("extras").and_then(|e| e.as_obj()).unwrap()
            .keys().cloned().collect()
    };
    let ka = extras_of("keepalive-surge");
    for label in ["ka_immediate", "ka_fixed", "ka_hybrid", "static"] {
        for metric in ["op_kg", "emb_kg", "carbon_kg", "slo_attainment",
                       "ttft_p90_s", "provisioned_server_hours"] {
            let key = format!("{metric}_{label}");
            assert!(ka.contains(&key),
                    "keepalive-surge missing extra '{key}' (has {ka:?})");
        }
    }
    let nl = extras_of("nonlinear-power");
    assert_eq!(nl, vec!["carbon_kg_stock_freq", "energy_j_stock_freq",
                        "op_kg_stock_freq", "slo_attainment_stock_freq",
                        "tpot_p90_s_stock_freq", "util_fleet_mean",
                        "util_server_max", "util_server_min"]);
}

#[test]
fn summary_table_columns_match_the_golden_order() {
    let sel = catalog::by_names(&["online-latency"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 5, duration_s: 30.0,
                            ..Default::default() };
    let table = run_sweep(&sel, &cfg).summary_table().render();
    let header = table.lines().next().expect("empty table");
    let mut pos = 0usize;
    for col in TABLE_COLUMNS {
        let at = header[pos..].find(col).unwrap_or_else(|| {
            panic!("column '{col}' missing (or out of order) in '{header}'")
        });
        pos += at + col.len();
    }
}
