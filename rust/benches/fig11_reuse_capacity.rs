//! Fig 11: GPU capacity demand under peak-only vs continuous CPU reuse.
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::demand::{demand_trace, Service};

fn main() {
    println!("== Fig 11: offline GPU capacity vs CPU-reuse policy (Llama-8B) ==");
    // CPU fleet can absorb this fraction of mean offline demand.
    let cpu_absorb = 0.35;
    let tr = demand_trace(Service::B, 7, 4.0 * 3600.0, 42); // 4-hour reallocation
    let peak_off = tr.iter().map(|p| p.offline).fold(0.0, f64::max);
    let mean_off: f64 = tr.iter().map(|p| p.offline).sum::<f64>() / tr.len() as f64;
    // Peak-aware reuse: CPUs only during the top-25% demand windows.
    let mut sorted: Vec<f64> = tr.iter().map(|p| p.offline).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p75 = sorted[(0.75 * sorted.len() as f64) as usize];
    let peak_aware: f64 = tr.iter()
        .map(|p| if p.offline > p75 { (p.offline - cpu_absorb * mean_off).max(0.0) } else { p.offline })
        .fold(0.0, f64::max);
    let continuous: f64 = tr.iter()
        .map(|p| (p.offline - cpu_absorb * mean_off).max(0.0))
        .fold(0.0, f64::max);
    let mut t = Table::new(&["policy", "peak offline GPU capacity", "reduction x"]);
    t.row(&["no reuse".into(), fnum(peak_off), "1.00".into()]);
    t.row(&["peak-aware reuse".into(), fnum(peak_aware), fnum(peak_off / peak_aware)]);
    t.row(&["continuous reuse".into(), fnum(continuous), fnum(peak_off / continuous)]);
    t.print();
    println!("(paper: up to 1.32x peak offline capacity reduction)");
}
