//! Compile stub for the XLA/PJRT bindings.
//!
//! The offline build environment does not ship the native XLA runtime, so
//! this vendored crate provides the exact API surface the EcoServe engine
//! (`runtime/engine.rs`) uses — clients, executables, and literals — with
//! every entry point that would touch the real runtime returning a clear
//! [`Error`]. The serving layer therefore compiles and fails gracefully at
//! `Engine::load` time; the planner / simulator / carbon stack (which is
//! what the test suite exercises) never touches this crate at runtime.
//! Swap this path dependency for the real bindings to serve compiled
//! artifacts.

use std::fmt;

/// Error type mirroring the native bindings' error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT native runtime is not available in this build \
         (vendored stub; link the real xla bindings to serve artifacts)"
    ))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value. The stub keeps no data: nothing can execute.
#[derive(Debug, Default, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { _priv: () })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<(), Error> {
        Err(unavailable("Literal::copy_raw_to"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module (stub: parsing requires the native runtime).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute over borrowed literals; `result[device][output]` buffers.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let mut buf = [0f32; 2];
        assert!(lit.copy_raw_to::<f32>(&mut buf).is_err());
    }
}
