//! Property-testing substrate (proptest is not in the offline vendor set).
//!
//! Seeded random case generation with greedy shrinking: on failure, the
//! harness tries progressively simpler inputs derived by the caller's
//! `shrink` function and reports the smallest failing case. Used by the
//! solver / coordinator / simulator property suites.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xEC0_5E27E, max_shrink_steps: 500 }
    }
}

/// Run `check` on `cases` random inputs produced by `gen`. On failure,
/// repeatedly apply `shrink` (returning candidate simpler inputs) while the
/// failure persists, then panic with the minimal case.
pub fn forall<T, G, S, C>(cfg: &PropConfig, mut gen: G, shrink: S, check: C)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    C: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = check(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: loop {
                for cand in shrink(&best) {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(msg) = check(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

/// Convenience: shrinker for vectors — tries removing halves and single
/// elements, and element-wise shrinks via `elem`.
pub fn shrink_vec<T: Clone>(xs: &[T], elem: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n > 0 {
        out.push(xs[..n / 2].to_vec());
        out.push(xs[n / 2..].to_vec());
        for i in 0..n.min(8) {
            let mut v = xs.to_vec();
            v.remove(i);
            out.push(v);
        }
        for i in 0..n.min(8) {
            for e in elem(&xs[i]) {
                let mut v = xs.to_vec();
                v[i] = e;
                out.push(v);
            }
        }
    }
    out
}

/// Shrink a positive f64 toward simpler magnitudes.
pub fn shrink_f64(x: f64) -> Vec<f64> {
    let mut out = Vec::new();
    if x != 0.0 { out.push(0.0); }
    if x.abs() > 1.0 { out.push(x / 2.0); out.push(x.trunc()); }
    if x < 0.0 { out.push(-x); }
    out
}

/// Shrink a usize toward zero.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > 0 { out.push(0); out.push(x / 2); out.push(x - 1); }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            &PropConfig { cases: 50, ..Default::default() },
            |r| r.below(100),
            |x| shrink_usize(*x),
            |x| if *x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    fn shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall(
                &PropConfig { cases: 100, max_shrink_steps: 10_000, ..Default::default() },
                |r| r.below(1000),
                |x| shrink_usize(*x),
                |x| if *x < 500 { Ok(()) } else { Err(format!("{x} too big")) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // With an ample step budget, greedy shrink converges to the
        // boundary case exactly.
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let xs = vec![5usize, 6, 7, 8];
        let cands = shrink_vec(&xs, |x| shrink_usize(*x));
        assert!(cands.iter().any(|c| c.len() < xs.len()));
        assert!(cands.iter().all(|c| c.len() <= xs.len()));
    }
}
