//! Loader for the ECOW weights format emitted by python/compile/aot.py.
//!
//! Layout (little-endian): magic "ECOW", version:u32, count:u32, then per
//! tensor: name_len:u16, name:utf8, dtype:u8 (0 = f32), ndim:u8,
//! dims:u32 × ndim, data:f32 × prod(dims). Tensor order is the HLO
//! parameter order (the contract recorded in model_config.json).

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

pub const MAGIC: &[u8; 4] = b"ECOW";
pub const VERSION: u32 = 1;

pub fn load(path: &Path) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading weights {}", path.display()))?;
    parse(&bytes)
}

pub fn parse(bytes: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic {:?}", magic);
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported ECOW version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let name_len = read_u16(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        if dtype != 0 {
            bail!("tensor {i} ({name}): unsupported dtype {dtype}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = vec![0f32; numel];
        {
            // Bulk-read the raw f32 block.
            let need = numel * 4;
            if r.len() < need {
                bail!("tensor {i} ({name}): truncated data");
            }
            let (raw, rest) = r.split_at(need);
            for (o, c) in data.iter_mut().zip(raw.chunks_exact(4)) {
                *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            r = rest;
        }
        out.push(Tensor { name, dims, data });
    }
    if !r.is_empty() {
        bail!("{} trailing bytes after {} tensors", r.len(), count);
    }
    Ok(out)
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut &[u8]) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(0);
            out.push(dims.len() as u8);
            for d in *dims {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for x in *data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let bytes = encode(&[
            ("embed", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("scalar", &[], &[7.5]),
        ]);
        let ts = parse(&bytes).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "embed");
        assert_eq!(ts[0].dims, vec![2, 3]);
        assert_eq!(ts[0].data[5], 6.0);
        assert_eq!(ts[1].dims, Vec::<usize>::new());
        assert_eq!(ts[1].data, vec![7.5]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse(b"NOPE").is_err());
        let mut bytes = encode(&[("w", &[4], &[1.0, 2.0, 3.0, 4.0])]);
        bytes.truncate(bytes.len() - 3);
        assert!(parse(&bytes).is_err());
        let good = encode(&[("w", &[1], &[1.0])]);
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(parse(&trailing).is_err());
        assert!(parse(&good).is_ok());
    }
}
