//! Discrete-event core (dslab-style): a sequence-numbered, total-order
//! event queue and the engine that drives servers, policies, the deferral
//! queue, the metrics sink, and the carbon meter.
//!
//! Ordering is total by construction: events compare by `(time, seq)` via
//! `f64::total_cmp`, so ties at equal timestamps pop in FIFO order and NaN
//! cannot silently collapse to `Ordering::Equal`. Busy servers are modelled
//! with explicit completion generations instead of the old
//! `busy_until > now + 1e-12` stale-wake epsilon: a `Complete` event names
//! the busy period it ends, and `Wake` nudges are ignored while a period is
//! in flight.
//!
//! The engine is *streaming*: it pulls requests from an
//! [`ArrivalSource`] one at a time and keeps exactly one pending `Arrival`
//! event in the queue (pull-next-on-pop), with job state in a recycling
//! [`JobArena`]. Heap size and job memory therefore scale with the fleet
//! and the in-flight work, never with the trace length — the property the
//! `production-day`/`production-week` scale scenarios (and the CI
//! `scale-smoke` RSS gate) exercise end to end.

use crate::carbon::intensity::{CiSignal, Region};
use crate::models::LlmSpec;
use crate::obs::{Observer, SpanTrace, TimelineSample};
use crate::workload::{ArrivalSource, RequestClass};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::carbon_meter::CarbonMeter;
use super::fault::{Fault, FaultPlan};
use super::metrics::{MetricsSink, ServerUsage, SimReport};
use super::policy::{BatchPolicy, Batcher, DeferState, DeferralPolicy,
                    RouteCtx, RoutePolicy, Router};
use super::server::{Job, JobArena, Lifecycle, Role, Server, ServerSpec,
                    MAX_PROMPT_TOKENS};

/// What a scheduled fleet event does to its server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetAction {
    /// Bring the server online (from `Pending`, `Draining`, or even
    /// `Retired` — re-provisioning a recycled server reopens its
    /// embodied/idle accounting interval).
    Provision,
    /// Stop admitting: the server finishes in-flight batches, then
    /// decommissions itself once empty.
    Drain,
}

/// One scheduled provisioning decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    pub t: f64,
    pub server: usize,
    pub action: FleetAction,
}

/// A provisioning schedule for the fleet, typically produced by the
/// rolling-horizon controller ([`crate::planner::horizon`]). The default
/// (empty) schedule is the static fleet: every server provisioned at t=0
/// and never drained — exactly the pre-elasticity behavior.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetSchedule {
    /// Which servers are provisioned at t=0; empty means all of them.
    /// When non-empty it must have one entry per server.
    pub initially_active: Vec<bool>,
    /// Provision/Drain decisions, applied at their timestamps.
    pub events: Vec<FleetEvent>,
}

impl FleetSchedule {
    /// True for the all-on, never-drained (static-fleet) schedule.
    pub fn is_static(&self) -> bool {
        self.initially_active.is_empty() && self.events.is_empty()
    }
}

/// What happens to a draining server once it goes idle-empty: retire on
/// the spot, or stay warm (paying idle power) for a window in case the
/// next re-provision arrives before it — trading idle carbon against the
/// cold-start latency a retired server pays on the next surge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeepAlivePolicy {
    /// Retire the instant the server drains empty (pre-existing behavior).
    Immediate,
    /// Hold every drained server warm for a fixed window.
    Fixed { window_s: f64 },
    /// Azure-style hybrid histogram: each server tracks how long it sat
    /// warm before being reused, and its window is the `percentile` of
    /// that distribution (bins of `bin_s`), capped at `max_window_s`.
    /// While a server has no observations it keeps the conservative
    /// `max_window_s`.
    HybridHistogram { bin_s: f64, percentile: f64, max_window_s: f64 },
}

impl Default for KeepAlivePolicy {
    fn default() -> KeepAlivePolicy {
        KeepAlivePolicy::Immediate
    }
}

/// Window implied by an idle-before-reuse histogram: the smallest bin
/// boundary covering `percentile` of the observations, capped. Free
/// function so the property suite can exercise it directly.
pub fn histogram_window(hist: &[u64], total: u64, bin_s: f64,
                        percentile: f64, max_window_s: f64) -> f64 {
    if total == 0 {
        return max_window_s;
    }
    let target = percentile.clamp(0.0, 1.0) * total as f64;
    let mut cum = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        cum += c;
        if cum as f64 >= target {
            return ((i as f64 + 1.0) * bin_s).min(max_window_s);
        }
    }
    max_window_s
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub servers: Vec<ServerSpec>,
    /// Routing policy selector (maps to a [`RoutePolicy`] impl).
    pub router: Router,
    /// Batch-formation policy selector (maps to a [`BatchPolicy`] impl).
    pub batcher: Batcher,
    /// Grid carbon-intensity signal: flat scalar or time-varying trace.
    pub ci: CiSignal,
    /// Per-server embodied amortization, kgCO₂e per server-hour — charged
    /// only over each server's provisioned intervals.
    pub emb_kg_per_hr: Vec<f64>,
    /// KV transfer bandwidth between prefill and decode servers, B/s.
    pub kv_transfer_bw: f64,
    /// Temporal scheduling of offline-class requests.
    pub deferral: DeferralPolicy,
    /// Fleet provisioning schedule (default: static all-on fleet).
    pub fleet_plan: FleetSchedule,
    /// Time-varying CI signals for pinned-region servers: a server whose
    /// `ServerSpec::region` matches an entry sees that signal instead of
    /// the region's flat published average. Empty (the default) keeps the
    /// pre-existing flat-override behavior bit for bit.
    pub region_signals: Vec<(Region, CiSignal)>,
    /// Cold-start delay (s): a `Provision` of a pending/retired server
    /// takes this long before the server actually admits work. 0.0 (the
    /// default) activates inline, pushing no extra events — byte-identical
    /// to the pre-cold-start engine.
    pub coldstart_s: f64,
    /// Keep-alive policy for drained-empty servers.
    pub keepalive: KeepAlivePolicy,
    /// Deterministic fault-injection plan ([`super::fault`]): server
    /// deaths and region outages expand into ordinary queue events at
    /// construction; the default (empty) plan injects zero events, so
    /// fault-free runs are byte-identical to the pre-fault engine.
    pub faults: FaultPlan,
}

impl SimConfig {
    /// The common case: a flat CI, online-first batching, no deferral,
    /// a static fleet.
    pub fn flat(servers: Vec<ServerSpec>, router: Router, ci: f64,
                emb_kg_per_hr: Vec<f64>) -> SimConfig {
        SimConfig {
            servers,
            router,
            batcher: Batcher::OnlineFirst,
            ci: CiSignal::flat(ci),
            emb_kg_per_hr,
            kv_transfer_bw: 64e9,
            deferral: DeferralPolicy::Immediate,
            fleet_plan: FleetSchedule::default(),
            region_signals: Vec::new(),
            coldstart_s: 0.0,
            keepalive: KeepAlivePolicy::Immediate,
            faults: FaultPlan::default(),
        }
    }

    /// Effective CI signal for a pinned server in `region`: the
    /// configured per-region trace when one exists, else the region's
    /// flat published average.
    pub fn region_signal(&self, region: Region) -> CiSignal {
        self.region_signals.iter()
            .find(|(r, _)| *r == region)
            .map(|(_, s)| s.clone())
            .unwrap_or(CiSignal::Flat(region.avg_ci()))
    }
}

/// Discrete-event payloads. Public so the property suite can drive
/// [`EventQueue`] directly; the engine itself is crate-internal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A request enters the system (its job is already in the arena).
    Arrival(usize),
    /// A deferred offline request is released to the routers.
    Release(usize),
    /// Nudge a server to schedule work (ignored while mid-iteration).
    Wake(usize),
    /// A prefilled sequence's KV cache lands on `server` (after transfer);
    /// only now may the decode side admit the job.
    Handoff { job: usize, server: usize },
    /// End of `server`'s busy period number `gen`.
    Complete { server: usize, gen: u64 },
    /// Bring `server` online (scheduled fleet elasticity).
    Provision(usize),
    /// End of `server`'s cold-start: it actually comes online now. Only
    /// scheduled when `SimConfig::coldstart_s > 0`.
    Activate(usize),
    /// Stop admitting on `server`; it decommissions once empty.
    Drain(usize),
    /// Retire `server` if (and only if) it is draining and empty; a guard
    /// re-check at fire time makes double-scheduling harmless.
    Decommission(usize),
    /// Injected fault: `server` dies abruptly — its in-flight batch is
    /// killed (energy already drawn stays charged), queued and running
    /// jobs are re-routed to survivors or parked in the recovery queue,
    /// and the server retires on the spot.
    Kill { server: usize },
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t: f64,
    /// Monotonic sequence number assigned at push: makes the order total
    /// and deterministic (FIFO among equal timestamps).
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq); total_cmp keeps the order total even
        // for non-finite timestamps.
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The sequence-numbered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn push(&mut self, t: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { t, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// The simulation engine. Stepping logic (prefill/decode) lives in
/// `server.rs`; this file owns the event loop, arrival streaming, and
/// lifecycle.
pub(crate) struct Sim<'a> {
    pub model: &'a LlmSpec,
    pub cfg: &'a SimConfig,
    pub route: &'a dyn RoutePolicy,
    pub batch: &'a dyn BatchPolicy,
    pub source: &'a mut dyn ArrivalSource,
    pub jobs: JobArena,
    pub servers: Vec<Server>,
    pub queue: EventQueue,
    pub metrics: MetricsSink,
    pub meter: CarbonMeter,
    pub defer: DeferState,
    pub prompt_eligible: Vec<usize>,
    pub now: f64,
    slo_ttft: f64,
    slo_tpot: f64,
    /// Latest arrival time pulled so far (the demand horizon).
    last_arrival: f64,
    /// Jobs parked because a fault took down the last admitting
    /// prompt-capable server, with their park times; drained (and their
    /// waits metered) when capacity returns.
    recover_prompt: Vec<(usize, f64)>,
    /// Prefilled jobs whose KV found no live decode-capable server.
    recover_decode: Vec<(usize, f64)>,
    /// Latest time any *work or capacity* event fired. Deferred
    /// retirements (keep-alive windows expiring after the workload ends)
    /// close their own server's interval but must not stretch the sim
    /// horizon every other server's idle and embodied books close at.
    work_end: f64,
    /// Reusable batch-selection buffer (hot-path allocation avoidance).
    pub(crate) batch_scratch: Vec<usize>,
    /// Passive observability hooks ([`crate::obs`]). `None` (the default)
    /// keeps every code path byte-identical to the unobserved engine: the
    /// hooks are `Option`-gated reads that push no events and never touch
    /// simulation state.
    obs: Option<&'a mut Observer>,
}

impl<'a> Sim<'a> {
    pub fn new(model: &'a LlmSpec, source: &'a mut dyn ArrivalSource,
               cfg: &'a SimConfig, slo_ttft: f64, slo_tpot: f64,
               route: &'a dyn RoutePolicy, batch: &'a dyn BatchPolicy)
        -> Sim<'a> {
        assert_eq!(cfg.servers.len(), cfg.emb_kg_per_hr.len());
        let plan = &cfg.fleet_plan;
        assert!(plan.initially_active.is_empty()
                    || plan.initially_active.len() == cfg.servers.len(),
                "fleet schedule initially_active length mismatch");
        let mut servers: Vec<Server> = cfg.servers.iter().map(Server::new).collect();
        let mut meter = CarbonMeter::new(cfg);
        for (i, s) in servers.iter_mut().enumerate() {
            let active0 = plan.initially_active.is_empty()
                || plan.initially_active[i];
            if active0 {
                meter.provision(i, 0.0);
            } else {
                s.lifecycle = Lifecycle::Pending;
            }
        }
        let mut queue = EventQueue::default();
        for e in &plan.events {
            assert!(e.server < servers.len(), "fleet event for unknown server");
            assert!(e.t >= 0.0, "fleet event before t=0");
            let kind = match e.action {
                FleetAction::Provision => EventKind::Provision(e.server),
                FleetAction::Drain => EventKind::Drain(e.server),
            };
            queue.push(e.t, kind);
        }
        // Expand the fault plan into queue events: a death is a `Kill`, an
        // outage is a `Kill` + restoring `Provision` per pinned server.
        // CI spikes are signal faults, applied upstream of the meter
        // ([`super::fault::apply_ci_spikes`]) — inert here by design.
        for f in &cfg.faults.faults {
            match *f {
                Fault::ServerDeath { t, server } => {
                    // Plans may be written before the planner sized the
                    // fleet; a death past the fleet edge is a no-op.
                    if server < servers.len() {
                        queue.push(t, EventKind::Kill { server });
                    }
                }
                Fault::RegionOutage { region, t0, t1 } => {
                    for (i, s) in cfg.servers.iter().enumerate() {
                        if s.region == Some(region) {
                            queue.push(t0, EventKind::Kill { server: i });
                            queue.push(t1, EventKind::Provision(i));
                        }
                    }
                }
                Fault::CiSpike { .. } => {}
            }
        }
        let mut sim = Sim {
            model,
            cfg,
            route,
            batch,
            source,
            jobs: JobArena::new(),
            servers,
            queue,
            metrics: MetricsSink::default(),
            meter,
            defer: DeferState::new(cfg.deferral),
            prompt_eligible: Vec::new(),
            now: 0.0,
            slo_ttft,
            slo_tpot,
            last_arrival: 0.0,
            recover_prompt: Vec::new(),
            recover_decode: Vec::new(),
            work_end: 0.0,
            batch_scratch: Vec::new(),
            obs: None,
        };
        sim.pull_next_arrival();
        sim.refresh_eligibility();
        assert!(!sim.prompt_eligible.is_empty(),
                "no active prompt-capable servers at t=0");
        sim
    }

    /// Pull the next request off the stream and schedule its `Arrival` —
    /// the one-pending-arrival invariant. Called once at start-up and then
    /// exactly once per popped `Arrival`, so the event heap never holds
    /// more than one future arrival regardless of trace length.
    fn pull_next_arrival(&mut self) {
        let Some(r) = self.source.next_request() else { return };
        debug_assert!(r.arrival_s >= self.last_arrival,
                      "arrival source must be time-ordered");
        self.last_arrival = self.last_arrival.max(r.arrival_s);
        self.metrics.arrivals += 1;
        if r.prompt_tokens > MAX_PROMPT_TOKENS {
            self.metrics.truncated_prompts += 1;
        }
        let slot = self.jobs.alloc(Job {
            arrival: r.arrival_s,
            prompt: r.prompt_tokens.min(MAX_PROMPT_TOKENS),
            output: r.output_tokens.max(1),
            class: r.class,
            slo_ttft: self.slo_ttft,
            slo_tpot: self.slo_tpot,
            deadline: self.cfg.deferral.deadline_for(r.class, r.arrival_s),
            dispatched_t: r.arrival_s,
            first_token_t: None,
            decoded: 0,
        });
        self.queue.push(r.arrival_s, EventKind::Arrival(slot));
    }

    /// Rebuild the routing-eligible set (active, prompt-capable servers)
    /// after a lifecycle transition. Fleets are small; a rebuild keeps
    /// the set trivially consistent.
    fn refresh_eligibility(&mut self) {
        self.prompt_eligible.clear();
        self.prompt_eligible.extend(
            self.servers.iter().enumerate()
                .filter(|(_, s)| s.spec.role != Role::Decode && s.is_admitting())
                .map(|(i, _)| i));
    }

    /// Schedule retirement for a draining server that has gone empty —
    /// immediately, or after its keep-alive window (during which it stays
    /// warm, paying idle power, ready to be reused without a cold start).
    fn maybe_retire(&mut self, sid: usize) {
        if self.servers[sid].lifecycle == Lifecycle::Draining
            && self.servers[sid].is_idle_empty()
        {
            let window = self.keepalive_window(sid);
            let s = &mut self.servers[sid];
            s.retire_at = self.now + window;
            if window > 0.0 {
                s.warm_since = Some(self.now);
            }
            self.queue.push(self.now + window, EventKind::Decommission(sid));
        }
    }

    /// How long `sid` should stay warm once drained empty, per the
    /// configured keep-alive policy.
    fn keepalive_window(&self, sid: usize) -> f64 {
        match self.cfg.keepalive {
            KeepAlivePolicy::Immediate => 0.0,
            KeepAlivePolicy::Fixed { window_s } => window_s.max(0.0),
            KeepAlivePolicy::HybridHistogram { bin_s, percentile,
                                               max_window_s } => {
                let s = &self.servers[sid];
                histogram_window(&s.ka_hist, s.ka_obs, bin_s, percentile,
                                 max_window_s)
            }
        }
    }

    /// Bring `sid` online from `Pending`: open its accounting interval
    /// and nudge it. Shared by the inline (no cold-start) `Provision` arm
    /// and the delayed `Activate` handler.
    fn activate(&mut self, sid: usize) {
        self.servers[sid].lifecycle = Lifecycle::Active;
        self.meter.provision(sid, self.now);
        self.metrics.provision_events += 1;
        self.refresh_eligibility();
        self.queue.push(self.now, EventKind::Wake(sid));
        self.drain_recovery();
    }

    /// Drain the recovery queues once capacity has returned. Prompt-phase
    /// jobs re-route (their dispatch stamp is preserved, so TTFT includes
    /// the outage wait); prefilled jobs land on the best live decode
    /// target. A queue whose capacity is still missing keeps its jobs —
    /// and their original park times.
    fn drain_recovery(&mut self) {
        if !self.recover_prompt.is_empty() && !self.prompt_eligible.is_empty() {
            let parked = std::mem::take(&mut self.recover_prompt);
            for (ji, park_t) in parked {
                self.metrics.jobs_recovered += 1;
                self.metrics.recovery_wait_s += self.now - park_t;
                let now = self.now;
                if let Some(sp) = self.spans_mut() {
                    sp.on_recover(ji, now);
                }
                self.route_job(ji);
            }
        }
        if !self.recover_decode.is_empty()
            && self.best_decode_target().is_some()
        {
            let parked = std::mem::take(&mut self.recover_decode);
            for (ji, park_t) in parked {
                self.metrics.jobs_recovered += 1;
                self.metrics.recovery_wait_s += self.now - park_t;
                let now = self.now;
                if let Some(sp) = self.spans_mut() {
                    sp.on_recover(ji, now);
                }
                let sid = self.best_decode_target()
                    .expect("checked: a live decode target exists");
                let class = self.jobs[ji].class;
                self.servers[sid].decode_q.push(ji, class);
                self.queue.push(self.now, EventKind::Wake(sid));
            }
        }
    }

    /// Attach the passive observability recorders for this run. Called
    /// (at most once, before [`Sim::run`]) only on observed paths; the
    /// default engine carries `None` and is byte-identical without it.
    pub(crate) fn attach_observer(&mut self, obs: &'a mut Observer) {
        self.obs = Some(obs);
    }

    /// The span recorder, when one is attached and span tracing is on.
    /// Hook sites copy whatever they need out of `self` first — this
    /// borrow spans all of `Sim`.
    pub(crate) fn spans_mut(&mut self) -> Option<&mut SpanTrace> {
        self.obs.as_deref_mut().and_then(|o| o.spans.as_mut())
    }

    /// Emit every timeline sample due at or before `upto` (and the
    /// progress heartbeat). Called before each popped event is processed
    /// — counts are the state *just before* the first event past each
    /// grid instant — and with `upto = ∞` from the finish path so every
    /// recorder produces its full grid.
    fn obs_tick(&mut self, upto: f64) {
        let Some(obs) = self.obs.as_deref_mut() else { return };
        if let Some(p) = obs.progress.as_mut() {
            p.maybe_emit(self.metrics.events, self.now);
        }
        let Some(tl) = obs.timeline.as_mut() else { return };
        while let Some(t) = tl.due(upto) {
            let (mut pending, mut active, mut draining, mut retired) =
                (0usize, 0usize, 0usize, 0usize);
            let (mut q_po, mut q_pf, mut q_do, mut q_df) =
                (0usize, 0usize, 0usize, 0usize);
            let mut power_w = 0.0;
            let mut emb_kg = 0.0;
            for (i, s) in self.servers.iter().enumerate() {
                match s.lifecycle {
                    Lifecycle::Pending => pending += 1,
                    Lifecycle::Active => active += 1,
                    Lifecycle::Draining => draining += 1,
                    Lifecycle::Retired => retired += 1,
                }
                q_po += s.prompt_q.len_online();
                q_pf += s.prompt_q.len_offline();
                q_do += s.decode_q.len_online();
                q_df += s.decode_q.len_offline();
                if matches!(s.lifecycle,
                            Lifecycle::Active | Lifecycle::Draining) {
                    power_w += if s.in_flight && s.busy_until > t {
                        s.last_power_w
                    } else {
                        crate::carbon::operational::idle_power(
                            s.spec.device.idle_w, s.spec.tp)
                    };
                }
                emb_kg += self.cfg.emb_kg_per_hr[i]
                    * self.meter.provisioned_s_through(i, t) / 3600.0;
            }
            let mut ci = Vec::with_capacity(1 + self.cfg.region_signals.len());
            ci.push(self.cfg.ci.at(t));
            for (_, sig) in &self.cfg.region_signals {
                ci.push(sig.at(t));
            }
            tl.push(TimelineSample {
                t_s: t,
                pending,
                active,
                draining,
                retired,
                q_prompt_online: q_po,
                q_prompt_offline: q_pf,
                q_decode_online: q_do,
                q_decode_offline: q_df,
                recovery: self.recover_prompt.len() + self.recover_decode.len(),
                power_w,
                op_kg: self.meter.op_kg(),
                emb_kg,
                online_done: self.metrics.online_done,
                slo_ok: self.metrics.slo_ok,
                ci,
            });
        }
    }

    /// Drain the event queue to completion.
    pub fn run(&mut self) {
        while let Some(ev) = self.queue.pop() {
            if self.obs.is_some() {
                self.obs_tick(ev.t);
            }
            self.now = ev.t;
            self.metrics.events += 1;
            if !matches!(ev.kind, EventKind::Decommission(_)) {
                self.work_end = self.now;
            }
            match ev.kind {
                EventKind::Arrival(ji) => {
                    // Keep the stream primed before handling this arrival,
                    // so the next arrival is in the heap (and ordered)
                    // before any same-time Wake/Handoff churn.
                    self.pull_next_arrival();
                    if self.obs.is_some() {
                        let j = &self.jobs[ji];
                        let (arrival, prompt, output, online) =
                            (j.arrival, j.prompt, j.output,
                             j.class == RequestClass::Online);
                        if let Some(sp) = self.spans_mut() {
                            sp.on_arrival(ji, arrival, prompt, output, online);
                        }
                    }
                    if self.jobs[ji].class == RequestClass::Offline {
                        let release =
                            self.defer.release_time(self.now, self.meter.primary());
                        if let Some(t) = release {
                            self.metrics.deferred += 1;
                            self.queue.push(t, EventKind::Release(ji));
                            continue;
                        }
                    }
                    self.dispatch(ji);
                }
                EventKind::Release(ji) => self.dispatch(ji),
                EventKind::Wake(sid) => {
                    if !self.servers[sid].in_flight {
                        self.step(sid);
                    }
                }
                EventKind::Handoff { job, server } => {
                    // The target was chosen at prefill time; if it retired
                    // (or never came up) during the KV transfer, re-route
                    // to a live decode server at landing time. A fault
                    // that killed every live server while KV was in
                    // transit parks the job in the recovery queue instead
                    // of panicking — it drains when capacity returns.
                    let target = match self.servers[server].lifecycle {
                        Lifecycle::Active | Lifecycle::Draining => Some(server),
                        Lifecycle::Pending | Lifecycle::Retired => {
                            let now = self.now;
                            if let Some(sp) = self.spans_mut() {
                                sp.on_reroute(job, now, server);
                            }
                            self.best_decode_target()
                        }
                    };
                    match target {
                        Some(server) => {
                            let class = self.jobs[job].class;
                            self.servers[server].decode_q.push(job, class);
                            self.queue.push(self.now, EventKind::Wake(server));
                        }
                        None => {
                            let now = self.now;
                            if let Some(sp) = self.spans_mut() {
                                sp.on_park(job, now);
                            }
                            self.recover_decode.push((job, now));
                        }
                    }
                }
                EventKind::Complete { server, gen } => {
                    // A new busy period only starts once the previous one's
                    // Complete has fired, so the named generation matches —
                    // unless a Kill ended the period early by bumping the
                    // generation, which turns this event into a stale
                    // no-op (the fault-free engine never takes the skip).
                    if self.servers[server].busy_gen != gen {
                        continue;
                    }
                    self.servers[server].in_flight = false;
                    self.step(server);
                    self.maybe_retire(server);
                }
                EventKind::Provision(sid) => {
                    match self.servers[sid].lifecycle {
                        Lifecycle::Active => {}
                        Lifecycle::Draining => {
                            // Cancel the drain; the accounting interval is
                            // still open. If the server was sitting warm,
                            // this is a reuse — record how long it waited
                            // (the hybrid-histogram training signal).
                            let now = self.now;
                            let s = &mut self.servers[sid];
                            if let Some(ws) = s.warm_since.take() {
                                if let KeepAlivePolicy::HybridHistogram {
                                    bin_s, ..
                                } = self.cfg.keepalive {
                                    s.record_warm_reuse(now - ws, bin_s);
                                }
                            }
                            s.lifecycle = Lifecycle::Active;
                            self.refresh_eligibility();
                            self.drain_recovery();
                        }
                        Lifecycle::Pending | Lifecycle::Retired => {
                            // The newest scheduling intent wins: a fresh
                            // Provision cancels any drain deferred from
                            // the boot window.
                            self.servers[sid].drain_pending = false;
                            if self.cfg.coldstart_s > 0.0 {
                                // Boot takes a while: mark it pending and
                                // come online only after the cold start.
                                self.servers[sid].lifecycle = Lifecycle::Pending;
                                self.queue.push(self.now + self.cfg.coldstart_s,
                                                EventKind::Activate(sid));
                            } else {
                                self.activate(sid);
                            }
                        }
                    }
                }
                EventKind::Activate(sid) => {
                    // Guarded like Decommission: a double Provision during
                    // the boot window schedules two Activates; the second
                    // finds the server already Active and no-ops.
                    if self.servers[sid].lifecycle == Lifecycle::Pending {
                        self.activate(sid);
                        if self.servers[sid].drain_pending {
                            // A Drain arrived mid-boot: apply it the
                            // moment the boot ends (the accounting
                            // interval opens and closes honestly instead
                            // of the drain being silently dropped).
                            self.servers[sid].drain_pending = false;
                            self.servers[sid].lifecycle = Lifecycle::Draining;
                            self.refresh_eligibility();
                            self.maybe_retire(sid);
                        }
                    }
                }
                EventKind::Drain(sid) => {
                    match self.servers[sid].lifecycle {
                        Lifecycle::Active => {
                            self.servers[sid].lifecycle = Lifecycle::Draining;
                            self.refresh_eligibility();
                            self.maybe_retire(sid);
                        }
                        // A drain aimed at a cold-starting server used to
                        // be dropped on the floor, leaving the server
                        // Active forever once its boot finished; defer it
                        // to the Activate instead.
                        Lifecycle::Pending =>
                            self.servers[sid].drain_pending = true,
                        Lifecycle::Draining | Lifecycle::Retired => {}
                    }
                }
                EventKind::Decommission(sid) => {
                    // Guarded: only a draining *and empty* server retires;
                    // work that landed after the check was scheduled (e.g.
                    // an in-transit KV handoff) keeps it alive until the
                    // next empty transition re-schedules retirement. The
                    // `retire_at` stamp additionally invalidates events
                    // whose keep-alive window was re-armed later.
                    if self.servers[sid].lifecycle == Lifecycle::Draining
                        && self.servers[sid].is_idle_empty()
                        && self.now >= self.servers[sid].retire_at
                    {
                        self.servers[sid].lifecycle = Lifecycle::Retired;
                        self.servers[sid].warm_since = None;
                        self.meter.decommission(sid, self.now);
                        self.metrics.decommission_events += 1;
                    }
                }
                EventKind::Kill { server: sid } => self.kill_server(sid),
            }
        }
    }

    /// An injected server death: the in-flight batch dies (energy already
    /// drawn stays charged; the unserved remainder is trimmed from busy
    /// time), every job the server held is displaced to survivors or the
    /// recovery queue, and the server retires immediately — closing its
    /// embodied/idle interval at the moment of death.
    fn kill_server(&mut self, sid: usize) {
        match self.servers[sid].lifecycle {
            // Already dead (an outage overlapping a death): no-op.
            Lifecycle::Retired => {}
            // Death during boot: cancel it. The stale Activate finds the
            // server Retired and no-ops; the meter never opened an
            // interval, so there is nothing to close.
            Lifecycle::Pending => {
                self.metrics.faults_injected += 1;
                self.servers[sid].lifecycle = Lifecycle::Retired;
                self.servers[sid].drain_pending = false;
            }
            Lifecycle::Active | Lifecycle::Draining => {
                self.metrics.faults_injected += 1;
                let now = self.now;
                let s = &mut self.servers[sid];
                if s.in_flight {
                    // Bumping the generation turns the scheduled Complete
                    // into a stale no-op; the busy-time trim keeps
                    // busy_s ≤ provisioned_s now that the interval closes
                    // at death rather than at batch end.
                    s.busy_s -= (s.busy_until - now).max(0.0);
                    s.busy_gen += 1;
                    s.in_flight = false;
                }
                s.lifecycle = Lifecycle::Retired;
                s.warm_since = None;
                s.drain_pending = false;
                // Everything the server held is displaced, in a fixed
                // order (running decodes, decode queue, waiting prompts)
                // so re-routing is deterministic.
                let mut decode_orphans = std::mem::take(&mut s.active);
                s.decode_q.pop_fifo_into(usize::MAX, &mut decode_orphans);
                let mut prompt_orphans = Vec::new();
                s.prompt_q.pop_fifo_into(usize::MAX, &mut prompt_orphans);
                self.meter.decommission(sid, now);
                self.refresh_eligibility();
                self.metrics.jobs_rescheduled +=
                    decode_orphans.len() + prompt_orphans.len();
                if let Some(sp) = self.spans_mut() {
                    for &ji in decode_orphans.iter().chain(&prompt_orphans) {
                        sp.on_reroute(ji, now, sid);
                    }
                }
                for ji in decode_orphans {
                    match self.best_decode_target() {
                        Some(t) => {
                            let class = self.jobs[ji].class;
                            self.servers[t].decode_q.push(ji, class);
                            self.queue.push(now, EventKind::Wake(t));
                        }
                        None => {
                            if let Some(sp) = self.spans_mut() {
                                sp.on_park(ji, now);
                            }
                            self.recover_decode.push((ji, now));
                        }
                    }
                }
                for ji in prompt_orphans {
                    self.route_job(ji);
                }
            }
        }
    }

    /// Route a request and nudge the chosen server. Only admitting
    /// (active) prompt-capable servers are eligible; planner schedules
    /// keep at least one alive, but an injected fault can take the last
    /// one down — then the job parks instead of panicking.
    fn dispatch(&mut self, ji: usize) {
        self.jobs[ji].dispatched_t = self.now;
        self.route_job(ji);
    }

    /// Route `ji` to an admitting prompt-capable server, or park it in
    /// the prompt recovery queue when none exists (graceful degradation
    /// under total capacity loss). Never re-stamps `dispatched_t`, so a
    /// recovered job's TTFT includes its outage wait.
    fn route_job(&mut self, ji: usize) {
        if self.prompt_eligible.is_empty() {
            let now = self.now;
            if let Some(sp) = self.spans_mut() {
                sp.on_park(ji, now);
            }
            self.recover_prompt.push((ji, now));
            return;
        }
        let ctx = RouteCtx { now: self.now, meter: &self.meter };
        let sid = self.route.route(&self.jobs[ji], &self.servers,
                                   &self.prompt_eligible, &ctx);
        debug_assert!(self.prompt_eligible.contains(&sid),
                      "policy routed to an ineligible server");
        let class = self.jobs[ji].class;
        self.servers[sid].prompt_q.push(ji, class);
        let now = self.now;
        if let Some(sp) = self.spans_mut() {
            sp.on_route(ji, now, sid);
        }
        self.queue.push(now, EventKind::Wake(sid));
    }

    /// Close the books: idle-floor energy, operational + embodied carbon.
    /// Idle power and amortized embodied are charged per *provisioned*
    /// server-hour (the meter's intervals), so an elastic fleet that
    /// decommissions surplus servers is visibly cheaper than a static
    /// peak-provisioned one.
    pub fn finish(self) -> SimReport {
        self.finish_parts().0
    }

    /// [`Sim::finish`] that also hands back the closed-books carbon meter,
    /// so the sharded runtime can merge shard meters (disjoint server
    /// partitions) into one fleet-wide meter instead of reconstructing
    /// interval totals from the report.
    pub fn finish_parts(mut self) -> (SimReport, CarbonMeter) {
        // Flush the observers first: the timeline owes its full grid
        // (every shard must emit the same instants), and stranded spans
        // must leave the arena-slot table before their jobs are freed.
        if self.obs.is_some() {
            self.obs_tick(f64::INFINITY);
            if let Some(sp) = self.spans_mut() {
                sp.flush_stranded();
            }
        }
        // Jobs still parked when the queue drains were stranded by a
        // fault plan that never restored capacity: release their slots
        // (they count as arrivals, never completions) so the books still
        // close without tripping the leak assert below.
        for (ji, _) in std::mem::take(&mut self.recover_prompt) {
            self.jobs.free(ji);
        }
        for (ji, _) in std::mem::take(&mut self.recover_decode) {
            self.jobs.free(ji);
        }
        debug_assert_eq!(self.jobs.live(), 0,
                         "jobs still live after the event queue drained");
        let dur = self.work_end.max(self.last_arrival);
        self.meter.finalize(dur);
        let mut energy = 0.0;
        let mut emb = 0.0;
        let mut per_server = Vec::with_capacity(self.servers.len());
        for (i, s) in self.servers.iter().enumerate() {
            let prov_s = self.meter.provisioned_s(i);
            debug_assert!(s.busy_s <= prov_s + 1e-6,
                          "server {i} busy outside its provisioned interval");
            let idle_s = (prov_s - s.busy_s).max(0.0);
            // The same idle floor the planner's objective columns price.
            let idle_j = idle_s * crate::carbon::operational::idle_power(
                s.spec.device.idle_w, s.spec.tp);
            self.meter.record_idle(i, idle_j, dur);
            energy += s.energy_j + idle_j;
            emb += self.cfg.emb_kg_per_hr[i] * prov_s / 3600.0;
            per_server.push(ServerUsage {
                busy_s: s.busy_s,
                energy_j: s.energy_j + idle_j,
                provisioned_s: prov_s,
            });
        }
        self.metrics.peak_live_jobs = self.jobs.peak_live();
        let report = self.metrics.into_report(dur, energy, self.meter.op_kg(),
                                              emb, per_server);
        (report, self.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sim::{homogeneous_fleet, simulate, simulate_stream};
    use crate::workload::{generate_trace, Arrivals, GeneratorSource,
                          LengthDist, Request};

    fn small_trace(rate: f64, seed: u64) -> Vec<Request> {
        generate_trace(Arrivals::Poisson { rate }, LengthDist::ShareGpt,
                       RequestClass::Online, 120.0, seed)
    }

    fn cfg_for(servers: Vec<ServerSpec>, router: Router) -> SimConfig {
        let n = servers.len();
        SimConfig::flat(servers, router, 261.0, vec![0.005; n])
    }

    #[test]
    fn event_order_is_total_and_fifo_at_ties() {
        let mut q = EventQueue::default();
        q.push(2.0, EventKind::Wake(0));
        q.push(1.0, EventKind::Wake(1));
        q.push(1.0, EventKind::Wake(2));
        q.push(1.0, EventKind::Wake(3));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::Wake(s) => s,
                _ => unreachable!(),
            })
            .collect();
        // Equal timestamps pop in push order; later time last.
        assert_eq!(order, vec![1, 2, 3, 0]);
    }

    #[test]
    fn nan_timestamps_do_not_break_the_heap() {
        // total_cmp orders NaN after +inf; the queue still drains fully.
        let mut q = EventQueue::default();
        q.push(f64::NAN, EventKind::Wake(0));
        q.push(0.5, EventKind::Wake(1));
        q.push(f64::NAN, EventKind::Wake(2));
        let popped: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(popped.len(), 3);
        assert!(matches!(popped[0].kind, EventKind::Wake(1)));
    }

    #[test]
    fn completes_all_requests() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 1);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 4, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.arrivals, tr.len());
        assert!(r.generated_tokens > 0);
        assert!(r.op_kg > 0.0 && r.emb_kg > 0.0);
        assert!(r.events >= 2 * tr.len());
    }

    #[test]
    fn streaming_keeps_job_memory_bounded_by_in_flight_work() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = cfg_for(homogeneous_fleet("A100-40", 4, m, 2048), Router::Jsq);
        let mut src = GeneratorSource::new(Arrivals::Poisson { rate: 8.0 },
                                           LengthDist::ShareGpt,
                                           RequestClass::Online, 240.0, 17);
        let r = simulate_stream(m, &mut src, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, r.arrivals);
        assert!(r.arrivals > 1000, "trace too small: {}", r.arrivals);
        // The arena high-water mark tracks concurrent work, not the trace.
        assert!(r.peak_live_jobs * 4 < r.arrivals,
                "peak live {} vs {} arrivals — arena is not recycling",
                r.peak_live_jobs, r.arrivals);
    }

    #[test]
    fn empty_source_still_closes_the_books() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let r = simulate(m, &[], &cfg, 0.5, 0.1);
        assert_eq!(r.arrivals, 0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.peak_live_jobs, 0);
        assert_eq!(r.sim_duration_s, 0.0);
    }

    #[test]
    fn overload_degrades_ttft() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let light = simulate(m, &small_trace(0.5, 2), &cfg, 0.5, 0.1);
        let heavy = simulate(m, &small_trace(12.0, 2), &cfg, 0.5, 0.1);
        assert!(heavy.ttft.p90() > light.ttft.p90(),
                "heavy {} vs light {}", heavy.ttft.p90(), light.ttft.p90());
    }

    #[test]
    fn more_servers_more_throughput_headroom() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(8.0, 3);
        let small = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let big = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let r_small = simulate(m, &tr, &small, 0.5, 0.1);
        let r_big = simulate(m, &tr, &big, 0.5, 0.1);
        assert!(r_big.ttft.p90() <= r_small.ttft.p90() * 1.1 + 1e-9,
                "big {} small {}", r_big.ttft.p90(), r_small.ttft.p90());
        assert!(r_big.slo_attainment >= r_small.slo_attainment);
    }

    #[test]
    fn disaggregated_pd_split_works() {
        let m = models::llm("llama-8b").unwrap();
        let mut servers = homogeneous_fleet("H100", 2, m, 2048);
        servers[0].role = Role::Prompt;
        servers[1].role = Role::Decode;
        let cfg = cfg_for(servers, Router::Jsq);
        let r = simulate(m, &small_trace(1.0, 4), &cfg, 0.5, 0.1);
        assert_eq!(r.completed, simulate(m, &small_trace(1.0, 4),
            &cfg_for(homogeneous_fleet("H100", 2, m, 2048), Router::Jsq),
            0.5, 0.1).completed);
        assert!(r.ttft.len() > 0 && r.tpot.len() > 0);
    }

    #[test]
    fn energy_includes_idle_floor() {
        let m = models::llm("llama-8b").unwrap();
        // One request on a big fleet: idle power dominates.
        let tr = small_trace(0.05, 6);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        let idle_j = r.sim_duration_s * 8.0 * 50.0; // 8x idle 50 W
        assert!(r.energy_j > 0.8 * idle_j, "energy {} idle floor {idle_j}", r.energy_j);
    }

    #[test]
    fn explicit_all_on_schedule_matches_the_static_default() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(3.0, 9);
        let base = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        let mut explicit = base.clone();
        explicit.fleet_plan.initially_active = vec![true; 3];
        let a = simulate(m, &tr, &base, 0.5, 0.1);
        let b = simulate(m, &tr, &explicit, 0.5, 0.1);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.emb_kg.to_bits(), b.emb_kg.to_bits());
        assert_eq!(a.provision_events, 0);
        assert_eq!(b.provision_events, 0);
        assert!((a.provisioned_server_hours
                     - 3.0 * a.sim_duration_s / 3600.0).abs() < 1e-9);
    }

    #[test]
    fn drained_empty_server_retires_immediately_and_costs_nothing() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 10);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        cfg.fleet_plan.events.push(FleetEvent {
            t: 0.0, server: 2, action: FleetAction::Drain,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 1);
        // Drained before any arrival: never admitted, never busy, never
        // charged a provisioned second beyond t=0.
        assert_eq!(r.per_server[2].busy_s, 0.0);
        assert_eq!(r.per_server[2].provisioned_s, 0.0);
        let static_r = simulate(m, &tr, &cfg_for(
            homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq), 0.5, 0.1);
        assert!(r.emb_kg < static_r.emb_kg,
                "elastic emb {} !< static emb {}", r.emb_kg, static_r.emb_kg);
    }

    #[test]
    fn late_provisioned_server_is_charged_only_from_provision_time() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 11);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.fleet_plan.initially_active = vec![true, false];
        cfg.fleet_plan.events.push(FleetEvent {
            t: 60.0, server: 1, action: FleetAction::Provision,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.provision_events, 1);
        let prov = r.per_server[1].provisioned_s;
        assert!((prov - (r.sim_duration_s - 60.0)).abs() < 1e-9,
                "provisioned {prov} vs horizon {}", r.sim_duration_s);
        assert!(r.per_server[0].provisioned_s > prov);
    }

    #[test]
    fn mid_trace_drain_finishes_in_flight_work_before_retiring() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(6.0, 12);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.fleet_plan.events.push(FleetEvent {
            t: 40.0, server: 1, action: FleetAction::Drain,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // No requests are lost when a busy server drains, the retirement
        // waits for the in-flight batches, and busy time never exceeds
        // the provisioned interval.
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 1);
        let u = &r.per_server[1];
        assert!(u.provisioned_s >= 40.0 - 1e-9);
        assert!(u.provisioned_s < r.sim_duration_s);
        assert!(u.busy_s <= u.provisioned_s + 1e-6);
    }

    #[test]
    fn cold_start_delays_activation_and_its_accounting() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 11);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.fleet_plan.initially_active = vec![true, false];
        cfg.fleet_plan.events.push(FleetEvent {
            t: 60.0, server: 1, action: FleetAction::Provision,
        });
        let mut cold = cfg.clone();
        cold.coldstart_s = 30.0;
        let warm = simulate(m, &tr, &cfg, 0.5, 0.1);
        let r = simulate(m, &tr, &cold, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.provision_events, 1);
        // Provision fires at 60, the server comes up at 90: its
        // accounting interval (and capacity) starts 30 s later.
        assert!((r.per_server[1].provisioned_s
                     - (r.sim_duration_s - 90.0)).abs() < 1e-9,
                "provisioned {} vs horizon {}", r.per_server[1].provisioned_s,
                r.sim_duration_s);
        assert!(r.per_server[1].provisioned_s
                    < warm.per_server[1].provisioned_s);
    }

    #[test]
    fn fixed_keepalive_holds_a_drained_server_warm() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 10);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        cfg.fleet_plan.events.push(FleetEvent {
            t: 0.0, server: 2, action: FleetAction::Drain,
        });
        let mut warm = cfg.clone();
        warm.keepalive = KeepAlivePolicy::Fixed { window_s: 45.0 };
        let imm = simulate(m, &tr, &cfg, 0.5, 0.1);
        let r = simulate(m, &tr, &warm, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 1);
        // Warm for the window, then retired — and the warm seconds are
        // idle-metered, so keep-alive strictly costs energy and carbon.
        assert!((r.per_server[2].provisioned_s - 45.0).abs() < 1e-9,
                "provisioned {}", r.per_server[2].provisioned_s);
        assert!(r.energy_j > imm.energy_j);
        assert!(r.op_kg > imm.op_kg);
        assert!(r.emb_kg > imm.emb_kg);
    }

    #[test]
    fn keepalive_window_crossing_reuse_cancels_retirement() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 13);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.keepalive = KeepAlivePolicy::Fixed { window_s: 40.0 };
        cfg.fleet_plan.events.push(FleetEvent {
            t: 10.0, server: 1, action: FleetAction::Drain,
        });
        cfg.fleet_plan.events.push(FleetEvent {
            t: 30.0, server: 1, action: FleetAction::Provision,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // Re-provisioned inside the warm window: the stale Decommission is
        // invalidated, the server serves to the end, nothing ever retires.
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 0);
        assert_eq!(r.provision_events, 0);
        assert!((r.per_server[1].provisioned_s - r.sim_duration_s).abs() < 1e-9);
    }

    #[test]
    fn hybrid_histogram_window_learns_from_observations() {
        // Empty histogram: conservative max.
        assert_eq!(histogram_window(&[], 0, 60.0, 0.95, 600.0), 600.0);
        // 10 reuses, 9 within the first minute, 1 in the fifth: p95 covers
        // the straggler bin, p50 stops at the first.
        let hist = [9u64, 0, 0, 0, 1];
        assert_eq!(histogram_window(&hist, 10, 60.0, 0.5, 600.0), 60.0);
        assert_eq!(histogram_window(&hist, 10, 60.0, 0.95, 600.0), 300.0);
        // The cap binds.
        assert_eq!(histogram_window(&hist, 10, 60.0, 0.95, 120.0), 120.0);
    }

    #[test]
    fn immediate_keepalive_and_zero_coldstart_match_the_old_engine_bitwise() {
        // The knobs' defaults must be invisible: an explicitly-spelled
        // default config produces the same bytes and event count as flat().
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(4.0, 14);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        cfg.fleet_plan.events.push(FleetEvent {
            t: 0.0, server: 2, action: FleetAction::Drain,
        });
        let mut explicit = cfg.clone();
        explicit.coldstart_s = 0.0;
        explicit.keepalive = KeepAlivePolicy::Immediate;
        explicit.faults = FaultPlan::new();
        let a = simulate(m, &tr, &cfg, 0.5, 0.1);
        let b = simulate(m, &tr, &explicit, 0.5, 0.1);
        assert_eq!(a.events, b.events);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.op_kg.to_bits(), b.op_kg.to_bits());
        assert_eq!(a.emb_kg.to_bits(), b.emb_kg.to_bits());
    }

    #[test]
    fn drain_during_coldstart_is_deferred_until_activate() {
        // Regression: a Drain landing while the server is still cold-
        // starting (`Pending`) used to be silently dropped, leaving the
        // server active (and charging carbon) forever. It must instead
        // apply the moment the boot completes.
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 15);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.coldstart_s = 30.0;
        cfg.fleet_plan.initially_active = vec![true, false];
        cfg.fleet_plan.events.push(FleetEvent {
            t: 10.0, server: 1, action: FleetAction::Provision,
        });
        cfg.fleet_plan.events.push(FleetEvent {
            t: 20.0, server: 1, action: FleetAction::Drain,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.provision_events, 1);
        assert_eq!(r.decommission_events, 1);
        // Boot completes at t=40, the deferred drain fires on the spot:
        // the server retires empty with no provisioned time to its name.
        assert!(r.per_server[1].provisioned_s.abs() < 1e-9,
                "deferred drain must retire the server at activation, \
                 provisioned {}", r.per_server[1].provisioned_s);
    }

    #[test]
    fn double_drain_is_idempotent() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 10);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        for t in [30.0, 35.0] {
            cfg.fleet_plan.events.push(FleetEvent {
                t, server: 2, action: FleetAction::Drain,
            });
        }
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 1,
                   "a second Drain on a draining/retired server is a no-op");
    }

    #[test]
    fn provision_during_drain_cancels_the_retirement() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(6.0, 12);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.keepalive = KeepAlivePolicy::Fixed { window_s: 60.0 };
        cfg.fleet_plan.events.push(FleetEvent {
            t: 40.0, server: 1, action: FleetAction::Drain,
        });
        cfg.fleet_plan.events.push(FleetEvent {
            t: 45.0, server: 1, action: FleetAction::Provision,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // Whether the server was mid-batch or warm-idle at t=45, the
        // re-provision wins: it serves to the end and never retires.
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 0);
        assert!((r.per_server[1].provisioned_s - r.sim_duration_s).abs() < 1e-9);
    }

    #[test]
    fn reprovision_after_decommission_reopens_the_meter_interval() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 10);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        cfg.fleet_plan.events.push(FleetEvent {
            t: 0.0, server: 2, action: FleetAction::Drain,
        });
        cfg.fleet_plan.events.push(FleetEvent {
            t: 60.0, server: 2, action: FleetAction::Provision,
        });
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.decommission_events, 1);
        assert_eq!(r.provision_events, 1);
        // Retired at t=0, back at t=60: only the second interval accrues.
        let prov = r.per_server[2].provisioned_s;
        assert!((prov - (r.sim_duration_s - 60.0)).abs() < 1e-9,
                "provisioned {prov} vs horizon {}", r.sim_duration_s);
    }

    #[test]
    fn server_death_midbatch_reroutes_work_and_trims_busy_time() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(8.0, 16);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.faults = FaultPlan::new().server_death(20.0, 1);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // The killed server's queued and in-flight jobs finish elsewhere.
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.faults_injected, 1);
        assert!(r.jobs_rescheduled > 0,
                "a server under 8 req/s holds work at t=20");
        // The meter interval closes at death and the unserved remainder of
        // the in-flight batch is trimmed out of busy time.
        let u = &r.per_server[1];
        assert!((u.provisioned_s - 20.0).abs() < 1e-9,
                "provisioned {} vs kill at 20", u.provisioned_s);
        assert!(u.busy_s <= u.provisioned_s + 1e-6);
        assert_eq!(r.decommission_events, 0, "a kill is not a decommission");
    }

    #[test]
    fn total_capacity_loss_parks_jobs_until_recovery() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 17);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.faults = FaultPlan::new()
            .server_death(30.0, 0)
            .server_death(30.0, 1);
        for server in [0, 1] {
            cfg.fleet_plan.events.push(FleetEvent {
                t: 60.0, server, action: FleetAction::Provision,
            });
        }
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // Killing the whole fleet must not panic: arrivals park in the
        // recovery queue and drain once the servers come back, with the
        // parked time metered (and visible in TTFT, which is not
        // re-stamped on recovery).
        assert_eq!(r.completed, tr.len());
        assert_eq!(r.faults_injected, 2);
        assert!(r.jobs_recovered > 0, "arrivals in (30,60) must park");
        assert!(r.recovery_wait_s > 0.0);
    }

    #[test]
    fn stranded_jobs_release_without_completing_when_capacity_never_returns() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 17);
        let mut cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        cfg.faults = FaultPlan::new()
            .server_death(30.0, 0)
            .server_death(30.0, 1);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        // No recovery ever comes: the books still close cleanly, with the
        // parked jobs counted as arrivals but not completions.
        assert_eq!(r.arrivals, tr.len());
        assert!(r.completed < tr.len());
        assert_eq!(r.faults_injected, 2);
        assert_eq!(r.jobs_recovered, 0);
    }

    #[test]
    fn same_config_same_bytes() {
        // The core is deterministic: two runs over the same trace agree on
        // every counter, including the event count.
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(4.0, 8);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        let a = simulate(m, &tr, &cfg, 0.5, 0.1);
        let b = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(a.events, b.events);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.op_kg.to_bits(), b.op_kg.to_bits());
    }
}
