//! perf_sim: throughput of the streaming discrete-event core on a
//! 50k-request trace — reported as events/sec and persisted to
//! `BENCH_sim.json` at the repository root (resolved via
//! `CARGO_MANIFEST_DIR`, so the output lands in the same place whatever
//! directory cargo was invoked from) so sim-core perf regressions are
//! visible across PRs and comparable on CI. The committed baseline lives
//! at `rust/benches/BENCH_sim_baseline.json`; the CI `perf-sim` job fails
//! on a >30% events/sec regression against it (single-core key:
//! `events_per_sec`). Each measured iteration drives the full streaming
//! path: lazy trace generation → pull-on-pop arrivals → arena-recycled
//! jobs → histogram metrics. A second leg runs the same fleet on the
//! sharded runtime (per-cluster partition, scoped threads) and reports
//! `sharded_events_per_sec` — the wall-clock scaling the `scale`
//! subcommand studies, not a gated metric.
use ecoserve::bench::{run, BenchConfig};
use ecoserve::models;
use ecoserve::sim::{homogeneous_fleet, simulate_sharded, simulate_stream,
                    Router, ShardPlan, SimConfig};
use ecoserve::util::json::Json;
use ecoserve::workload::{Arrivals, ArrivalSource, GeneratorSource, LengthDist,
                         RequestClass};
use std::time::Duration;

fn main() {
    let m = models::llm("llama-8b").unwrap();
    // ~50k requests (Poisson 250/s over 200 s) on a 32-server fleet near
    // its saturation point — the regime where event pressure is highest.
    // PERF_SIM_DURATION trims the trace (CI runs a shorter slice; the
    // reported events/sec metric is scale-invariant).
    let duration: f64 = std::env::var("PERF_SIM_DURATION")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|d: &f64| d.is_finite() && *d > 0.0)
        .unwrap_or(200.0);
    let source = || GeneratorSource::new(Arrivals::Poisson { rate: 250.0 },
                                         LengthDist::ShareGpt,
                                         RequestClass::Online, duration, 42);
    let servers = homogeneous_fleet("A100-40", 32, m, 2048);
    let n = servers.len();
    let cfg = SimConfig::flat(servers, Router::Jsq, 261.0, vec![0.005; n]);

    // One probe run pins down the (deterministic) event count.
    let probe = simulate_stream(m, &mut source(), &cfg, 0.5, 0.1);
    assert_eq!(probe.completed, probe.arrivals);

    let bcfg = BenchConfig {
        warmup: Duration::from_millis(200),
        measure: Duration::from_secs(2),
        min_samples: 3,
        max_samples: 50,
    };
    let r = run("sim_50k_requests_32_servers", &bcfg, || {
        std::hint::black_box(simulate_stream(m, &mut source(), &cfg, 0.5, 0.1));
    });
    println!("{}", r.report());
    let events_per_sec = probe.events as f64 / r.mean_s;
    println!("events/sec: {events_per_sec:.0}  ({} events, {} requests, \
              {} tokens, peak {} live jobs)",
             probe.events, probe.arrivals, probe.generated_tokens,
             probe.peak_live_jobs);

    // Sharded leg: the same fleet partitioned per cluster (32 servers →
    // 4 shards of 8), simulated on 4 scoped threads. Its event count
    // differs from the single-core run's (two-level routing is its own
    // design point); the metric is merged events per wall-second.
    let plan = ShardPlan::partition(&cfg, 42);
    let shards = plan.len();
    let mk = || {
        Box::new(GeneratorSource::new(Arrivals::Poisson { rate: 250.0 },
                                      LengthDist::ShareGpt,
                                      RequestClass::Online, duration, 42))
            as Box<dyn ArrivalSource>
    };
    let sharded_probe = simulate_sharded(m, &cfg, 0.5, 0.1, &plan, shards,
                                         &mk, None);
    assert_eq!(sharded_probe.completed, sharded_probe.arrivals);
    let rs = run("sim_50k_requests_sharded", &bcfg, || {
        std::hint::black_box(simulate_sharded(m, &cfg, 0.5, 0.1, &plan,
                                              shards, &mk, None));
    });
    println!("{}", rs.report());
    let sharded_events_per_sec = sharded_probe.events as f64 / rs.mean_s;
    println!("sharded events/sec: {sharded_events_per_sec:.0}  \
              ({shards} shards, {} events, {} requests)",
             sharded_probe.events, sharded_probe.arrivals);

    let j = Json::obj()
        .set("bench", "perf_sim")
        .set("trace_duration_s", duration)
        .set("requests", probe.arrivals)
        .set("servers", n)
        .set("events", probe.events)
        .set("generated_tokens", probe.generated_tokens)
        .set("peak_live_jobs", probe.peak_live_jobs)
        .set("mean_s", r.mean_s)
        .set("p50_s", r.p50_s)
        .set("events_per_sec", events_per_sec)
        .set("shards", shards)
        .set("sharded_events", sharded_probe.events)
        .set("sharded_mean_s", rs.mean_s)
        .set("sharded_events_per_sec", sharded_events_per_sec);
    // The package lives at <repo>/rust; the report belongs at <repo>.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let out = manifest.parent().unwrap_or(manifest).join("BENCH_sim.json");
    std::fs::write(&out, j.to_string().as_bytes())
        .unwrap_or_else(|e| panic!("write {}: {e}", out.display()));
    eprintln!("wrote {}", out.display());
}
