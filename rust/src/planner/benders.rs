//! Benders-style interval cuts for the rolling-horizon re-solve.
//!
//! Decomposition: the cached epoch plan is the *master* — it fixed the
//! slice-phase → device assignment and the fleet sizing for the demand it
//! was solved against. When the next epoch's demand grows, we do not
//! re-solve the full horizon MILP; instead we sweep the epoch's
//! quarter-chunk arrival/departure events (the dslab-faas `benders.cpp`
//! recipe: sort event edges, walk them once, track the alive total) to
//! find the intervals where offered load exceeds the master's capacity,
//! and solve one *small* feasibility subproblem per overload interval —
//! integer device-count increments over the master's own device support,
//! a handful of variables instead of the full slice×phase×device
//! assignment polytope. The resulting capacity cuts patch the cached
//! plan's counts (elementwise max across intervals: capacity must cover
//! the worst interval, the intervals are disjoint in time).
//!
//! Cuts only ever *add* capacity; scale-down and demand that appears in
//! buckets the master never assigned (no column to scale) fall back to a
//! full re-solve upstream in [`super::horizon::IncrementalPlanner`]. This
//! whole layer sits behind `HorizonConfig::interval_cuts` (default off)
//! — it is a modeling shortcut, deliberately not bitwise-equal to the
//! from-scratch solve.

use super::{device_options, idle_op_kg_per_hr, Phase, Plan, PlanConfig,
            WarmStart};
use crate::solver::{MilpConfig, MilpStatus, ProblemBuilder};

/// A time interval where offered load exceeds the master plan's capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadInterval {
    pub t_lo: f64,
    pub t_hi: f64,
    /// Peak alive total inside the interval (same units as the events).
    pub peak: f64,
}

/// Sweep `(time, ±delta)` events and return the maximal intervals where
/// the running total strictly exceeds `threshold`. Events at equal times
/// apply releases (negative deltas) before admissions, so a burst handing
/// over to another at the same instant never fabricates an overload.
pub fn sweep_overloads(events: &[(f64, f64)], threshold: f64)
    -> Vec<OverloadInterval> {
    let mut ev = events.to_vec();
    ev.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap()
            .then(a.1.partial_cmp(&b.1).unwrap())
    });
    let mut out = Vec::new();
    let mut alive = 0.0f64;
    let mut open: Option<(f64, f64)> = None; // (t_lo, peak)
    let mut i = 0usize;
    while i < ev.len() {
        let t = ev[i].0;
        // Apply every delta at this instant before judging the level.
        while i < ev.len() && ev[i].0 == t {
            alive += ev[i].1;
            i += 1;
        }
        match (&mut open, alive > threshold) {
            (None, true) => open = Some((t, alive)),
            (Some((_, peak)), true) => *peak = peak.max(alive),
            (Some((t_lo, peak)), false) => {
                out.push(OverloadInterval { t_lo: *t_lo, t_hi: t, peak: *peak });
                open = None;
            }
            (None, false) => {}
        }
    }
    if let Some((t_lo, peak)) = open {
        // Trailing overload: close at the last event edge.
        let t_hi = ev.last().map(|e| e.0).unwrap_or(t_lo);
        out.push(OverloadInterval { t_lo, t_hi, peak });
    }
    out
}

/// Rate-weighted mean request dwell (service residence) under the master
/// plan's assignments: prompt latency plus the decode-phase latency of
/// whatever device each slice landed on. A fluid smoothing constant for
/// the sweep, not a latency model — clamped to at least a quarter chunk.
fn service_dwell_s(prev: &WarmStart, q: f64) -> f64 {
    let mut rate = 0.0f64;
    let mut weighted = 0.0f64;
    for (i, s) in prev.slices.iter().enumerate() {
        let service: f64 = prev.plan.assignments.iter()
            .filter(|a| a.slice_idx == i)
            .map(|a| a.latency_s)
            .sum();
        if service > 0.0 && s.rate > 0.0 {
            rate += s.rate;
            weighted += s.rate * service;
        }
    }
    if rate > 0.0 { (weighted / rate).max(q * 0.25) } else { q }
}

/// What one patch pass produced.
#[derive(Debug, Clone)]
pub struct CutOutcome {
    pub plan: Plan,
    /// Per-interval feasibility subproblems solved.
    pub cuts: usize,
    /// Branch-and-bound nodes spent across the subproblems.
    pub nodes: usize,
}

/// Patch the master plan against this epoch's chunk demand.
///
/// `chunks` are `(chunk_start_s, raw_rate_req_per_s)` at quarter-epoch
/// resolution `q`; `headroom` is the horizon's capacity margin (the
/// master's slices already carry it, so the chunk rates must too).
/// Returns `None` when the master gives the cut generator nothing to work
/// with (no GPU support with served rate) — the caller falls back to a
/// full re-solve. `cuts == 0` means the master's capacity already covers
/// every interval and the cached plan is returned untouched.
pub fn patch_plan(prev: &WarmStart, cfg: &PlanConfig,
                  chunks: &[(f64, f64)], q: f64, headroom: f64)
    -> Option<CutOutcome> {
    assert!(q > 0.0);
    let r_prev: f64 = prev.slices.iter().map(|s| s.rate).sum();
    if !(r_prev > 0.0) {
        return None;
    }

    // Effective request rate one provisioned device of each type carries
    // under the master's assignment (prompt admissions per GPU). The cut
    // subproblem scales these columns instead of re-deriving rooflines.
    let mut support: Vec<(String, f64)> = Vec::new(); // (device, eff rate)
    for (name, &count) in &prev.plan.counts {
        if name == "cpu-host" || count == 0 {
            continue;
        }
        let served: f64 = prev.plan.assignments.iter()
            .filter(|a| a.phase == Phase::Prompt && &a.device == name)
            .map(|a| prev.slices[a.slice_idx].rate)
            .sum();
        if served > 0.0 {
            support.push((name.clone(), served / count as f64));
        }
    }
    if support.is_empty() {
        return None;
    }

    // Fluid sweep: each chunk's (headroom-scaled) rate stays alive for the
    // chunk plus one mean service dwell, so the alive total at time t is
    // the trailing (q + dwell)-window mean rate scaled by (q + dwell)/q.
    // Comparing it against r_prev in the same units finds the intervals
    // where smoothed demand outruns what the master was sized for.
    let dwell = service_dwell_s(prev, q);
    let stretch = (q + dwell) / q;
    let mut events = Vec::with_capacity(chunks.len() * 2);
    for &(t, r) in chunks {
        if r > 0.0 {
            let scaled = r * headroom;
            events.push((t, scaled));
            events.push((t + q + dwell, -scaled));
        }
    }
    let intervals = sweep_overloads(&events, r_prev * stretch);

    let mut patched = prev.plan.clone();
    patched.solve_s = 0.0;
    patched.nodes = 0;
    if intervals.is_empty() {
        return Some(CutOutcome { plan: patched, cuts: 0, nodes: 0 });
    }

    // One tiny feasibility ILP per overload interval: integer extra
    // devices E_d ≥ 0 over the master's support, covering the interval's
    // excess rate at minimum provisioning objective (same (1-α)·cost +
    // α·(embodied + idle) pricing as the full ILP's B columns). Disjoint
    // intervals need the elementwise max, not the sum.
    let opts = device_options(cfg, prev.slices[0].model);
    let milp = MilpConfig { max_nodes: 64, ..Default::default() };
    let mut extra: Vec<usize> = vec![0; support.len()];
    let mut cuts = 0usize;
    let mut nodes = 0usize;
    for iv in &intervals {
        let excess = iv.peak / stretch - r_prev;
        if !(excess > 0.0) {
            continue;
        }
        cuts += 1;
        let mut pb = ProblemBuilder::new();
        let mut cover = Vec::with_capacity(support.len());
        let vars: Vec<_> = support.iter().map(|(name, eff)| {
            let opt = opts.iter().find(|o| &o.name == name)
                .expect("master device missing from menu");
            let obj = (1.0 - cfg.alpha) * opt.cost_hr
                + cfg.alpha * (opt.emb_kg_per_hr + idle_op_kg_per_hr(opt, cfg.ci));
            let v = pb.var(&format!("E_{name}"), obj, true);
            cover.push((v, *eff));
            v
        }).collect();
        pb.ge(&cover, excess);
        let sol = pb.solve(&milp);
        nodes += sol.nodes;
        if matches!(sol.status, MilpStatus::Optimal | MilpStatus::Feasible) {
            for (d, v) in vars.iter().enumerate() {
                let e = pb.value(&sol, *v).round().max(0.0) as usize;
                extra[d] = extra[d].max(e);
            }
        } else {
            // Degenerate subproblem: cover with the highest-rate column.
            let d = support.iter().enumerate()
                .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                .map(|(d, _)| d)
                .unwrap();
            extra[d] = extra[d].max((excess / support[d].1).ceil() as usize);
        }
    }

    for (d, (name, _)) in support.iter().enumerate() {
        if extra[d] == 0 {
            continue;
        }
        let opt = opts.iter().find(|o| &o.name == name).unwrap();
        *patched.counts.get_mut(name).unwrap() += extra[d];
        let e = extra[d] as f64;
        patched.cost_hr += e * opt.cost_hr;
        patched.emb_kg_per_hr += e * opt.emb_kg_per_hr;
        patched.op_kg_per_hr += e * idle_op_kg_per_hr(opt, cfg.ci);
    }
    patched.status = MilpStatus::Feasible;
    Some(CutOutcome { plan: patched, cuts, nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::planner::slicing::Slice;
    use crate::planner::{plan, WarmStart};
    use crate::workload::slo::Slo;

    #[test]
    fn sweep_finds_step_overload() {
        // Base rate 2.0 with a surge to 8.0 over [40, 60).
        let mut ev = Vec::new();
        for j in 0..10 {
            let t = j as f64 * 10.0;
            let r = if (40.0..60.0).contains(&t) { 8.0 } else { 2.0 };
            ev.push((t, r));
            ev.push((t + 10.0, -r));
        }
        let ivs = sweep_overloads(&ev, 5.0);
        assert_eq!(ivs.len(), 1, "{ivs:?}");
        assert_eq!(ivs[0].t_lo, 40.0);
        assert_eq!(ivs[0].t_hi, 60.0);
        assert_eq!(ivs[0].peak, 8.0);
    }

    #[test]
    fn sweep_applies_releases_before_admissions() {
        // 4.0 hands over to 4.0 at t=10: never above 6.0 at any instant.
        let ev = vec![(0.0, 4.0), (10.0, -4.0), (10.0, 4.0), (20.0, -4.0)];
        assert!(sweep_overloads(&ev, 6.0).is_empty());
        // Overlapping instead of handing over: exceeds.
        let ev = vec![(0.0, 4.0), (12.0, -4.0), (10.0, 4.0), (20.0, -4.0)];
        let ivs = sweep_overloads(&ev, 6.0);
        assert_eq!(ivs.len(), 1);
        assert_eq!(ivs[0].peak, 8.0);
    }

    #[test]
    fn sweep_separates_two_bursts() {
        let ev = vec![
            (0.0, 10.0), (5.0, -10.0),
            (20.0, 12.0), (25.0, -12.0),
        ];
        let ivs = sweep_overloads(&ev, 6.0);
        assert_eq!(ivs.len(), 2);
        assert!(ivs[0].t_hi <= ivs[1].t_lo);
    }

    fn master(rate: f64) -> (WarmStart, PlanConfig) {
        let m = models::llm("llama-8b").unwrap();
        let slo = Slo { ttft_s: 2.0, tpot_s: 0.2 };
        let slices = vec![Slice {
            model: m, rate, prompt: 256, output: 128, slo, offline: false,
        }];
        let cfg = PlanConfig {
            gpu_menu: vec!["A100-40"],
            cpu_reuse: false,
            ..Default::default()
        };
        let p = plan(&slices, &cfg);
        assert!(p.total_gpus() > 0);
        (WarmStart::new(&slices, &cfg, p), cfg)
    }

    #[test]
    fn no_overload_returns_master_untouched() {
        let (prev, cfg) = master(8.0);
        // Chunk demand well below what the master was sized for.
        let chunks: Vec<(f64, f64)> = (0..4)
            .map(|j| (j as f64 * 5.0, 2.0)).collect();
        let out = patch_plan(&prev, &cfg, &chunks, 5.0, 1.0).unwrap();
        assert_eq!(out.cuts, 0);
        assert_eq!(out.plan.counts, prev.plan.counts);
        assert_eq!(out.plan.cost_hr.to_bits(), prev.plan.cost_hr.to_bits());
    }

    #[test]
    fn surge_generates_capacity_cuts() {
        let (prev, cfg) = master(8.0);
        // One chunk spikes to 5x the planned rate.
        let chunks: Vec<(f64, f64)> = (0..8)
            .map(|j| (j as f64 * 5.0, if j == 4 { 40.0 } else { 8.0 }))
            .collect();
        let out = patch_plan(&prev, &cfg, &chunks, 5.0, 1.0).unwrap();
        assert!(out.cuts >= 1, "no cuts for a 5x surge");
        let before = prev.plan.total_gpus();
        let after = out.plan.total_gpus();
        assert!(after > before, "counts never grew: {before} -> {after}");
        assert!(out.plan.cost_hr > prev.plan.cost_hr);
        assert!(out.plan.emb_kg_per_hr > prev.plan.emb_kg_per_hr);
        // Assignments are the master's — cuts only add capacity.
        assert_eq!(out.plan.assignments.len(), prev.plan.assignments.len());
    }
}
