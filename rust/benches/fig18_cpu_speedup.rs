//! Fig 18: EcoServe CPU decode speedup over a llama.cpp-style baseline
//! across batch, context, and core count (Gemma-2B / Gemma-27B).
use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::cpu::{decode_throughput, CpuStrategy};
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 18: CPU decode speedup vs llama.cpp baseline ==");
    let mut all = Vec::new();
    for cpu_name in ["SPR-56", "SPR-112"] {
        let cpu = hw::cpu(cpu_name).unwrap();
        for model_name in ["gemma-2b", "gemma-27b"] {
            let m = models::llm(model_name).unwrap();
            let mut t = Table::new(&["batch", "ctx", "naive tok/s", "opt tok/s",
                                     "speedup"]);
            for &b in &[1usize, 4, 16, 64] {
                for &ctx in &[512usize, 2048, 8192] {
                    let n = decode_throughput(m, cpu, b, ctx, CpuStrategy::Naive);
                    let o = decode_throughput(m, cpu, b, ctx, CpuStrategy::Optimized);
                    all.push(o / n);
                    t.row(&[format!("{b}"), format!("{ctx}"), fnum(n), fnum(o),
                            fnum(o / n)]);
                }
            }
            println!("\n{model_name} on {cpu_name}:");
            t.print();
        }
    }
    let mean = all.iter().sum::<f64>() / all.len() as f64;
    let max = all.iter().cloned().fold(0.0, f64::max);
    println!("\nmean speedup {:.2}x, max {:.2}x (paper: avg 1.34x, up to 4.03x)",
             mean, max);
}
