//! Scenario-sweep integration: the full registry runs end to end, and the
//! report is byte-identical across repeated runs and across thread counts
//! (the determinism contract every future perf PR must preserve).

use ecoserve::scenarios::{registry, run_sweep, scenario_seed, SweepConfig};
use ecoserve::util::json::Json;

const TEST_DURATION_S: f64 = 60.0;

#[test]
fn sweep_is_deterministic_across_runs_and_thread_counts() {
    let cfg1 = SweepConfig { threads: 1, seed: 7, duration_s: TEST_DURATION_S,
                             ..Default::default() };
    let cfg4 = SweepConfig { threads: 4, ..cfg1.clone() };

    let a = run_sweep(&registry(), &cfg1).to_json().to_string();
    let b = run_sweep(&registry(), &cfg1).to_json().to_string();
    let c = run_sweep(&registry(), &cfg4).to_json().to_string();

    assert_eq!(a, b, "same seed + same thread count must be byte-identical");
    assert_eq!(a, c, "thread count must not change the report bytes");

    // The report is also valid JSON with every registered scenario present,
    // sorted by name, carrying the required per-scenario metrics.
    let j = Json::parse(&a).expect("report must be valid JSON");
    let scenarios = j.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    assert!(scenarios.len() >= 14, "only {} scenarios", scenarios.len());
    let names: Vec<&str> = scenarios.iter()
        .map(|s| s.get("name").and_then(|n| n.as_str()).unwrap())
        .collect();
    for want in ["diurnal-shift", "carbon-router", "autoscale-diurnal",
                 "demand-surge", "production-day", "production-week",
                 "keepalive-surge", "nonlinear-power"] {
        assert!(names.contains(&want), "missing scenario {want}");
    }
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "scenarios must be name-sorted");
    for s in scenarios {
        let name = s.get("name").unwrap().as_str().unwrap();
        let num = |k: &str| -> f64 {
            s.get(k).and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("{name}: missing numeric {k}"))
        };
        let op = num("op_kg");
        let emb = num("emb_kg");
        let carbon = num("carbon_kg");
        assert!(op > 0.0 && emb > 0.0, "{name}: op {op} emb {emb}");
        assert!((carbon - (op + emb)).abs() <= 1e-9 * carbon.max(1.0),
                "{name}: carbon {carbon} != op {op} + emb {emb}");
        let slo = num("slo_attainment");
        assert!((0.0..=1.0).contains(&slo), "{name}: slo {slo}");
        let ddl = num("offline_deadline_attainment");
        assert!((0.0..=1.0).contains(&ddl), "{name}: deadline {ddl}");
        assert!(s.get("deferred_requests").and_then(|v| v.as_usize()).is_some(),
                "{name}: missing deferred_requests");
        assert!(s.get("truncated_prompts").and_then(|v| v.as_usize()).is_some(),
                "{name}: missing truncated_prompts");
        assert!(s.get("provision_events").and_then(|v| v.as_usize()).is_some(),
                "{name}: missing provision_events");
        let peak = s.get("peak_live_jobs").and_then(|v| v.as_usize())
            .unwrap_or_else(|| panic!("{name}: missing peak_live_jobs"));
        let requests = s.get("requests").and_then(|v| v.as_usize()).unwrap();
        assert!(peak <= requests, "{name}: peak {peak} > requests {requests}");
        let srv_hrs = num("provisioned_server_hours");
        assert!(srv_hrs > 0.0, "{name}: provisioned_server_hours {srv_hrs}");
        for k in ["ttft_p50_s", "ttft_p90_s", "ttft_p99_s", "tpot_p50_s",
                  "tpot_p90_s"] {
            let v = num(k);
            assert!(v >= 0.0, "{name}: {k} = {v}");
        }
        assert!(num("ttft_p50_s") <= num("ttft_p90_s") + 1e-12, "{name}");
        let requests = s.get("requests").and_then(|v| v.as_usize()).unwrap();
        let completed = s.get("completed").and_then(|v| v.as_usize()).unwrap();
        assert!(requests > 0 && completed <= requests,
                "{name}: {completed}/{requests}");
        assert!(s.get("generated_tokens").and_then(|v| v.as_usize()).unwrap() > 0,
                "{name}: no tokens generated");
        assert!(s.get("fleet_gpus").and_then(|v| v.as_usize()).unwrap() > 0,
                "{name}: empty fleet");
    }
}

#[test]
fn different_master_seeds_change_the_workload() {
    let sel = ecoserve::scenarios::catalog::by_names(&["mixed-4r"]).unwrap();
    let r1 = run_sweep(&sel, &SweepConfig { threads: 1, seed: 1, duration_s: 45.0,
                                            ..Default::default() });
    let r2 = run_sweep(&sel, &SweepConfig { threads: 1, seed: 2, duration_s: 45.0,
                                            ..Default::default() });
    assert_ne!(scenario_seed(1, "mixed-4r"), scenario_seed(2, "mixed-4r"));
    // Different seeds give different traces (request counts almost surely
    // differ for a Poisson+bursty mix; equality of both counts would mean
    // the seed plumbing collapsed somewhere).
    let a = &r1.outcomes[0];
    let b = &r2.outcomes[0];
    assert!(a.requests != b.requests || a.generated_tokens != b.generated_tokens,
            "seed change produced an identical workload");
}
