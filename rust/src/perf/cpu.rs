//! CPU decode performance model: threading × tiling co-selection
//! (paper §4.1.1, Figs 9/18/19).
//!
//! Models the paper's core CPU insight: a llama.cpp-style engine
//! parallelizes attention only over (batch × heads), leaving most cores —
//! and therefore most of the socket's DRAM bandwidth — idle for small
//! batches. EcoServe adds the KV *sequence-length* dimension (the same
//! split-KV schedule our Pallas kernel expresses on the grid, see
//! python/compile/kernels/decode_attention.py) and picks Linear-op tile
//! sizes by arithmetic intensity, recovering near-saturated bandwidth.
//!
//! Bandwidth scaling uses the standard per-core DRAM-concurrency model:
//! a single core sustains only `PER_CORE_BW` of the socket's bandwidth
//! (limited by outstanding misses), so effective BW ≈ min(total,
//! n_active_cores × per_core).

use crate::hw::CpuSpec;
use crate::models::LlmSpec;

/// Sustainable DRAM bandwidth per active core, B/s (SPR-class).
pub const PER_CORE_BW: f64 = 12e9;

/// CPU execution strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuStrategy {
    /// llama.cpp-like: attention parallel over batch × kv-heads; default
    /// row-major GEMM tiling.
    Naive,
    /// EcoServe: + KV sequence-dim parallelism (chunked), AI-driven tiles.
    Optimized,
}

/// KV chunk length used by the optimized sequence-dimension split.
pub const KV_CHUNK: usize = 64;

/// Number of cores the attention phase can keep busy.
pub fn attn_active_cores(m: &LlmSpec, cpu: &CpuSpec, batch: usize, ctx: usize,
                         strategy: CpuStrategy) -> usize {
    let units = match strategy {
        // llama.cpp shards attention per KV head (the unit that owns a
        // contiguous KV stream): batch × kv_heads busy cores.
        CpuStrategy::Naive => batch * m.n_kv_heads,
        CpuStrategy::Optimized => batch * m.n_heads * ctx.div_ceil(KV_CHUNK),
    };
    units.min(cpu.cores)
}

/// Fraction of stream bandwidth a default (untiled) weight-streaming GEMV
/// achieves vs an AI-tuned tiling (prefetch distance / NT loads).
fn dense_bw_frac(strategy: CpuStrategy) -> f64 {
    match strategy {
        CpuStrategy::Naive => 0.60,
        CpuStrategy::Optimized => 1.0,
    }
}

/// Effective bandwidth with `active` cores generating misses.
pub fn effective_bw(cpu: &CpuSpec, active: usize) -> f64 {
    (active as f64 * PER_CORE_BW).min(cpu.mem_bw_gbs * 1e9)
}

/// GEMM efficiency: fraction of peak AMX/AVX FLOPs by tiling quality.
fn gemm_mfu(strategy: CpuStrategy) -> f64 {
    match strategy {
        // Default tiles thrash L2 for skinny decode GEMVs.
        CpuStrategy::Naive => 0.35,
        // AI-selected tiles (Fig 9) keep the inner kernel resident.
        CpuStrategy::Optimized => 0.70,
    }
}

/// One decode step latency (seconds) for the whole batch on CPU.
pub fn decode_step_time(m: &LlmSpec, cpu: &CpuSpec, batch: usize, ctx: usize,
                        strategy: CpuStrategy) -> f64 {
    let peak_flops = cpu.bf16_tflops * 1e12;
    // Dense limb: weight-streaming GEMM. Batched across sequences, so the
    // weight read amortizes; bound by max(weight bytes / bw, flops / mfu).
    let dense_flops = 2.0 * m.active_params_b * 1e9 * batch as f64;
    let weight_bytes = m.params_b * 1e9 * m.dtype_bytes;
    // Dense GEMMs tile over output channels: plenty of parallel units.
    let dense_bw = effective_bw(cpu, cpu.cores) * dense_bw_frac(strategy);
    let t_dense = (dense_flops / (peak_flops * gemm_mfu(strategy)))
        .max(weight_bytes / dense_bw);
    // Attention limb: KV streaming, bandwidth-bound, parallelism-limited.
    let kv_bytes = batch as f64 * ctx as f64 * m.kv_bytes_per_token();
    let active = attn_active_cores(m, cpu, batch, ctx, strategy);
    let t_attn = kv_bytes / effective_bw(cpu, active.max(1));
    t_dense + t_attn
}

/// Decode throughput, tokens/s.
pub fn decode_throughput(m: &LlmSpec, cpu: &CpuSpec, batch: usize, ctx: usize,
                         strategy: CpuStrategy) -> f64 {
    batch as f64 / decode_step_time(m, cpu, batch, ctx, strategy)
}

/// Max CPU batch at a context length given DRAM capacity (Fig 8: 512 at
/// ctx 2048 vs the GPU's 16-74).
pub fn max_batch(m: &LlmSpec, dram_gb: f64, ctx: usize) -> usize {
    let avail = (dram_gb * 0.9 - m.weight_gb()) * 1e9;
    if avail <= 0.0 {
        return 0;
    }
    (avail / (ctx as f64 * m.kv_bytes_per_token())) as usize
}

/// Arithmetic intensity (FLOPs/byte) of a Linear-op slice when the output
/// dimension is split `pd` ways (Fig 9's PD × AI tradeoff): each slice
/// re-reads the full input but only 1/pd of the weights.
pub fn linear_slice_ai(d_in: usize, d_out: usize, batch: usize, pd: usize,
                       dtype_bytes: f64) -> f64 {
    let pd = pd.max(1) as f64;
    let flops = 2.0 * d_in as f64 * d_out as f64 / pd * batch as f64;
    let bytes = (d_in as f64 * batch as f64          // input slice (re-read)
        + d_in as f64 * d_out as f64 / pd            // weight slice
        + d_out as f64 / pd * batch as f64)          // output slice
        * dtype_bytes;
    flops / bytes
}

/// Pick the parallelism degree maximizing throughput for a Linear op:
/// enough slices to keep all cores busy, but not so many that per-slice AI
/// falls below the CPU's roofline knee (Fig 9's co-design rule).
pub fn best_linear_pd(cpu: &CpuSpec, d_in: usize, d_out: usize, batch: usize,
                      dtype_bytes: f64) -> usize {
    let knee = cpu.bf16_tflops * 1e12 / (cpu.mem_bw_gbs * 1e9);
    let mut best = (1usize, f64::NEG_INFINITY);
    for pd in 1..=cpu.cores {
        let ai = linear_slice_ai(d_in, d_out, batch, pd, dtype_bytes);
        // Throughput proxy: core utilization × min(1, AI/knee).
        let util = (pd as f64 / cpu.cores as f64).min(1.0);
        let score = util * (ai / knee).min(1.0);
        if score > best.1 {
            best = (pd, score);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw;
    use crate::models;

    fn spr() -> &'static CpuSpec { hw::cpu("SPR-112").unwrap() }

    #[test]
    fn optimized_beats_naive() {
        let m = models::llm("gemma-27b").unwrap();
        for &(b, ctx) in &[(1usize, 2048usize), (4, 2048), (16, 512)] {
            let n = decode_throughput(m, spr(), b, ctx, CpuStrategy::Naive);
            let o = decode_throughput(m, spr(), b, ctx, CpuStrategy::Optimized);
            assert!(o > n, "b={b} ctx={ctx}: {o} <= {n}");
        }
    }

    #[test]
    fn speedup_band_matches_fig18() {
        // Paper: up to 4.03x, average 1.34x across batch sizes / dims.
        let mut speedups = Vec::new();
        for model in ["gemma-2b", "gemma-27b"] {
            let m = models::llm(model).unwrap();
            for &b in &[1usize, 2, 4, 8, 16, 32] {
                for &ctx in &[256usize, 1024, 4096, 8192] {
                    let n = decode_throughput(m, spr(), b, ctx, CpuStrategy::Naive);
                    let o = decode_throughput(m, spr(), b, ctx, CpuStrategy::Optimized);
                    speedups.push(o / n);
                }
            }
        }
        let max = speedups.iter().cloned().fold(0.0, f64::max);
        let mean = speedups.iter().sum::<f64>() / speedups.len() as f64;
        assert!(max > 2.5 && max < 7.0, "max speedup {max}");
        assert!(mean > 1.2 && mean < 2.2, "mean speedup {mean}");
    }

    #[test]
    fn long_context_small_batch_benefits_most() {
        // Sequence-dim parallelism matters exactly when batch × heads
        // under-fills the socket.
        let m = models::llm("gemma-2b").unwrap();
        let s_small = decode_throughput(m, spr(), 1, 8192, CpuStrategy::Optimized)
            / decode_throughput(m, spr(), 1, 8192, CpuStrategy::Naive);
        let s_big = decode_throughput(m, spr(), 32, 8192, CpuStrategy::Optimized)
            / decode_throughput(m, spr(), 32, 8192, CpuStrategy::Naive);
        assert!(s_small > s_big, "small {s_small} big {s_big}");
    }

    #[test]
    fn cpu_batch_capacity_dwarfs_gpu() {
        // Fig 8: ~512 sequences at ctx 2048 for llama-8b in 512 GB DRAM.
        let m = models::llm("llama-8b").unwrap();
        let b = max_batch(m, 512.0, 2048);
        assert!(b >= 400, "cpu batch {b}");
    }

    #[test]
    fn slice_ai_decreases_with_pd() {
        let a1 = linear_slice_ai(4096, 4096, 8, 1, 2.0);
        let a16 = linear_slice_ai(4096, 4096, 8, 16, 2.0);
        let a112 = linear_slice_ai(4096, 4096, 8, 112, 2.0);
        assert!(a1 > a16 && a16 > a112);
    }

    #[test]
    fn best_pd_balances_cores_and_ai() {
        let pd = best_linear_pd(spr(), 4608, 36864, 16, 2.0);
        assert!(pd > 8, "pd {pd} should engage many cores");
        // Tiny op: don't shard to all cores at worthless AI.
        let pd_small = best_linear_pd(spr(), 256, 256, 1, 2.0);
        assert!(pd_small <= spr().cores);
    }

    #[test]
    fn effective_bw_saturates() {
        let c = spr();
        assert!(effective_bw(c, 1) < 0.1 * c.mem_bw_gbs * 1e9);
        assert_eq!(effective_bw(c, c.cores), c.mem_bw_gbs * 1e9);
    }
}
