//! Minimal leveled stderr logger for the CLI and the sweep engine — no
//! external crates, no timestamps (timestamps would make stderr
//! nondeterministic), no global mutable formatting state.
//!
//! Two jobs:
//!
//! 1. **Leveled emission** — `error!`-style free functions gated on a
//!    process-wide [`Level`] (`--quiet` → `Error`, default → `Info`,
//!    `-v`/`--verbose` → `Debug`).
//! 2. **Deterministic capture for parallel sweeps** — a worker thread
//!    brackets each scenario job with [`capture_begin`]/[`capture_end`];
//!    anything logged in between is buffered instead of hitting stderr,
//!    and the sweep engine replays the buffers in registry order after
//!    the parallel scope. The same sweep at 1 and 8 threads therefore
//!    produces byte-identical stderr, matching the report-byte contract.
//!
//! Capture is per-thread (a `thread_local` stack), so concurrent workers
//! never interleave lines mid-capture; the level check happens at log
//! time, so captured output honors the same verbosity as direct output.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity levels, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide verbosity (CLI `--quiet` / `-v`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

thread_local! {
    /// Stack of active capture buffers on this thread (innermost last).
    static CAPTURE: RefCell<Vec<Vec<String>>> = const { RefCell::new(Vec::new()) };
}

/// Start buffering this thread's log lines instead of writing stderr.
/// Nests; each `capture_begin` must be matched by a [`capture_end`].
pub fn capture_begin() {
    CAPTURE.with(|c| c.borrow_mut().push(Vec::new()));
}

/// Stop the innermost capture and return its buffered lines (already
/// level-filtered) for deterministic replay via [`replay`].
pub fn capture_end() -> Vec<String> {
    CAPTURE.with(|c| c.borrow_mut().pop().unwrap_or_default())
}

/// Re-emit captured lines verbatim (they passed the level gate when
/// logged).
pub fn replay(lines: &[String]) {
    for line in lines {
        eprintln!("{line}");
    }
}

/// Emit at Info level straight to stderr, bypassing any active capture —
/// the wall-clock progress heartbeat must appear in real time, not after
/// its scenario finishes. Callers opted in explicitly (`--progress`),
/// accepting nondeterministic stderr interleaving for liveness.
pub fn info_now(msg: &str) {
    if enabled(Level::Info) {
        eprintln!("{msg}");
    }
}

fn emit(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let captured = CAPTURE.with(|c| {
        if let Some(buf) = c.borrow_mut().last_mut() {
            buf.push(msg.to_string());
            true
        } else {
            false
        }
    });
    if !captured {
        eprintln!("{msg}");
    }
}

pub fn error(msg: &str) {
    emit(Level::Error, msg);
}

pub fn warn(msg: &str) {
    emit(Level::Warn, msg);
}

pub fn info(msg: &str) {
    emit(Level::Info, msg);
}

pub fn debug(msg: &str) {
    emit(Level::Debug, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test body: the level gate is process-global, so concurrent
    // test threads poking it would race each other's assertions.
    #[test]
    fn levels_gate_and_captures_nest() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));

        set_level(Level::Info);
        capture_begin();
        warn("captured line");
        debug("filtered line"); // below Info: dropped at log time
        assert_eq!(capture_end(), vec!["captured line"]);
        // An end without a begin is an empty no-op, not a panic.
        assert!(capture_end().is_empty());

        capture_begin();
        info("outer");
        capture_begin();
        info("inner");
        assert_eq!(capture_end(), vec!["inner"]);
        info("outer2");
        assert_eq!(capture_end(), vec!["outer", "outer2"]);
    }
}
