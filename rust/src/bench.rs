//! Criterion-like measurement harness substrate (criterion is not in the
//! offline vendor set). Warmup, timed sampling, MAD-based outlier rejection,
//! and a compact report. All `cargo bench` targets (`harness = false`) use
//! this, then print the paper's table/figure rows.

use crate::util::stats::Samples;
use crate::util::table::ftime;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1000),
            min_samples: 10,
            max_samples: 10_000,
        }
    }
}

impl BenchConfig {
    /// Faster profile for heavyweight end-to-end benches.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_samples: 3,
            max_samples: 1_000,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub std_s: f64,
    pub outliers: usize,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10}  p50 {:>10}  p90 {:>10}  n={} ({} outliers)",
            self.name, ftime(self.mean_s), ftime(self.p50_s),
            ftime(self.p90_s), self.samples, self.outliers
        )
    }
}

/// Measure a closure. The closure runs once per sample; use
/// [`run_batched`] when one invocation is too fast to time.
pub fn run<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // Sample.
    let mut raw = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || raw.len() < cfg.min_samples)
        && raw.len() < cfg.max_samples
    {
        let t0 = Instant::now();
        f();
        raw.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, raw)
}

/// Measure `f(iters)` where the closure runs the workload `iters` times —
/// for sub-microsecond bodies.
pub fn run_batched<F: FnMut(u64)>(
    name: &str, cfg: &BenchConfig, iters: u64, mut f: F,
) -> BenchResult {
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f(iters);
    }
    let mut raw = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || raw.len() < cfg.min_samples)
        && raw.len() < cfg.max_samples
    {
        let t0 = Instant::now();
        f(iters);
        raw.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    summarize(name, raw)
}

fn summarize(name: &str, raw: Vec<f64>) -> BenchResult {
    let mut s = Samples::new();
    s.extend(&raw);
    let med = s.p50();
    let mad = s.mad().max(f64::MIN_POSITIVE);
    // Reject samples beyond 5 MADs (≈ 3.4 sigma for normal data).
    let kept: Vec<f64> = raw.iter().copied()
        .filter(|x| (x - med).abs() <= 5.0 * 1.4826 * mad)
        .collect();
    let outliers = raw.len() - kept.len();
    let mut ks = Samples::new();
    ks.extend(&kept);
    let mean = ks.mean();
    let std = (kept.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / kept.len().max(1) as f64).sqrt();
    BenchResult {
        name: name.to_string(),
        samples: kept.len(),
        mean_s: mean,
        p50_s: ks.p50(),
        p90_s: ks.p90(),
        std_s: std,
        outliers,
    }
}

/// Prevent the optimizer from discarding a value (std::hint based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_scale() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(50),
            min_samples: 5,
            max_samples: 100,
        };
        let r = run("sleep1ms", &cfg, || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean_s > 0.0008 && r.mean_s < 0.01, "mean {}", r.mean_s);
        assert!(r.samples >= 5);
    }

    #[test]
    fn batched_divides() {
        let cfg = BenchConfig::quick();
        let r = run_batched("noop", &cfg, 1000, |n| {
            let mut acc = 0u64;
            for i in 0..n { acc = acc.wrapping_add(black_box(i)); }
            black_box(acc);
        });
        assert!(r.mean_s < 1e-5);
    }

    #[test]
    fn outlier_rejection() {
        let mut raw: Vec<f64> = vec![1.0; 50];
        raw.push(100.0);
        let r = summarize("x", raw);
        assert_eq!(r.outliers, 1);
        assert!((r.mean_s - 1.0).abs() < 1e-9);
    }
}
