//! Property tests on coordinator-side invariants: routing, slicing,
//! and simulator conservation laws.

use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::sim::{homogeneous_fleet, simulate, Router, SimConfig};
use ecoserve::testkit::{forall, PropConfig};
use ecoserve::util::rng::Rng;
use ecoserve::workload::slo::Slo;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, Request,
                         RequestClass};

#[derive(Debug, Clone)]
struct TraceCase {
    rate: f64,
    seed: u64,
    dur: f64,
}

fn gen_case(r: &mut Rng) -> TraceCase {
    TraceCase {
        rate: r.range(0.2, 6.0),
        seed: r.next_u64(),
        dur: r.range(30.0, 90.0),
    }
}

fn trace_of(c: &TraceCase) -> Vec<Request> {
    generate_trace(Arrivals::Poisson { rate: c.rate }, LengthDist::ShareGpt,
                   RequestClass::Online, c.dur, c.seed)
}

#[test]
fn simulator_conserves_requests_and_tokens() {
    let m = models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 25, ..Default::default() },
        gen_case,
        |_| Vec::new(),
        |c| {
            let tr = trace_of(c);
            let servers = homogeneous_fleet("A100-40", 3, m, 2048);
            let n = servers.len();
            let cfg = SimConfig::flat(servers, Router::Jsq, 261.0,
                                      vec![0.005; n]);
            let r = simulate(m, &tr, &cfg, 0.5, 0.1);
            if r.completed != tr.len() {
                return Err(format!("completed {} of {}", r.completed, tr.len()));
            }
            let want: usize = tr.iter().map(|x| x.output_tokens.max(1)).sum();
            if r.generated_tokens != want {
                return Err(format!("tokens {} vs {}", r.generated_tokens, want));
            }
            if r.ttft.len() != tr.len() || r.tpot.len() != tr.len() {
                return Err("sample counts mismatch".into());
            }
            if !(r.energy_j.is_finite() && r.energy_j > 0.0) {
                return Err("bad energy".into());
            }
            Ok(())
        },
    );
}

#[test]
fn ttft_never_precedes_arrival() {
    let m = models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 15, ..Default::default() },
        gen_case,
        |_| Vec::new(),
        |c| {
            let tr = trace_of(c);
            let servers = homogeneous_fleet("L4", 2, m, 2048);
            let cfg = SimConfig::flat(servers, Router::WorkloadAware, 100.0,
                                      vec![0.001; 2]);
            let r = simulate(m, &tr, &cfg, 0.5, 0.1);
            if r.ttft.min() < 0.0 {
                return Err(format!("negative TTFT {}", r.ttft.min()));
            }
            if r.tpot.min() < 0.0 {
                return Err("negative TPOT".into());
            }
            Ok(())
        },
    );
}

#[test]
fn slicing_conserves_rate_under_any_factor() {
    let m = models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 30, ..Default::default() },
        |r| (gen_case(r), 1 + r.below(6)),
        |_| Vec::new(),
        |(c, f)| {
            let tr = trace_of(c);
            if tr.is_empty() {
                return Ok(());
            }
            let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
            let slices = slice_trace(m, &tr, c.dur, slo, *f);
            let total: f64 = slices.iter().map(|s| s.rate).sum();
            let want = tr.len() as f64 / c.dur;
            if (total - want).abs() > 1e-9 * want.max(1.0) {
                return Err(format!("rate {total} vs {want} (f={f})"));
            }
            let clustered = cluster_slices(&slices);
            let ctotal: f64 = clustered.iter().map(|s| s.rate).sum();
            if (ctotal - want).abs() > 1e-9 * want.max(1.0) {
                return Err("clustering lost rate".into());
            }
            if clustered.len() > slices.len() {
                return Err("clustering grew".into());
            }
            Ok(())
        },
    );
}

#[test]
fn planner_respects_slo_feasibility() {
    use ecoserve::planner::{device_options, max_tput, Phase, PlanConfig};
    use ecoserve::planner::slicing::Slice;
    let m = models::llm("llama-8b").unwrap();
    forall(
        &PropConfig { cases: 40, ..Default::default() },
        |r| (r.range(0.02, 3.0), r.below(4096) + 16, r.below(512) + 8),
        |_| Vec::new(),
        |(ttft, prompt, output)| {
            let s = Slice {
                model: m,
                rate: 1.0,
                prompt: *prompt,
                output: *output,
                slo: Slo { ttft_s: *ttft, tpot_s: 0.1 },
                offline: false,
            };
            let cfg = PlanConfig::default();
            for opt in device_options(&cfg, m) {
                if let Some((tput, lat)) = max_tput(&opt, &s, Phase::Prompt) {
                    if lat > *ttft + 1e-9 {
                        return Err(format!(
                            "{}: latency {lat} exceeds SLO {ttft}", opt.name));
                    }
                    if tput <= 0.0 {
                        return Err("non-positive throughput".into());
                    }
                }
            }
            Ok(())
        },
    );
}
