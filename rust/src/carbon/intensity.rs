//! Grid carbon intensity (CI): regional constants + diurnal traces.
//!
//! The paper samples WattTime / GreenSKU for regional CI; offline we encode
//! the regions it names with their published averages (gCO₂e/kWh): North
//! Central Sweden 17 (Low), California 261 (Mid), Midcontinent 501 (High),
//! plus the Fig 6 regions. Diurnal traces model solar-driven intra-day
//! swing for runtime carbon-aware scheduling studies.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Region {
    SwedenNorth,
    California,
    Midcontinent,
    UsEast,
    Europe,
    UsCentral,
    HyperscaleRenewable,
}

impl Region {
    pub fn name(&self) -> &'static str {
        match self {
            Region::SwedenNorth => "SE-North (Low)",
            Region::California => "CAISO (Mid)",
            Region::Midcontinent => "MISO (High)",
            Region::UsEast => "US-East",
            Region::Europe => "EU-Central",
            Region::UsCentral => "US-Central/South",
            Region::HyperscaleRenewable => "Hyperscale-PPA",
        }
    }

    /// Average CI, gCO₂e/kWh.
    pub fn avg_ci(&self) -> f64 {
        match self {
            Region::SwedenNorth => 17.0,
            Region::California => 261.0,
            Region::Midcontinent => 501.0,
            Region::UsEast => 390.0,
            Region::Europe => 300.0,
            Region::UsCentral => 420.0,
            Region::HyperscaleRenewable => 50.0,
        }
    }

    /// Representative grid longitude, degrees east — sets how far a
    /// region's solar day is phase-shifted from another's (15° ≈ 1 h).
    /// Multi-grid fleets use this so e.g. SE-North's midday dip does not
    /// implausibly coincide with MISO's.
    pub fn longitude_deg(&self) -> f64 {
        match self {
            Region::SwedenNorth => 17.0,
            Region::California => -120.0,
            Region::Midcontinent => -93.0,
            Region::UsEast => -77.0,
            Region::Europe => 10.0,
            Region::UsCentral => -97.0,
            Region::HyperscaleRenewable => -100.0,
        }
    }

    /// Hours by which this region's solar day leads `other`'s.
    pub fn solar_offset_hours(&self, other: Region) -> f64 {
        (self.longitude_deg() - other.longitude_deg()) / 15.0
    }

    /// Fraction of the day-night CI swing (solar share proxy).
    fn diurnal_swing(&self) -> f64 {
        match self {
            Region::SwedenNorth => 0.05,
            Region::California => 0.45, // duck curve
            Region::Midcontinent => 0.15,
            Region::UsEast => 0.20,
            Region::Europe => 0.30,
            Region::UsCentral => 0.20,
            Region::HyperscaleRenewable => 0.35,
        }
    }

    pub fn all() -> &'static [Region] {
        &[
            Region::SwedenNorth,
            Region::California,
            Region::Midcontinent,
            Region::UsEast,
            Region::Europe,
            Region::UsCentral,
            Region::HyperscaleRenewable,
        ]
    }

    /// The three-level setup from §6.2.1.
    pub fn low_mid_high() -> [Region; 3] {
        [Region::SwedenNorth, Region::California, Region::Midcontinent]
    }

    /// CI at an hour-of-day for this region's synthetic solar day: dip
    /// centred at 13:00, evening ramp peak at 19:30, plus caller noise.
    /// Gaussian distances are circular (mod 24), so a phase-shifted day
    /// whose dip lands near midnight keeps its full curve instead of
    /// being truncated at the 0/24 boundary.
    fn ci_at_hour(&self, hour: f64, noise: f64) -> f64 {
        let avg = self.avg_ci();
        let swing = self.diurnal_swing();
        let solar = (-(circular_hours(hour, 13.0) / 3.5).powi(2)).exp();
        let evening = (-(circular_hours(hour, 19.5) / 2.0).powi(2)).exp();
        (avg * (1.0 - swing * solar + 0.5 * swing * evening + noise)).max(1.0)
    }
}

/// Shortest distance between two points on the 24 h clock circle.
fn circular_hours(a: f64, b: f64) -> f64 {
    let d = (a - b).rem_euclid(24.0);
    d.min(24.0 - d)
}

/// A CI time series at fixed resolution.
#[derive(Debug, Clone)]
pub struct CiTrace {
    pub region: Region,
    pub step_s: f64,
    pub values: Vec<f64>,
}

impl CiTrace {
    /// Synthesize a diurnal trace: CI dips mid-day with solar, peaks in the
    /// evening ramp, plus small AR(1) noise. Values stay positive.
    pub fn diurnal(region: Region, days: usize, step_s: f64, seed: u64) -> CiTrace {
        let mut rng = Rng::new(seed ^ 0xC1);
        let n = ((days as f64 * 86_400.0) / step_s).ceil() as usize;
        let mut noise = 0.0f64;
        let values = (0..n)
            .map(|i| {
                let t = i as f64 * step_s;
                let hour = (t / 3600.0) % 24.0;
                noise = 0.9 * noise + 0.1 * rng.normal() * 0.05;
                region.ci_at_hour(hour, noise)
            })
            .collect();
        CiTrace { region, step_s, values }
    }

    /// One synthetic solar day compressed onto `period_s` seconds, repeated
    /// `periods` times — lets short simulated traces exercise intra-day CI
    /// swings (the temporal-shifting lever) without simulating 24 h.
    pub fn compressed_diurnal(region: Region, period_s: f64, periods: usize,
                              steps_per_period: usize, seed: u64) -> CiTrace {
        Self::compressed_diurnal_shifted(region, period_s, periods,
                                         steps_per_period, seed, 0.0)
    }

    /// [`CiTrace::compressed_diurnal`] with the solar day phase-shifted by
    /// `shift_hours` (positive = this grid's clock runs ahead): sample
    /// hour `h` reads the day shape at `h + shift`. Multi-grid fleets use
    /// [`Region::solar_offset_hours`] so each grid's dip lands where its
    /// longitude puts it instead of all grids dipping in lockstep.
    pub fn compressed_diurnal_shifted(region: Region, period_s: f64,
                                      periods: usize, steps_per_period: usize,
                                      seed: u64, shift_hours: f64) -> CiTrace {
        assert!(period_s > 0.0 && steps_per_period > 0);
        let mut rng = Rng::new(seed ^ 0xC1);
        let step_s = period_s / steps_per_period as f64;
        let mut noise = 0.0f64;
        let values = (0..periods.max(1) * steps_per_period)
            .map(|i| {
                let hour = ((i % steps_per_period) as f64
                    / steps_per_period as f64 * 24.0
                    + shift_hours).rem_euclid(24.0);
                noise = 0.9 * noise + 0.1 * rng.normal() * 0.05;
                region.ci_at_hour(hour, noise)
            })
            .collect();
        CiTrace { region, step_s, values }
    }

    /// Flat trace at the regional average (for aggregate studies).
    pub fn flat(region: Region, days: usize, step_s: f64) -> CiTrace {
        let n = ((days as f64 * 86_400.0) / step_s).ceil() as usize;
        CiTrace { region, step_s, values: vec![region.avg_ci(); n] }
    }

    /// CI at time t (seconds), clamped to the trace extent.
    pub fn at(&self, t_s: f64) -> f64 {
        if self.values.is_empty() {
            return self.region.avg_ci();
        }
        let idx = ((t_s / self.step_s) as usize).min(self.values.len() - 1);
        self.values[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return self.region.avg_ci();
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Mean CI over [t0, t1], length-weighted: each step contributes
    /// exactly its overlap with the window, so an interval that barely
    /// grazes a step no longer counts it whole. The final step extends
    /// indefinitely (the trace clamps at its extent, matching [`at`]).
    pub fn mean_over(&self, t0_s: f64, t1_s: f64) -> f64 {
        if self.values.is_empty() {
            return self.region.avg_ci();
        }
        if t1_s <= t0_s {
            return self.at(t0_s);
        }
        let last = self.values.len() - 1;
        let lo = ((t0_s / self.step_s) as usize).min(last);
        let hi = ((t1_s / self.step_s) as usize).min(last).max(lo);
        let mut weighted = 0.0;
        for (k, &v) in self.values[lo..=hi].iter().enumerate() {
            let i = lo + k;
            let s0 = i as f64 * self.step_s;
            let s1 = if i == last { f64::INFINITY } else { s0 + self.step_s };
            let w = (t1_s.min(s1) - t0_s.max(s0)).max(0.0);
            weighted += w * v;
        }
        weighted / (t1_s - t0_s)
    }
}

/// A grid-CI signal as the simulator consumes it: a flat scalar (the
/// regional average), a time-varying in-memory [`CiTrace`], or a chunked
/// file-backed [`CiStream`](crate::carbon::ci_stream::CiStream). Keeping
/// all three under one type lets every sim/scenario knob accept any
/// without special cases.
#[derive(Debug, Clone)]
pub enum CiSignal {
    /// Constant CI, gCO₂e/kWh.
    Flat(f64),
    /// Time-varying CI sampled from a trace (clamped at the extent).
    Trace(CiTrace),
    /// File-backed CI served from a sliding window — year-scale grid
    /// traces without materializing (see [`crate::carbon::ci_stream`]).
    /// Answers every query with arithmetic bitwise-identical to a
    /// materialized [`CiTrace`] over the same file.
    Streaming(crate::carbon::ci_stream::CiStream),
}

impl CiSignal {
    pub fn flat(ci_g_per_kwh: f64) -> CiSignal {
        CiSignal::Flat(ci_g_per_kwh)
    }

    /// CI at time t (seconds from trace start).
    pub fn at(&self, t_s: f64) -> f64 {
        match self {
            CiSignal::Flat(ci) => *ci,
            CiSignal::Trace(tr) => tr.at(t_s),
            CiSignal::Streaming(st) => st.at(t_s),
        }
    }

    pub fn mean(&self) -> f64 {
        match self {
            CiSignal::Flat(ci) => *ci,
            CiSignal::Trace(tr) => tr.mean(),
            CiSignal::Streaming(st) => st.mean(),
        }
    }

    /// Mean CI over [t0, t1].
    pub fn mean_over(&self, t0_s: f64, t1_s: f64) -> f64 {
        match self {
            CiSignal::Flat(ci) => *ci,
            CiSignal::Trace(tr) => tr.mean_over(t0_s, t1_s),
            CiSignal::Streaming(st) => st.mean_over(t0_s, t1_s),
        }
    }

    /// Sampling resolution; `None` for flat signals (nothing to scan).
    pub fn step_s(&self) -> Option<f64> {
        match self {
            CiSignal::Flat(_) => None,
            CiSignal::Trace(tr) => Some(tr.step_s),
            CiSignal::Streaming(st) => Some(st.step_s()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        let [lo, mid, hi] = Region::low_mid_high();
        assert!(lo.avg_ci() < mid.avg_ci() && mid.avg_ci() < hi.avg_ci());
        assert_eq!(lo.avg_ci(), 17.0);
        assert_eq!(mid.avg_ci(), 261.0);
        assert_eq!(hi.avg_ci(), 501.0);
    }

    #[test]
    fn diurnal_mean_near_average() {
        let tr = CiTrace::diurnal(Region::California, 7, 900.0, 7);
        let rel = (tr.mean() - 261.0).abs() / 261.0;
        assert!(rel < 0.15, "mean {} off by {rel}", tr.mean());
    }

    #[test]
    fn diurnal_has_midday_dip() {
        let tr = CiTrace::diurnal(Region::California, 1, 900.0, 3);
        let noon = tr.at(13.0 * 3600.0);
        let night = tr.at(3.0 * 3600.0);
        assert!(noon < night, "noon {noon} night {night}");
    }

    #[test]
    fn trace_positive_and_clamped() {
        let tr = CiTrace::diurnal(Region::SwedenNorth, 2, 600.0, 5);
        assert!(tr.values.iter().all(|&v| v > 0.0));
        assert_eq!(tr.at(1e12), *tr.values.last().unwrap());
    }

    #[test]
    fn flat_trace() {
        let tr = CiTrace::flat(Region::Midcontinent, 1, 3600.0);
        assert_eq!(tr.at(0.0), 501.0);
        assert_eq!(tr.mean(), 501.0);
    }

    #[test]
    fn compressed_day_has_the_same_shape_at_trace_scale() {
        // A 180 s "day": the solar dip lands at 13/24 of the period and is
        // the global minimum of the cycle, just as in the real-time trace.
        let tr = CiTrace::compressed_diurnal(Region::California, 180.0, 2, 96, 9);
        assert_eq!(tr.values.len(), 192);
        assert!((tr.step_s - 180.0 / 96.0).abs() < 1e-12);
        let dip = tr.at(13.0 / 24.0 * 180.0);
        let night = tr.at(3.0 / 24.0 * 180.0);
        let evening = tr.at(19.5 / 24.0 * 180.0);
        assert!(dip < night && dip < evening, "dip {dip} night {night} evening {evening}");
        // Second period repeats the day shape (modulo AR(1) noise).
        let dip2 = tr.at(180.0 + 13.0 / 24.0 * 180.0);
        assert!(dip2 < tr.at(180.0 + 3.0 / 24.0 * 180.0));
    }

    #[test]
    fn shifted_day_moves_the_dip_by_the_phase() {
        // A +6 h shift pulls the 13:00 solar dip back to 07:00 trace time:
        // the value sampled at trace-hour 7 reads the shape at 7+6 = 13.
        let base = CiTrace::compressed_diurnal(Region::California,
                                               240.0, 1, 96, 11);
        let shifted = CiTrace::compressed_diurnal_shifted(
            Region::California, 240.0, 1, 96, 11, 6.0);
        let at_hour = |tr: &CiTrace, h: f64| tr.at(h / 24.0 * 240.0);
        assert!((at_hour(&shifted, 7.0) - at_hour(&base, 13.0)).abs()
                    < 0.05 * 261.0,
                "shifted@7h {} vs base@13h {}",
                at_hour(&shifted, 7.0), at_hour(&base, 13.0));
        // Zero shift is bit-identical to the unshifted constructor.
        let zero = CiTrace::compressed_diurnal_shifted(
            Region::California, 240.0, 1, 96, 11, 0.0);
        assert!(base.values.iter().zip(&zero.values)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
        // SE-North leads MISO by its longitude gap (~7.3 h).
        let off = Region::SwedenNorth.solar_offset_hours(Region::Midcontinent);
        assert!((off - (17.0 + 93.0) / 15.0).abs() < 1e-12);
    }

    #[test]
    fn mean_over_weights_partial_steps_by_overlap() {
        let tr = CiTrace { region: Region::California, step_s: 10.0,
                           values: vec![100.0, 200.0, 400.0] };
        // [5, 15): half of step 0, half of step 1.
        assert!((tr.mean_over(5.0, 15.0) - 150.0).abs() < 1e-9);
        // Barely grazing the next step no longer counts it whole:
        // [0, 10.1] is 10 s at 100 plus 0.1 s at 200.
        let got = tr.mean_over(0.0, 10.1);
        let want = (10.0 * 100.0 + 0.1 * 200.0) / 10.1;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        // The clamped tail holds the last value indefinitely.
        assert!((tr.mean_over(25.0, 65.0) - 400.0).abs() < 1e-12);
        // Degenerate windows fall back to point sampling.
        assert_eq!(tr.mean_over(12.0, 12.0), 200.0);
        // A window exactly covering one step is that step's value.
        assert!((tr.mean_over(10.0, 20.0) - 200.0).abs() < 1e-12);
    }

    #[test]
    fn phase_shift_is_a_rotation_not_a_truncation() {
        // A +12 h shift parks the 13:00 solar dip at 01:00 trace time —
        // right on the 0/24 boundary. With circular Gaussian distance the
        // dip keeps its full depth there, and every shifted sample equals
        // the base sample half a day ahead, up to the AR(1) noise band
        // (the two traces draw different noise at the same index).
        let spp = 96usize;
        let base = CiTrace::compressed_diurnal(Region::California,
                                               240.0, 1, spp, 11);
        let sh = CiTrace::compressed_diurnal_shifted(
            Region::California, 240.0, 1, spp, 11, 12.0);
        let min_of = |tr: &CiTrace| {
            tr.values.iter().cloned().fold(f64::INFINITY, f64::min)
        };
        assert!((min_of(&sh) - min_of(&base)).abs() < 0.07 * 261.0,
                "dip truncated at the midnight boundary: {} vs {}",
                min_of(&sh), min_of(&base));
        for i in 0..spp {
            let want = base.values[(i + spp / 2) % spp];
            let got = sh.values[i];
            assert!((got - want).abs() < 0.12 * 261.0,
                    "sample {i}: shifted {got} vs rotated base {want}");
        }
    }

    #[test]
    fn signal_flat_vs_trace() {
        let f = CiSignal::flat(261.0);
        assert_eq!(f.at(1e6), 261.0);
        assert_eq!(f.mean_over(0.0, 500.0), 261.0);
        assert!(f.step_s().is_none());
        let s = CiSignal::Trace(CiTrace::compressed_diurnal(
            Region::California, 120.0, 1, 96, 4));
        assert!(s.step_s().is_some());
        let m = s.mean_over(0.0, 120.0);
        assert!((m - 261.0).abs() / 261.0 < 0.2, "mean {m}");
        // mean_over of a window stays near the window's values.
        let dip = s.at(65.0);
        assert!(s.mean_over(60.0, 70.0) >= dip * 0.9);
    }
}
