//! Workload slicing: histogram bucketing of a request trace into the
//! planner's (prompt, output) slices (paper §4.2.2, "Workload Slicing and
//! Disaggregation").

use crate::models::LlmSpec;
use crate::workload::slo::Slo;
use crate::workload::{Request, RequestClass};

/// One planner slice: a (length-bucket, SLO-class) aggregate with a rate.
#[derive(Debug, Clone)]
pub struct Slice {
    pub model: &'static LlmSpec,
    /// Requests per second.
    pub rate: f64,
    /// Representative prompt length (bucket geometric mean).
    pub prompt: usize,
    /// Representative output length.
    pub output: usize,
    pub slo: Slo,
    pub offline: bool,
}

/// Histogram bucket edges (tokens) for prompt and output dimensions.
pub const PROMPT_EDGES: &[usize] = &[0, 128, 512, 2048, 8192, 40_000];
pub const OUTPUT_EDGES: &[usize] = &[0, 64, 256, 1024, 8_192];

fn bucket_of(x: usize, edges: &[usize]) -> usize {
    for (i, w) in edges.windows(2).enumerate() {
        if x >= w[0] && x < w[1] {
            return i;
        }
    }
    edges.len().saturating_sub(2)
}

fn representative(edges: &[usize], idx: usize) -> usize {
    let lo = edges[idx].max(1);
    let hi = edges[idx + 1];
    ((lo as f64 * hi as f64).sqrt()) as usize
}

/// Streaming bucket accumulator: the counting half of [`slice_trace`],
/// split out so planning passes can ingest requests one at a time from an
/// arrival stream (or a sliding demand window) without materializing a
/// trace. `slice_trace` delegates here, so the two paths are identical by
/// construction — bucket counts are integers, and the rate arithmetic in
/// [`SliceAccum::slices`] is shared.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SliceAccum {
    /// counts[class][p][o]
    counts: Vec<Vec<Vec<usize>>>,
    total: usize,
}

impl Default for SliceAccum {
    fn default() -> Self {
        SliceAccum::new()
    }
}

impl SliceAccum {
    pub fn new() -> SliceAccum {
        let np = PROMPT_EDGES.len() - 1;
        let no = OUTPUT_EDGES.len() - 1;
        SliceAccum { counts: vec![vec![vec![0usize; no]; np]; 2], total: 0 }
    }

    pub fn push(&mut self, r: &Request) {
        let (ci, p, o) = Self::bucket(r);
        self.push_bucket(ci, p, o);
    }

    /// Bucket coordinates `(class, prompt, output)` of a request. Split out
    /// so the fused demand pass can bucket each arrival once and then fan
    /// the increment out to every window accumulator it falls in.
    pub fn bucket(r: &Request) -> (usize, usize, usize) {
        let ci = match r.class { RequestClass::Online => 0, RequestClass::Offline => 1 };
        let p = bucket_of(r.prompt_tokens, PROMPT_EDGES);
        let o = bucket_of(r.output_tokens, OUTPUT_EDGES);
        (ci, p, o)
    }

    /// Increment one pre-computed bucket (see [`SliceAccum::bucket`]).
    pub fn push_bucket(&mut self, class: usize, p: usize, o: usize) {
        self.counts[class][p][o] += 1;
        self.total += 1;
    }

    /// Add another accumulator's counts into this one. Integer sums
    /// commute, so merging per-worker partial accumulators in any order
    /// yields the same histogram as a single-threaded ingest.
    pub fn merge(&mut self, other: &SliceAccum) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            for (ar, br) in a.iter_mut().zip(b) {
                for (ac, bc) in ar.iter_mut().zip(br) {
                    *ac += bc;
                }
            }
        }
        self.total += other.total;
    }

    /// L1 distance between two bucket histograms: the total number of
    /// requests that moved bucket (or appeared/disappeared). The
    /// incremental planner's drift metric is this over `max(total)`.
    pub fn l1_delta(&self, other: &SliceAccum) -> usize {
        let mut d = 0usize;
        for (a, b) in self.counts.iter().zip(&other.counts) {
            for (ar, br) in a.iter().zip(b) {
                for (ac, bc) in ar.iter().zip(br) {
                    d += ac.abs_diff(*bc);
                }
            }
        }
        d
    }

    /// True when `other` has arrivals in a bucket this histogram has none
    /// in — demand the previous solve never assigned capacity for, which
    /// the cut patcher cannot cover by scaling existing assignments.
    pub fn has_new_bucket(&self, other: &SliceAccum) -> bool {
        self.counts.iter().zip(&other.counts).any(|(a, b)| {
            a.iter().zip(b).any(|(ar, br)| {
                ar.iter().zip(br).any(|(ac, bc)| *ac == 0 && *bc > 0)
            })
        })
    }

    /// Requests ingested so far.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Fold the accumulated buckets into planner slices over `duration_s`
    /// seconds of demand.
    pub fn slices(&self, model: &'static LlmSpec, duration_s: f64,
                  online_slo: Slo, slice_factor: usize) -> Vec<Slice> {
        assert!(duration_s > 0.0 && slice_factor >= 1);
        let mut out = Vec::new();
        for (ci, class_counts) in self.counts.iter().enumerate() {
            let offline = ci == 1;
            for (p, row) in class_counts.iter().enumerate() {
                for (o, &n) in row.iter().enumerate() {
                    if n == 0 {
                        continue;
                    }
                    let total_rate = n as f64 / duration_s;
                    let slo = if offline {
                        Slo { ttft_s: crate::workload::slo::OFFLINE_DEADLINE_S,
                              tpot_s: f64::INFINITY }
                    } else {
                        online_slo
                    };
                    for _ in 0..slice_factor {
                        out.push(Slice {
                            model,
                            rate: total_rate / slice_factor as f64,
                            prompt: representative(PROMPT_EDGES, p),
                            output: representative(OUTPUT_EDGES, o),
                            slo,
                            offline,
                        });
                    }
                }
            }
        }
        out
    }
}

/// Bucket a trace into slices. `slice_factor` ≥ 1 subdivides each bucket's
/// rate into f equal slices for finer-grained allocation (the paper's f).
pub fn slice_trace(
    model: &'static LlmSpec,
    trace: &[Request],
    duration_s: f64,
    online_slo: Slo,
    slice_factor: usize,
) -> Vec<Slice> {
    let mut acc = SliceAccum::new();
    for r in trace {
        acc.push(r);
    }
    acc.slices(model, duration_s, online_slo, slice_factor)
}

/// Merge slices that are identical (bucket, class) — the clustering that
/// gives the control plane its sub-linear scaling (paper §6.2.2).
///
/// Pre-sorts an index permutation by bucket key (index-tiebroken, so equal
/// keys stay in input order) and merges each run in one pass, then emits
/// the merged groups in first-appearance order. Output order and the rate
/// summation order both match the old linear-rescan implementation
/// exactly, so the result is bit-identical — without the O(n²) `find` on
/// large slice sets.
pub fn cluster_slices(slices: &[Slice]) -> Vec<Slice> {
    if slices.len() <= 1 {
        return slices.to_vec();
    }
    let key = |i: usize| {
        let s = &slices[i];
        (s.model.name, s.prompt, s.output, s.offline, i)
    };
    let mut idx: Vec<usize> = (0..slices.len()).collect();
    idx.sort_unstable_by(|&a, &b| key(a).cmp(&key(b)));
    let same = |a: &Slice, b: &Slice| {
        a.prompt == b.prompt && a.output == b.output && a.offline == b.offline
            && a.model.name == b.model.name
    };
    // (first input index, merged slice); rates accumulate in ascending
    // input order within a group — the same float-add sequence as before.
    let mut groups: Vec<(usize, Slice)> = Vec::new();
    for &i in &idx {
        match groups.last_mut() {
            Some((_, g)) if same(g, &slices[i]) => g.rate += slices[i].rate,
            _ => groups.push((i, slices[i].clone())),
        }
    }
    groups.sort_unstable_by_key(|&(first, _)| first);
    groups.into_iter().map(|(_, s)| s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::{generate_trace, Arrivals, LengthDist};

    fn trace() -> Vec<Request> {
        generate_trace(Arrivals::Poisson { rate: 10.0 }, LengthDist::ShareGpt,
                       RequestClass::Online, 600.0, 11)
    }

    #[test]
    fn rates_conserved() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let slices = slice_trace(m, &tr, 600.0, slo, 1);
        let total: f64 = slices.iter().map(|s| s.rate).sum();
        assert!((total - tr.len() as f64 / 600.0).abs() < 1e-9);
    }

    #[test]
    fn slice_factor_subdivides() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let s1 = slice_trace(m, &tr, 600.0, slo, 1);
        let s4 = slice_trace(m, &tr, 600.0, slo, 4);
        assert_eq!(s4.len(), 4 * s1.len());
        let t1: f64 = s1.iter().map(|s| s.rate).sum();
        let t4: f64 = s4.iter().map(|s| s.rate).sum();
        assert!((t1 - t4).abs() < 1e-9);
    }

    #[test]
    fn clustering_inverts_slicing() {
        let m = models::llm("llama-8b").unwrap();
        let tr = trace();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        let s4 = slice_trace(m, &tr, 600.0, slo, 4);
        let clustered = cluster_slices(&s4);
        let s1 = slice_trace(m, &tr, 600.0, slo, 1);
        assert_eq!(clustered.len(), s1.len());
    }

    /// The pre-sort + merge clustering must reproduce the old quadratic
    /// rescan bit-for-bit: same group order, same float-add order.
    #[test]
    fn clustering_matches_naive_rescan_bitwise() {
        fn naive(slices: &[Slice]) -> Vec<Slice> {
            let mut out: Vec<Slice> = Vec::new();
            for s in slices {
                if let Some(e) = out.iter_mut().find(|e| {
                    e.prompt == s.prompt && e.output == s.output
                        && e.offline == s.offline && e.model.name == s.model.name
                }) {
                    e.rate += s.rate;
                } else {
                    out.push(s.clone());
                }
            }
            out
        }
        let m = models::llm("llama-8b").unwrap();
        let slo = Slo { ttft_s: 0.5, tpot_s: 0.1 };
        // Interleaved duplicates with awkward rates exercise both the
        // grouping and the summation order.
        let mut rng = crate::util::rng::Rng::new(7);
        let mut slices = Vec::new();
        for i in 0..200 {
            let p = [64usize, 300, 1000, 9000][i % 4];
            let o = [32usize, 100, 500][rng.below(3)];
            slices.push(Slice {
                model: m,
                rate: 0.1 + rng.f64() * 3.0,
                prompt: p,
                output: o,
                slo,
                offline: rng.below(2) == 1,
            });
        }
        let fast = cluster_slices(&slices);
        let slow = naive(&slices);
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.output, b.output);
            assert_eq!(a.offline, b.offline);
            assert_eq!(a.rate.to_bits(), b.rate.to_bits(), "rate diverged");
        }
    }

    #[test]
    fn accum_merge_matches_single_ingest() {
        let tr = trace();
        let mut whole = SliceAccum::new();
        for r in &tr {
            whole.push(r);
        }
        // Modulo-partitioned partial accumulators merged in index order.
        for workers in [2usize, 3, 8] {
            let mut parts = vec![SliceAccum::new(); workers];
            for (i, r) in tr.iter().enumerate() {
                parts[i % workers].push(r);
            }
            let mut merged = SliceAccum::new();
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged, whole);
        }
        assert_eq!(whole.l1_delta(&whole), 0);
        let mut shifted = whole.clone();
        shifted.push_bucket(0, 0, 0);
        assert_eq!(whole.l1_delta(&shifted), 1);
        let empty = SliceAccum::new();
        assert!(empty.has_new_bucket(&whole));
        assert!(!whole.has_new_bucket(&empty));
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0, PROMPT_EDGES), 0);
        assert_eq!(bucket_of(127, PROMPT_EDGES), 0);
        assert_eq!(bucket_of(128, PROMPT_EDGES), 1);
        assert_eq!(bucket_of(1_000_000, PROMPT_EDGES), PROMPT_EDGES.len() - 2);
        let rep = representative(PROMPT_EDGES, 1);
        assert!(rep >= 128 && rep < 512);
    }
}
