//! Sharded multi-region simulation runtime: partition one scenario's
//! fleet into per-region / per-cluster shards, run each shard's
//! discrete-event core on its own scoped thread over a deterministic
//! substream of the arrivals, and merge the shard results into one
//! [`SimReport`] — so a multi-million-request production day scales in
//! *wall-clock*, not just memory.
//!
//! ## Determinism contract
//!
//! The partition ([`ShardPlan::partition`]) is a pure function of the
//! fleet: servers group by pinned region (first-appearance order), groups
//! split into clusters of at most [`MAX_SHARD_SERVERS`], and a repair
//! pass merges clusters until every shard can both prefill and decode.
//! The shard *count* therefore never depends on how many worker threads
//! (`--shards N`) execute the plan — N only caps parallelism — which is
//! what makes an N-shard run byte-identical to a 1-shard run by
//! construction.
//!
//! Requests split across shards via a two-level routing decomposition: a
//! top-level splitter ([`ShardSplitter`]) reuses the [`Router`] semantics
//! at shard granularity (JSQ by normalized assigned load, workload-aware
//! by shard memory, carbon-greedy by the shard's current grid CI), as a
//! pure state machine over the request sequence — no execution-time
//! inputs — so every shard independently reconstructs the same partition
//! of the stream ([`PartitionSource`]). Within a shard, the existing
//! per-server policies run unchanged.
//!
//! Merging is order-fixed: shard results fold in ascending shard index
//! (histogram bins, counter sums, and [`CarbonMeter::merge_shard`]
//! interval totals), so the merged report is a pure function of the
//! partition set and never of thread interleaving.
//!
//! ## What sharding means semantically
//!
//! A sharded run is its *own* deterministic design point, not a bitwise
//! re-execution of the unsharded run: routing state does not cross shard
//! boundaries (the splitter sees assigned-load proxies, not live queue
//! depths), KV handoffs stay within a shard, and each shard defers and
//! re-provisions against its own substream. The invariant the runtime
//! guarantees — and the one `tests/integration_shard.rs` enforces — is
//! shard-count/interleaving invariance, plus exact equality with the
//! unsharded engine whenever the partition degenerates to a single shard.

use crate::carbon::intensity::CiSignal;
use crate::models::LlmSpec;
use crate::obs::Observer;
use crate::util::stats::Histogram;
use crate::workload::{ArrivalSource, PartitionSource, Request};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::carbon_meter::CarbonMeter;
use super::core::{FleetSchedule, Sim, SimConfig};
use super::fault::{Fault, FaultPlan};
use super::metrics::{ServerUsage, SimReport};
use super::policy::{Router, LONG_PROMPT_TOKENS};
use super::server::Role;

/// Largest server group a single shard may hold; region groups larger
/// than this split into balanced clusters. A fixed constant (never the
/// CLI thread count) so the partition — and with it every merged byte —
/// is independent of how much parallelism a run asks for.
pub const MAX_SHARD_SERVERS: usize = 8;

/// One shard of a partitioned fleet.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Stable identity: `<region name or "primary">/<cluster index>`.
    pub key: String,
    /// Global indices into `SimConfig::servers`, in fleet order.
    pub servers: Vec<usize>,
    /// Shard-derived deterministic seed (FNV of the key mixed with the
    /// run seed): the identity future per-shard noise sources key off.
    /// Independent of shard count and execution order.
    pub seed: u64,
}

/// A deterministic partition of a fleet into shards.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shards: Vec<ShardSpec>,
}

fn shard_seed(master: u64, key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^ master.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl ShardPlan {
    /// Partition `cfg`'s fleet: group by pinned region in first-appearance
    /// order, split groups into clusters of ≤ [`MAX_SHARD_SERVERS`], then
    /// merge neighbours until every shard holds at least one
    /// prompt-capable and one decode-capable server (a disaggregated
    /// prompt/decode fleet may collapse to one shard — KV handoffs never
    /// cross shard boundaries). Pure function of the fleet + seed.
    pub fn partition(cfg: &SimConfig, seed: u64) -> ShardPlan {
        assert!(!cfg.servers.is_empty(), "cannot shard an empty fleet");
        // Region groups in first-appearance order.
        let mut names: Vec<String> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, s) in cfg.servers.iter().enumerate() {
            let name = match s.region {
                Some(r) => r.name().to_string(),
                None => "primary".to_string(),
            };
            match names.iter().position(|n| *n == name) {
                Some(g) => groups[g].push(i),
                None => {
                    names.push(name);
                    groups.push(vec![i]);
                }
            }
        }
        // Balanced clusters of at most MAX_SHARD_SERVERS per group.
        let mut shards: Vec<(String, Vec<usize>)> = Vec::new();
        for (name, idxs) in names.iter().zip(&groups) {
            let k = idxs.len().div_ceil(MAX_SHARD_SERVERS);
            let per = idxs.len().div_ceil(k);
            for (c, chunk) in idxs.chunks(per).enumerate() {
                shards.push((format!("{name}/{c}"), chunk.to_vec()));
            }
        }
        // Repair: merge shards that cannot serve a request end to end.
        let valid = |cfg: &SimConfig, servers: &[usize]| {
            servers.iter().any(|&i| cfg.servers[i].role != Role::Decode)
                && servers.iter().any(|&i| cfg.servers[i].role != Role::Prompt)
        };
        let mut i = 0usize;
        while i < shards.len() {
            if valid(cfg, &shards[i].1) || shards.len() == 1 {
                i += 1;
                continue;
            }
            // Fold into the previous shard when one exists, else absorb
            // the next — indices stay sorted within a shard only if we
            // re-sort after the merge, which keeps per_server scatter and
            // fleet-order invariants simple.
            let j = if i > 0 { i - 1 } else { 0 };
            let (_, moved) = shards.remove(if i > 0 { i } else { 1 });
            shards[j].1.extend(moved);
            shards[j].1.sort_unstable();
            i = j; // re-check the merged shard
        }
        let shards = shards
            .into_iter()
            .map(|(key, servers)| {
                let seed = shard_seed(seed, &key);
                ShardSpec { key, servers, seed }
            })
            .collect();
        ShardPlan { shards }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// The sub-fleet `SimConfig` for shard `k`: server/embodied slices in
    /// fleet order, the shared CI signal and policies, and the global
    /// fleet schedule filtered + re-indexed to the shard's servers.
    pub fn sub_config(&self, cfg: &SimConfig, k: usize) -> SimConfig {
        let shard = &self.shards[k];
        let local_of = |g: usize| shard.servers.iter().position(|&i| i == g);
        let mut fleet_plan = FleetSchedule::default();
        if !cfg.fleet_plan.initially_active.is_empty() {
            fleet_plan.initially_active = shard.servers.iter()
                .map(|&g| cfg.fleet_plan.initially_active[g])
                .collect();
        }
        for e in &cfg.fleet_plan.events {
            if let Some(local) = local_of(e.server) {
                let mut e = *e;
                e.server = local;
                fleet_plan.events.push(e);
            }
        }
        // Fault plans shard like fleet schedules: server deaths re-index
        // to shard-local ids (deaths outside the shard drop out); region
        // outages and CI spikes pass through verbatim — an outage expands
        // against the shard's own pinned servers at `Sim::new`, a spike
        // was already applied to the signal upstream of the partition.
        let mut faults = FaultPlan::default();
        for f in &cfg.faults.faults {
            match *f {
                Fault::ServerDeath { t, server } => {
                    if let Some(local) = local_of(server) {
                        faults.faults.push(
                            Fault::ServerDeath { t, server: local });
                    }
                }
                other => faults.faults.push(other),
            }
        }
        SimConfig {
            servers: shard.servers.iter()
                .map(|&g| cfg.servers[g].clone())
                .collect(),
            router: cfg.router,
            batcher: cfg.batcher,
            ci: cfg.ci.clone(),
            emb_kg_per_hr: shard.servers.iter()
                .map(|&g| cfg.emb_kg_per_hr[g])
                .collect(),
            kv_transfer_bw: cfg.kv_transfer_bw,
            deferral: cfg.deferral,
            fleet_plan,
            region_signals: cfg.region_signals.clone(),
            coldstart_s: cfg.coldstart_s,
            keepalive: cfg.keepalive,
            faults,
        }
    }
}

/// Per-shard facts the splitter scores against.
#[derive(Debug, Clone)]
struct ShardMeta {
    n_servers: f64,
    max_mem_gb: f64,
    min_mem_gb: f64,
    /// Effective CI signal of each server in the shard (region override
    /// or the primary signal).
    signals: Vec<CiSignal>,
}

/// The top-level region splitter: assigns each request to a shard with
/// the configured [`Router`]'s semantics lifted to shard granularity,
/// using a per-shard assigned-load proxy (assigned count / servers) in
/// place of live queue depth. A pure state machine over the request
/// sequence: every [`PartitionSource`] rebuilds an identical instance and
/// reaches identical decisions with no cross-thread coordination.
#[derive(Debug, Clone)]
pub struct ShardSplitter {
    router: Router,
    metas: Vec<ShardMeta>,
    assigned: Vec<u64>,
    /// Per-request shard-CI scratch (carbon-greedy): each shard's CI at
    /// the arrival time is computed once per request, not once per
    /// comparison inside the argmin.
    ci_scratch: Vec<f64>,
}

/// Queue-pressure discount mirroring the per-server carbon-greedy
/// policy's default weight.
const SPLIT_QUEUE_WEIGHT: f64 = 0.25;

impl ShardSplitter {
    pub fn new(cfg: &SimConfig, plan: &ShardPlan) -> ShardSplitter {
        let metas = plan.shards.iter()
            .map(|sh| {
                let mems: Vec<f64> = sh.servers.iter()
                    .map(|&g| cfg.servers[g].device.mem_gb)
                    .collect();
                ShardMeta {
                    n_servers: sh.servers.len() as f64,
                    max_mem_gb: mems.iter().copied().fold(f64::MIN, f64::max),
                    min_mem_gb: mems.iter().copied().fold(f64::MAX, f64::min),
                    signals: sh.servers.iter()
                        .map(|&g| match cfg.servers[g].region {
                            Some(r) => cfg.region_signal(r),
                            None => cfg.ci.clone(),
                        })
                        .collect(),
                }
            })
            .collect::<Vec<_>>();
        let n = metas.len();
        ShardSplitter {
            router: cfg.router,
            metas,
            assigned: vec![0; n],
            ci_scratch: Vec::with_capacity(n),
        }
    }

    /// Mean grid CI this shard's servers see at time `t`.
    fn ci(&self, k: usize, t_s: f64) -> f64 {
        let m = &self.metas[k];
        m.signals.iter().map(|s| s.at(t_s)).sum::<f64>() / m.n_servers
    }

    /// Assigned-load proxy: requests routed here per server.
    fn load(&self, k: usize) -> f64 {
        self.assigned[k] as f64 / self.metas[k].n_servers
    }

    /// Pick the shard for `r` and record the assignment. Ties break to
    /// the lowest shard index, mirroring the per-server policies.
    pub fn assign(&mut self, r: &Request) -> usize {
        let n = self.metas.len();
        if n == 1 {
            self.assigned[0] += 1;
            return 0;
        }
        let best = match self.router {
            Router::Jsq => argmin(n, |k| (self.load(k), 0.0)),
            Router::WorkloadAware => {
                let long = r.prompt_tokens >= LONG_PROMPT_TOKENS;
                argmin(n, |k| {
                    let m = &self.metas[k];
                    let pref = if long { -m.max_mem_gb } else { m.min_mem_gb };
                    (pref, self.load(k))
                })
            }
            Router::CarbonGreedy => {
                let t = r.arrival_s;
                self.ci_scratch.clear();
                for k in 0..n {
                    let ci = self.ci(k, t);
                    self.ci_scratch.push(ci);
                }
                let mean_ci = (self.ci_scratch.iter().sum::<f64>()
                    / n as f64).max(1e-9);
                argmin(n, |k| {
                    (self.ci_scratch[k] / mean_ci
                         + SPLIT_QUEUE_WEIGHT * self.load(k),
                     0.0)
                })
            }
        };
        self.assigned[best] += 1;
        best
    }
}

/// Index of the lexicographic minimum of `key` over `0..n`; first wins
/// ties (total_cmp keeps the order total for any float garbage).
fn argmin(n: usize, key: impl Fn(usize) -> (f64, f64)) -> usize {
    (0..n)
        .min_by(|&a, &b| {
            let (pa, sa) = key(a);
            let (pb, sb) = key(b);
            pa.total_cmp(&pb).then_with(|| sa.total_cmp(&sb))
        })
        .unwrap()
}

/// Factory handing each shard a fresh copy of the *full* arrival stream
/// (the shard filters it down itself).
pub type SourceFn<'a> = dyn Fn() -> Box<dyn ArrivalSource + 'a> + Sync;

/// What one shard worker hands back: its merged-ready report plus the
/// closed-books meter (for interval-total merging).
type ShardResult = (SimReport, CarbonMeter);

/// Per-shard fleet scheduling hook: given the shard's sub-config and its
/// arrival substream, produce the shard's [`FleetSchedule`] (the scenario
/// layer plugs the rolling-horizon controller in here). `None` keeps the
/// sub-config's own (typically static) schedule.
pub type ScheduleFn<'a> =
    dyn Fn(&SimConfig, &mut dyn ArrivalSource) -> FleetSchedule + Sync + 'a;

/// Shard `shard`'s substream: the full stream filtered through a fresh
/// deterministic splitter.
pub fn shard_stream<'a>(cfg: &SimConfig, plan: &ShardPlan, shard: usize,
                        inner: Box<dyn ArrivalSource + 'a>)
    -> PartitionSource<'a> {
    let mut splitter = ShardSplitter::new(cfg, plan);
    PartitionSource::new(inner, shard, Box::new(move |r| splitter.assign(r)))
}

/// Run `n` independent jobs on up to `threads` scoped worker threads and
/// return the results in job order. The order-fixed slot collection is
/// what makes every fan-out in the codebase (shard sims, the fused
/// planner pass) thread-count-deterministic: workers race only for *which*
/// job to pull, never for where its result lands.
pub fn parallel_slots<T, F>(n: usize, threads: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(n > 0, "parallel_slots needs at least one job");
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::SeqCst);
                if k >= n {
                    break;
                }
                let part = job(k);
                *slots[k].lock().unwrap() = Some(part);
            });
        }
    });

    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("worker poisoned a result slot")
                .expect("worker skipped a job")
        })
        .collect()
}

/// Run `cfg`'s fleet sharded under `plan` on up to `threads` scoped
/// worker threads and merge the shard results into one [`SimReport`].
/// Deterministic: the report depends only on (model, cfg, plan, stream),
/// never on `threads` or scheduling order.
#[allow(clippy::too_many_arguments)]
pub fn simulate_sharded<'a, 'b>(model: &LlmSpec, cfg: &SimConfig,
                                slo_ttft: f64, slo_tpot: f64,
                                plan: &ShardPlan, threads: usize,
                                make_source: &SourceFn<'a>,
                                schedule: Option<&ScheduleFn<'b>>)
    -> SimReport {
    assert!(!plan.is_empty(), "empty shard plan");
    let parts: Vec<ShardResult> = parallel_slots(plan.len(), threads, |k| {
        run_shard(model, cfg, plan, k, slo_ttft, slo_tpot, make_source,
                  schedule, None)
    });
    merge_shard_reports(cfg, plan, parts)
}

/// [`simulate_sharded`] with the passive recorders of [`crate::obs`]
/// attached: every shard worker runs with a fresh [`Observer::shard`]
/// recorder (same grids and span seed, scoped to the shard's servers),
/// and the recorders fold back into `obs` in ascending shard index — so
/// the merged timeline/span artifacts, like the report itself, are
/// byte-identical for any `threads` value. Returns the merged report
/// plus the wall-clock seconds spent in the order-fixed merge (the
/// self-profiling `merge_s` stage). `obs = None` is byte-identical to
/// [`simulate_sharded`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_sharded_observed<'a, 'b>(
    model: &LlmSpec, cfg: &SimConfig, slo_ttft: f64, slo_tpot: f64,
    plan: &ShardPlan, threads: usize, make_source: &SourceFn<'a>,
    schedule: Option<&ScheduleFn<'b>>, obs: Option<&mut Observer>)
    -> (SimReport, f64) {
    assert!(!plan.is_empty(), "empty shard plan");
    let parts: Vec<(SimReport, CarbonMeter, Option<Observer>)> = {
        let template: Option<&Observer> = obs.as_deref();
        parallel_slots(plan.len(), threads, |k| {
            let mut shard_obs = template.map(|o| {
                o.shard(&plan.shards[k].servers,
                        &format!(":{}", plan.shards[k].key))
            });
            let (report, meter) = run_shard(
                model, cfg, plan, k, slo_ttft, slo_tpot, make_source,
                schedule, shard_obs.as_mut());
            (report, meter, shard_obs)
        })
    };
    let t0 = std::time::Instant::now();
    let mut reports: Vec<ShardResult> = Vec::with_capacity(parts.len());
    match obs {
        Some(o) => {
            // Ascending shard index: the slot-ordered `parts` vector is
            // already in plan order regardless of worker interleaving.
            for (r, m, so) in parts {
                reports.push((r, m));
                if let Some(so) = so {
                    o.merge(so);
                }
            }
        }
        None => reports.extend(parts.into_iter().map(|(r, m, _)| (r, m))),
    }
    let merged = merge_shard_reports(cfg, plan, reports);
    (merged, t0.elapsed().as_secs_f64())
}

#[allow(clippy::too_many_arguments)]
fn run_shard<'a, 'b>(model: &LlmSpec, cfg: &SimConfig, plan: &ShardPlan,
                     k: usize, slo_ttft: f64, slo_tpot: f64,
                     make_source: &SourceFn<'a>,
                     schedule: Option<&ScheduleFn<'b>>,
                     obs: Option<&mut Observer>)
    -> (SimReport, CarbonMeter) {
    let mut sub = plan.sub_config(cfg, k);
    if let Some(sched) = schedule {
        let mut src = shard_stream(cfg, plan, k, make_source());
        sub.fleet_plan = sched(&sub, &mut src);
    }
    let mut src = shard_stream(cfg, plan, k, make_source());
    let mut sim = Sim::new(model, &mut src, &sub, slo_ttft, slo_tpot,
                           sub.router.policy(), sub.batcher.policy());
    if let Some(o) = obs {
        sim.attach_observer(o);
    }
    sim.run();
    sim.finish_parts()
}

/// Fold shard `(SimReport, CarbonMeter)` pairs — in ascending shard index
/// — into one fleet-wide report: histogram merge for latency, exact
/// counter sums, attainment recomputed from the summed raw counters,
/// per-server usage scattered back to global fleet order, and operational
/// carbon taken from the merged meter's interval totals.
fn merge_shard_reports(cfg: &SimConfig, plan: &ShardPlan,
                       parts: Vec<ShardResult>) -> SimReport {
    let n_servers = cfg.servers.len();
    let mut meter = CarbonMeter::new(cfg);
    let mut ttft = Histogram::new();
    let mut tpot = Histogram::new();
    let mut per_server = vec![ServerUsage::default(); n_servers];
    let (mut arrivals, mut completed, mut generated_tokens) = (0usize, 0, 0);
    let (mut online_done, mut slo_ok) = (0usize, 0);
    let (mut offline_done, mut offline_on_time) = (0usize, 0);
    let (mut deferred, mut truncated, mut events) = (0usize, 0, 0);
    let (mut provision_events, mut decommission_events) = (0usize, 0);
    let mut peak_live_jobs = 0usize;
    let (mut faults_injected, mut jobs_rescheduled) = (0usize, 0);
    let (mut jobs_recovered, mut recovery_wait_s) = (0usize, 0.0f64);
    let (mut sim_duration_s, mut energy_j, mut emb_kg) = (0.0f64, 0.0, 0.0);

    for (k, (r, shard_meter)) in parts.iter().enumerate() {
        meter.merge_shard(shard_meter, &plan.shards[k].servers);
        ttft.merge(&r.ttft);
        tpot.merge(&r.tpot);
        arrivals += r.arrivals;
        completed += r.completed;
        generated_tokens += r.generated_tokens;
        online_done += r.online_done;
        slo_ok += r.slo_ok;
        offline_done += r.offline_done;
        offline_on_time += r.offline_on_time;
        deferred += r.deferred_requests;
        truncated += r.truncated_prompts;
        events += r.events;
        provision_events += r.provision_events;
        decommission_events += r.decommission_events;
        // Shards run concurrently, so the fleet-wide arena bound is the
        // sum of the shard high-water marks (conservative: shard peaks
        // need not coincide in time).
        peak_live_jobs += r.peak_live_jobs;
        faults_injected += r.faults_injected;
        jobs_rescheduled += r.jobs_rescheduled;
        jobs_recovered += r.jobs_recovered;
        recovery_wait_s += r.recovery_wait_s;
        sim_duration_s = sim_duration_s.max(r.sim_duration_s);
        energy_j += r.energy_j;
        emb_kg += r.emb_kg;
        for (local, &g) in plan.shards[k].servers.iter().enumerate() {
            per_server[g] = r.per_server[local].clone();
            // The scatter and the meter merge must agree on the index
            // map — a mismatch here means a shard plan / sub-config
            // indexing bug, not a rounding issue.
            debug_assert_eq!(per_server[g].provisioned_s.to_bits(),
                             meter.provisioned_s(g).to_bits(),
                             "per-server scatter diverged from the merged \
                              meter at server {g}");
        }
    }

    let slo_attainment = if online_done == 0 {
        1.0
    } else {
        slo_ok as f64 / online_done as f64
    };
    let offline_deadline_attainment = if offline_done == 0 {
        1.0
    } else {
        offline_on_time as f64 / offline_done as f64
    };
    // From the merged meter's interval totals, summed in fleet order —
    // bitwise what `into_report` computes from `per_server` on the
    // unsharded path.
    let provisioned_server_hours = (0..n_servers)
        .map(|i| meter.provisioned_s(i))
        .sum::<f64>()
        / 3600.0;
    SimReport {
        ttft,
        tpot,
        arrivals,
        completed,
        generated_tokens,
        sim_duration_s,
        energy_j,
        op_kg: meter.op_kg(),
        emb_kg,
        slo_attainment,
        offline_deadline_attainment,
        online_done,
        slo_ok,
        offline_done,
        offline_on_time,
        deferred_requests: deferred,
        truncated_prompts: truncated,
        events,
        provision_events,
        decommission_events,
        peak_live_jobs,
        faults_injected,
        jobs_rescheduled,
        jobs_recovered,
        recovery_wait_s,
        provisioned_server_hours,
        per_server,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::intensity::Region;
    use crate::models;
    use crate::sim::{homogeneous_fleet, simulate_stream};
    use crate::workload::{Arrivals, GeneratorSource, LengthDist, RequestClass};

    fn fleet_cfg(n: usize, router: Router) -> SimConfig {
        let m = models::llm("llama-8b").unwrap();
        SimConfig::flat(homogeneous_fleet("A100-40", n, m, 2048), router,
                        261.0, vec![0.005; n])
    }

    fn two_region_cfg(n: usize, router: Router) -> SimConfig {
        let mut cfg = fleet_cfg(n, router);
        for (i, s) in cfg.servers.iter_mut().enumerate() {
            if i % 2 == 0 {
                s.region = Some(Region::SwedenNorth);
            }
        }
        cfg
    }

    fn source_fn(rate: f64, duration_s: f64, seed: u64)
        -> impl Fn() -> Box<dyn ArrivalSource + 'static> + Sync {
        move || {
            Box::new(GeneratorSource::new(Arrivals::Poisson { rate },
                                          LengthDist::ShareGpt,
                                          RequestClass::Online, duration_s,
                                          seed))
        }
    }

    #[test]
    fn partition_covers_the_fleet_once_and_respects_the_cluster_cap() {
        let cfg = two_region_cfg(20, Router::Jsq);
        let plan = ShardPlan::partition(&cfg, 42);
        let mut seen: Vec<usize> =
            plan.shards.iter().flat_map(|s| s.servers.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        for sh in &plan.shards {
            assert!(sh.servers.len() <= MAX_SHARD_SERVERS,
                    "shard {} too large: {}", sh.key, sh.servers.len());
            assert!(sh.servers.iter()
                        .all(|&i| cfg.servers[i].region
                            == cfg.servers[sh.servers[0]].region),
                    "shard {} mixes regions", sh.key);
        }
        // 10 + 10 servers, cap 8 → 2 clusters per region.
        assert_eq!(plan.len(), 4);
        // Shard identity (key + seed) is stable and unique.
        let plan2 = ShardPlan::partition(&cfg, 42);
        let keys: Vec<&str> =
            plan.shards.iter().map(|s| s.key.as_str()).collect();
        let keys2: Vec<&str> =
            plan2.shards.iter().map(|s| s.key.as_str()).collect();
        assert_eq!(keys, keys2);
        let mut seeds: Vec<u64> = plan.shards.iter().map(|s| s.seed).collect();
        assert_eq!(seeds, plan2.shards.iter().map(|s| s.seed).collect::<Vec<_>>());
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), plan.len(), "shard seeds collide");
    }

    #[test]
    fn disaggregated_fleets_repair_to_servable_shards() {
        let mut cfg = fleet_cfg(12, Router::Jsq);
        for (i, s) in cfg.servers.iter_mut().enumerate() {
            s.role = if i < 9 { Role::Prompt } else { Role::Decode };
        }
        let plan = ShardPlan::partition(&cfg, 7);
        for sh in &plan.shards {
            assert!(sh.servers.iter().any(|&i| cfg.servers[i].role != Role::Decode),
                    "shard {} cannot prefill", sh.key);
            assert!(sh.servers.iter().any(|&i| cfg.servers[i].role != Role::Prompt),
                    "shard {} cannot decode", sh.key);
        }
    }

    #[test]
    fn splitter_instances_agree_and_balance_jsq() {
        let cfg = two_region_cfg(8, Router::Jsq);
        let plan = ShardPlan::partition(&cfg, 1);
        assert!(plan.len() >= 2);
        let mk = source_fn(8.0, 60.0, 5);
        let trace: Vec<Request> = mk().materialize();
        let mut a = ShardSplitter::new(&cfg, &plan);
        let mut b = ShardSplitter::new(&cfg, &plan);
        let mut counts = vec![0usize; plan.len()];
        for r in &trace {
            let ka = a.assign(r);
            assert_eq!(ka, b.assign(r), "splitter instances diverged");
            counts[ka] += 1;
        }
        // JSQ at shard level: equal-weight shards get near-equal load.
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "unbalanced JSQ split: {counts:?}");
    }

    #[test]
    fn carbon_greedy_splitter_prefers_the_clean_grid() {
        let cfg = two_region_cfg(8, Router::CarbonGreedy);
        let plan = ShardPlan::partition(&cfg, 1);
        let clean: Vec<usize> = plan.shards.iter().enumerate()
            .filter(|(_, s)| s.key.starts_with(Region::SwedenNorth.name()))
            .map(|(k, _)| k)
            .collect();
        assert!(!clean.is_empty());
        let mut sp = ShardSplitter::new(&cfg, &plan);
        let mk = source_fn(8.0, 30.0, 9);
        let trace = mk().materialize();
        let (mut to_clean, mut total) = (0usize, 0usize);
        for r in &trace {
            if clean.contains(&sp.assign(r)) {
                to_clean += 1;
            }
            total += 1;
        }
        assert!(to_clean * 2 > total,
                "clean grid got only {to_clean}/{total} requests");
    }

    #[test]
    fn single_shard_run_matches_the_unsharded_engine_bitwise() {
        let m = models::llm("llama-8b").unwrap();
        // 4 servers, one region, under the cluster cap → exactly 1 shard.
        let cfg = fleet_cfg(4, Router::Jsq);
        let plan = ShardPlan::partition(&cfg, 3);
        assert_eq!(plan.len(), 1);
        let mk = source_fn(4.0, 90.0, 11);
        let sharded = simulate_sharded(m, &cfg, 0.5, 0.1, &plan, 2, &mk, None);
        let flat = simulate_stream(m, &mut *mk(), &cfg, 0.5, 0.1);
        assert_eq!(sharded.arrivals, flat.arrivals);
        assert_eq!(sharded.completed, flat.completed);
        assert_eq!(sharded.events, flat.events);
        assert_eq!(sharded.energy_j.to_bits(), flat.energy_j.to_bits());
        assert_eq!(sharded.op_kg.to_bits(), flat.op_kg.to_bits());
        assert_eq!(sharded.emb_kg.to_bits(), flat.emb_kg.to_bits());
        assert_eq!(sharded.ttft.p90().to_bits(), flat.ttft.p90().to_bits());
        assert_eq!(sharded.peak_live_jobs, flat.peak_live_jobs);
    }

    #[test]
    fn sharded_report_is_thread_count_invariant_and_complete() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = two_region_cfg(20, Router::CarbonGreedy);
        let plan = ShardPlan::partition(&cfg, 13);
        assert!(plan.len() >= 4);
        let mk = source_fn(10.0, 60.0, 17);
        let total = mk().materialize().len();
        let runs: Vec<SimReport> = [1, 2, 4]
            .iter()
            .map(|&t| simulate_sharded(m, &cfg, 0.5, 0.1, &plan, t, &mk, None))
            .collect();
        for r in &runs {
            assert_eq!(r.arrivals, total, "requests lost across shards");
            assert_eq!(r.completed, total);
            assert_eq!(r.per_server.len(), 20);
        }
        for w in runs.windows(2) {
            assert_eq!(w[0].events, w[1].events);
            assert_eq!(w[0].energy_j.to_bits(), w[1].energy_j.to_bits());
            assert_eq!(w[0].op_kg.to_bits(), w[1].op_kg.to_bits());
            assert_eq!(w[0].ttft.p99().to_bits(), w[1].ttft.p99().to_bits());
            assert_eq!(w[0].slo_attainment.to_bits(),
                       w[1].slo_attainment.to_bits());
        }
    }
}
