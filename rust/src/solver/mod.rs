//! Linear / mixed-integer optimization substrate (the offline CVXpy
//! replacement). `ProblemBuilder` is the ergonomic front door used by
//! planner/: named variables with bounds + integrality, sparse constraints,
//! minimize or maximize.

pub mod lp;
pub mod milp;

pub use lp::{Cmp, LpStatus};
pub use milp::{MilpConfig, MilpSolution, MilpStatus};

use lp::Row;

/// Variable handle returned by [`ProblemBuilder::var`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize);

#[derive(Debug, Default, Clone)]
pub struct ProblemBuilder {
    costs: Vec<f64>,
    integer: Vec<bool>,
    names: Vec<String>,
    rows: Vec<Row>,
}

impl ProblemBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable (x >= 0) with objective coefficient `cost`.
    pub fn var(&mut self, name: &str, cost: f64, integer: bool) -> Var {
        self.costs.push(cost);
        self.integer.push(integer);
        self.names.push(name.to_string());
        Var(self.costs.len() - 1)
    }

    /// Add a variable with an upper bound (emitted as a row).
    pub fn var_bounded(&mut self, name: &str, cost: f64, integer: bool, hi: f64) -> Var {
        let v = self.var(name, cost, integer);
        self.le(&[(v, 1.0)], hi);
        v
    }

    /// Binary (0/1) variable.
    pub fn binary(&mut self, name: &str, cost: f64) -> Var {
        self.var_bounded(name, cost, true, 1.0)
    }

    pub fn num_vars(&self) -> usize {
        self.costs.len()
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn name(&self, v: Var) -> &str {
        &self.names[v.0]
    }

    fn cons(&mut self, terms: &[(Var, f64)], cmp: Cmp, rhs: f64) {
        self.rows.push(Row {
            coeffs: terms.iter().map(|(v, c)| (v.0, *c)).collect(),
            cmp,
            rhs,
        });
    }

    pub fn le(&mut self, terms: &[(Var, f64)], rhs: f64) {
        self.cons(terms, Cmp::Le, rhs);
    }

    pub fn ge(&mut self, terms: &[(Var, f64)], rhs: f64) {
        self.cons(terms, Cmp::Ge, rhs);
    }

    pub fn eq(&mut self, terms: &[(Var, f64)], rhs: f64) {
        self.cons(terms, Cmp::Eq, rhs);
    }

    /// Solve as LP (integrality relaxed).
    pub fn solve_lp(&self) -> lp::LpSolution {
        lp::solve(self.costs.len(), &self.costs, &self.rows)
    }

    /// Solve with integrality enforced via branch-and-bound.
    pub fn solve(&self, cfg: &MilpConfig) -> MilpSolution {
        milp::solve(self.costs.len(), &self.costs, &self.rows, &self.integer, cfg)
    }

    pub fn value(&self, sol: &MilpSolution, v: Var) -> f64 {
        sol.x[v.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_end_to_end() {
        // Facility location sketch: open machines (cost 5 each, integer),
        // serve demand 7 with capacity 3/machine → 3 machines, cost 15.
        let mut p = ProblemBuilder::new();
        let machines = p.var("machines", 5.0, true);
        p.ge(&[(machines, 3.0)], 7.0);
        let s = p.solve(&MilpConfig::default());
        assert_eq!(s.status, MilpStatus::Optimal);
        assert_eq!(p.value(&s, machines), 3.0);
        assert!((s.objective - 15.0).abs() < 1e-6);
    }

    #[test]
    fn binary_bound_enforced() {
        let mut p = ProblemBuilder::new();
        let b = p.binary("b", -10.0); // maximize b → 1
        let s = p.solve(&MilpConfig::default());
        assert_eq!(p.value(&s, b), 1.0);
    }

    #[test]
    fn lp_relaxation_leq_milp() {
        let mut p = ProblemBuilder::new();
        let x = p.var("x", 1.0, true);
        p.ge(&[(x, 1.0)], 2.5);
        let rel = p.solve_lp();
        let int = p.solve(&MilpConfig::default());
        assert!(rel.objective <= int.objective + 1e-9);
        assert_eq!(int.x[x.0], 3.0);
    }
}
