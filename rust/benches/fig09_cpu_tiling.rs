//! Fig 9: parallelism degree vs arithmetic intensity for Linear operators,
//! and the PD the runtime co-selects.
use ecoserve::hw;
use ecoserve::perf::cpu::{best_linear_pd, linear_slice_ai};
use ecoserve::util::table::{fnum, Table};

fn main() {
    let cpu = hw::cpu("SPR-112").unwrap();
    println!("== Fig 9: Linear-op slice AI vs parallelism degree (SPR-112) ==");
    let knee = cpu.bf16_tflops * 1e12 / (cpu.mem_bw_gbs * 1e9);
    println!("roofline knee: {} FLOP/byte", fnum(knee));
    for (d_in, d_out, batch) in [(4608, 36864, 16), (4096, 4096, 8), (2304, 2304, 1)] {
        let mut t = Table::new(&["PD", "slice AI", "vs knee"]);
        for pd in [1usize, 4, 16, 56, 112] {
            let ai = linear_slice_ai(d_in, d_out, batch, pd, 2.0);
            t.row(&[format!("{pd}"), fnum(ai),
                    if ai >= knee { "compute-ok".into() } else { "bw-starved".into() }]);
        }
        let best = best_linear_pd(cpu, d_in, d_out, batch, 2.0);
        println!("linear {d_in}x{d_out} batch {batch}: chosen PD = {best}");
        t.print();
    }
}
