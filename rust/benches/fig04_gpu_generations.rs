//! Fig 4: embodied carbon breakdown, TDP, and cost across GPU generations.
use ecoserve::carbon::embodied::gpu_embodied;
use ecoserve::hw;
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 4: embodied breakdown / power / cost by GPU generation ==");
    let mut t = Table::new(&["gpu", "soc", "memory", "pcb", "cooling", "pdn",
                             "total kg", "soc %", "tdp W", "$/hr"]);
    for g in hw::gpu_catalog() {
        let b = gpu_embodied(g);
        t.row(&[g.name.into(), fnum(b.soc), fnum(b.memory), fnum(b.pcb),
                fnum(b.cooling), fnum(b.pdn), fnum(b.total()),
                fnum(100.0 * b.soc / b.total()), fnum(g.tdp_w), fnum(g.cost_hr)]);
    }
    t.print();
    println!("(SoC/ACT share ~20%: the rest is memory, board, cooling, PDN)");
}
