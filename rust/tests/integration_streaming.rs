//! Differential suite for the streaming workload layer: for every
//! registry scenario, the lazy-generator arrival path must produce a
//! byte-identical `ScenarioOutcome` JSON to the reference
//! materialized-trace adapter, the report must stay byte-identical across
//! thread counts, and the scale scenarios must hold the streaming core's
//! memory promise (peak live jobs ≪ trace length).

use ecoserve::scenarios::{registry, run_spec_materialized, run_sweep,
                          scenario_seed, SweepConfig};

const DIFF_DURATION_S: f64 = 24.0;

#[test]
fn streaming_matches_materialized_for_every_registry_scenario() {
    for sc in registry() {
        let seed = scenario_seed(97, sc.name());
        let streamed = sc.run(seed, DIFF_DURATION_S).to_json().to_string();
        let materialized =
            run_spec_materialized(sc.name(), &sc.spec(), seed, DIFF_DURATION_S)
                .to_json()
                .to_string();
        assert_eq!(streamed, materialized,
                   "{}: streaming and materialized outcomes diverge",
                   sc.name());
    }
}

#[test]
fn streaming_sweep_is_byte_identical_across_thread_counts() {
    let mk = |threads| {
        let cfg = SweepConfig { threads, seed: 13, duration_s: DIFF_DURATION_S,
                                ..Default::default() };
        run_sweep(&registry(), &cfg).to_json().to_string()
    };
    assert_eq!(mk(1), mk(8),
               "thread count changed the streaming sweep report bytes");
}

fn production_day_outcome(seed: u64, duration_s: f64)
    -> ecoserve::scenarios::ScenarioOutcome {
    let sel = ecoserve::scenarios::catalog::by_names(&["production-day"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed, duration_s,
                            ..Default::default() };
    run_sweep(&sel, &cfg).outcomes.remove(0)
}

#[test]
fn production_day_smoke_streams_with_bounded_job_memory() {
    // Trimmed slice of the production day: every request completes, the
    // elastic fleet actually flexes, and the arena high-water mark stays
    // far below the trace length (the memory-bound proxy the full-scale
    // run relies on).
    let o = production_day_outcome(7, 60.0);
    assert!(o.requests > 10_000, "day too quiet: {} requests", o.requests);
    assert_eq!(o.completed, o.requests, "requests lost");
    assert!(o.peak_live_jobs * 2 < o.requests,
            "peak live jobs {} vs {} requests — streaming bound broken",
            o.peak_live_jobs, o.requests);
    assert!(o.extras.contains_key("op_kg_jsq"),
            "missing carbon-greedy routing baseline");
    assert!(o.extras.contains_key("carbon_kg_static"),
            "missing static provisioning baseline");
}

#[test]
#[ignore = "full-scale production day (~2M requests); run with --ignored in release"]
fn production_day_full_scale_completes_two_million_requests() {
    let o = production_day_outcome(42, 7200.0);
    assert!(o.requests >= 2_000_000,
            "expected a >=2M-request day, got {}", o.requests);
    assert_eq!(o.completed, o.requests, "requests lost at scale");
    // Memory bounded by fleet + in-flight jobs: the arena never holds
    // more than a sliver of the trace.
    assert!(o.peak_live_jobs * 50 < o.requests,
            "peak live jobs {} vs {} requests", o.peak_live_jobs, o.requests);
    assert!(o.decommission_events > 0, "the elastic day never scaled down");
}

#[test]
fn production_week_runs_with_weekend_lull_and_streams() {
    let sel = ecoserve::scenarios::catalog::by_names(&["production-week"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 7, duration_s: 70.0,
                            ..Default::default() };
    let o = run_sweep(&sel, &cfg).outcomes.remove(0);
    assert_eq!(o.completed, o.requests, "requests lost");
    assert!(o.requests > 2_000, "week too quiet: {}", o.requests);
    assert!(o.peak_live_jobs * 2 < o.requests,
            "peak live jobs {} vs {} requests", o.peak_live_jobs, o.requests);
}
