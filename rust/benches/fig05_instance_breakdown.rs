//! Fig 5: embodied breakdown of cloud instances, varying GPU type/count.
use ecoserve::carbon::embodied::platform_embodied;
use ecoserve::hw::platform::{azure_nd96_a100, standard_platform};
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 5: instance embodied carbon (host vs GPUs) ==");
    let mut t = Table::new(&["instance", "host kg", "gpu kg", "host %",
                             "host mem+storage %"]);
    let mut add = |p: &ecoserve::hw::platform::Platform| {
        let (h, g) = platform_embodied(p);
        let total = h.total() + g.total();
        t.row(&[p.name.clone(), fnum(h.total()), fnum(g.total()),
                fnum(100.0 * h.total() / total),
                fnum(100.0 * (h.memory + h.storage) / total)]);
    };
    add(&azure_nd96_a100());
    for (gpu, n) in [("T4", 1), ("L4", 1), ("A6000", 2), ("A100-40", 4),
                     ("A100-80", 8), ("H100", 4), ("H100", 8)] {
        add(&standard_platform(gpu, n));
    }
    t.print();
}
