//! Request-path runtime: PJRT engine over AOT HLO artifacts, weight loader,
//! manifest, tokenizer. Python is build-time only — this module is the
//! entire serving compute layer.

pub mod engine;
pub mod manifest;
pub mod tokenizer;
pub mod weights;

pub use engine::{Engine, KvCache};
pub use manifest::Manifest;
