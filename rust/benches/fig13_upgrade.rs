//! Fig 13: relative carbon per token vs V100 across hardware, for prompt-
//! vs decode-heavy workloads and low/high carbon intensity.
use ecoserve::carbon::embodied::gpu_embodied;
use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::roofline::{decode_throughput, prefill_throughput, Device};
use ecoserve::util::table::{fnum, Table};

fn main() {
    let m = models::llm("llama-8b").unwrap();
    println!("== Fig 13: carbon per token relative to V100 (<1 is better) ==");
    let carbon_per_tok = |g: &'static str, prompt_heavy: bool, ci: f64| -> f64 {
        let spec = hw::gpu(g).unwrap();
        let dev = Device::from_gpu(spec);
        let tp = if m.weight_gb() > 0.85 * dev.mem_gb { 2 } else { 1 };
        let tput = if prompt_heavy {
            prefill_throughput(m, &dev, 4, 2048, tp)
        } else {
            decode_throughput(m, &dev, 16, 1024, tp)
        };
        let power = spec.tdp_w * 0.8 * tp as f64;
        let op = power / 1000.0 * ci / 1000.0 / 3600.0; // kg/s
        let emb = gpu_embodied(spec).total() * tp as f64 / (4.0 * 365.25 * 86400.0);
        (op + emb) / tput
    };
    for (label, ci, ph) in [("prompt-heavy CI=400", 400.0, true),
                            ("prompt-heavy CI=50", 50.0, true),
                            ("decode-heavy CI=50", 50.0, false)] {
        println!("\n{label}:");
        let base = carbon_per_tok("V100", ph, ci);
        let mut t = Table::new(&["gpu", "rel carbon/token", "saving %"]);
        for g in ["V100", "A100-40", "A100-80", "L4", "H100", "GH200"] {
            let c = carbon_per_tok(g, ph, ci);
            t.row(&[g.into(), fnum(c / base), fnum(100.0 * (1.0 - c / base))]);
        }
        t.print();
    }
    println!("(optimal upgrade target differs by workload mix and CI)");
}
