//! EcoServe's capacity planner: workload slicing + the cross-stack ILP
//! (paper §4.2.2), solved with the in-repo branch-and-bound MILP.
//!
//! Pipeline: a request trace is bucketed into (prompt, output) slices with
//! per-slice rates; for every (slice, phase, device) the roofline model
//! yields the max SLO-feasible throughput (the `MaxTput` term); the ILP
//! assigns each slice-phase to a device type and sizes the fleet, minimizing
//! (1-α)·cost + α·carbon subject to SLO, capacity, and host budgets.
//!
//! CPU *Reuse* appears as an extra device column available to offline
//! decode slice-phases whose marginal embodied carbon is zero (the host
//! ships with the GPUs regardless) and whose capacity is tied linearly to
//! the provisioned machine count — so reuse and provisioning co-optimize in
//! one solve, the paper's "cross-layer" point.
//!
//! [`horizon`] runs this same ILP *periodically*: the rolling-horizon
//! controller re-solves against the observed demand window and the grid-CI
//! forecast every epoch and emits fleet provisioning events for the
//! simulator (periodic pool management).

pub mod benders;
pub mod fused;
pub mod horizon;
pub mod pools;
pub mod slicing;

use crate::carbon::embodied;
use crate::carbon::operational::{dynamic_power, idle_power, op_kg_per_hr,
                                 PLANNING_UTIL};
use crate::hw::{self, platform};
use crate::models::LlmSpec;
use crate::perf::cpu::{self as cpuperf, CpuStrategy};
use crate::perf::roofline::{self, Device};
use crate::solver::{MilpConfig, MilpStatus, ProblemBuilder, Var};
use slicing::Slice;
use std::collections::BTreeMap;

/// GPUs per host machine in provisioned fleets (embodied attribution).
pub const GPUS_PER_HOST: usize = 4;
/// Reusable host CPU sockets per provisioned GPU (dual-socket, 4-GPU
/// machines → 0.5 — ties CPU-reuse capacity linearly to the fleet size).
pub const HOST_SOCKETS_PER_GPU: f64 = 0.5;
/// Hourly cost of a host CPU core / GB of DRAM ($/hr, cloud-normalized).
pub const CPU_CORE_COST_HR: f64 = 0.012;
pub const MEM_GB_COST_HR: f64 = 0.0015;

/// A provisionable device type (GPU SKU, or the reuse-CPU pseudo-device).
#[derive(Debug, Clone)]
pub struct DeviceOption {
    pub name: String,
    pub dev: Device,
    pub cost_hr: f64,
    /// Embodied kg attributed per device-hour (device + host share / LT).
    pub emb_kg_per_hr: f64,
    pub is_cpu: bool,
}

/// Planner configuration — the strategy knobs (4R) live here.
#[derive(Debug, Clone)]
pub struct PlanConfig {
    /// Carbon-vs-cost weight α (paper default 1.0 = pure carbon).
    pub alpha: f64,
    /// Grid carbon intensity, gCO₂e/kWh.
    pub ci: f64,
    /// GPU menu (catalog names). Rightsize = full menu; baselines restrict.
    pub gpu_menu: Vec<&'static str>,
    /// Reuse: offer host CPUs for offline decode.
    pub cpu_reuse: bool,
    /// Reduce: lean host SKU in the embodied amortization.
    pub reduce_host: bool,
    /// Recycle: host lifetime (years). 4 = baseline, 9 = EcoServe.
    pub host_lifetime_y: f64,
    pub gpu_lifetime_y: f64,
    /// Force both phases of a slice onto one device type (Melange-style).
    pub couple_phases: bool,
    /// Integral assignment (paper formulation). False relaxes A to [0,1]
    /// for large-cluster solves.
    pub integral_assignment: bool,
    /// Fraction of the SLO the operating point must hit. Perf-opt runs at
    /// 0.35 (latency-minimizing small batches — and hence more devices);
    /// carbon/cost planners use the full slack (1.0).
    pub slo_scale: f64,
    pub milp: MilpConfig,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            alpha: 1.0,
            ci: 261.0,
            gpu_menu: vec!["L4", "A40", "A6000", "A100-40", "A100-80", "H100"],
            cpu_reuse: true,
            reduce_host: true,
            host_lifetime_y: 9.0,
            gpu_lifetime_y: 3.0,
            couple_phases: false,
            integral_assignment: true,
            slo_scale: 1.0,
            milp: MilpConfig { max_nodes: 2000,
                               time_limit: std::time::Duration::from_secs(2),
                               ..Default::default() },
        }
    }
}

impl PlanConfig {
    /// Performance-optimized baseline: single fastest SKU, cost objective.
    pub fn perf_opt() -> Self {
        PlanConfig {
            alpha: 0.0,
            gpu_menu: vec!["H100"],
            cpu_reuse: false,
            reduce_host: false,
            host_lifetime_y: 4.0,
            gpu_lifetime_y: 4.0,
            slo_scale: 0.35,
            ..Default::default()
        }
    }

    /// Melange-like cost-optimized baseline.
    pub fn melange() -> Self {
        PlanConfig {
            alpha: 0.0,
            cpu_reuse: false,
            reduce_host: false,
            host_lifetime_y: 4.0,
            gpu_lifetime_y: 4.0,
            couple_phases: true,
            ..Default::default()
        }
    }

    /// Energy-optimized baseline: minimizes energy (CI set to 1 so carbon
    /// ∝ energy, embodied ignored via long lifetimes).
    pub fn energy_opt() -> Self {
        PlanConfig {
            alpha: 1.0,
            ci: 1.0,
            cpu_reuse: false,
            reduce_host: false,
            host_lifetime_y: 1e6,
            gpu_lifetime_y: 1e6,
            ..Default::default()
        }
    }

    /// EcoServe with selectable Rs.
    pub fn ecoserve(reuse: bool, rightsize: bool, reduce: bool, recycle: bool) -> Self {
        PlanConfig {
            cpu_reuse: reuse,
            gpu_menu: if rightsize {
                vec!["L4", "A40", "A6000", "A100-40", "A100-80", "H100"]
            } else {
                vec!["H100"]
            },
            reduce_host: reduce,
            host_lifetime_y: if recycle { 9.0 } else { 4.0 },
            gpu_lifetime_y: if recycle { 3.0 } else { 4.0 },
            ..Default::default()
        }
    }
}

/// Per-(slice, phase) routing decision.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub slice_idx: usize,
    pub phase: Phase,
    pub device: String,
    /// Fraction of one device consumed.
    pub load: f64,
    /// Modeled latency at the operating batch size, seconds.
    pub latency_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prompt,
    Decode,
}

/// Planner output.
#[derive(Debug, Clone)]
pub struct Plan {
    pub counts: BTreeMap<String, usize>,
    /// Slice-phases no device could hold (rejected at admission).
    pub shed: usize,
    pub assignments: Vec<Assignment>,
    pub cost_hr: f64,
    pub op_kg_per_hr: f64,
    pub emb_kg_per_hr: f64,
    pub solve_s: f64,
    pub nodes: usize,
    pub status: MilpStatus,
}

impl Plan {
    pub fn carbon_kg_per_hr(&self) -> f64 {
        self.op_kg_per_hr + self.emb_kg_per_hr
    }

    pub fn total_gpus(&self) -> usize {
        self.counts.iter().filter(|(k, _)| *k != "cpu-host").map(|(_, v)| v).sum()
    }

    /// Modeled p50 latency for a phase, weighted by slice rate.
    pub fn mean_latency(&self, phase: Phase) -> f64 {
        let xs: Vec<&Assignment> = self.assignments.iter()
            .filter(|a| a.phase == phase)
            .collect();
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().map(|a| a.latency_s).sum::<f64>() / xs.len() as f64
    }
}

/// Build the device menu with carbon rates under the given config.
pub fn device_options(cfg: &PlanConfig, model: &LlmSpec) -> Vec<DeviceOption> {
    let hours_gpu = cfg.gpu_lifetime_y * 365.25 * 24.0;
    let hours_host = cfg.host_lifetime_y * 365.25 * 24.0;
    let mut out = Vec::new();
    for name in &cfg.gpu_menu {
        let g = hw::gpu(name).expect("unknown gpu in menu");
        let plat = if cfg.reduce_host {
            platform::reduced_platform(name, GPUS_PER_HOST, model.weight_gb(),
                                       0.25 * model.weight_gb())
        } else {
            platform::standard_platform(name, GPUS_PER_HOST)
        };
        let gpu_emb = embodied::gpu_embodied(g).total();
        let host_emb = embodied::host_embodied(&plat.host).total();
        // Per GPU-hour: own board over GPU lifetime + host share over host
        // lifetime.
        let emb_rate = gpu_emb / hours_gpu
            + host_emb / GPUS_PER_HOST as f64 / hours_host;
        out.push(DeviceOption {
            name: name.to_string(),
            dev: Device::from_gpu(g),
            cost_hr: g.cost_hr,
            emb_kg_per_hr: emb_rate,
            is_cpu: false,
        });
    }
    if cfg.cpu_reuse {
        let c = hw::cpu("SPR-112").unwrap();
        out.push(DeviceOption {
            name: "cpu-host".to_string(),
            dev: Device::from_cpu(c, 512.0),
            // Marginal cost of already-provisioned host cores.
            cost_hr: 0.25 * CPU_CORE_COST_HR * c.cores as f64,
            // Reuse's whole point: zero *marginal* embodied carbon.
            emb_kg_per_hr: 0.0,
            is_cpu: true,
        });
    }
    out
}

/// Max SLO-feasible throughput (requests/s per device) and the operating
/// latency, for a slice-phase on a device. None if infeasible.
pub fn max_tput(opt: &DeviceOption, s: &Slice, phase: Phase) -> Option<(f64, f64)> {
    max_tput_scaled(opt, s, phase, 1.0)
}

/// As [`max_tput`] with an SLO-tightening factor (`slo_scale` < 1 forces
/// lower-latency, smaller-batch operating points).
pub fn max_tput_scaled(opt: &DeviceOption, s: &Slice, phase: Phase,
                       slo_scale: f64) -> Option<(f64, f64)> {
    let m = s.model;
    let tp = tp_for(m, opt);
    if opt.is_cpu {
        // CPU only does offline decode (paper: prefill stays on GPU).
        if phase == Phase::Prompt || !s.offline {
            return None;
        }
        let batch = cpuperf::max_batch(m, 512.0, s.prompt + s.output).min(512).max(1);
        let step = cpuperf::decode_step_time(m, hw::cpu("SPR-112").unwrap(),
                                             batch, s.prompt + s.output,
                                             CpuStrategy::Optimized);
        let req_rate = batch as f64 / (step * s.output as f64);
        return Some((req_rate, step));
    }
    if m.max_batch(opt.dev.mem_gb, s.prompt + s.output, tp) == 0 {
        return None;
    }
    let mut best: Option<(f64, f64)> = None;
    let max_b = m.max_batch(opt.dev.mem_gb, s.prompt + s.output, tp).min(256);
    let mut b = 1usize;
    while b <= max_b {
        let (lat, rate) = match phase {
            Phase::Prompt => {
                let p = roofline::prefill_perf(m, &opt.dev, b, s.prompt, tp);
                // Queueing headroom: operate at 80% of saturation.
                (p.latency_s, 0.8 * b as f64 / p.latency_s)
            }
            Phase::Decode => {
                let p = roofline::decode_step_perf(m, &opt.dev, b,
                                                   s.prompt + s.output / 2, tp);
                (p.latency_s, 0.8 * b as f64 / (p.latency_s * s.output as f64))
            }
        };
        let slo_ok = match phase {
            Phase::Prompt => lat <= slo_scale * s.slo.ttft_s,
            Phase::Decode => lat <= slo_scale * s.slo.tpot_s || s.offline,
        };
        if slo_ok && best.map(|(r, _)| rate > r).unwrap_or(true) {
            best = Some((rate, lat));
        }
        b *= 2;
    }
    // Normalize per single device (tp devices act as one unit).
    best.map(|(r, l)| (r / tp as f64, l))
}

/// Latency-optimal (batch-1) operating point: (latency, requests/s per
/// device). Used for best-effort columns when no batch meets the SLO.
pub fn latency_point(opt: &DeviceOption, s: &Slice, phase: Phase)
    -> Option<(f64, f64)> {
    let m = s.model;
    let tp = tp_for(m, opt);
    if m.max_batch(opt.dev.mem_gb, s.prompt + s.output, tp) == 0 && !opt.is_cpu {
        return None;
    }
    let (lat, rate) = match phase {
        Phase::Prompt => {
            let p = roofline::prefill_perf(m, &opt.dev, 1, s.prompt, tp);
            (p.latency_s, 0.8 / p.latency_s)
        }
        Phase::Decode => {
            let p = roofline::decode_step_perf(m, &opt.dev, 1,
                                               s.prompt + s.output / 2, tp);
            (p.latency_s, 0.8 / (p.latency_s * s.output as f64))
        }
    };
    Some((lat, rate / tp as f64))
}

/// Tensor-parallel degree needed to fit the model (Table 2's minimum).
pub fn tp_for(m: &LlmSpec, opt: &DeviceOption) -> usize {
    if opt.is_cpu {
        return 1;
    }
    let mut tp = 1usize;
    while tp <= 8 {
        // Must leave KV room under the 0.5 capacity reserve (models::
        // max_batch), not merely fit the weights.
        if m.weight_gb() < 0.45 * opt.dev.mem_gb * tp as f64 {
            return tp;
        }
        tp *= 2;
    }
    8
}

/// Operating power attributed to serving on a device at the planning
/// utilization ([`PLANNING_UTIL`]). For reuse-CPU hosts only dynamic power
/// is marginal — the host idles for its GPUs regardless (paper §4.1.1's
/// "free lunch" accounting). Priced on the same nonlinear curve the
/// simulator's meter integrates.
pub fn marginal_power(opt: &DeviceOption) -> f64 {
    let p = crate::carbon::device_power(
        opt.dev.idle_w, opt.dev.tdp_w, PLANNING_UTIL, opt.dev.power_gamma);
    if opt.is_cpu { p - opt.dev.idle_w } else { p }
}

/// Dynamic (above-idle) share of [`marginal_power`] — what busy (A)
/// columns charge. Idle power is charged once, on the provisioned fleet
/// (B) columns, via [`idle_power`]; this split is what keeps CPU reuse's
/// marginal accounting and the GPU columns on one formula.
fn busy_dynamic_power(opt: &DeviceOption) -> f64 {
    dynamic_power(opt.dev.idle_w, opt.dev.tdp_w, PLANNING_UTIL,
                  opt.dev.power_gamma)
}

/// Idle operational carbon (kg per device-hour) of one provisioned GPU.
/// `B_j` counts *individual GPUs* (capacity rows scale loads by `tp`), so
/// the per-unit idle floor is `idle_power(idle_w, 1)`; the simulator
/// charges the same watts as `idle_power(idle_w, tp)` per tp-group server,
/// which agrees whenever the GPU count divides evenly into servers (the
/// `div_ceil` remainder in fleet materialization is the only slack — see
/// the planner-vs-sim parity test).
pub(crate) fn idle_op_kg_per_hr(opt: &DeviceOption, ci: f64) -> f64 {
    op_kg_per_hr(idle_power(opt.dev.idle_w, 1), ci)
}

/// A previous solve to warm-start from: the plan plus the exact inputs it
/// was solved against. [`plan_warm`] reuses the plan only on a *bitwise*
/// input match, so warm starts can never perturb the branch-and-bound
/// search (a tighter incumbent cutoff would change which nodes consume a
/// truncated node budget, and with it the returned plan). The caller must
/// hold every `PlanConfig` field other than `ci` fixed between epochs —
/// `ci` is the one knob the rolling horizon varies, so it is captured
/// here.
#[derive(Debug, Clone)]
pub struct WarmStart {
    pub slices: Vec<Slice>,
    pub ci: f64,
    pub plan: Plan,
}

impl WarmStart {
    pub fn new(slices: &[Slice], cfg: &PlanConfig, plan: Plan) -> WarmStart {
        WarmStart { slices: slices.to_vec(), ci: cfg.ci, plan }
    }

    /// Bitwise input match: same slice sequence (rates compared on bits,
    /// not epsilon) and the same planning carbon intensity.
    pub fn matches(&self, slices: &[Slice], cfg: &PlanConfig) -> bool {
        self.ci.to_bits() == cfg.ci.to_bits()
            && self.slices.len() == slices.len()
            && self.slices.iter().zip(slices).all(|(a, b)| {
                a.model.name == b.model.name
                    && a.rate.to_bits() == b.rate.to_bits()
                    && a.prompt == b.prompt
                    && a.output == b.output
                    && a.offline == b.offline
                    && a.slo.ttft_s.to_bits() == b.slo.ttft_s.to_bits()
                    && a.slo.tpot_s.to_bits() == b.slo.tpot_s.to_bits()
            })
    }
}

/// [`plan`] with cross-solve memoization: when `warm` carries a plan
/// solved for bitwise-identical inputs, return it without re-running the
/// MILP ([`plan`] is a pure function of `(slices, cfg)` apart from the
/// wall-clock `solve_s`, which a memoized return reports as `0.0` — no
/// solve happened). Anything short of an exact match falls through to a
/// full cold solve, so the output is always byte-identical to [`plan`].
pub fn plan_warm(slices: &[Slice], cfg: &PlanConfig,
                 warm: Option<&WarmStart>) -> Plan {
    if let Some(w) = warm {
        if w.matches(slices, cfg) {
            let mut p = w.plan.clone();
            p.solve_s = 0.0;
            p.nodes = 0;
            return p;
        }
    }
    plan(slices, cfg)
}

/// Solve the allocation ILP for a set of slices.
pub fn plan(slices: &[Slice], cfg: &PlanConfig) -> Plan {
    assert!(!slices.is_empty(), "no slices");
    let model = slices[0].model;
    let opts = device_options(cfg, model);
    let t0 = std::time::Instant::now();

    // Feasible (slice, phase, device) triples with their loads/latencies.
    struct Col {
        s: usize,
        phase: Phase,
        d: usize,
        load_per_rate: f64,
        latency: f64,
    }
    let mut cols = Vec::new();
    for (si, s) in slices.iter().enumerate() {
        for phase in [Phase::Prompt, Phase::Decode] {
            let before = cols.len();
            for (di, opt) in opts.iter().enumerate() {
                if let Some((tput, lat)) = max_tput_scaled(opt, s, phase, cfg.slo_scale) {
                    cols.push(Col {
                        s: si,
                        phase,
                        d: di,
                        load_per_rate: 1.0 / tput,
                        latency: lat,
                    });
                }
            }
            if cols.len() == before && cfg.slo_scale < 1.0 {
                // The tightened operating target is infeasible; fall back
                // to the true SLO before going best-effort.
                for (di, opt) in opts.iter().enumerate() {
                    if let Some((tput, lat)) = max_tput_scaled(opt, s, phase, 1.0) {
                        cols.push(Col {
                            s: si, phase, d: di,
                            load_per_rate: 1.0 / tput,
                            latency: lat,
                        });
                    }
                }
            }
            if cols.len() == before {
                // No device meets the SLO at all (e.g. a very long prompt
                // under a tight TTFT): serve best-effort at the *latency-
                // optimal* point (batch 1) on the fastest device — an SLO
                // miss must not become a throughput-optimal freebie.
                let mut best: Option<(f64, f64, usize)> = None;
                for (di, opt) in opts.iter().enumerate() {
                    if opt.is_cpu && (phase == Phase::Prompt || !s.offline) {
                        continue;
                    }
                    if let Some((lat, tput)) = latency_point(opt, s, phase) {
                        if best.map(|(l, _, _)| lat < l).unwrap_or(true) {
                            best = Some((lat, tput, di));
                        }
                    }
                }
                if let Some((lat, tput, di)) = best {
                    cols.push(Col {
                        s: si,
                        phase,
                        d: di,
                        load_per_rate: 1.0 / tput,
                        latency: lat,
                    });
                }
            }
        }
    }

    let mut pb = ProblemBuilder::new();
    // B_j: provisioned device counts. Provisioning carries the hourly
    // cloud cost, the full embodied amortization, and idle power — this is
    // what CPU reuse displaces (capacity, not just busy energy).
    let b_vars: Vec<Var> = opts.iter()
        .map(|o| {
            let idle_op = idle_op_kg_per_hr(o, cfg.ci);
            let obj = (1.0 - cfg.alpha) * o.cost_hr
                + cfg.alpha * (o.emb_kg_per_hr + idle_op);
            pb.var(&format!("B_{}", o.name), obj, true)
        })
        .collect();
    // A variables per column.
    let mut a_vars = Vec::with_capacity(cols.len());
    for c in &cols {
        let s = &slices[c.s];
        let opt = &opts[c.d];
        let load = s.rate * c.load_per_rate;
        // Busy columns carry *dynamic* operational carbon only; idle
        // power and embodied are charged on the provisioned fleet (B).
        let op_rate = op_kg_per_hr(busy_dynamic_power(opt), cfg.ci);
        let carbon = load * op_rate * tp_for(s.model, opt) as f64;
        // CPU reuse pays a small marginal core-hour cost; GPUs are costed
        // on provisioning (B).
        let cost = if opt.is_cpu { load * opt.cost_hr } else { 0.0 };
        let obj = (1.0 - cfg.alpha) * cost + cfg.alpha * carbon;
        let name = format!("A_{}_{:?}_{}", c.s, c.phase, opts[c.d].name);
        let v = if cfg.integral_assignment {
            pb.binary(&name, obj)
        } else {
            pb.var_bounded(&name, obj, false, 1.0)
        };
        a_vars.push(v);
    }

    // Each (slice, phase) assigned exactly once. A slice no device can
    // hold at all (e.g. MHA KV of an extreme context exceeding every
    // card's capacity) is *shed* — real clusters reject such requests at
    // admission; the plan records how many were dropped.
    let mut shed = 0usize;
    for (si, _) in slices.iter().enumerate() {
        for phase in [Phase::Prompt, Phase::Decode] {
            let terms: Vec<(Var, f64)> = cols.iter().zip(&a_vars)
                .filter(|(c, _)| c.s == si && c.phase == phase)
                .map(|(_, v)| (*v, 1.0))
                .collect();
            if terms.is_empty() {
                shed += 1;
                continue;
            }
            pb.eq(&terms, 1.0);
        }
    }

    // Capacity: Σ_cols(load on j) ≤ B_j (GPUs); CPU capacity ties to fleet:
    // Σ cpu load ≤ (Σ_j B_j) / GPUS_PER_HOST.
    for (di, opt) in opts.iter().enumerate() {
        let mut terms: Vec<(Var, f64)> = cols.iter().zip(&a_vars)
            .filter(|(c, _)| c.d == di)
            .map(|(c, v)| {
                let s = &slices[c.s];
                (*v, s.rate * c.load_per_rate * tp_for(s.model, opt) as f64)
            })
            .collect();
        if opt.is_cpu {
            for (j, o2) in opts.iter().enumerate() {
                if !o2.is_cpu {
                    terms.push((b_vars[j], -HOST_SOCKETS_PER_GPU));
                }
            }
            pb.le(&terms, 0.0);
        } else {
            terms.push((b_vars[di], -1.0));
            pb.le(&terms, 0.0);
        }
    }

    // Melange-style phase coupling: both phases of a slice on one type.
    if cfg.couple_phases {
        for (si, _) in slices.iter().enumerate() {
            for (di, _) in opts.iter().enumerate() {
                let p = cols.iter().position(|c|
                    c.s == si && c.phase == Phase::Prompt && c.d == di);
                let d = cols.iter().position(|c|
                    c.s == si && c.phase == Phase::Decode && c.d == di);
                match (p, d) {
                    (Some(pi), Some(dj)) => {
                        pb.eq(&[(a_vars[pi], 1.0), (a_vars[dj], -1.0)], 0.0);
                    }
                    // A type feasible for only one phase can't be coupled.
                    (Some(pi), None) => pb.eq(&[(a_vars[pi], 1.0)], 0.0),
                    (None, Some(dj)) => pb.eq(&[(a_vars[dj], 1.0)], 0.0),
                    (None, None) => {}
                }
            }
        }
    }

    // Greedy warm-start incumbent: per (slice, phase), the device with the
    // lowest amortized objective; B = ceil of accumulated load. Used both
    // as a branch-and-bound cutoff and as a fallback when search truncates.
    let b_objs: Vec<f64> = opts.iter().map(|o| {
        let idle_op = idle_op_kg_per_hr(o, cfg.ci);
        (1.0 - cfg.alpha) * o.cost_hr + cfg.alpha * (o.emb_kg_per_hr + idle_op)
    }).collect();
    let col_obj = |c: &Col| -> f64 {
        let s = &slices[c.s];
        let opt = &opts[c.d];
        let load = s.rate * c.load_per_rate;
        let carbon = load * op_kg_per_hr(busy_dynamic_power(opt), cfg.ci)
            * tp_for(s.model, opt) as f64;
        let cost = if opt.is_cpu { load * opt.cost_hr } else { 0.0 };
        (1.0 - cfg.alpha) * cost + cfg.alpha * carbon
    };
    let greedy: Vec<usize> = {
        let mut chosen = Vec::new();
        for (si, s) in slices.iter().enumerate() {
            for phase in [Phase::Prompt, Phase::Decode] {
                let mut best: Option<(f64, usize)> = None;
                for (ci, c) in cols.iter().enumerate() {
                    if c.s != si || c.phase != phase {
                        continue;
                    }
                    if cfg.couple_phases && opts[c.d].is_cpu {
                        continue; // CPU can't host both phases
                    }
                    let opt = &opts[c.d];
                    let load = s.rate * c.load_per_rate * tp_for(s.model, opt) as f64;
                    // Amortize provisioning into the greedy metric; CPU
                    // columns consume host share instead of new devices.
                    let prov = if opt.is_cpu { 0.0 } else { load * b_objs[c.d] };
                    let score = col_obj(c) + prov;
                    if best.map(|(b, _)| score < b).unwrap_or(true) {
                        best = Some((score, ci));
                    }
                }
                if let Some((_, ci)) = best {
                    chosen.push(ci);
                }
            }
        }
        chosen
    };
    // Greedy fleet + objective (respect CPU-capacity by bumping the
    // cheapest GPU count if reuse over-consumes host sockets).
    let (greedy_obj, greedy_b) = {
        let mut b = vec![0.0f64; opts.len()];
        let mut cpu_load = 0.0;
        let mut obj = 0.0;
        for &ci in &greedy {
            let c = &cols[ci];
            let s = &slices[c.s];
            let opt = &opts[c.d];
            let load = s.rate * c.load_per_rate * tp_for(s.model, opt) as f64;
            if opt.is_cpu {
                cpu_load += load;
            } else {
                b[c.d] += load;
            }
            obj += col_obj(c);
        }
        let mut b: Vec<f64> = b.iter().map(|x| x.ceil()).collect();
        let gpu_total: f64 = opts.iter().zip(&b)
            .filter(|(o, _)| !o.is_cpu)
            .map(|(_, x)| *x)
            .sum();
        if cpu_load > HOST_SOCKETS_PER_GPU * gpu_total {
            // Need more hosts: add the cheapest-provisioning GPU type.
            let need = ((cpu_load / HOST_SOCKETS_PER_GPU) - gpu_total).ceil();
            if let Some((j, _)) = opts.iter().enumerate()
                .filter(|(_, o)| !o.is_cpu)
                .min_by(|(a, _), (b2, _)| b_objs[*a].partial_cmp(&b_objs[*b2]).unwrap()) {
                b[j] += need;
            }
        }
        for (j, o) in opts.iter().enumerate() {
            if !o.is_cpu {
                obj += b[j] * b_objs[j];
            }
        }
        (obj, b)
    };

    let milp_cfg = MilpConfig {
        cutoff: Some(greedy_obj * (1.0 + 1e-6) + 1e-9),
        ..cfg.milp.clone()
    };
    // Very large instances skip branch-and-bound (a single dense-tableau
    // LP node would already blow the control-plane budget) and take the
    // greedy incumbent — this is the pruning that keeps Table 3's scaling
    // sub-linear.
    let mut sol = if pb.num_vars() <= 320 {
        pb.solve(&milp_cfg)
    } else {
        crate::solver::MilpSolution {
            status: MilpStatus::Unknown,
            x: vec![0.0; pb.num_vars()],
            objective: f64::NAN,
            nodes: 0,
        }
    };
    // Fall back to / prefer the greedy incumbent when search truncated or
    // found nothing better.
    let use_greedy = !matches!(sol.status, MilpStatus::Optimal | MilpStatus::Feasible)
        || !sol.objective.is_finite()
        || sol.objective > greedy_obj + 1e-9;
    if use_greedy {
        let mut x = vec![0.0; pb.num_vars()];
        for &ci in &greedy {
            x[a_vars[ci].0] = 1.0;
        }
        for (j, bv) in b_vars.iter().enumerate() {
            x[bv.0] = greedy_b[j];
        }
        sol = crate::solver::MilpSolution {
            status: MilpStatus::Feasible,
            x,
            objective: greedy_obj,
            nodes: sol.nodes,
        };
    }
    let solve_s = t0.elapsed().as_secs_f64();

    // Extract.
    let mut counts = BTreeMap::new();
    for (di, opt) in opts.iter().enumerate() {
        let v = sol.x.get(b_vars[di].0).copied().unwrap_or(0.0).round() as usize;
        if v > 0 {
            counts.insert(opt.name.clone(), v);
        }
    }
    let mut assignments = Vec::new();
    let mut op_kg = 0.0;
    let mut emb_kg = 0.0;
    let mut cost = 0.0;
    for (c, v) in cols.iter().zip(&a_vars) {
        let x = sol.x.get(v.0).copied().unwrap_or(0.0);
        if x > 0.01 {
            let s = &slices[c.s];
            let opt = &opts[c.d];
            let tp = tp_for(s.model, opt) as f64;
            let load = x * s.rate * c.load_per_rate * tp;
            op_kg += load * op_kg_per_hr(busy_dynamic_power(opt), cfg.ci);
            if opt.is_cpu {
                cost += load * opt.cost_hr;
            }
            assignments.push(Assignment {
                slice_idx: c.s,
                phase: c.phase,
                device: opt.name.clone(),
                load,
                latency_s: c.latency,
            });
        }
    }
    // Provisioned fleet: embodied + idle power + cloud cost.
    for (di, opt) in opts.iter().enumerate() {
        if opt.is_cpu {
            continue;
        }
        let b = sol.x.get(b_vars[di].0).copied().unwrap_or(0.0);
        op_kg += b * idle_op_kg_per_hr(opt, cfg.ci);
        emb_kg += b * opt.emb_kg_per_hr;
        cost += b * opt.cost_hr;
    }

    Plan {
        counts,
        shed,
        assignments,
        cost_hr: cost,
        op_kg_per_hr: op_kg,
        emb_kg_per_hr: emb_kg,
        solve_s,
        nodes: sol.nodes,
        status: sol.status,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::workload::slo::Slo;

    fn mk_slices(model: &'static LlmSpec, rate: f64, offline: bool) -> Vec<Slice> {
        vec![
            Slice { model, rate, prompt: 256, output: 128,
                    slo: Slo { ttft_s: 1.0, tpot_s: 0.15 }, offline },
            Slice { model, rate: rate / 2.0, prompt: 2048, output: 256,
                    slo: Slo { ttft_s: 5.0, tpot_s: 0.2 }, offline },
        ]
    }

    #[test]
    fn plan_solves_and_provisions() {
        let m = models::llm("llama-8b").unwrap();
        let plan = plan(&mk_slices(m, 4.0, false), &PlanConfig::default());
        assert!(matches!(plan.status, MilpStatus::Optimal | MilpStatus::Feasible),
                "{:?}", plan.status);
        assert!(plan.total_gpus() > 0);
        assert!(plan.carbon_kg_per_hr() > 0.0);
        // Every slice-phase got exactly one device.
        assert_eq!(plan.assignments.len(), 4);
    }

    #[test]
    fn ecoserve_beats_perf_opt_on_carbon() {
        let m = models::llm("llama-8b").unwrap();
        let slices = mk_slices(m, 4.0, false);
        let eco = plan(&slices, &PlanConfig::default());
        let perf = plan(&slices, &PlanConfig::perf_opt());
        assert!(eco.carbon_kg_per_hr() < perf.carbon_kg_per_hr(),
                "eco {} vs perf {}", eco.carbon_kg_per_hr(), perf.carbon_kg_per_hr());
    }

    #[test]
    fn cpu_reuse_engaged_for_long_context_offline() {
        // The paper routes *long-context* offline decode to host CPUs: GPU
        // batch capacity collapses with context while DRAM-backed CPU
        // decode holds large batches (Fig 8 / §6.3).
        let m = models::llm("llama-8b").unwrap();
        let slices = vec![
            Slice { model: m, rate: 1.0, prompt: 8192, output: 256,
                    slo: Slo { ttft_s: 86_400.0, tpot_s: f64::INFINITY },
                    offline: true },
            Slice { model: m, rate: 2.0, prompt: 256, output: 128,
                    slo: Slo { ttft_s: 1.0, tpot_s: 0.15 }, offline: false },
        ];
        // Reuse pays off where embodied dominates: low-CI region (Fig 16).
        let cfg = PlanConfig { ci: 17.0, ..Default::default() };
        let p = plan(&slices, &cfg);
        let cpu_decode = p.assignments.iter().any(|a| {
            a.device == "cpu-host" && a.phase == Phase::Decode && a.slice_idx == 0
        });
        assert!(cpu_decode, "long offline decode should reuse host CPUs: {:?}",
                p.assignments);
    }

    #[test]
    fn no_cpu_for_online_decode() {
        let m = models::llm("llama-8b").unwrap();
        let slices = mk_slices(m, 2.0, false);
        let p = plan(&slices, &PlanConfig::default());
        assert!(p.assignments.iter().all(|a| a.device != "cpu-host"));
    }

    #[test]
    fn tp_sized_to_model() {
        let cfg = PlanConfig::default();
        let big = models::llm("llama-70b").unwrap();
        let small = models::llm("llama-8b").unwrap();
        let opts = device_options(&cfg, big);
        let a100 = opts.iter().find(|o| o.name == "A100-40").unwrap();
        assert!(tp_for(big, a100) >= 4);
        assert_eq!(tp_for(small, a100), 1);
    }

    #[test]
    fn planner_prices_the_shared_power_curve() {
        let m = models::llm("llama-8b").unwrap();
        let opts = device_options(&PlanConfig::default(), m);
        let g = opts.iter().find(|o| !o.is_cpu).unwrap();
        // GPU marginal power = idle floor + shared dynamic term; the CPU
        // pseudo-device charges only the dynamic term (reuse accounting).
        let d = dynamic_power(g.dev.idle_w, g.dev.tdp_w, PLANNING_UTIL,
                              g.dev.power_gamma);
        assert!((marginal_power(g) - (g.dev.idle_w + d)).abs() < 1e-9);
        let c = opts.iter().find(|o| o.is_cpu).unwrap();
        let dc = dynamic_power(c.dev.idle_w, c.dev.tdp_w, PLANNING_UTIL,
                               c.dev.power_gamma);
        assert!((marginal_power(c) - dc).abs() < 1e-9);
        // The idle objective column is the shared helper in planner units.
        assert!((idle_op_kg_per_hr(g, 261.0)
                     - op_kg_per_hr(idle_power(g.dev.idle_w, 1), 261.0))
                    .abs() < 1e-15);
    }

    #[test]
    fn reduce_and_recycle_lower_embodied_rate() {
        let m = models::llm("llama-8b").unwrap();
        let lean = device_options(&PlanConfig::default(), m);
        let fat = device_options(&PlanConfig {
            reduce_host: false,
            host_lifetime_y: 4.0,
            gpu_lifetime_y: 4.0,
            ..Default::default()
        }, m);
        let l = lean.iter().find(|o| o.name == "A100-40").unwrap();
        let f = fat.iter().find(|o| o.name == "A100-40").unwrap();
        assert!(l.emb_kg_per_hr < f.emb_kg_per_hr,
                "lean {} vs fat {}", l.emb_kg_per_hr, f.emb_kg_per_hr);
    }
}
