//! Property tests for the LP/MILP substrate: optimality vs brute force,
//! feasibility of returned solutions, relaxation bounds.

use ecoserve::solver::lp::{self, Cmp, LpStatus, Row};
use ecoserve::solver::{milp, MilpConfig, MilpStatus};
use ecoserve::testkit::{forall, PropConfig};
use ecoserve::util::rng::Rng;

#[derive(Debug, Clone)]
struct Knapsack {
    values: Vec<f64>,
    weights: Vec<f64>,
    cap: f64,
}

fn gen_knapsack(r: &mut Rng) -> Knapsack {
    let n = 2 + r.below(7);
    Knapsack {
        values: (0..n).map(|_| (1.0 + r.f64() * 9.0).round()).collect(),
        weights: (0..n).map(|_| (1.0 + r.f64() * 9.0).round()).collect(),
        cap: (5.0 + r.f64() * 20.0).round(),
    }
}

fn brute_force(k: &Knapsack) -> f64 {
    let n = k.values.len();
    let mut best = 0.0f64;
    for mask in 0..(1usize << n) {
        let (mut v, mut w) = (0.0, 0.0);
        for i in 0..n {
            if mask & (1 << i) != 0 {
                v += k.values[i];
                w += k.weights[i];
            }
        }
        if w <= k.cap + 1e-9 {
            best = best.max(v);
        }
    }
    best
}

#[test]
fn milp_matches_brute_force_knapsack() {
    forall(
        &PropConfig { cases: 60, ..Default::default() },
        gen_knapsack,
        |k| {
            let mut out = Vec::new();
            if k.values.len() > 2 {
                let mut s = k.clone();
                s.values.pop();
                s.weights.pop();
                out.push(s);
            }
            out
        },
        |k| {
            let n = k.values.len();
            let c: Vec<f64> = k.values.iter().map(|v| -v).collect();
            let mut rows = vec![Row {
                coeffs: k.weights.iter().cloned().enumerate().collect(),
                cmp: Cmp::Le,
                rhs: k.cap,
            }];
            for j in 0..n {
                rows.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
            }
            let sol = milp::solve(n, &c, &rows, &vec![true; n], &MilpConfig::default());
            let expect = brute_force(k);
            if sol.status != MilpStatus::Optimal {
                return Err(format!("status {:?}", sol.status));
            }
            if (-sol.objective - expect).abs() > 1e-6 {
                return Err(format!("milp {} vs brute {expect}", -sol.objective));
            }
            Ok(())
        },
    );
}

#[derive(Debug, Clone)]
struct RandomLp {
    n: usize,
    c: Vec<f64>,
    rows: Vec<Row>,
}

fn gen_lp(r: &mut Rng) -> RandomLp {
    let n = 2 + r.below(5);
    let m = 1 + r.below(5);
    let c: Vec<f64> = (0..n).map(|_| r.range(0.1, 5.0)).collect();
    // Feasible by construction: a·x <= b with b >= 0 and a >= 0, plus a
    // couple of >= floors that are mutually satisfiable.
    let mut rows: Vec<Row> = (0..m)
        .map(|_| Row {
            coeffs: (0..n).map(|j| (j, r.range(0.0, 3.0))).collect(),
            cmp: Cmp::Le,
            rhs: r.range(1.0, 20.0),
        })
        .collect();
    rows.push(Row { coeffs: vec![(0, 1.0)], cmp: Cmp::Ge, rhs: 0.1 });
    RandomLp { n, c, rows }
}

#[test]
fn lp_solutions_are_feasible() {
    forall(
        &PropConfig { cases: 80, ..Default::default() },
        gen_lp,
        |_| Vec::new(),
        |p| {
            let sol = lp::solve(p.n, &p.c, &p.rows);
            if sol.status == LpStatus::Infeasible {
                // Floor of 0.1 on x0 can conflict with a tight <= row; fine.
                return Ok(());
            }
            if sol.status != LpStatus::Optimal {
                return Err(format!("status {:?}", sol.status));
            }
            for (i, row) in p.rows.iter().enumerate() {
                let lhs: f64 = row.coeffs.iter().map(|(j, a)| a * sol.x[*j]).sum();
                let ok = match row.cmp {
                    Cmp::Le => lhs <= row.rhs + 1e-6,
                    Cmp::Ge => lhs >= row.rhs - 1e-6,
                    Cmp::Eq => (lhs - row.rhs).abs() <= 1e-6,
                };
                if !ok {
                    return Err(format!("row {i} violated: {lhs} vs {}", row.rhs));
                }
            }
            if sol.x.iter().any(|&x| x < -1e-9) {
                return Err("negative variable".into());
            }
            Ok(())
        },
    );
}

/// Randomized capacitated-allocation instance mirroring the planner's ILP
/// shape: binary assignment a[s][d] of slices to devices, integer device
/// counts b[d], capacity rows, and a provisioning + assignment objective.
#[derive(Debug, Clone)]
struct AllocInstance {
    /// load[s][d]: device-fraction slice s consumes on device d.
    load: Vec<Vec<f64>>,
    /// cap[d]: capacity of one device of type d.
    cap: Vec<f64>,
    /// dev_cost[d]: objective per provisioned device.
    dev_cost: Vec<f64>,
    /// assign_cost[s][d]: objective per assignment.
    assign_cost: Vec<Vec<f64>>,
}

fn gen_alloc(r: &mut Rng) -> AllocInstance {
    let s = 1 + r.below(4);
    let d = 1 + r.below(3);
    AllocInstance {
        load: (0..s).map(|_| (0..d).map(|_| r.range(0.1, 1.5)).collect()).collect(),
        cap: (0..d).map(|_| r.range(1.0, 4.0)).collect(),
        dev_cost: (0..d).map(|_| (1.0 + r.f64() * 9.0).round()).collect(),
        assign_cost: (0..s).map(|_| (0..d).map(|_| r.range(0.0, 1.0)).collect()).collect(),
    }
}

/// Build the MILP rows for an instance. Variable layout: b[0..D) integer,
/// then a[s*D + d] binary.
fn alloc_rows(k: &AllocInstance) -> (usize, Vec<f64>, Vec<Row>, Vec<bool>) {
    let (ns, nd) = (k.load.len(), k.cap.len());
    let ncols = nd + ns * nd;
    let a_idx = |s: usize, d: usize| nd + s * nd + d;
    let mut c = k.dev_cost.clone();
    for s in 0..ns {
        for d in 0..nd {
            c.push(k.assign_cost[s][d]);
        }
    }
    let mut rows = Vec::new();
    // Each slice assigned exactly once.
    for s in 0..ns {
        rows.push(Row {
            coeffs: (0..nd).map(|d| (a_idx(s, d), 1.0)).collect(),
            cmp: Cmp::Eq,
            rhs: 1.0,
        });
    }
    // Capacity: sum_s load[s][d]·a[s][d] <= cap[d]·b[d].
    for d in 0..nd {
        let mut coeffs: Vec<(usize, f64)> =
            (0..ns).map(|s| (a_idx(s, d), k.load[s][d])).collect();
        coeffs.push((d, -k.cap[d]));
        rows.push(Row { coeffs, cmp: Cmp::Le, rhs: 0.0 });
    }
    // Binary bounds on the assignment variables.
    for s in 0..ns {
        for d in 0..nd {
            rows.push(Row { coeffs: vec![(a_idx(s, d), 1.0)], cmp: Cmp::Le, rhs: 1.0 });
        }
    }
    let integer = vec![true; ncols];
    (ncols, c, rows, integer)
}

/// Greedy baseline mirroring the planner's warm start: each slice takes
/// the device minimizing assignment cost + amortized provisioning, then
/// counts are the ceil of accumulated load.
fn greedy_alloc_objective(k: &AllocInstance) -> f64 {
    let (ns, nd) = (k.load.len(), k.cap.len());
    let mut load_on = vec![0.0f64; nd];
    let mut obj = 0.0;
    for s in 0..ns {
        let mut best = (f64::INFINITY, 0usize);
        for d in 0..nd {
            let score = k.assign_cost[s][d]
                + k.load[s][d] / k.cap[d] * k.dev_cost[d];
            if score < best.0 {
                best = (score, d);
            }
        }
        let d = best.1;
        load_on[d] += k.load[s][d];
        obj += k.assign_cost[s][d];
    }
    for d in 0..nd {
        obj += (load_on[d] / k.cap[d]).ceil() * k.dev_cost[d];
    }
    obj
}

#[test]
fn milp_allocations_feasible_and_never_worse_than_greedy() {
    forall(
        &PropConfig { cases: 50, ..Default::default() },
        gen_alloc,
        |k| {
            let mut out = Vec::new();
            if k.load.len() > 1 {
                let mut s = k.clone();
                s.load.pop();
                s.assign_cost.pop();
                out.push(s);
            }
            out
        },
        |k| {
            let (ncols, c, rows, integer) = alloc_rows(k);
            // Generous node budget: these instances are tiny (≤ 15 vars),
            // so search must terminate optimally, never truncated.
            let cfg = MilpConfig { max_nodes: 100_000, ..Default::default() };
            let sol = milp::solve(ncols, &c, &rows, &integer, &cfg);
            if sol.status != MilpStatus::Optimal {
                return Err(format!("status {:?}", sol.status));
            }
            let (ns, nd) = (k.load.len(), k.cap.len());
            let a_idx = |s: usize, d: usize| nd + s * nd + d;
            // Integrality and variable domains.
            for (j, &x) in sol.x.iter().enumerate() {
                if x < -1e-6 {
                    return Err(format!("negative var {j}: {x}"));
                }
                if (x - x.round()).abs() > 1e-6 {
                    return Err(format!("fractional integer var {j}: {x}"));
                }
            }
            // Every slice assigned exactly once.
            for s in 0..ns {
                let total: f64 = (0..nd).map(|d| sol.x[a_idx(s, d)]).sum();
                if (total - 1.0).abs() > 1e-6 {
                    return Err(format!("slice {s} assigned {total} times"));
                }
            }
            // Returned allocation is feasible w.r.t. every capacity row.
            for d in 0..nd {
                let used: f64 = (0..ns)
                    .map(|s| k.load[s][d] * sol.x[a_idx(s, d)])
                    .sum();
                let avail = k.cap[d] * sol.x[d];
                if used > avail + 1e-6 {
                    return Err(format!(
                        "capacity violated on device {d}: {used} > {avail}"));
                }
            }
            // The MILP objective is never worse than the greedy baseline.
            let greedy = greedy_alloc_objective(k);
            if sol.objective > greedy + 1e-6 {
                return Err(format!("milp {} worse than greedy {greedy}",
                                   sol.objective));
            }
            Ok(())
        },
    );
}

#[test]
fn relaxation_bounds_milp() {
    forall(
        &PropConfig { cases: 40, ..Default::default() },
        gen_knapsack,
        |_| Vec::new(),
        |k| {
            let n = k.values.len();
            let c: Vec<f64> = k.values.iter().map(|v| -v).collect();
            let mut rows = vec![Row {
                coeffs: k.weights.iter().cloned().enumerate().collect(),
                cmp: Cmp::Le,
                rhs: k.cap,
            }];
            for j in 0..n {
                rows.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
            }
            let rel = lp::solve(n, &c, &rows);
            let int = milp::solve(n, &c, &rows, &vec![true; n], &MilpConfig::default());
            if rel.status != LpStatus::Optimal || int.status != MilpStatus::Optimal {
                return Err("unexpected status".into());
            }
            if rel.objective > int.objective + 1e-6 {
                return Err(format!("relaxation {} worse than MILP {}",
                                   rel.objective, int.objective));
            }
            Ok(())
        },
    );
}
