//! Grid carbon intensity (CI): regional constants + diurnal traces.
//!
//! The paper samples WattTime / GreenSKU for regional CI; offline we encode
//! the regions it names with their published averages (gCO₂e/kWh): North
//! Central Sweden 17 (Low), California 261 (Mid), Midcontinent 501 (High),
//! plus the Fig 6 regions. Diurnal traces model solar-driven intra-day
//! swing for runtime carbon-aware scheduling studies.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Region {
    SwedenNorth,
    California,
    Midcontinent,
    UsEast,
    Europe,
    UsCentral,
    HyperscaleRenewable,
}

impl Region {
    pub fn name(&self) -> &'static str {
        match self {
            Region::SwedenNorth => "SE-North (Low)",
            Region::California => "CAISO (Mid)",
            Region::Midcontinent => "MISO (High)",
            Region::UsEast => "US-East",
            Region::Europe => "EU-Central",
            Region::UsCentral => "US-Central/South",
            Region::HyperscaleRenewable => "Hyperscale-PPA",
        }
    }

    /// Average CI, gCO₂e/kWh.
    pub fn avg_ci(&self) -> f64 {
        match self {
            Region::SwedenNorth => 17.0,
            Region::California => 261.0,
            Region::Midcontinent => 501.0,
            Region::UsEast => 390.0,
            Region::Europe => 300.0,
            Region::UsCentral => 420.0,
            Region::HyperscaleRenewable => 50.0,
        }
    }

    /// Fraction of the day-night CI swing (solar share proxy).
    fn diurnal_swing(&self) -> f64 {
        match self {
            Region::SwedenNorth => 0.05,
            Region::California => 0.45, // duck curve
            Region::Midcontinent => 0.15,
            Region::UsEast => 0.20,
            Region::Europe => 0.30,
            Region::UsCentral => 0.20,
            Region::HyperscaleRenewable => 0.35,
        }
    }

    pub fn all() -> &'static [Region] {
        &[
            Region::SwedenNorth,
            Region::California,
            Region::Midcontinent,
            Region::UsEast,
            Region::Europe,
            Region::UsCentral,
            Region::HyperscaleRenewable,
        ]
    }

    /// The three-level setup from §6.2.1.
    pub fn low_mid_high() -> [Region; 3] {
        [Region::SwedenNorth, Region::California, Region::Midcontinent]
    }
}

/// A CI time series at fixed resolution.
#[derive(Debug, Clone)]
pub struct CiTrace {
    pub region: Region,
    pub step_s: f64,
    pub values: Vec<f64>,
}

impl CiTrace {
    /// Synthesize a diurnal trace: CI dips mid-day with solar, peaks in the
    /// evening ramp, plus small AR(1) noise. Values stay positive.
    pub fn diurnal(region: Region, days: usize, step_s: f64, seed: u64) -> CiTrace {
        let mut rng = Rng::new(seed ^ 0xC1);
        let n = ((days as f64 * 86_400.0) / step_s).ceil() as usize;
        let avg = region.avg_ci();
        let swing = region.diurnal_swing();
        let mut noise = 0.0f64;
        let values = (0..n)
            .map(|i| {
                let t = i as f64 * step_s;
                let hour = (t / 3600.0) % 24.0;
                // Solar dip centred at 13:00, evening peak at 19:00.
                let solar = (-((hour - 13.0) / 3.5).powi(2)).exp();
                let evening = (-((hour - 19.5) / 2.0).powi(2)).exp();
                noise = 0.9 * noise + 0.1 * rng.normal() * 0.05;
                let v = avg * (1.0 - swing * solar + 0.5 * swing * evening + noise);
                v.max(1.0)
            })
            .collect();
        CiTrace { region, step_s, values }
    }

    /// Flat trace at the regional average (for aggregate studies).
    pub fn flat(region: Region, days: usize, step_s: f64) -> CiTrace {
        let n = ((days as f64 * 86_400.0) / step_s).ceil() as usize;
        CiTrace { region, step_s, values: vec![region.avg_ci(); n] }
    }

    /// CI at time t (seconds), clamped to the trace extent.
    pub fn at(&self, t_s: f64) -> f64 {
        if self.values.is_empty() {
            return self.region.avg_ci();
        }
        let idx = ((t_s / self.step_s) as usize).min(self.values.len() - 1);
        self.values[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return self.region.avg_ci();
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        let [lo, mid, hi] = Region::low_mid_high();
        assert!(lo.avg_ci() < mid.avg_ci() && mid.avg_ci() < hi.avg_ci());
        assert_eq!(lo.avg_ci(), 17.0);
        assert_eq!(mid.avg_ci(), 261.0);
        assert_eq!(hi.avg_ci(), 501.0);
    }

    #[test]
    fn diurnal_mean_near_average() {
        let tr = CiTrace::diurnal(Region::California, 7, 900.0, 7);
        let rel = (tr.mean() - 261.0).abs() / 261.0;
        assert!(rel < 0.15, "mean {} off by {rel}", tr.mean());
    }

    #[test]
    fn diurnal_has_midday_dip() {
        let tr = CiTrace::diurnal(Region::California, 1, 900.0, 3);
        let noon = tr.at(13.0 * 3600.0);
        let night = tr.at(3.0 * 3600.0);
        assert!(noon < night, "noon {noon} night {night}");
    }

    #[test]
    fn trace_positive_and_clamped() {
        let tr = CiTrace::diurnal(Region::SwedenNorth, 2, 600.0, 5);
        assert!(tr.values.iter().all(|&v| v > 0.0));
        assert_eq!(tr.at(1e12), *tr.values.last().unwrap());
    }

    #[test]
    fn flat_trace() {
        let tr = CiTrace::flat(Region::Midcontinent, 1, 3600.0);
        assert_eq!(tr.at(0.0), 501.0);
        assert_eq!(tr.mean(), 501.0);
    }
}
