//! Harness self-profiling: wall-clock stage timings for the scenario
//! pipeline (demand pass → planning → sim loop → shard merge) next to
//! the planner's deterministic epoch accounting
//! ([`crate::planner::horizon::PlannerStats`]), so a `plan-bench` or
//! `scale` regression is attributable to a stage instead of a rerun
//! guessing game. Wall clocks are *measurements* — the profile artifact
//! is deliberately excluded from every byte-diff determinism gate; the
//! planner counters inside it are exact and thread-invariant.
//!
//! This module also owns the process-RSS helpers (previously private to
//! `main.rs`) and the opt-in wall-clock progress heartbeat for long-haul
//! runs (`--progress SECS`).

use std::time::Instant;

use crate::planner::horizon::PlannerStats;
use crate::util::log;

/// Peak resident-set size of this process so far, in KB (Linux `VmHWM`;
/// `None` elsewhere). Pair with [`reset_peak_rss`] before each cell;
/// where the reset is unsupported the numbers degrade to a monotone
/// high-water mark that bounds each cell from above — CI additionally
/// wraps the whole run in `/usr/bin/time -v` for an exact envelope.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status.lines()
        .find(|l| l.starts_with("VmHWM:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Reset the kernel's peak-RSS watermark (`echo 5 > /proc/self/clear_refs`)
/// so each capacity-study cell reports its own high-water mark. Best
/// effort: silently a no-op where unsupported.
pub fn reset_peak_rss() {
    let _ = std::fs::write("/proc/self/clear_refs", "5");
}

/// Stage wall clocks + planner epoch accounting for one scenario run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Profile {
    /// Fused single-pass demand analysis (reprovision scenarios; 0 when
    /// the scenario plans from a materialized slice instead).
    pub demand_pass_s: f64,
    /// Rolling-horizon schedule construction (epoch ILP ladder).
    pub plan_s: f64,
    /// The primary simulation itself (sharded: all shard workers,
    /// wall-clock of the scoped-thread scope).
    pub sim_s: f64,
    /// Order-fixed shard merge back into one report (0 unsharded).
    pub merge_s: f64,
    /// Planner decision-ladder counters summed over every horizon solve
    /// of the primary run (deterministic — `usize` sums commute).
    pub planner: PlannerStats,
}

impl Profile {
    /// Time `f`, crediting its wall clock to the stage slot `pick`
    /// selects.
    pub fn stage<R>(&mut self, pick: fn(&mut Profile) -> &mut f64,
                    f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let out = f();
        *pick(self) += t0.elapsed().as_secs_f64();
        out
    }

    /// Render as a small JSON object (sorted keys via the `Json` object
    /// representation).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("demand_pass_s", self.demand_pass_s)
            .set("plan_s", self.plan_s)
            .set("sim_s", self.sim_s)
            .set("merge_s", self.merge_s)
            .set("planner_epochs", self.planner.epochs as f64)
            .set("planner_full_solves", self.planner.full_solves as f64)
            .set("planner_warm_hits", self.planner.warm_hits as f64)
            .set("planner_drift_skips", self.planner.drift_skips as f64)
            .set("planner_cut_patches", self.planner.cut_patches as f64)
            .set("planner_cuts", self.planner.cuts as f64)
            .set("planner_nodes", self.planner.nodes as f64)
    }

    /// Accumulate another run's planner counters (e.g. per-shard stats).
    pub fn add_planner(&mut self, s: PlannerStats) {
        self.planner.absorb(s);
    }
}

/// Wall-clock progress heartbeat for long-haul runs: events processed,
/// sim-time fraction, and peak RSS, printed to stderr at most once per
/// `every_s` seconds of wall time. Stderr-only and wall-clock-driven —
/// it never touches an artifact, so determinism gates are unaffected.
#[derive(Debug)]
pub struct Progress {
    every_s: f64,
    last: Instant,
    label: String,
    duration_s: f64,
}

impl Progress {
    pub fn new(every_s: f64, label: &str, duration_s: f64) -> Progress {
        Progress {
            every_s: every_s.max(0.01),
            last: Instant::now(),
            label: label.to_string(),
            duration_s: duration_s.max(1e-9),
        }
    }

    /// Called from the engine loop (rate-limited by the caller's event
    /// mask before it ever reaches the clock).
    pub fn maybe_emit(&mut self, events: usize, now_s: f64) {
        if self.last.elapsed().as_secs_f64() < self.every_s {
            return;
        }
        self.last = Instant::now();
        let pct = (now_s / self.duration_s * 100.0).min(100.0);
        let rss = peak_rss_kb()
            .map(|kb| format!("{} MiB", kb / 1024))
            .unwrap_or_else(|| "n/a".to_string());
        log::info_now(&format!(
            "[progress{}] {events} events, sim t {:.0}/{:.0}s ({pct:.0}%), \
             peak rss {rss}",
            self.label, now_s.min(self.duration_s), self.duration_s));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accumulates_and_json_is_stable() {
        let mut p = Profile::default();
        let v = p.stage(|p| &mut p.sim_s, || 41 + 1);
        assert_eq!(v, 42);
        assert!(p.sim_s >= 0.0);
        p.add_planner(PlannerStats { epochs: 3, full_solves: 1,
                                     warm_hits: 2, ..Default::default() });
        p.add_planner(PlannerStats { epochs: 2, ..Default::default() });
        assert_eq!(p.planner.epochs, 5);
        assert_eq!(p.planner.warm_hits, 2);
        let j = p.to_json().to_string();
        assert!(j.contains("\"planner_epochs\""), "{j}");
        assert!(j.contains("\"sim_s\""), "{j}");
    }

    #[test]
    fn rss_probe_is_best_effort() {
        // On Linux this returns a positive watermark; elsewhere None.
        if let Some(kb) = peak_rss_kb() {
            assert!(kb > 0);
        }
        reset_peak_rss(); // must never panic
    }
}
