//! Per-job span tracing with deterministic hash-based sampling, exported
//! as Chrome trace-event JSON (loadable in Perfetto / chrome://tracing).
//!
//! **Sampling** is a pure function of the request, never of scheduling:
//! an FNV-1a hash over `(arrival bits, prompt, output, class)` mixed
//! with a seed derived from the scenario seed is compared against
//! `rate × u64::MAX`. Every shard sees the same full arrival stream
//! (`PartitionSource` filters it), so the same jobs are sampled at any
//! shard count, and the hash doubles as a shard-invariant trace id.
//!
//! **Recording** happens at the engine's hook points: arrival, route,
//! prefill (start → done), decode admission, completion, plus the fault
//! path's reroute/park/recover edges. Server ids are translated
//! local → global at record time (`server_base`), so shard-local traces
//! speak fleet coordinates. Finished jobs append to `done` in completion
//! order; [`SpanTrace::merge`] concatenates shards in ascending shard
//! index — with the shard partition a pure function of the fleet, the
//! merged export is byte-identical across shard-thread budgets (a
//! sharded run remains its own design point vs the unsharded engine,
//! exactly like the report bytes).
//!
//! **Export** ([`SpanTrace::to_chrome_json`]): one trace-event process
//! per server (pid = global id + 1, named after the GPU) plus a `router`
//! pseudo-process (pid 0) for pre-placement instants; each job is a
//! thread (tid = low 32 bits of its trace id) so its queue/prefill/
//! decode slices ("X" events, µs) stack on the server that served them,
//! with instant events ("i") marking arrival/route/reroute/park/recover/
//! complete.

/// Span-relevant lifecycle moments of one sampled job.
#[derive(Debug, Clone, PartialEq)]
pub enum SpanEvent {
    /// Placed on a server's prompt queue.
    Route { t: f64, server: usize },
    /// Displaced off a killed (or dead-target) server, re-entering
    /// routing.
    Reroute { t: f64, from: usize },
    /// Parked in the recovery queue: no live server could take it.
    Park { t: f64 },
    /// Drained out of the recovery queue after capacity returned.
    Recover { t: f64 },
    /// One prefill busy period serving this job.
    Prefill { server: usize, t0: f64, t1: f64 },
    /// Admitted into a server's decode batch.
    DecodeStart { t: f64, server: usize },
    /// All output tokens produced.
    Complete { t: f64 },
}

/// The recorded spans of one sampled job.
#[derive(Debug, Clone)]
pub struct JobSpan {
    /// Shard-invariant trace id (the sampling hash).
    pub id: u64,
    pub arrival: f64,
    pub online: bool,
    pub events: Vec<SpanEvent>,
}

/// Deterministic per-job span recorder. See the module docs.
#[derive(Debug)]
pub struct SpanTrace {
    seed: u64,
    /// Sample iff `hash < threshold` (`rate` mapped onto the u64 range).
    threshold: u64,
    /// Local → global server-id map (identity when unsharded).
    server_base: Vec<usize>,
    /// Open spans indexed by arena slot (slots recycle; completion or
    /// stranded-flush clears the slot before the arena reuses it).
    open: Vec<Option<JobSpan>>,
    /// Finished (or flushed) spans in completion order.
    done: Vec<JobSpan>,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The sampling hash: a pure function of the request and the span seed.
pub fn job_hash(seed: u64, arrival_s: f64, prompt: usize, output: usize,
                online: bool) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &seed.to_le_bytes());
    h = fnv1a(h, &arrival_s.to_bits().to_le_bytes());
    h = fnv1a(h, &(prompt as u64).to_le_bytes());
    h = fnv1a(h, &(output as u64).to_le_bytes());
    fnv1a(h, &[online as u8])
}

/// `rate` ∈ [0, 1] mapped onto the u64 hash range.
fn rate_threshold(rate: f64) -> u64 {
    if rate >= 1.0 {
        u64::MAX
    } else if rate <= 0.0 {
        0
    } else {
        (rate * u64::MAX as f64) as u64
    }
}

impl SpanTrace {
    /// `server_base[local]` names the global server id behind each local
    /// slot (identity for an unsharded fleet).
    pub fn new(seed: u64, rate: f64, server_base: Vec<usize>) -> SpanTrace {
        SpanTrace {
            seed,
            threshold: rate_threshold(rate),
            server_base,
            open: Vec::new(),
            done: Vec::new(),
        }
    }

    fn global(&self, server: usize) -> usize {
        self.server_base.get(server).copied().unwrap_or(server)
    }

    /// Sampling decision at job admission; opens a span in `slot` when
    /// the hash falls under the rate threshold.
    pub fn on_arrival(&mut self, slot: usize, arrival_s: f64, prompt: usize,
                      output: usize, online: bool) {
        if self.open.len() <= slot {
            self.open.resize_with(slot + 1, || None);
        }
        let h = job_hash(self.seed, arrival_s, prompt, output, online);
        self.open[slot] = (self.threshold == u64::MAX || h < self.threshold)
            .then(|| JobSpan {
                id: h,
                arrival: arrival_s,
                online,
                events: Vec::new(),
            });
    }

    fn record(&mut self, slot: usize, ev: SpanEvent) {
        if let Some(Some(span)) = self.open.get_mut(slot) {
            span.events.push(ev);
        }
    }

    pub fn on_route(&mut self, slot: usize, t: f64, server: usize) {
        let server = self.global(server);
        self.record(slot, SpanEvent::Route { t, server });
    }

    pub fn on_reroute(&mut self, slot: usize, t: f64, from: usize) {
        let from = self.global(from);
        self.record(slot, SpanEvent::Reroute { t, from });
    }

    pub fn on_park(&mut self, slot: usize, t: f64) {
        self.record(slot, SpanEvent::Park { t });
    }

    pub fn on_recover(&mut self, slot: usize, t: f64) {
        self.record(slot, SpanEvent::Recover { t });
    }

    pub fn on_prefill(&mut self, slot: usize, server: usize, t0: f64,
                      t1: f64) {
        let server = self.global(server);
        self.record(slot, SpanEvent::Prefill { server, t0, t1 });
    }

    pub fn on_decode_start(&mut self, slot: usize, t: f64, server: usize) {
        let server = self.global(server);
        self.record(slot, SpanEvent::DecodeStart { t, server });
    }

    /// Completion closes the span and frees the slot for arena reuse.
    pub fn on_complete(&mut self, slot: usize, t: f64) {
        if let Some(mut span) = self.open.get_mut(slot).and_then(|o| o.take()) {
            span.events.push(SpanEvent::Complete { t });
            self.done.push(span);
        }
    }

    /// Flush never-completed spans (stranded by total capacity loss or
    /// the horizon) in slot order, after the completion-ordered ones.
    pub fn flush_stranded(&mut self) {
        for slot in 0..self.open.len() {
            if let Some(span) = self.open[slot].take() {
                self.done.push(span);
            }
        }
    }

    /// Sampled spans recorded so far (completed + flushed).
    pub fn len(&self) -> usize {
        self.done.len()
    }

    pub fn is_empty(&self) -> bool {
        self.done.is_empty()
    }

    pub fn spans(&self) -> &[JobSpan] {
        &self.done
    }

    /// Fold a shard's finished spans into this trace (ascending shard
    /// index — the order-fixed merge discipline).
    pub fn merge(&mut self, mut other: SpanTrace) {
        debug_assert!(other.open.iter().all(Option::is_none),
                      "merging a span trace with open spans");
        self.done.append(&mut other.done);
    }

    /// Render as Chrome trace-event JSON (`{"traceEvents": [...]}`).
    /// `server_labels[g]` names global server `g`'s track. Timestamps are
    /// microseconds, formatted through the default f64 `Display` — the
    /// same shortest-round-trip rendering every other artifact uses, so
    /// the export is byte-deterministic.
    pub fn to_chrome_json(&self, server_labels: &[String]) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, ev: String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
            out.push_str(&ev);
        };
        // Process-name metadata: the router pseudo-process plus one
        // process per server track.
        push(&mut out, meta_event(0, "router"));
        for (g, label) in server_labels.iter().enumerate() {
            push(&mut out, meta_event(g + 1, label));
        }
        for span in &self.done {
            let tid = span.id & 0xffff_ffff;
            let class = if span.online { "online" } else { "offline" };
            push(&mut out, instant_event("arrival", 0, tid, span.arrival,
                                         span.id, class));
            let mut route_t: Option<(f64, usize)> = None;
            let mut decode_open: Option<(f64, usize)> = None;
            let mut close_decode =
                |out: &mut String,
                 push: &mut dyn FnMut(&mut String, String),
                 open: &mut Option<(f64, usize)>, t1: f64| {
                    if let Some((t0, server)) = open.take() {
                        push(out, slice_event("decode", server + 1, tid,
                                              t0, t1, span.id, class));
                    }
                };
            for ev in &span.events {
                match *ev {
                    SpanEvent::Route { t, server } => {
                        route_t = Some((t, server));
                        push(&mut out, instant_event("route", server + 1,
                                                     tid, t, span.id, class));
                    }
                    SpanEvent::Reroute { t, from } => {
                        close_decode(&mut out, &mut push, &mut decode_open, t);
                        push(&mut out, instant_event("reroute", from + 1,
                                                     tid, t, span.id, class));
                    }
                    SpanEvent::Park { t } => {
                        close_decode(&mut out, &mut push, &mut decode_open, t);
                        push(&mut out, instant_event("park", 0, tid, t,
                                                     span.id, class));
                    }
                    SpanEvent::Recover { t } => {
                        push(&mut out, instant_event("recover", 0, tid, t,
                                                     span.id, class));
                    }
                    SpanEvent::Prefill { server, t0, t1 } => {
                        if let Some((rt, _)) = route_t.take() {
                            push(&mut out, slice_event("queue", server + 1,
                                                       tid, rt, t0, span.id,
                                                       class));
                        }
                        push(&mut out, slice_event("prefill", server + 1,
                                                   tid, t0, t1, span.id,
                                                   class));
                    }
                    SpanEvent::DecodeStart { t, server } => {
                        close_decode(&mut out, &mut push, &mut decode_open, t);
                        decode_open = Some((t, server));
                    }
                    SpanEvent::Complete { t } => {
                        close_decode(&mut out, &mut push, &mut decode_open, t);
                        push(&mut out, instant_event("complete", 0, tid, t,
                                                     span.id, class));
                    }
                }
            }
            // A stranded span's open decode slice closes at its last
            // recorded moment.
            if let Some((t0, server)) = decode_open {
                push(&mut out, slice_event("decode", server + 1, tid, t0, t0,
                                           span.id, class));
            }
        }
        out.push_str("]}");
        out
    }
}

fn us(t_s: f64) -> f64 {
    t_s * 1e6
}

fn meta_event(pid: usize, name: &str) -> String {
    format!("{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\
             \"tid\":0,\"args\":{{\"name\":\"{name}\"}}}}")
}

fn instant_event(name: &str, pid: usize, tid: u64, t_s: f64, id: u64,
                 class: &str) -> String {
    format!("{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":{pid},\
             \"tid\":{tid},\"ts\":{},\
             \"args\":{{\"job\":\"{id:016x}\",\"class\":\"{class}\"}}}}",
            us(t_s))
}

fn slice_event(name: &str, pid: usize, tid: u64, t0_s: f64, t1_s: f64,
               id: u64, class: &str) -> String {
    format!("{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
             \"ts\":{},\"dur\":{},\
             \"args\":{{\"job\":\"{id:016x}\",\"class\":\"{class}\"}}}}",
            us(t0_s), us((t1_s - t0_s).max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_a_pure_function_of_the_request() {
        let h1 = job_hash(42, 1.5, 128, 64, true);
        let h2 = job_hash(42, 1.5, 128, 64, true);
        assert_eq!(h1, h2);
        assert_ne!(h1, job_hash(43, 1.5, 128, 64, true));
        assert_ne!(h1, job_hash(42, 1.5, 128, 64, false));
    }

    #[test]
    fn rate_bounds_sample_none_or_all() {
        let mut none = SpanTrace::new(7, 0.0, vec![0]);
        let mut all = SpanTrace::new(7, 1.0, vec![0]);
        for slot in 0..50 {
            let t = slot as f64 * 0.1;
            none.on_arrival(slot, t, 100, 50, true);
            all.on_arrival(slot, t, 100, 50, true);
            none.on_complete(slot, t + 1.0);
            all.on_complete(slot, t + 1.0);
        }
        assert_eq!(none.len(), 0);
        assert_eq!(all.len(), 50);
    }

    #[test]
    fn slots_recycle_without_cross_talk() {
        let mut tr = SpanTrace::new(1, 1.0, vec![0, 1]);
        tr.on_arrival(0, 0.0, 10, 5, true);
        tr.on_route(0, 0.1, 1);
        tr.on_complete(0, 1.0);
        // Slot 0 reused by a different job: a fresh span, new hash.
        tr.on_arrival(0, 2.0, 20, 5, false);
        tr.on_complete(0, 3.0);
        assert_eq!(tr.len(), 2);
        assert_ne!(tr.spans()[0].id, tr.spans()[1].id);
        assert_eq!(tr.spans()[0].events.len(), 2); // route + complete
    }

    #[test]
    fn chrome_export_has_metadata_slices_and_instants() {
        let mut tr = SpanTrace::new(1, 1.0, vec![0]);
        tr.on_arrival(0, 0.0, 10, 2, true);
        tr.on_route(0, 0.0, 0);
        tr.on_prefill(0, 0, 0.5, 0.8);
        tr.on_decode_start(0, 0.9, 0);
        tr.on_complete(0, 1.5);
        let json = tr.to_chrome_json(&["server0 A100".to_string()]);
        let parsed = crate::util::json::Json::parse(&json)
            .expect("chrome export must be valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr())
            .expect("traceEvents array");
        let phases: Vec<&str> = events.iter()
            .filter_map(|e| e.get("ph").and_then(|p| p.as_str()))
            .collect();
        assert!(phases.contains(&"M"));
        assert!(phases.contains(&"X"));
        assert!(phases.contains(&"i"));
        let names: Vec<&str> = events.iter()
            .filter_map(|e| e.get("name").and_then(|p| p.as_str()))
            .collect();
        for expect in ["arrival", "route", "queue", "prefill", "decode",
                       "complete"] {
            assert!(names.contains(&expect), "missing {expect}: {names:?}");
        }
    }

    #[test]
    fn shard_merge_concatenates_in_fold_order() {
        let mut parent = SpanTrace::new(1, 1.0, vec![0, 1]);
        let mut a = SpanTrace::new(1, 1.0, vec![0]);
        let mut b = SpanTrace::new(1, 1.0, vec![1]);
        a.on_arrival(0, 0.0, 10, 2, true);
        a.on_route(0, 0.0, 0);
        a.on_complete(0, 1.0);
        b.on_arrival(0, 0.5, 12, 2, true);
        b.on_route(0, 0.5, 0); // shard-local 0 → global 1
        b.on_complete(0, 1.5);
        parent.merge(a);
        parent.merge(b);
        assert_eq!(parent.len(), 2);
        assert_eq!(parent.spans()[1].events[0],
                   SpanEvent::Route { t: 0.5, server: 1 });
    }
}
