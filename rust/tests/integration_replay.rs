//! Replay suite over the committed production-trace fixtures: the
//! streaming trace reader must agree byte-for-byte with the materialized
//! path and be invariant in threads and shard counts, rate rescaling must
//! be exact, the chunked CI-file stream must agree bitwise with the
//! materialized `CiTrace`, the burstiness extras panel must land with the
//! golden key set, and the malformed fixtures must produce *counted*
//! skips/repairs — never panics — under the skip policy and line-numbered
//! errors under fail-fast.

use ecoserve::carbon::intensity::{CiTrace, Region};
use ecoserve::carbon::CiStream;
use ecoserve::scenarios::{catalog, run_spec_materialized, run_sweep,
                          scenario_seed, SweepConfig, TraceOverride};
use ecoserve::workload::trace::{probe, sniff_dialect};
use ecoserve::workload::{ArrivalSource, TraceDialect, TraceErrorPolicy,
                         TraceRescale, TraceSource};
use ecoserve::workload::RequestClass;

fn fixture(name: &str) -> String {
    format!("{}/fixtures/traces/{name}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn replay_scenarios_match_materialized_and_are_shard_invariant() {
    for name in ["replay-day", "replay-year"] {
        let sc = catalog::by_names(&[name]).unwrap().remove(0);
        let seed = scenario_seed(31, name);
        let streamed = sc.run(seed, 48.0).to_json().to_string();
        let materialized =
            run_spec_materialized(name, &sc.spec(), seed, 48.0)
                .to_json().to_string();
        assert_eq!(streamed, materialized,
                   "{name}: streaming and materialized replay diverge");
    }
    // Thread count and shard count must not move a byte of the report.
    let run = |threads, shards| {
        let sel = catalog::by_names(&["replay-day"]).unwrap();
        let cfg = SweepConfig { threads, shards, seed: 31, duration_s: 48.0,
                                ..Default::default() };
        run_sweep(&sel, &cfg).to_json().to_string()
    };
    let reference = run(1, Some(1));
    assert_eq!(reference, run(8, Some(1)), "threads changed replay bytes");
    assert_eq!(reference, run(1, Some(4)), "shards changed replay bytes");
    assert_eq!(reference, run(8, Some(4)),
               "threads x shards changed replay bytes");
}

#[test]
fn rescale_rate_is_exact_and_fit_duration_round_trips() {
    let path = fixture("azure_llm_day.csv");
    let count = |rate: f64, duration_s: f64| -> (usize, f64) {
        let mut src = TraceSource::open(
            &path, TraceDialect::Azure, TraceErrorPolicy::Fail,
            TraceRescale { fit_duration: true, rate },
            RequestClass::Online, duration_s).unwrap();
        let mut n = 0usize;
        let mut last = 0.0f64;
        while let Some(r) = src.next_request() {
            assert!(r.arrival_s >= last, "arrivals must be monotone");
            assert!(r.arrival_s < duration_s, "arrival past the duration");
            last = r.arrival_s;
            n += 1;
        }
        (n, last)
    };
    let (base, last) = count(1.0, 100.0);
    assert!(base > 1_000, "fixture too small: {base} arrivals");
    // fit_duration maps the recorded span onto [0, duration): the stream
    // fills the window at any duration, same arrival count either way.
    assert!(last > 95.0, "replay did not cover the duration: last {last}");
    let (base_long, _) = count(1.0, 10_000.0);
    // The half-open [0, duration) cut can move the single span-end record
    // in or out depending on how `span * (duration / span)` rounds.
    assert!((base as i64 - base_long as i64).abs() <= 1,
            "arrival count depends on duration: {base} vs {base_long}");
    // The credit accumulator makes integer rates exact, not statistical.
    let (doubled, _) = count(2.0, 100.0);
    assert_eq!(doubled, base * 2, "2x rate must emit exactly 2x arrivals");
    let (halved, _) = count(0.5, 100.0);
    let expect = base / 2;
    assert!(halved == expect || halved == expect + 1,
            "0.5x rate: got {halved}, expected ~{expect}");
}

#[test]
fn streamed_ci_file_matches_materialized_trace_bitwise() {
    let path = fixture("caiso_ci_day.csv");
    let dur = 300.0;
    let tr = CiTrace::from_file(&path, Region::California, dur).unwrap();
    let st = CiStream::open(&path, Region::California, dur).unwrap();
    assert_eq!(st.meta().n, 288);
    assert_eq!(st.step_s().to_bits(), tr.step_s.to_bits());
    assert_eq!(st.mean().to_bits(), tr.mean().to_bits());
    for k in 0..200 {
        let t = k as f64 * 1.7;
        assert_eq!(st.at(t).to_bits(), tr.at(t).to_bits(), "at({t})");
    }
    for (a, b) in [(0.0, dur), (12.5, 13.5), (250.0, 1e6), (7.0, 7.0),
                   (299.0, 301.0)] {
        assert_eq!(st.mean_over(a, b).to_bits(), tr.mean_over(a, b).to_bits(),
                   "mean_over({a},{b})");
    }
    // Backward seek after a tail read (the rewind path).
    let _ = st.at(299.0);
    assert_eq!(st.at(1.0).to_bits(), tr.at(1.0).to_bits());
}

#[test]
fn replay_day_extras_carry_the_golden_burstiness_panel() {
    let sel = catalog::by_names(&["replay-day"]).unwrap();
    let cfg = SweepConfig { threads: 1, seed: 5, duration_s: 48.0,
                            ..Default::default() };
    let o = run_sweep(&sel, &cfg).outcomes.remove(0);
    let keys: Vec<&str> = o.extras.keys().map(|k| k.as_str()).collect();
    assert_eq!(keys,
               vec!["burst_cv_replay", "burst_cv_synthetic",
                    "burst_peak_to_mean_replay",
                    "burst_peak_to_mean_synthetic", "carbon_kg_static",
                    "emb_kg_static", "op_kg_static",
                    "provisioned_server_hours_static",
                    "slo_attainment_static", "trace_records",
                    "trace_repaired_timestamps", "trace_skipped_lines",
                    "ttft_p90_s_static", "util_fleet_mean",
                    "util_server_max", "util_server_min"],
               "replay-day extras drifted from the golden key set");
    // The committed fixtures are clean and bursty: the replayed CV must
    // exceed the rate-matched Poisson baseline, and the health counters
    // must report a full parse.
    assert!(o.extras["burst_cv_replay"] > o.extras["burst_cv_synthetic"],
            "replayed trace should be burstier than matched Poisson");
    assert_eq!(o.extras["trace_skipped_lines"], 0.0);
    assert_eq!(o.extras["trace_repaired_timestamps"], 0.0);
    assert!(o.extras["trace_records"] >= 3_000.0,
            "both fixtures should contribute records");
    assert_eq!(o.completed, o.requests, "replayed requests lost");
}

#[test]
fn trace_and_ci_file_overrides_rewire_any_scenario() {
    let mk = |threads| {
        let sel = catalog::by_names(&["online-latency"]).unwrap();
        let cfg = SweepConfig {
            threads,
            seed: 9,
            duration_s: 36.0,
            trace: Some(TraceOverride {
                path: fixture("burstgpt_day.csv"),
                dialect: TraceDialect::BurstGpt,
                errors: TraceErrorPolicy::Fail,
                rate: 1.0,
            }),
            ci_file: Some(fixture("caiso_ci_day.csv")),
            ..Default::default()
        };
        run_sweep(&sel, &cfg)
    };
    let r = mk(1);
    let o = &r.outcomes[0];
    assert!(o.requests > 500, "override replay too quiet: {}", o.requests);
    assert!(o.extras.contains_key("burst_cv_replay"),
            "trace override must light up the burstiness panel");
    // The streamed duck curve replaces the flat default: the effective CI
    // differs from the region's flat average.
    assert!((o.ci - Region::California.avg_ci()).abs() > 1.0,
            "ci file override did not take effect");
    assert_eq!(r.to_json().to_string(), mk(4).to_json().to_string(),
               "override replay must stay thread-invariant");
}

#[test]
fn malformed_fixtures_are_counted_under_skip_and_fatal_under_fail() {
    let cases = [
        // (fixture, bad lines skipped, timestamps repaired, fail-fast errors)
        ("malformed_truncated.csv", 2, 0, true),
        ("malformed_nonmonotonic.csv", 0, 3, false),
        ("malformed_badfields.csv", 3, 0, true),
    ];
    for (name, skipped, repaired, fail_errors) in cases {
        let path = fixture(name);
        let st = probe(&path, TraceDialect::Azure, TraceErrorPolicy::Skip)
            .unwrap_or_else(|e| panic!("{name}: skip policy must not error: {e}"));
        assert_eq!(st.skipped_lines, skipped, "{name}: skip count");
        assert_eq!(st.repaired_timestamps, repaired, "{name}: repair count");
        assert!(st.records >= 45, "{name}: good rows lost ({})", st.records);
        let fail = probe(&path, TraceDialect::Azure, TraceErrorPolicy::Fail);
        if fail_errors {
            let e = fail.expect_err(
                &format!("{name}: fail policy must reject bad lines"));
            assert!(e.to_string().contains("line"),
                    "{name}: error should cite a line number: {e}");
        } else {
            // Non-monotonic stamps are repaired-and-counted under *both*
            // policies — never an error.
            assert_eq!(fail.unwrap().repaired_timestamps, repaired,
                       "{name}: fail policy must still repair");
        }
        // A skip-policy replay of a malformed file still serves requests.
        let mut src = TraceSource::open(
            &path, TraceDialect::Azure, TraceErrorPolicy::Skip,
            TraceRescale::default(), RequestClass::Online, 30.0).unwrap();
        let mut n = 0usize;
        while src.next_request().is_some() {
            n += 1;
        }
        assert!(n >= 40, "{name}: replay under skip lost requests ({n})");
    }
}

#[test]
fn committed_fixtures_sniff_to_their_documented_dialects() {
    assert_eq!(sniff_dialect(&fixture("azure_llm_day.csv")).unwrap(),
               TraceDialect::Azure);
    assert_eq!(sniff_dialect(&fixture("burstgpt_day.csv")).unwrap(),
               TraceDialect::BurstGpt);
}
