//! Simulator integration: planner-built fleets serve their own workloads
//! within SLO, and carbon accounting is self-consistent.

use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::sim::{simulate, Router};
use ecoserve::strategies::{fleet_from_plan, sim_config, splitwise_fleet, Strategy};
use ecoserve::workload::slo::slo_for;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

#[test]
fn planned_fleet_meets_slo_mostly() {
    let m = models::llm("llama-8b").unwrap();
    let slo = slo_for("llama-8b", false).unwrap().slo;
    let tr = generate_trace(Arrivals::Poisson { rate: 6.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            180.0, 9);
    let slices = cluster_slices(&slice_trace(m, &tr, 180.0, slo, 1));
    let plan = Strategy::EcoFull.plan(&slices, 261.0);
    let fleet = fleet_from_plan(&plan, m, 2048);
    assert!(!fleet.is_empty());
    let cfg = sim_config(fleet, &plan, 261.0);
    let r = simulate(m, &tr, &cfg, slo.ttft_s, slo.tpot_s);
    assert_eq!(r.completed, tr.len());
    assert!(r.slo_attainment > 0.6,
            "planned fleet SLO attainment too low: {}", r.slo_attainment);
}

#[test]
fn carbon_accounting_scales_with_ci() {
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate: 2.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            120.0, 10);
    let mk = |ci: f64| {
        let servers = ecoserve::sim::homogeneous_fleet("A100-40", 4, m, 2048);
        let cfg = ecoserve::sim::SimConfig::flat(servers, Router::Jsq, ci,
                                                 vec![0.005; 4]);
        simulate(m, &tr, &cfg, 0.5, 0.1)
    };
    let low = mk(17.0);
    let high = mk(501.0);
    // Same trace, same fleet: identical energy, op carbon ∝ CI.
    assert!((low.energy_j - high.energy_j).abs() < 1e-6);
    let ratio = high.op_kg / low.op_kg;
    assert!((ratio - 501.0 / 17.0).abs() < 0.1, "op ratio {ratio}");
    assert!((low.emb_kg - high.emb_kg).abs() < 1e-9);
}

#[test]
fn splitwise_vs_ecoserve_shape() {
    // Fig 17's qualitative claim on one point: at iso fleet size, the
    // workload-aware heterogeneous plan emits no more carbon than the
    // fixed H100 PD split.
    let m = models::llm("llama-70b").unwrap();
    let slo = slo_for("llama-70b", false).unwrap().slo;
    let tr = generate_trace(Arrivals::Poisson { rate: 0.6 },
                            LengthDist::AzureCode, RequestClass::Online,
                            120.0, 11);
    let slices = cluster_slices(&slice_trace(m, &tr, 120.0, slo, 1));
    let eco_plan = Strategy::EcoFull.plan(&slices, 261.0);
    let eco_fleet = fleet_from_plan(&eco_plan, m, 2048);
    let eco = simulate(m, &tr, &sim_config(eco_fleet, &eco_plan, 261.0),
                       slo.ttft_s, slo.tpot_s);

    let total = eco_plan.total_gpus().max(4);
    let sw_fleet = splitwise_fleet(m, (total * 3 / 4).max(1),
                                   (total / 4).max(1), 2048);
    let sw_plan = Strategy::Splitwise.plan(&slices, 261.0);
    let mut sw_cfg = sim_config(sw_fleet, &sw_plan, 261.0);
    sw_cfg.router = Router::Jsq;
    let sw = simulate(m, &tr, &sw_cfg, slo.ttft_s, slo.tpot_s);

    assert_eq!(eco.completed, sw.completed);
    assert!(eco.carbon_kg() <= sw.carbon_kg() * 1.1,
            "eco {} vs splitwise {}", eco.carbon_kg(), sw.carbon_kg());
}
