"""AOT compile path: lower the L2 model to HLO-text artifacts for Rust.

Runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards. Interchange format is HLO *text*, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  model_config.json              dims, buckets, parameter manifest
  weights.bin                    seeded weights, ECOW format (runtime/weights.rs)
  prefill_b{B}_s{S}.hlo.txt      bucketed prefill executables
  decode_b{B}.hlo.txt            batched decode step (Pallas split-KV attention)
  decode_ref_b{B}.hlo.txt        decode step with pure-jnp attention (perf A/B)
  gemm_pallas_{N}.hlo.txt        L1 blocked-GEMM microbench
  gemm_xla_{N}.hlo.txt           XLA-native dot microbench (baseline)
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.gemm import gemm

PREFILL_BUCKETS = [(1, 32), (1, 128), (4, 32), (4, 128), (8, 32)]
DECODE_BUCKETS = [1, 4, 8]
GEMM_SIZES = [256, 512]

WEIGHTS_MAGIC = b"ECOW"
WEIGHTS_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params):
    """Deterministic (name, leaf) list — the weights.bin / HLO-param contract."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]

    def name_of(path):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return ".".join(parts)

    return [(name_of(path), leaf) for path, leaf in leaves_with_paths]


def write_weights(path: str, named_leaves) -> None:
    """ECOW v1: magic, version:u32, count:u32, then per tensor
    name_len:u16 name:utf8 dtype:u8(0=f32) ndim:u8 dims:u32* data:f32le*."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", WEIGHTS_VERSION, len(named_leaves)))
        for name, leaf in named_leaves:
            arr = jax.numpy.asarray(leaf, dtype=jnp.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            import numpy as np
            f.write(np.asarray(arr).astype("<f4").tobytes())


def lower_prefill(cfg, params, batch, seq):
    fn = functools.partial(M.prefill, cfg)
    spec_tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    spec_len = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(fn).lower(params, spec_tok, spec_len)


def lower_decode(cfg, params, batch, use_pallas=True):
    fn = functools.partial(M.decode_step, cfg, use_pallas=use_pallas)
    cshape = (cfg.n_layers, batch, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    spec_c = jax.ShapeDtypeStruct(cshape, jnp.float32)
    spec_i = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return jax.jit(fn).lower(params, spec_c, spec_c, spec_i, spec_i)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest bucket of each kind (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.ModelCfg()
    params = M.init_params(cfg, seed=args.seed)
    named = flatten_params(params)

    write_weights(os.path.join(args.out_dir, "weights.bin"), named)
    print(f"weights.bin: {len(named)} tensors, "
          f"{sum(int(l.size) for _, l in named)} params")

    prefill_buckets = PREFILL_BUCKETS[:1] if args.quick else PREFILL_BUCKETS
    decode_buckets = DECODE_BUCKETS[:1] if args.quick else DECODE_BUCKETS
    gemm_sizes = GEMM_SIZES[:1] if args.quick else GEMM_SIZES

    artifacts = {}

    for b, s in prefill_buckets:
        name = f"prefill_b{b}_s{s}"
        text = to_hlo_text(lower_prefill(cfg, params, b, s))
        with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        artifacts[name] = {"kind": "prefill", "batch": b, "seq": s}
        print(f"{name}: {len(text)} chars")

    for b in decode_buckets:
        name = f"decode_b{b}"
        text = to_hlo_text(lower_decode(cfg, params, b, use_pallas=True))
        with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        artifacts[name] = {"kind": "decode", "batch": b, "pallas": True}
        print(f"{name}: {len(text)} chars")

    # Reference-attention decode at the largest bucket: the perf A/B partner.
    b = decode_buckets[-1]
    name = f"decode_ref_b{b}"
    text = to_hlo_text(lower_decode(cfg, params, b, use_pallas=False))
    with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    artifacts[name] = {"kind": "decode", "batch": b, "pallas": False}
    print(f"{name}: {len(text)} chars")

    for n in gemm_sizes:
        spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
        bm = min(128, n)
        lowered = jax.jit(
            functools.partial(gemm, bm=bm, bn=bm, bk=bm)).lower(spec, spec)
        pname = f"gemm_pallas_{n}"
        with open(os.path.join(args.out_dir, f"{pname}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts[pname] = {"kind": "gemm", "n": n, "pallas": True}
        lowered = jax.jit(lambda a, b: (jnp.dot(a, b),)).lower(spec, spec)
        xname = f"gemm_xla_{n}"
        with open(os.path.join(args.out_dir, f"{xname}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts[xname] = {"kind": "gemm", "n": n, "pallas": False}
        print(f"gemm {n}: pallas + xla")

    config = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "ffn_hidden": cfg.ffn_hidden, "max_seq": cfg.max_seq,
            "pad": M.PAD, "bos": M.BOS, "eos": M.EOS,
        },
        "params": [{"name": n, "shape": list(l.shape)} for n, l in named],
        "artifacts": artifacts,
        "prefill_buckets": [list(t) for t in prefill_buckets],
        "decode_buckets": decode_buckets,
    }
    with open(os.path.join(args.out_dir, "model_config.json"), "w") as f:
        json.dump(config, f, indent=1)
    print(f"model_config.json: {len(config['params'])} params, "
          f"{len(artifacts)} artifacts")


if __name__ == "__main__":
    main()
