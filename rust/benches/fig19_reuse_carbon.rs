//! Fig 19: decode throughput, operational and embodied carbon for
//! CPU-naive (llama.cpp-like) / CPU-optimized (EcoServe reuse) / GPU,
//! normalized to an A100 at max throughput.
//!
//! Embodied attribution follows the paper's iso-throughput lens: carbon
//! per token = (amortized component embodied) / throughput, with the reuse
//! engine charged the host share and the GPU charged its board.
use ecoserve::carbon::embodied::{gpu_embodied, host_embodied};
use ecoserve::carbon::operational::device_power;
use ecoserve::hw::{self, platform::standard_platform};
use ecoserve::models;
use ecoserve::perf::cpu::{decode_throughput as cpu_tput, max_batch, CpuStrategy};
use ecoserve::perf::roofline::{decode_throughput as gpu_tput, Device};
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 19: reuse throughput & carbon, normalized to A100 ==");
    let ci = 261.0;
    let spr = hw::cpu("SPR-56").unwrap();
    let a100 = hw::gpu("A100-40").unwrap();
    let dev = Device::from_gpu(a100);
    let gpu_emb = gpu_embodied(a100).total();
    let host_emb = host_embodied(&standard_platform("A100-40", 4).host).total() / 4.0;
    let lt_s = 4.0 * 365.25 * 86_400.0;

    let mut t = Table::new(&["model", "ctx", "engine", "tput/GPU",
                             "op-carbon/GPU", "emb-carbon/GPU"]);
    for (model_name, ctxs) in [("gemma-27b", [512usize, 4096]),
                               ("llama-8b", [512, 4096])] {
        let m = models::llm(model_name).unwrap();
        for ctx in ctxs {
            let mut tp = 1usize;
            while m.max_batch(dev.mem_gb, ctx, tp) == 0 && tp < 8 {
                tp *= 2;
            }
            let gb = m.max_batch(dev.mem_gb, ctx, tp).max(1);
            let g_tput = gpu_tput(m, &dev, gb, ctx, tp);
            let g_power = device_power(dev.idle_w, dev.tdp_w, 0.8, 0.85);
            let g_op = g_power * ci / g_tput;          // ∝ gCO2/token
            let g_emb = gpu_emb * tp as f64 / lt_s / g_tput;
            for (engine, strat) in [("cpu-naive", CpuStrategy::Naive),
                                    ("cpu-opt", CpuStrategy::Optimized)] {
                let cb = max_batch(m, 512.0, ctx).clamp(1, 512);
                let c_tp = cpu_tput(m, spr, cb, ctx, strat);
                // Marginal dynamic power: host idles for the GPU anyway.
                let c_power = device_power(spr.idle_w, spr.tdp_w, 0.8, 0.5)
                    - spr.idle_w;
                let c_op = c_power * ci / c_tp;
                let c_emb = host_emb / lt_s / c_tp;
                t.row(&[model_name.into(), format!("{ctx}"), engine.into(),
                        fnum(c_tp / g_tput), fnum(c_op / g_op),
                        fnum(c_emb / g_emb)]);
            }
        }
    }
    t.print();
    println!("(cpu-opt recovers the embodied loss of cpu-naive; op carbon\n\
              stays >1 for short-ctx large models — route long-context\n\
              offline decode to CPUs, per §6.3)");
}
