//! Deterministic PRNG substrate (no external `rand`: offline vendor set).
//!
//! SplitMix64 core with helpers for the distributions the workload
//! generators need (uniform, exponential, normal, lognormal, Poisson,
//! gamma). Deterministic across platforms — every experiment seed in
//! EXPERIMENTS.md reproduces bit-identically.

/// SplitMix64 PRNG. Passes BigCrush as a 64-bit mixer; plenty for
/// simulation workloads (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (e.g. one per simulated server).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the given log-space mean and std.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang (k >= 1 fast path).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let g = self.gamma(k + 1.0, 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / k) * theta;
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(6);
        for lambda in [0.5, 4.0, 120.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda.max(1.0) * 0.05,
                    "lambda {lambda} mean {mean}");
        }
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(7);
        let (k, theta) = (2.5, 1.5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gamma(k, theta)).sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn gamma_shape_below_one() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gamma(0.5, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
