//! Carbon-meter observer: integrates operational carbon against the
//! deployment's time-varying CI signal as the simulation runs, instead of
//! multiplying total energy by a scalar CI after the fact. Multi-region
//! fleets attach per-server overrides — full [`CiSignal`]s, so a pinned
//! grid can carry its own (phase-shifted) diurnal trace rather than a
//! flat average; `SimConfig::region_signals` supplies the traces and an
//! empty map falls back to the flat published average per region.
//!
//! The meter also keeps each server's **provisioned intervals** — opened
//! by `Provision`, closed by `Decommission` events — so embodied carbon
//! amortizes per provisioned-hour (the 4R Rightsize/Recycle accounting: a
//! decommissioned server stops accruing embodied and idle carbon) rather
//! than being charged for the whole sim horizon regardless of fleet size.

use crate::carbon::intensity::CiSignal;
use crate::carbon::operational::op_kg_from_joules;

use super::core::SimConfig;

#[derive(Debug)]
pub struct CarbonMeter {
    primary: CiSignal,
    /// Per-server CI-signal overrides (multi-region fleets), indexed like
    /// `SimConfig::servers`. Flat for regions without a configured trace.
    overrides: Vec<Option<CiSignal>>,
    op_kg: f64,
    /// Closed provisioned intervals per server, in time order (consulted
    /// only for traced signals when pricing idle energy).
    intervals: Vec<Vec<(f64, f64)>>,
    /// Start of each server's currently open provisioned interval.
    open_since: Vec<Option<f64>>,
    /// Running per-server provisioned-second totals, maintained at
    /// decommission time so [`CarbonMeter::provisioned_s`] is O(1) on the
    /// per-server finish path instead of re-summing interval lists.
    total_s: Vec<f64>,
}

impl CarbonMeter {
    pub fn new(cfg: &SimConfig) -> CarbonMeter {
        let n = cfg.servers.len();
        CarbonMeter {
            primary: cfg.ci.clone(),
            overrides: cfg.servers.iter()
                .map(|s| s.region.map(|r| cfg.region_signal(r)))
                .collect(),
            op_kg: 0.0,
            intervals: vec![Vec::new(); n],
            open_since: vec![None; n],
            total_s: vec![0.0; n],
        }
    }

    /// Open a provisioned interval for `server` at `t_s` (idempotent
    /// while an interval is already open).
    pub(crate) fn provision(&mut self, server: usize, t_s: f64) {
        if self.open_since[server].is_none() {
            self.open_since[server] = Some(t_s);
        }
    }

    /// Close `server`'s open provisioned interval at `t_s`.
    pub(crate) fn decommission(&mut self, server: usize, t_s: f64) {
        if let Some(t0) = self.open_since[server].take() {
            let t1 = t_s.max(t0);
            self.intervals[server].push((t0, t1));
            self.total_s[server] += t1 - t0;
        }
    }

    /// Close every still-open interval at the end of the sim horizon.
    pub(crate) fn finalize(&mut self, horizon_s: f64) {
        for i in 0..self.open_since.len() {
            self.decommission(i, horizon_s);
        }
    }

    /// Total provisioned seconds accumulated by `server` so far (open
    /// intervals count only after [`CarbonMeter::finalize`]). O(1).
    pub fn provisioned_s(&self, server: usize) -> f64 {
        self.total_s[server]
    }

    /// Provisioned seconds `server` has accrued *through* `t_s`: closed
    /// intervals clipped at `t_s` plus the still-open interval, if any.
    /// Drives the fleet timeline's cumulative embodied column; pure
    /// read — O(intervals), never mutates the books.
    pub fn provisioned_s_through(&self, server: usize, t_s: f64) -> f64 {
        let closed: f64 = self.intervals[server].iter()
            .map(|&(t0, t1)| (t1.min(t_s) - t0).max(0.0))
            .sum();
        let open = self.open_since[server]
            .map(|t0| (t_s - t0).max(0.0))
            .unwrap_or(0.0);
        closed + open
    }

    /// Mean of `sig` over `server`'s provisioned intervals, weighted by
    /// interval length — what idle draw should be priced at (an elastic
    /// server is only idle while it is provisioned). Falls back to the
    /// horizon mean for a never-provisioned server (its idle energy is
    /// zero anyway).
    fn provisioned_mean_ci(&self, server: usize, horizon_s: f64,
                           sig: &CiSignal) -> f64 {
        if let CiSignal::Flat(ci) = sig {
            return *ci; // interval weighting is moot for a flat signal
        }
        let iv = &self.intervals[server];
        let total: f64 = iv.iter().map(|(a, b)| b - a).sum();
        if total <= 0.0 {
            return sig.mean_over(0.0, horizon_s);
        }
        iv.iter()
            .map(|(a, b)| sig.mean_over(*a, *b) * (b - a))
            .sum::<f64>()
            / total
    }

    /// The deployment's primary CI signal (drives deferral decisions).
    pub fn primary(&self) -> &CiSignal {
        &self.primary
    }

    /// The signal `server` meters against: its region override, else the
    /// deployment's primary signal.
    fn signal_for(&self, server: usize) -> &CiSignal {
        match self.overrides.get(server).and_then(|o| o.as_ref()) {
            Some(sig) => sig,
            None => &self.primary,
        }
    }

    /// Grid CI seen by `server` at time `t`.
    pub fn ci_at(&self, server: usize, t_s: f64) -> f64 {
        self.signal_for(server).at(t_s)
    }

    /// Charge a busy interval's energy at the mean CI over the interval.
    /// Called once per busy period — the meter's hot path — so flat
    /// signals skip the interval-integration machinery entirely.
    pub fn record(&mut self, server: usize, t0_s: f64, dur_s: f64, energy_j: f64) {
        let ci = match self.signal_for(server) {
            CiSignal::Flat(ci) => *ci,
            sig => sig.mean_over(t0_s, t0_s + dur_s.max(0.0)),
        };
        self.op_kg += op_kg_from_joules(energy_j, ci);
    }

    /// Charge idle-floor energy at the signal's mean over the server's
    /// provisioned intervals (idle draw is spread across the time the
    /// server was actually up — the whole run for a static fleet).
    pub fn record_idle(&mut self, server: usize, energy_j: f64, dur_s: f64) {
        let ci = self.provisioned_mean_ci(server, dur_s,
                                          self.signal_for(server));
        self.op_kg += op_kg_from_joules(energy_j, ci);
    }

    /// Accumulated operational carbon, kgCO₂e.
    pub fn op_kg(&self) -> f64 {
        self.op_kg
    }

    /// Fold a shard meter covering a *disjoint* slice of the fleet into
    /// this fleet-wide meter: `global_idx[local]` names the global server
    /// each of `other`'s slots corresponds to. Interval lists and
    /// provisioned totals scatter exactly (disjoint slots); `op_kg` is an
    /// f64 accumulation, so the sharded runtime always folds shards in
    /// ascending shard-index order to keep the total a pure function of
    /// the partition set.
    pub fn merge_shard(&mut self, other: &CarbonMeter, global_idx: &[usize]) {
        assert_eq!(other.total_s.len(), global_idx.len(),
                   "shard meter / index map size mismatch");
        for (local, &g) in global_idx.iter().enumerate() {
            assert!(self.intervals[g].is_empty() && self.total_s[g] == 0.0,
                    "shard meters overlap on server {g}");
            self.intervals[g] = other.intervals[local].clone();
            self.open_since[g] = other.open_since[local];
            self.total_s[g] = other.total_s[local];
        }
        self.op_kg += other.op_kg;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::intensity::{CiTrace, Region};
    use crate::models;
    use crate::sim::policy::Router;
    use crate::sim::server::homogeneous_fleet;

    fn cfg(ci: CiSignal, regions: &[Option<Region>]) -> SimConfig {
        let m = models::llm("llama-8b").unwrap();
        let mut fleet = homogeneous_fleet("A100-40", regions.len(), m, 2048);
        for (s, r) in fleet.iter_mut().zip(regions) {
            s.region = *r;
        }
        let n = fleet.len();
        let mut c = SimConfig::flat(fleet, Router::Jsq, 0.0, vec![0.005; n]);
        c.ci = ci;
        c
    }

    #[test]
    fn flat_meter_matches_closed_form() {
        let mut m = CarbonMeter::new(&cfg(CiSignal::flat(261.0), &[None, None]));
        m.record(0, 0.0, 10.0, 3.6e6);
        m.record_idle(1, 3.6e6, 100.0);
        // 2 kWh at 261 g/kWh = 0.522 kg.
        assert!((m.op_kg() - 2.0 * 261.0 / 1000.0).abs() < 1e-12);
        assert_eq!(m.ci_at(0, 55.0), 261.0);
    }

    #[test]
    fn overrides_pin_a_server_to_its_region() {
        let m = CarbonMeter::new(&cfg(
            CiSignal::flat(261.0),
            &[Some(Region::SwedenNorth), None],
        ));
        assert_eq!(m.ci_at(0, 0.0), 17.0);
        assert_eq!(m.ci_at(1, 0.0), 261.0);
        let mut m2 = CarbonMeter::new(&cfg(
            CiSignal::flat(261.0),
            &[Some(Region::SwedenNorth), None],
        ));
        m2.record(0, 0.0, 1.0, 3.6e6);
        assert!((m2.op_kg() - 17.0 / 1000.0).abs() < 1e-12);
    }

    #[test]
    fn provisioned_intervals_accumulate_and_close() {
        let mut m = CarbonMeter::new(&cfg(CiSignal::flat(261.0), &[None, None]));
        m.provision(0, 0.0);
        m.provision(0, 5.0); // idempotent while open
        m.decommission(0, 10.0);
        m.provision(0, 20.0); // re-provision opens a second interval
        m.provision(1, 0.0);
        m.finalize(30.0);
        assert!((m.provisioned_s(0) - 20.0).abs() < 1e-12,
                "server 0: {}", m.provisioned_s(0));
        assert!((m.provisioned_s(1) - 30.0).abs() < 1e-12,
                "server 1: {}", m.provisioned_s(1));
        // Closing an already-closed interval is a no-op.
        m.decommission(0, 40.0);
        assert!((m.provisioned_s(0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn region_trace_override_is_time_varying() {
        let mut c = cfg(CiSignal::flat(501.0), &[Some(Region::SwedenNorth), None]);
        c.region_signals = vec![(
            Region::SwedenNorth,
            CiSignal::Trace(CiTrace::compressed_diurnal(
                Region::SwedenNorth, 240.0, 1, 96, 3)),
        )];
        let m = CarbonMeter::new(&c);
        // The pinned server follows its own diurnal trace, not a flat 17.
        let dip = m.ci_at(0, 13.0 / 24.0 * 240.0);
        let night = m.ci_at(0, 3.0 / 24.0 * 240.0);
        assert!(dip < night, "dip {dip} night {night}");
        assert!((dip - 17.0).abs() > 1e-9 || (night - 17.0).abs() > 1e-9,
                "trace override collapsed to the flat average");
        // The unpinned server still sees the primary signal.
        assert_eq!(m.ci_at(1, 120.0), 501.0);
    }

    #[test]
    fn merge_shard_scatters_disjoint_interval_totals() {
        let c = cfg(CiSignal::flat(261.0), &[None, None, None]);
        let mut whole = CarbonMeter::new(&c);
        let shard_cfg = cfg(CiSignal::flat(261.0), &[None]);
        let mut a = CarbonMeter::new(&shard_cfg);
        a.provision(0, 0.0);
        a.record(0, 0.0, 5.0, 3.6e6);
        a.finalize(50.0);
        let shard_cfg2 = cfg(CiSignal::flat(261.0), &[None, None]);
        let mut b = CarbonMeter::new(&shard_cfg2);
        b.provision(0, 10.0);
        b.provision(1, 0.0);
        b.record(1, 0.0, 2.0, 3.6e6);
        b.finalize(30.0);
        whole.merge_shard(&a, &[1]);
        whole.merge_shard(&b, &[0, 2]);
        assert!((whole.provisioned_s(1) - 50.0).abs() < 1e-12);
        assert!((whole.provisioned_s(0) - 20.0).abs() < 1e-12);
        assert!((whole.provisioned_s(2) - 30.0).abs() < 1e-12);
        assert!((whole.op_kg() - (a.op_kg() + b.op_kg())).abs() < 1e-15);
    }

    #[test]
    fn traced_meter_charges_less_in_the_dip() {
        let tr = CiTrace::compressed_diurnal(Region::California, 240.0, 1, 96, 3);
        let sig = CiSignal::Trace(tr);
        let dip_t = 13.0 / 24.0 * 240.0;
        let night_t = 3.0 / 24.0 * 240.0;
        let mk = |t0: f64| {
            let mut m = CarbonMeter::new(&cfg(sig.clone(), &[None]));
            m.record(0, t0, 2.0, 1e6);
            m.op_kg()
        };
        assert!(mk(dip_t) < mk(night_t),
                "dip {} night {}", mk(dip_t), mk(night_t));
    }
}
