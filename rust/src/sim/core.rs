//! Discrete-event core (dslab-style): a sequence-numbered, total-order
//! event queue and the engine that drives servers, policies, the deferral
//! queue, the metrics sink, and the carbon meter.
//!
//! Ordering is total by construction: events compare by `(time, seq)` via
//! `f64::total_cmp`, so ties at equal timestamps pop in FIFO order and NaN
//! cannot silently collapse to `Ordering::Equal`. Busy servers are modelled
//! with explicit completion generations instead of the old
//! `busy_until > now + 1e-12` stale-wake epsilon: a `Complete` event names
//! the busy period it ends, and `Wake` nudges are ignored while a period is
//! in flight.

use crate::carbon::intensity::CiSignal;
use crate::models::LlmSpec;
use crate::workload::{Request, RequestClass};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::carbon_meter::CarbonMeter;
use super::metrics::{MetricsSink, SimReport};
use super::policy::{BatchPolicy, Batcher, DeferState, DeferralPolicy,
                    RouteCtx, RoutePolicy, Router};
use super::server::{Job, Role, Server, ServerSpec, MAX_PROMPT_TOKENS};

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub servers: Vec<ServerSpec>,
    /// Routing policy selector (maps to a [`RoutePolicy`] impl).
    pub router: Router,
    /// Batch-formation policy selector (maps to a [`BatchPolicy`] impl).
    pub batcher: Batcher,
    /// Grid carbon-intensity signal: flat scalar or time-varying trace.
    pub ci: CiSignal,
    /// Per-server embodied amortization, kgCO₂e per server-hour.
    pub emb_kg_per_hr: Vec<f64>,
    /// KV transfer bandwidth between prefill and decode servers, B/s.
    pub kv_transfer_bw: f64,
    /// Temporal scheduling of offline-class requests.
    pub deferral: DeferralPolicy,
}

impl SimConfig {
    /// The common case: a flat CI, online-first batching, no deferral.
    pub fn flat(servers: Vec<ServerSpec>, router: Router, ci: f64,
                emb_kg_per_hr: Vec<f64>) -> SimConfig {
        SimConfig {
            servers,
            router,
            batcher: Batcher::OnlineFirst,
            ci: CiSignal::flat(ci),
            emb_kg_per_hr,
            kv_transfer_bw: 64e9,
            deferral: DeferralPolicy::Immediate,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum EventKind {
    /// A request enters the system.
    Arrival(usize),
    /// A deferred offline request is released to the routers.
    Release(usize),
    /// Nudge a server to schedule work (ignored while mid-iteration).
    Wake(usize),
    /// A prefilled sequence's KV cache lands on `server` (after transfer);
    /// only now may the decode side admit the job.
    Handoff { job: usize, server: usize },
    /// End of `server`'s busy period number `gen`.
    Complete { server: usize, gen: u64 },
}

#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub t: f64,
    /// Monotonic sequence number assigned at push: makes the order total
    /// and deterministic (FIFO among equal timestamps).
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq); total_cmp keeps the order total even
        // for non-finite timestamps.
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The sequence-numbered event queue.
#[derive(Debug, Default)]
pub(crate) struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn push(&mut self, t: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { t, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }
}

/// The simulation engine. Stepping logic (prefill/decode) lives in
/// `server.rs`; this file owns the event loop and lifecycle.
pub(crate) struct Sim<'a> {
    pub model: &'a LlmSpec,
    pub cfg: &'a SimConfig,
    pub route: &'a dyn RoutePolicy,
    pub batch: &'a dyn BatchPolicy,
    pub jobs: Vec<Job>,
    pub servers: Vec<Server>,
    pub queue: EventQueue,
    pub metrics: MetricsSink,
    pub meter: CarbonMeter,
    pub defer: DeferState,
    pub prompt_eligible: Vec<usize>,
    pub now: f64,
}

impl<'a> Sim<'a> {
    pub fn new(model: &'a LlmSpec, trace: &[Request], cfg: &'a SimConfig,
               slo_ttft: f64, slo_tpot: f64, route: &'a dyn RoutePolicy,
               batch: &'a dyn BatchPolicy) -> Sim<'a> {
        assert_eq!(cfg.servers.len(), cfg.emb_kg_per_hr.len());
        let mut metrics = MetricsSink::default();
        let jobs: Vec<Job> = trace
            .iter()
            .map(|r| {
                if r.prompt_tokens > MAX_PROMPT_TOKENS {
                    metrics.truncated_prompts += 1;
                }
                Job {
                    arrival: r.arrival_s,
                    prompt: r.prompt_tokens.min(MAX_PROMPT_TOKENS),
                    output: r.output_tokens.max(1),
                    class: r.class,
                    slo_ttft,
                    slo_tpot,
                    deadline: cfg.deferral.deadline_for(r.class, r.arrival_s),
                    dispatched_t: r.arrival_s,
                    first_token_t: None,
                    decoded: 0,
                }
            })
            .collect();
        let servers: Vec<Server> = cfg.servers.iter().map(Server::new).collect();
        let mut queue = EventQueue::default();
        for (i, j) in jobs.iter().enumerate() {
            queue.push(j.arrival, EventKind::Arrival(i));
        }
        let prompt_eligible: Vec<usize> = servers
            .iter()
            .enumerate()
            .filter(|(_, s)| s.spec.role != Role::Decode)
            .map(|(i, _)| i)
            .collect();
        assert!(!prompt_eligible.is_empty(), "no prompt-capable servers");
        Sim {
            model,
            cfg,
            route,
            batch,
            jobs,
            servers,
            queue,
            metrics,
            meter: CarbonMeter::new(cfg),
            defer: DeferState::new(cfg.deferral),
            prompt_eligible,
            now: 0.0,
        }
    }

    /// Drain the event queue to completion.
    pub fn run(&mut self) {
        while let Some(ev) = self.queue.pop() {
            self.now = ev.t;
            self.metrics.events += 1;
            match ev.kind {
                EventKind::Arrival(ji) => {
                    if self.jobs[ji].class == RequestClass::Offline {
                        let release =
                            self.defer.release_time(self.now, self.meter.primary());
                        if let Some(t) = release {
                            self.metrics.deferred += 1;
                            self.queue.push(t, EventKind::Release(ji));
                            continue;
                        }
                    }
                    self.dispatch(ji);
                }
                EventKind::Release(ji) => self.dispatch(ji),
                EventKind::Wake(sid) => {
                    if !self.servers[sid].in_flight {
                        self.step(sid);
                    }
                }
                EventKind::Handoff { job, server } => {
                    let class = self.jobs[job].class;
                    self.servers[server].decode_q.push(job, class);
                    self.queue.push(self.now, EventKind::Wake(server));
                }
                EventKind::Complete { server, gen } => {
                    // A new busy period only starts once the previous one's
                    // Complete has fired, so the named generation always
                    // matches — `in_flight` is the operative guard and the
                    // generation is a checked invariant.
                    debug_assert_eq!(self.servers[server].busy_gen, gen,
                                     "Complete must end the period it named");
                    self.servers[server].in_flight = false;
                    self.step(server);
                }
            }
        }
    }

    /// Route a request and nudge the chosen server.
    fn dispatch(&mut self, ji: usize) {
        self.jobs[ji].dispatched_t = self.now;
        let ctx = RouteCtx { now: self.now, meter: &self.meter };
        let sid = self.route.route(&self.jobs[ji], &self.servers,
                                   &self.prompt_eligible, &ctx);
        debug_assert!(self.prompt_eligible.contains(&sid),
                      "policy routed to an ineligible server");
        let class = self.jobs[ji].class;
        self.servers[sid].prompt_q.push(ji, class);
        self.queue.push(self.now, EventKind::Wake(sid));
    }

    /// Close the books: idle-floor energy, operational + embodied carbon.
    pub fn finish(mut self, trace: &[Request]) -> SimReport {
        let dur = self.now.max(trace.last().map(|r| r.arrival_s).unwrap_or(0.0));
        let mut energy = 0.0;
        for (i, s) in self.servers.iter().enumerate() {
            let tpf = s.spec.tp as f64;
            let idle_s = (dur - s.busy_s).max(0.0);
            let idle_j = idle_s * s.spec.device.idle_w * tpf;
            self.meter.record_idle(i, idle_j, dur);
            energy += s.energy_j + idle_j;
        }
        let emb: f64 = self.cfg.emb_kg_per_hr.iter().map(|r| r * dur / 3600.0).sum();
        self.metrics.into_report(dur, energy, self.meter.op_kg(), emb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sim::{homogeneous_fleet, simulate};
    use crate::workload::{generate_trace, Arrivals, LengthDist};

    fn small_trace(rate: f64, seed: u64) -> Vec<Request> {
        generate_trace(Arrivals::Poisson { rate }, LengthDist::ShareGpt,
                       RequestClass::Online, 120.0, seed)
    }

    fn cfg_for(servers: Vec<ServerSpec>, router: Router) -> SimConfig {
        let n = servers.len();
        SimConfig::flat(servers, router, 261.0, vec![0.005; n])
    }

    #[test]
    fn event_order_is_total_and_fifo_at_ties() {
        let mut q = EventQueue::default();
        q.push(2.0, EventKind::Wake(0));
        q.push(1.0, EventKind::Wake(1));
        q.push(1.0, EventKind::Wake(2));
        q.push(1.0, EventKind::Wake(3));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::Wake(s) => s,
                _ => unreachable!(),
            })
            .collect();
        // Equal timestamps pop in push order; later time last.
        assert_eq!(order, vec![1, 2, 3, 0]);
    }

    #[test]
    fn nan_timestamps_do_not_break_the_heap() {
        // total_cmp orders NaN after +inf; the queue still drains fully.
        let mut q = EventQueue::default();
        q.push(f64::NAN, EventKind::Wake(0));
        q.push(0.5, EventKind::Wake(1));
        q.push(f64::NAN, EventKind::Wake(2));
        let popped: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(popped.len(), 3);
        assert!(matches!(popped[0].kind, EventKind::Wake(1)));
    }

    #[test]
    fn completes_all_requests() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(2.0, 1);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 4, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(r.completed, tr.len());
        assert!(r.generated_tokens > 0);
        assert!(r.op_kg > 0.0 && r.emb_kg > 0.0);
        assert!(r.events >= 2 * tr.len());
    }

    #[test]
    fn overload_degrades_ttft() {
        let m = models::llm("llama-8b").unwrap();
        let cfg = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let mut light = simulate(m, &small_trace(0.5, 2), &cfg, 0.5, 0.1);
        let mut heavy = simulate(m, &small_trace(12.0, 2), &cfg, 0.5, 0.1);
        assert!(heavy.ttft.p90() > light.ttft.p90(),
                "heavy {} vs light {}", heavy.ttft.p90(), light.ttft.p90());
    }

    #[test]
    fn more_servers_more_throughput_headroom() {
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(8.0, 3);
        let small = cfg_for(homogeneous_fleet("A100-40", 2, m, 2048), Router::Jsq);
        let big = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let mut r_small = simulate(m, &tr, &small, 0.5, 0.1);
        let mut r_big = simulate(m, &tr, &big, 0.5, 0.1);
        assert!(r_big.ttft.p90() <= r_small.ttft.p90() * 1.1 + 1e-9,
                "big {} small {}", r_big.ttft.p90(), r_small.ttft.p90());
        assert!(r_big.slo_attainment >= r_small.slo_attainment);
    }

    #[test]
    fn disaggregated_pd_split_works() {
        let m = models::llm("llama-8b").unwrap();
        let mut servers = homogeneous_fleet("H100", 2, m, 2048);
        servers[0].role = Role::Prompt;
        servers[1].role = Role::Decode;
        let cfg = cfg_for(servers, Router::Jsq);
        let r = simulate(m, &small_trace(1.0, 4), &cfg, 0.5, 0.1);
        assert_eq!(r.completed, simulate(m, &small_trace(1.0, 4),
            &cfg_for(homogeneous_fleet("H100", 2, m, 2048), Router::Jsq),
            0.5, 0.1).completed);
        assert!(r.ttft.len() > 0 && r.tpot.len() > 0);
    }

    #[test]
    fn energy_includes_idle_floor() {
        let m = models::llm("llama-8b").unwrap();
        // One request on a big fleet: idle power dominates.
        let tr = small_trace(0.05, 6);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 8, m, 2048), Router::Jsq);
        let r = simulate(m, &tr, &cfg, 0.5, 0.1);
        let idle_j = r.sim_duration_s * 8.0 * 50.0; // 8x idle 50 W
        assert!(r.energy_j > 0.8 * idle_j, "energy {} idle floor {idle_j}", r.energy_j);
    }

    #[test]
    fn same_config_same_bytes() {
        // The core is deterministic: two runs over the same trace agree on
        // every counter, including the event count.
        let m = models::llm("llama-8b").unwrap();
        let tr = small_trace(4.0, 8);
        let cfg = cfg_for(homogeneous_fleet("A100-40", 3, m, 2048), Router::Jsq);
        let a = simulate(m, &tr, &cfg, 0.5, 0.1);
        let b = simulate(m, &tr, &cfg, 0.5, 0.1);
        assert_eq!(a.events, b.events);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.op_kg.to_bits(), b.op_kg.to_bits());
    }
}
