//! Planner integration: full trace -> slices -> ILP -> plan pipeline across
//! models, strategies, and CI levels; fleet feasibility checks.

use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::planner::{plan, Phase, PlanConfig};
use ecoserve::solver::MilpStatus;
use ecoserve::strategies::Strategy;
use ecoserve::workload::slo::{slo_for, Slo};
use ecoserve::workload::{generate_trace, merge_traces, Arrivals, LengthDist,
                         RequestClass};

fn workload(model: &'static ecoserve::models::LlmSpec, rate: f64)
    -> Vec<ecoserve::planner::slicing::Slice> {
    let online = generate_trace(Arrivals::Bursty { rate, cv: 2.0 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                300.0, 3);
    let offline = generate_trace(Arrivals::Poisson { rate: rate / 2.0 },
                                 LengthDist::LongBench, RequestClass::Offline,
                                 300.0, 4);
    let tr = merge_traces(vec![online, offline]);
    let slo = slo_for(model.name, false).map(|w| w.slo)
        .unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 });
    cluster_slices(&slice_trace(model, &tr, 300.0, slo, 1))
}

#[test]
fn full_pipeline_for_model_suite() {
    for name in ["gemma-2b", "llama-8b", "gemma-27b", "llama-70b"] {
        let m = models::llm(name).unwrap();
        let slices = workload(m, 6.0);
        let p = plan(&slices, &PlanConfig::default());
        assert!(matches!(p.status, MilpStatus::Optimal | MilpStatus::Feasible),
                "{name}: {:?}", p.status);
        assert!(p.total_gpus() > 0, "{name}: empty fleet");
        // Every slice-phase routed.
        let expected = slices.len() * 2;
        assert_eq!(p.assignments.len(), expected, "{name}");
        // Capacity: load per device type never exceeds count.
        for (dev, &count) in &p.counts {
            if dev == "cpu-host" { continue; }
            let load: f64 = p.assignments.iter()
                .filter(|a| &a.device == dev)
                .map(|a| a.load)
                .sum();
            assert!(load <= count as f64 + 1e-6,
                    "{name}: {dev} load {load} > count {count}");
        }
    }
}

#[test]
fn slo_respected_in_assignments() {
    let m = models::llm("llama-8b").unwrap();
    let slices = workload(m, 8.0);
    let p = plan(&slices, &PlanConfig::default());
    // Best-effort fallback columns are allowed to exceed the SLO; the
    // overwhelming majority must meet it.
    let total = p.assignments.len();
    let ok = p.assignments.iter().filter(|a| {
        let s = &slices[a.slice_idx];
        match a.phase {
            Phase::Prompt => a.latency_s <= s.slo.ttft_s + 1e-9,
            Phase::Decode => s.offline || a.latency_s <= s.slo.tpot_s + 1e-9,
        }
    }).count();
    assert!(ok as f64 >= 0.9 * total as f64, "only {ok}/{total} within SLO");
}

#[test]
fn alpha_sweeps_cost_carbon_tradeoff() {
    let m = models::llm("llama-8b").unwrap();
    let slices = workload(m, 8.0);
    let carbon_heavy = plan(&slices, &PlanConfig { alpha: 1.0, ..Default::default() });
    let cost_heavy = plan(&slices, &PlanConfig { alpha: 0.0, ..Default::default() });
    assert!(carbon_heavy.carbon_kg_per_hr() <= cost_heavy.carbon_kg_per_hr() + 1e-9);
    // Cost ordering holds up to heuristic-incumbent slack (the solver may
    // return the greedy warm start when search truncates).
    assert!(cost_heavy.cost_hr <= carbon_heavy.cost_hr * 1.25 + 1e-9,
            "cost α=0 {} vs α=1 {}", cost_heavy.cost_hr, carbon_heavy.cost_hr);
}

#[test]
fn strategies_rank_consistently_across_ci() {
    let m = models::llm("llama-8b").unwrap();
    let slices = workload(m, 8.0);
    for ci in [17.0, 261.0, 501.0] {
        let eco = Strategy::EcoFull.plan(&slices, ci).carbon_kg_per_hr();
        for s in Strategy::all() {
            let c = s.plan(&slices, ci).carbon_kg_per_hr();
            assert!(eco <= c * 1.02,
                    "CI {ci}: ecoserve {eco} vs {} {c}", s.name());
        }
    }
}

#[test]
fn planner_scales_sublinearly() {
    // Table 3's property: 16x cluster growth costs << 16x solve time.
    let m = models::llm("llama-8b").unwrap();
    let solve_at = |rate: f64| {
        let slices = workload(m, rate);
        plan(&slices, &PlanConfig::default()).solve_s
    };
    let t_small = solve_at(4.0).max(1e-4);
    let t_big = solve_at(64.0);
    assert!(t_big < t_small * 40.0, "small {t_small}s big {t_big}s");
    assert!(t_big < 5.0, "big solve too slow: {t_big}s");
}
