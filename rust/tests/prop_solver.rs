//! Property tests for the LP/MILP substrate: optimality vs brute force,
//! feasibility of returned solutions, relaxation bounds.

use ecoserve::solver::lp::{self, Cmp, LpStatus, Row};
use ecoserve::solver::{milp, MilpConfig, MilpStatus};
use ecoserve::testkit::{forall, PropConfig};
use ecoserve::util::rng::Rng;

#[derive(Debug, Clone)]
struct Knapsack {
    values: Vec<f64>,
    weights: Vec<f64>,
    cap: f64,
}

fn gen_knapsack(r: &mut Rng) -> Knapsack {
    let n = 2 + r.below(7);
    Knapsack {
        values: (0..n).map(|_| (1.0 + r.f64() * 9.0).round()).collect(),
        weights: (0..n).map(|_| (1.0 + r.f64() * 9.0).round()).collect(),
        cap: (5.0 + r.f64() * 20.0).round(),
    }
}

fn brute_force(k: &Knapsack) -> f64 {
    let n = k.values.len();
    let mut best = 0.0f64;
    for mask in 0..(1usize << n) {
        let (mut v, mut w) = (0.0, 0.0);
        for i in 0..n {
            if mask & (1 << i) != 0 {
                v += k.values[i];
                w += k.weights[i];
            }
        }
        if w <= k.cap + 1e-9 {
            best = best.max(v);
        }
    }
    best
}

#[test]
fn milp_matches_brute_force_knapsack() {
    forall(
        &PropConfig { cases: 60, ..Default::default() },
        gen_knapsack,
        |k| {
            let mut out = Vec::new();
            if k.values.len() > 2 {
                let mut s = k.clone();
                s.values.pop();
                s.weights.pop();
                out.push(s);
            }
            out
        },
        |k| {
            let n = k.values.len();
            let c: Vec<f64> = k.values.iter().map(|v| -v).collect();
            let mut rows = vec![Row {
                coeffs: k.weights.iter().cloned().enumerate().collect(),
                cmp: Cmp::Le,
                rhs: k.cap,
            }];
            for j in 0..n {
                rows.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
            }
            let sol = milp::solve(n, &c, &rows, &vec![true; n], &MilpConfig::default());
            let expect = brute_force(k);
            if sol.status != MilpStatus::Optimal {
                return Err(format!("status {:?}", sol.status));
            }
            if (-sol.objective - expect).abs() > 1e-6 {
                return Err(format!("milp {} vs brute {expect}", -sol.objective));
            }
            Ok(())
        },
    );
}

#[derive(Debug, Clone)]
struct RandomLp {
    n: usize,
    c: Vec<f64>,
    rows: Vec<Row>,
}

fn gen_lp(r: &mut Rng) -> RandomLp {
    let n = 2 + r.below(5);
    let m = 1 + r.below(5);
    let c: Vec<f64> = (0..n).map(|_| r.range(0.1, 5.0)).collect();
    // Feasible by construction: a·x <= b with b >= 0 and a >= 0, plus a
    // couple of >= floors that are mutually satisfiable.
    let mut rows: Vec<Row> = (0..m)
        .map(|_| Row {
            coeffs: (0..n).map(|j| (j, r.range(0.0, 3.0))).collect(),
            cmp: Cmp::Le,
            rhs: r.range(1.0, 20.0),
        })
        .collect();
    rows.push(Row { coeffs: vec![(0, 1.0)], cmp: Cmp::Ge, rhs: 0.1 });
    RandomLp { n, c, rows }
}

#[test]
fn lp_solutions_are_feasible() {
    forall(
        &PropConfig { cases: 80, ..Default::default() },
        gen_lp,
        |_| Vec::new(),
        |p| {
            let sol = lp::solve(p.n, &p.c, &p.rows);
            if sol.status == LpStatus::Infeasible {
                // Floor of 0.1 on x0 can conflict with a tight <= row; fine.
                return Ok(());
            }
            if sol.status != LpStatus::Optimal {
                return Err(format!("status {:?}", sol.status));
            }
            for (i, row) in p.rows.iter().enumerate() {
                let lhs: f64 = row.coeffs.iter().map(|(j, a)| a * sol.x[*j]).sum();
                let ok = match row.cmp {
                    Cmp::Le => lhs <= row.rhs + 1e-6,
                    Cmp::Ge => lhs >= row.rhs - 1e-6,
                    Cmp::Eq => (lhs - row.rhs).abs() <= 1e-6,
                };
                if !ok {
                    return Err(format!("row {i} violated: {lhs} vs {}", row.rhs));
                }
            }
            if sol.x.iter().any(|&x| x < -1e-9) {
                return Err("negative variable".into());
            }
            Ok(())
        },
    );
}

#[test]
fn relaxation_bounds_milp() {
    forall(
        &PropConfig { cases: 40, ..Default::default() },
        gen_knapsack,
        |_| Vec::new(),
        |k| {
            let n = k.values.len();
            let c: Vec<f64> = k.values.iter().map(|v| -v).collect();
            let mut rows = vec![Row {
                coeffs: k.weights.iter().cloned().enumerate().collect(),
                cmp: Cmp::Le,
                rhs: k.cap,
            }];
            for j in 0..n {
                rows.push(Row { coeffs: vec![(j, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
            }
            let rel = lp::solve(n, &c, &rows);
            let int = milp::solve(n, &c, &rows, &vec![true; n], &MilpConfig::default());
            if rel.status != LpStatus::Optimal || int.status != MilpStatus::Optimal {
                return Err("unexpected status".into());
            }
            if rel.objective > int.objective + 1e-6 {
                return Err(format!("relaxation {} worse than MILP {}",
                                   rel.objective, int.objective));
            }
            Ok(())
        },
    );
}
