//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored path
//! crate implements exactly the subset EcoServe uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait (for both `Result<_, E: StdError>` and
//! `Result<_, Error>`). Swapping in the real `anyhow` is a one-line
//! Cargo.toml change; no call site needs to move.

use std::error::Error as StdError;
use std::fmt;

/// An error message with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a standard error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The deepest underlying error, if one was preserved.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// Like real anyhow: any std error converts into Error via `?`. This does
// not overlap with `From<Error> for Error` because Error itself
// deliberately does not implement std::error::Error.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_wraps_message() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing");
        let r2: Result<(), Error> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e2.to_string(), "outer 1: inner");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
