//! Determinism and format suite for the passive observability layer:
//! obs-off runs must be byte-identical to the pre-observability engine,
//! obs-on artifacts must be byte-identical across shard-thread budgets
//! and across repeat runs, the span export must load as Chrome
//! trace-event JSON, and the timeline CSV header is golden.

use ecoserve::models;
use ecoserve::obs::{ObsArtifacts, ObsSettings, Observer};
use ecoserve::scenarios::{catalog, run_spec, run_spec_observed,
                          run_spec_sharded, scenario_seed};
use ecoserve::sim::{homogeneous_fleet, simulate_stream_observed, FaultPlan,
                    FleetAction, FleetEvent, Router, SimConfig};
use ecoserve::util::json::Json;
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass,
                         SliceSource};

fn obs_settings(rate: f64, interval_s: f64) -> ObsSettings {
    ObsSettings {
        timeline_interval_s: Some(interval_s),
        trace_jobs_rate: rate,
        profile: true,
        progress_s: None,
    }
}

/// Run `name` observed and return (outcome JSON, artifacts).
fn observed(name: &str, seed: u64, duration_s: f64, shards: Option<usize>,
            settings: &ObsSettings) -> (String, ObsArtifacts) {
    let s = catalog::by_names(&[name]).unwrap().remove(0);
    let (out, art) = run_spec_observed(name, &s.spec(), seed, duration_s,
                                       shards, settings);
    (out.to_json().to_string(), art)
}

#[test]
fn artifacts_are_byte_identical_across_shard_budgets() {
    // The headline determinism gate: the shard partition is a pure
    // function of the fleet and recorders fold in ascending shard index,
    // so timeline and span bytes are invariant in the thread budget —
    // and a repeat run reproduces them exactly.
    let name = "carbon-router";
    let seed = scenario_seed(71, name);
    let settings = obs_settings(0.25, 10.0);
    let runs: Vec<(String, ObsArtifacts)> = [1usize, 2, 4]
        .iter()
        .map(|&n| observed(name, seed, 60.0, Some(n), &settings))
        .collect();
    for (i, n) in [2usize, 4].iter().enumerate() {
        assert_eq!(runs[0].0, runs[i + 1].0,
                   "{name}: outcome bytes diverged at {n} shard threads");
        assert_eq!(runs[0].1.timeline_csv, runs[i + 1].1.timeline_csv,
                   "{name}: timeline bytes diverged at {n} shard threads");
        assert_eq!(runs[0].1.spans_json, runs[i + 1].1.spans_json,
                   "{name}: span bytes diverged at {n} shard threads");
    }
    let again = observed(name, seed, 60.0, Some(2), &settings);
    assert_eq!(runs[1].1.timeline_csv, again.1.timeline_csv,
               "repeat run must reproduce the timeline bytes");
    assert_eq!(runs[1].1.spans_json, again.1.spans_json,
               "repeat run must reproduce the span bytes");

    // The merged grid is complete: header + floor(60/10)+1 rows.
    let csv = runs[0].1.timeline_csv.as_ref().expect("timeline requested");
    assert_eq!(csv.lines().count(), 1 + 7, "timeline grid rows");
    assert!(runs[0].1.profile_json.is_some(), "profile requested");
}

#[test]
fn observed_outcome_bytes_match_unobserved() {
    // Byte-neutrality: attaching the recorders must not perturb a single
    // outcome byte — one scenario from each of the core, replay, and
    // failure packs, unsharded and sharded.
    let settings = obs_settings(1.0, 5.0);
    for name in ["carbon-router", "replay-day", "failure-storm"] {
        let s = catalog::by_names(&[name]).unwrap().remove(0);
        let seed = scenario_seed(23, name);
        let plain = run_spec(name, &s.spec(), seed, 40.0)
            .to_json().to_string();
        let (obs, _) = run_spec_observed(name, &s.spec(), seed, 40.0, None,
                                         &settings);
        assert_eq!(plain, obs.to_json().to_string(),
                   "{name}: observers changed the unsharded outcome bytes");
        let plain_sh = run_spec_sharded(name, &s.spec(), seed, 40.0, 2)
            .to_json().to_string();
        let (obs_sh, _) = run_spec_observed(name, &s.spec(), seed, 40.0,
                                            Some(2), &settings);
        assert_eq!(plain_sh, obs_sh.to_json().to_string(),
                   "{name}: observers changed the sharded outcome bytes");
    }
}

#[test]
fn failure_storm_span_trace_is_chrome_loadable_and_deterministic() {
    // Rate 1.0 samples every job; the storm's mid-trace kills must show
    // up as reroute instants on the killed servers' tracks, and the
    // export must parse as `{"traceEvents": [...]}`.
    let name = "failure-storm";
    let seed = scenario_seed(47, name);
    let settings = obs_settings(1.0, 15.0);
    let (out_json, art) = observed(name, seed, 60.0, None, &settings);
    let again = observed(name, seed, 60.0, None, &settings);
    assert_eq!(art.spans_json, again.1.spans_json,
               "span trace must be reproducible run-to-run");

    let json = art.spans_json.as_ref().expect("spans requested");
    let parsed = Json::parse(json).expect("chrome export must parse");
    let events = parsed.get("traceEvents").and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let phases: Vec<&str> = events.iter()
        .filter_map(|e| e.get("ph").and_then(|p| p.as_str()))
        .collect();
    assert!(phases.contains(&"M"), "process-name metadata events");
    assert!(phases.contains(&"X"), "queue/prefill/decode slices");
    assert!(phases.contains(&"i"), "lifecycle instants");
    let names: Vec<&str> = events.iter()
        .filter_map(|e| e.get("name").and_then(|p| p.as_str()))
        .collect();
    for expect in ["arrival", "route", "prefill", "complete"] {
        assert!(names.contains(&expect), "missing {expect} events");
    }

    let out = Json::parse(&out_json).unwrap();
    let extra = |k: &str| out.get("extras").and_then(|e| e.get(k))
        .and_then(|v| v.as_f64()).unwrap_or(0.0);
    if extra("jobs_rescheduled") > 0.0 {
        assert!(names.contains(&"reroute"),
                "rescheduled jobs must leave reroute edges in the trace");
    }
    // Server 0 always survives the storm, so nothing ever parks.
    if extra("jobs_recovered") == 0.0 {
        assert!(!names.contains(&"park"),
                "no park instants without recovery-queue traffic");
    }
}

#[test]
fn park_and_recover_edges_reach_the_span_trace() {
    // Total-capacity-loss fixture from the core suite
    // (`total_capacity_loss_parks_jobs_until_recovery`), observed: both
    // servers die at t=30 and re-provision at t=60, so arrivals in
    // (30, 60) park in the recovery queue and drain on return — the span
    // trace must carry the park/recover instants and the timeline must
    // show a non-empty recovery queue in between.
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate: 2.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            120.0, 17);
    let mut cfg = SimConfig::flat(homogeneous_fleet("A100-40", 2, m, 2048),
                                  Router::Jsq, 261.0, vec![0.005; 2]);
    cfg.faults = FaultPlan::new()
        .server_death(30.0, 0)
        .server_death(30.0, 1);
    for server in [0, 1] {
        cfg.fleet_plan.events.push(FleetEvent {
            t: 60.0, server, action: FleetAction::Provision,
        });
    }
    let settings = obs_settings(1.0, 10.0);
    let mut obs = Observer::for_run(&settings, 120.0, 0xEC05,
                                    vec!["ci_primary".to_string()], 2);
    let route = cfg.router.policy();
    let batch = cfg.batcher.policy();
    let mut src = SliceSource::new(&tr);
    let r = simulate_stream_observed(m, &mut src, &cfg, 0.5, 0.1,
                                     route, batch, Some(&mut obs));
    assert_eq!(r.completed, tr.len());
    assert!(r.jobs_recovered > 0, "arrivals in (30,60) must park");

    let spans = obs.spans.as_ref().expect("span recorder attached");
    let labels = vec!["s0 A100-40".to_string(), "s1 A100-40".to_string()];
    let json = spans.to_chrome_json(&labels);
    Json::parse(&json).expect("park/recover export must parse");
    assert!(json.contains("\"name\":\"park\""), "park instants recorded");
    assert!(json.contains("\"name\":\"recover\""),
            "recover instants recorded");

    let csv = obs.timeline.as_ref().expect("timeline attached").to_csv();
    let peak_recovery = csv.lines().skip(1)
        .filter_map(|l| l.split(',').nth(9))
        .filter_map(|v| v.parse::<usize>().ok())
        .max().unwrap_or(0);
    assert!(peak_recovery > 0,
            "recovery-queue depth must surface in the timeline: {csv}");
}

#[test]
fn timeline_csv_header_is_golden() {
    // The fixed column set is an external contract (plotting scripts,
    // `inspect`): changing it is a deliberate golden update.
    let name = "online-latency";
    let seed = scenario_seed(5, name);
    let (_, art) = observed(name, seed, 30.0, None, &obs_settings(0.0, 10.0));
    let csv = art.timeline_csv.expect("timeline requested");
    assert_eq!(csv.lines().next().unwrap(),
               "t_s,pending,active,draining,retired,q_prompt_online,\
                q_prompt_offline,q_decode_online,q_decode_offline,recovery,\
                power_w,op_kg,emb_kg,online_done,slo_ok,slo_window,\
                ci_primary");
    // A two-region fleet under a time-varying CI profile appends one CI
    // column per configured region signal.
    let (_, art2) = observed("production-day",
                             scenario_seed(5, "production-day"),
                             30.0, None, &obs_settings(0.0, 10.0));
    let header = art2.timeline_csv.expect("timeline requested");
    let header = header.lines().next().unwrap().to_string();
    assert!(header.starts_with("t_s,"), "{header}");
    assert!(header.contains(",ci_primary"), "{header}");
    assert!(header.split(',').count() > 17,
            "two-region fleet must add region CI columns: {header}");
}

#[test]
fn span_sampling_is_rate_monotone_and_shard_invariant() {
    // Sampling is a pure function of the request: the rate-0.2 sample
    // set must be a subset of the rate-1.0 set (same seed), and the
    // sampled job ids must not depend on the shard budget.
    let name = "carbon-router";
    let seed = scenario_seed(9, name);
    let ids = |art: &ObsArtifacts| -> Vec<String> {
        let parsed = Json::parse(art.spans_json.as_ref().unwrap()).unwrap();
        let mut ids: Vec<String> = parsed.get("traceEvents")
            .and_then(|e| e.as_arr()).unwrap()
            .iter()
            .filter_map(|e| e.get("args").and_then(|a| a.get("job"))
                .and_then(|j| j.as_str()).map(str::to_string))
            .collect();
        ids.sort();
        ids.dedup();
        ids
    };
    let (_, all) = observed(name, seed, 40.0, Some(1), &obs_settings(1.0, 20.0));
    let (_, some) = observed(name, seed, 40.0, Some(1),
                             &obs_settings(0.2, 20.0));
    let (_, some4) = observed(name, seed, 40.0, Some(4),
                              &obs_settings(0.2, 20.0));
    let (all_ids, some_ids, some4_ids) = (ids(&all), ids(&some), ids(&some4));
    assert!(!all_ids.is_empty(), "rate 1.0 must sample every job");
    assert!(some_ids.len() < all_ids.len(),
            "rate 0.2 must thin the sample set");
    assert!(some_ids.iter().all(|id| all_ids.binary_search(id).is_ok()),
            "low-rate samples must be a subset of the full set");
    assert_eq!(some_ids, some4_ids,
               "sampled job ids must not depend on the shard budget");
}
