//! END-TO-END DRIVER (DESIGN.md §4): serve a real AOT-compiled model
//! through the full three-layer stack — Pallas split-KV decode kernel →
//! JAX transformer → HLO text → PJRT CPU → rust continuous-batching
//! coordinator — under a mixed online/offline load, and report latency,
//! throughput, and the serving carbon estimate.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example serve_model [-- --requests 24 --rate 2.0]

use ecoserve::carbon::operational::op_kg;
use ecoserve::coordinator::{Coordinator, CoordinatorConfig, ServeRequest};
use ecoserve::runtime::engine::Engine;
use ecoserve::runtime::tokenizer;
use ecoserve::util::cli::Args;
use ecoserve::util::rng::Rng;
use ecoserve::util::stats::Samples;
use ecoserve::util::table::{fnum, ftime, Table};
use ecoserve::workload::RequestClass;
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n_req = args.usize("requests", 24);
    let rate = args.f64("rate", 2.0);
    let dir = PathBuf::from(args.str("artifacts", "artifacts"));

    println!("loading artifacts from {} ...", dir.display());
    let t0 = Instant::now();
    let eng = Engine::load(&dir)?;
    println!("engine ready in {:.1}s ({} prefill buckets, decode buckets {:?})",
             t0.elapsed().as_secs_f64(), eng.manifest.prefill_buckets.len(),
             eng.decode_buckets());

    let mut coord = Coordinator::new(&eng, CoordinatorConfig::default())?;
    let mut rng = Rng::new(42);
    let corpus = ["the carbon footprint of inference",
                  "schedule offline decode on host cpus",
                  "rightsize the gpu fleet for each slice",
                  "extend host lifetimes and recycle"];

    // Open-loop Poisson arrivals, mixed online/offline.
    let t_start = Instant::now();
    let mut submitted = 0u64;
    let mut next_arrival = 0.0f64;
    while submitted < n_req as u64 || !coord.is_idle() {
        let now = t_start.elapsed().as_secs_f64();
        while submitted < n_req as u64 && next_arrival <= now {
            let text = corpus[rng.below(corpus.len())];
            let class = if rng.bool(0.3) { RequestClass::Offline } else { RequestClass::Online };
            coord.submit(ServeRequest {
                id: submitted,
                tokens: tokenizer::encode(text),
                max_new_tokens: 8 + rng.below(24),
                class,
            });
            submitted += 1;
            next_arrival += rng.exp(rate);
        }
        coord.step()?;
        if coord.is_idle() && submitted < n_req as u64 {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let wall = t_start.elapsed().as_secs_f64();
    let done = coord.take_completions();

    let mut ttft = Samples::new();
    let mut tpot = Samples::new();
    let mut gen = 0usize;
    for c in &done {
        ttft.push(c.ttft_s);
        if c.tpot_s > 0.0 { tpot.push(c.tpot_s); }
        gen += c.output.len();
    }
    println!("\n== serving report ({} requests, {:.1}s wall) ==", done.len(), wall);
    let mut t = Table::new(&["metric", "p50", "p90", "mean"]);
    t.row(&["TTFT".into(), ftime(ttft.p50()), ftime(ttft.p90()), ftime(ttft.mean())]);
    t.row(&["TPOT".into(), ftime(tpot.p50()), ftime(tpot.p90()), ftime(tpot.mean())]);
    t.print();
    println!("throughput: {:.1} tok/s  | mean batch occupancy {:.2}  | decode steps {}",
             gen as f64 / wall, coord.stats.mean_batch_occupancy(),
             coord.stats.decode_steps);
    println!("engine time: prefill {:.2}s, decode {:.2}s, marshal {:.2}s",
             coord.stats.prefill_exec_s, coord.stats.decode_exec_s,
             coord.stats.marshal_s);

    // Serving-carbon estimate for this run on the host (SPR-like, RAPL
    // substitute: dynamic share of TDP at measured duty cycle).
    let cpu = ecoserve::hw::cpu("SPR-56").unwrap();
    let duty = (coord.stats.prefill_exec_s + coord.stats.decode_exec_s) / wall;
    let power = cpu.idle_w + (cpu.tdp_w - cpu.idle_w) * duty.min(1.0);
    let mut t = Table::new(&["region", "CI g/kWh", "run carbon (g)"]);
    for r in ecoserve::carbon::intensity::Region::low_mid_high() {
        t.row(&[r.name().into(), fnum(r.avg_ci()),
                fnum(op_kg(power, wall, r.avg_ci()) * 1000.0)]);
    }
    t.print();
    println!("\nsample output: {:?}",
             tokenizer::decode(&done[0].output).chars().take(48).collect::<String>());
    Ok(())
}
