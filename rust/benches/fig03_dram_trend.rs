//! Fig 3: embodied carbon per GB across DRAM technologies.
use ecoserve::carbon::embodied::mem_kg_per_gb;
use ecoserve::hw::MemTech;
use ecoserve::util::table::{fnum, Table};

fn main() {
    println!("== Fig 3: kgCO2e per GB by memory technology ==");
    let mut t = Table::new(&["tech", "kgCO2e/GB", "rel. bit-density (proxy)"]);
    for (name, tech, dens) in [
        ("GDDR5", MemTech::Gddr5, 1.0),
        ("DDR4/LPDDR5", MemTech::Ddr4, 1.4),
        ("GDDR6", MemTech::Gddr6, 1.1),
        ("HBM2", MemTech::Hbm2, 1.5),
        ("HBM2e", MemTech::Hbm2e, 1.6),
        ("HBM3", MemTech::Hbm3, 1.7),
        ("HBM3e", MemTech::Hbm3e, 1.9),
    ] {
        t.row(&[name.into(), fnum(mem_kg_per_gb(tech)), fnum(dens)]);
    }
    t.print();
    println!("(newer nodes: higher bit density -> lower embodied per GB)");
}
