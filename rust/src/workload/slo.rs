//! Service-level objectives per model (the paper's §5 table).

use super::LengthDist;

/// SLO pair: TTFT (time to first token) and TPOT (time per output token).
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    pub ttft_s: f64,
    pub tpot_s: f64,
}

/// Offline jobs carry a completion deadline instead of latency SLOs.
pub const OFFLINE_DEADLINE_S: f64 = 24.0 * 3600.0;

/// One row of the paper's §5 workload table.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub model: &'static str,
    pub slo: Slo,
    pub dataset: LengthDist,
    pub offline: bool,
}

/// The paper's model/SLO/dataset matrix.
pub fn workload_table() -> &'static [WorkloadSpec] {
    &[
        WorkloadSpec { model: "gemma-2b", slo: Slo { ttft_s: 0.25, tpot_s: 0.10 },
                       dataset: LengthDist::ShareGpt, offline: false },
        WorkloadSpec { model: "llama-8b", slo: Slo { ttft_s: 0.5, tpot_s: 0.10 },
                       dataset: LengthDist::ShareGpt, offline: false },
        WorkloadSpec { model: "llama-13b", slo: Slo { ttft_s: 1.5, tpot_s: 0.15 },
                       dataset: LengthDist::AzureCode, offline: false },
        WorkloadSpec { model: "llama-70b", slo: Slo { ttft_s: 15.0, tpot_s: 0.24 },
                       dataset: LengthDist::AzureCode, offline: false },
        WorkloadSpec { model: "mixtral-8x7b", slo: Slo { ttft_s: 2.5, tpot_s: 0.15 },
                       dataset: LengthDist::ShareGpt, offline: false },
        WorkloadSpec { model: "gemma-27b", slo: Slo { ttft_s: 10.0, tpot_s: 0.20 },
                       dataset: LengthDist::AzureCode, offline: false },
        WorkloadSpec { model: "gemma-27b", slo: Slo { ttft_s: OFFLINE_DEADLINE_S,
                                                      tpot_s: f64::INFINITY },
                       dataset: LengthDist::LongBench, offline: true },
        WorkloadSpec { model: "bloom-176b", slo: Slo { ttft_s: 20.0, tpot_s: 0.27 },
                       dataset: LengthDist::AzureCode, offline: false },
    ]
}

pub fn slo_for(model: &str, offline: bool) -> Option<&'static WorkloadSpec> {
    workload_table().iter().find(|w| w.model == model && w.offline == offline)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_complete() {
        assert_eq!(workload_table().len(), 8);
        assert!(slo_for("llama-70b", false).is_some());
        assert!(slo_for("gemma-27b", true).unwrap().offline);
    }

    #[test]
    fn bigger_models_get_looser_slos() {
        let small = slo_for("gemma-2b", false).unwrap().slo;
        let big = slo_for("bloom-176b", false).unwrap().slo;
        assert!(big.ttft_s > small.ttft_s);
        assert!(big.tpot_s > small.tpot_s);
    }

    #[test]
    fn offline_deadline_is_24h() {
        assert_eq!(OFFLINE_DEADLINE_S, 86_400.0);
        let off = slo_for("gemma-27b", true).unwrap();
        assert_eq!(off.slo.ttft_s, OFFLINE_DEADLINE_S);
    }
}
