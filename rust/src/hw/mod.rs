//! Hardware catalog: GPU / CPU / platform specs driving the carbon and
//! performance models.
//!
//! The paper's evaluation spans PCIe H100, A100, A6000, L4, A40 (plus T4,
//! V100, GH200 in the lifecycle studies) and dual-socket Sapphire Rapids
//! hosts. With no physical fleet available (DESIGN.md §1) the catalog holds
//! published specs: peak compute, memory technology/capacity/bandwidth, TDP,
//! idle power, die area + process node, PCB area, and cloud cost — exactly
//! the inputs the paper's offline profiling feeds its planner.

pub mod platform;

/// Memory technologies with distinct embodied-carbon intensities (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemTech {
    Ddr4,
    Ddr5,
    Lpddr5,
    Gddr5,
    Gddr6,
    Hbm2,
    Hbm2e,
    Hbm3,
    Hbm3e,
}

/// One GPU SKU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub year: u32,
    /// Peak dense FP16/BF16 tensor throughput, TFLOP/s.
    pub fp16_tflops: f64,
    pub mem_gb: f64,
    pub mem_tech: MemTech,
    pub mem_bw_gbs: f64,
    pub tdp_w: f64,
    pub idle_w: f64,
    pub die_mm2: f64,
    /// Logic process node in nm (drives the ACT-style die model).
    pub process_nm: f64,
    /// Board PCB area, cm².
    pub pcb_cm2: f64,
    /// Representative cloud price, $/hr.
    pub cost_hr: f64,
}

/// One CPU host SKU (socket-level).
#[derive(Debug, Clone)]
pub struct CpuSpec {
    pub name: &'static str,
    pub cores: usize,
    /// Sustained BF16/AMX throughput across all cores, TFLOP/s.
    pub bf16_tflops: f64,
    /// Aggregate DRAM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    pub tdp_w: f64,
    pub idle_w: f64,
    pub die_mm2: f64,
    pub process_nm: f64,
}

pub const fn gpu_catalog() -> &'static [GpuSpec] {
    &[
        GpuSpec { name: "K80", year: 2014, fp16_tflops: 8.7, mem_gb: 24.0,
                  mem_tech: MemTech::Gddr5, mem_bw_gbs: 480.0, tdp_w: 300.0,
                  idle_w: 60.0, die_mm2: 1122.0, process_nm: 28.0,
                  pcb_cm2: 580.0, cost_hr: 0.45 },
        GpuSpec { name: "P100", year: 2016, fp16_tflops: 21.2, mem_gb: 16.0,
                  mem_tech: MemTech::Hbm2, mem_bw_gbs: 732.0, tdp_w: 300.0,
                  idle_w: 30.0, die_mm2: 610.0, process_nm: 16.0,
                  pcb_cm2: 540.0, cost_hr: 0.95 },
        GpuSpec { name: "V100", year: 2017, fp16_tflops: 125.0, mem_gb: 32.0,
                  mem_tech: MemTech::Hbm2, mem_bw_gbs: 900.0, tdp_w: 300.0,
                  idle_w: 35.0, die_mm2: 815.0, process_nm: 12.0,
                  pcb_cm2: 540.0, cost_hr: 1.46 },
        GpuSpec { name: "T4", year: 2018, fp16_tflops: 65.0, mem_gb: 16.0,
                  mem_tech: MemTech::Gddr6, mem_bw_gbs: 320.0, tdp_w: 70.0,
                  idle_w: 10.0, die_mm2: 545.0, process_nm: 12.0,
                  pcb_cm2: 320.0, cost_hr: 0.35 },
        GpuSpec { name: "A40", year: 2020, fp16_tflops: 149.7, mem_gb: 48.0,
                  mem_tech: MemTech::Gddr6, mem_bw_gbs: 696.0, tdp_w: 300.0,
                  idle_w: 28.0, die_mm2: 628.0, process_nm: 8.0,
                  pcb_cm2: 560.0, cost_hr: 1.10 },
        GpuSpec { name: "A6000", year: 2020, fp16_tflops: 154.8, mem_gb: 48.0,
                  mem_tech: MemTech::Gddr6, mem_bw_gbs: 768.0, tdp_w: 300.0,
                  idle_w: 25.0, die_mm2: 628.0, process_nm: 8.0,
                  pcb_cm2: 560.0, cost_hr: 1.28 },
        GpuSpec { name: "A100-40", year: 2020, fp16_tflops: 312.0, mem_gb: 40.0,
                  mem_tech: MemTech::Hbm2, mem_bw_gbs: 1555.0, tdp_w: 400.0,
                  idle_w: 50.0, die_mm2: 826.0, process_nm: 7.0,
                  pcb_cm2: 600.0, cost_hr: 2.25 },
        GpuSpec { name: "A100-80", year: 2021, fp16_tflops: 312.0, mem_gb: 80.0,
                  mem_tech: MemTech::Hbm2e, mem_bw_gbs: 2039.0, tdp_w: 400.0,
                  idle_w: 52.0, die_mm2: 826.0, process_nm: 7.0,
                  pcb_cm2: 600.0, cost_hr: 3.05 },
        GpuSpec { name: "L4", year: 2023, fp16_tflops: 121.0, mem_gb: 24.0,
                  mem_tech: MemTech::Gddr6, mem_bw_gbs: 300.0, tdp_w: 72.0,
                  idle_w: 13.0, die_mm2: 294.0, process_nm: 5.0,
                  pcb_cm2: 320.0, cost_hr: 0.70 },
        GpuSpec { name: "H100", year: 2022, fp16_tflops: 756.0, mem_gb: 80.0,
                  mem_tech: MemTech::Hbm3, mem_bw_gbs: 2000.0, tdp_w: 350.0,
                  idle_w: 60.0, die_mm2: 814.0, process_nm: 4.0,
                  pcb_cm2: 600.0, cost_hr: 4.76 },
        GpuSpec { name: "GH200", year: 2023, fp16_tflops: 989.0, mem_gb: 96.0,
                  mem_tech: MemTech::Hbm3e, mem_bw_gbs: 4000.0, tdp_w: 700.0,
                  idle_w: 90.0, die_mm2: 814.0, process_nm: 4.0,
                  pcb_cm2: 800.0, cost_hr: 5.99 },
    ]
}

pub const fn cpu_catalog() -> &'static [CpuSpec] {
    &[
        // Dual-socket SPR 8480+ (2x56 cores); the paper's host testbed.
        CpuSpec { name: "SPR-112", cores: 112, bf16_tflops: 40.0,
                  mem_bw_gbs: 614.0, tdp_w: 700.0, idle_w: 160.0,
                  die_mm2: 1510.0, process_nm: 7.0 },
        // Single-socket 56-core variant (Fig 18's 56-core sweep).
        CpuSpec { name: "SPR-56", cores: 56, bf16_tflops: 20.0,
                  mem_bw_gbs: 307.0, tdp_w: 350.0, idle_w: 85.0,
                  die_mm2: 755.0, process_nm: 7.0 },
        // Older host generations (Recycle studies).
        CpuSpec { name: "SKX-48", cores: 48, bf16_tflops: 4.5,
                  mem_bw_gbs: 256.0, tdp_w: 330.0, idle_w: 80.0,
                  die_mm2: 1400.0, process_nm: 14.0 },
    ]
}

pub fn gpu(name: &str) -> Option<&'static GpuSpec> {
    gpu_catalog().iter().find(|g| g.name == name)
}

pub fn cpu(name: &str) -> Option<&'static CpuSpec> {
    cpu_catalog().iter().find(|c| c.name == name)
}

/// The GPU pool the planner chooses from by default (paper §5).
pub fn serving_gpus() -> Vec<&'static GpuSpec> {
    ["L4", "A40", "A6000", "A100-40", "A100-80", "H100"]
        .iter()
        .map(|n| gpu(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert_eq!(gpu("A100-40").unwrap().mem_gb, 40.0);
        assert_eq!(cpu("SPR-112").unwrap().cores, 112);
        assert!(gpu("B300").is_none());
    }

    #[test]
    fn generations_trend_upward() {
        // Fig 4's premise: newer generations raise compute AND embodied
        // inputs (die on denser nodes, more advanced memory).
        let v100 = gpu("V100").unwrap();
        let h100 = gpu("H100").unwrap();
        assert!(h100.fp16_tflops > 4.0 * v100.fp16_tflops);
        assert!(h100.process_nm < v100.process_nm);
    }

    #[test]
    fn l4_is_lean() {
        // Paper: "compared to an NVIDIA H100, an NVIDIA L4 incurs 3x lower
        // embodied carbon" — requires much smaller die/board/TDP.
        let l4 = gpu("L4").unwrap();
        let h100 = gpu("H100").unwrap();
        assert!(l4.die_mm2 < 0.4 * h100.die_mm2);
        assert!(l4.tdp_w < 0.25 * h100.tdp_w);
    }

    #[test]
    fn cpu_gpu_bandwidth_gap_smaller_than_compute_gap() {
        // Fig 8's premise: the CPU/GPU memory-bandwidth gap is far smaller
        // than the compute gap, which is what makes decode CPU-viable.
        let spr = cpu("SPR-112").unwrap();
        let a100 = gpu("A100-40").unwrap();
        let bw_gap = a100.mem_bw_gbs / spr.mem_bw_gbs;
        let compute_gap = a100.fp16_tflops / spr.bf16_tflops;
        assert!(bw_gap < 3.0, "bw gap {bw_gap}");
        assert!(compute_gap > 2.0 * bw_gap, "compute {compute_gap} bw {bw_gap}");
    }

    #[test]
    fn serving_pool_complete() {
        assert_eq!(serving_gpus().len(), 6);
    }
}
