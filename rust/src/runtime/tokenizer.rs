//! Byte-level tokenizer for the served model: token = byte + 3, with
//! PAD=0, BOS=1, EOS=2 (matching python/compile/model.py).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const BYTE_OFFSET: i32 = 3;

/// Encode text as BOS + bytes.
pub fn encode(text: &str) -> Vec<i32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.as_bytes().iter().map(|&b| b as i32 + BYTE_OFFSET));
    out
}

/// Decode tokens back to text, dropping specials and invalid UTF-8.
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t >= BYTE_OFFSET && t < BYTE_OFFSET + 256)
        .map(|&t| (t - BYTE_OFFSET) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

pub const VOCAB: usize = 259;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let toks = encode("hello, carbon!");
        assert_eq!(toks[0], BOS);
        assert_eq!(decode(&toks), "hello, carbon!");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "日本語 café";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped() {
        let mut toks = encode("ab");
        toks.push(EOS);
        toks.push(PAD);
        assert_eq!(decode(&toks), "ab");
    }

    #[test]
    fn tokens_in_vocab() {
        for t in encode("\u{0}\u{7f}xyz") {
            assert!((0..VOCAB as i32).contains(&t));
        }
    }
}
