//! Capacity-planning walkthrough: run every strategy over a demand trace
//! across the three CI regions and print the carbon/cost/fleet matrix —
//! the paper's Fig 15/16 workflow as a CLI tool.
//!
//! Run: `cargo run --release --example capacity_planner [-- --model llama-70b]`

use ecoserve::carbon::intensity::Region;
use ecoserve::models;
use ecoserve::planner::slicing::{cluster_slices, slice_trace};
use ecoserve::strategies::Strategy;
use ecoserve::util::cli::Args;
use ecoserve::util::table::{fnum, Table};
use ecoserve::workload::slo::{slo_for, Slo};
use ecoserve::workload::{generate_trace, merge_traces, Arrivals, LengthDist,
                         RequestClass};

fn main() {
    let args = Args::parse();
    let model_name = args.str("model", "llama-8b");
    let m = models::llm(&model_name).expect("unknown model");
    let slo = slo_for(&model_name, false).map(|w| w.slo)
        .unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 });

    let online = generate_trace(Arrivals::Diurnal { rate: 20.0, amplitude: 0.5 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                600.0, 1);
    let offline = generate_trace(Arrivals::Poisson { rate: 8.0 },
                                 LengthDist::LongBench, RequestClass::Offline,
                                 600.0, 2);
    let trace = merge_traces(vec![online, offline]);
    let slices = cluster_slices(&slice_trace(m, &trace, 600.0, slo, 1));
    println!("model {model_name}: {} slices from {} requests",
             slices.len(), trace.len());

    for region in Region::low_mid_high() {
        println!("\n== {} (CI {} g/kWh) ==", region.name(), region.avg_ci());
        let mut t = Table::new(&["strategy", "carbon kg/hr", "op", "emb", "$/hr",
                                 "fleet"]);
        for strat in Strategy::all() {
            let p = strat.plan(&slices, region.avg_ci());
            t.row(&[strat.name().into(), fnum(p.carbon_kg_per_hr()),
                    fnum(p.op_kg_per_hr), fnum(p.emb_kg_per_hr), fnum(p.cost_hr),
                    format!("{:?}", p.counts)]);
        }
        t.print();
    }
}
