//! Deterministic fault injection for the discrete-event core.
//!
//! A [`FaultPlan`] is part of [`super::core::SimConfig`]: server deaths
//! and region outages expand into ordinary [`super::core::EventQueue`]
//! events at `Sim::new`, so a faulted run stays byte-identical across
//! thread and shard counts exactly like a fault-free one. CI spikes are
//! *signal* faults, not engine events — [`apply_ci_spikes`] transforms a
//! [`CiSignal`] before the carbon meter is built, which keeps the meter's
//! interval integrals and the planner's forecasts reading one consistent
//! (spiked) signal.
//!
//! An empty plan is the default everywhere and injects **zero** events:
//! every pre-existing scenario runs the identical event sequence it ran
//! before this module existed.
//!
//! Scenario specs describe fault times as *fractions of the run duration*
//! (so `sweep --duration` scales the storm with the trace); the scenario
//! layer calls [`FaultPlan::scale_to`] once to produce the absolute-time
//! plan the engine consumes.

use crate::carbon::intensity::{CiSignal, CiTrace, Region};

/// One injected fault. Times are seconds on the sim clock (after
/// [`FaultPlan::scale_to`]; fractions of the duration before it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// `server` dies abruptly at `t`: its in-flight batch is killed (the
    /// partially-spent energy stays charged), queued and running jobs are
    /// re-routed to surviving servers, and the server retires. A death
    /// aimed at an index beyond the fleet is skipped — plans may be
    /// written before the planner has sized the fleet.
    ServerDeath { t: f64, server: usize },
    /// The grid CI of `region` multiplies by `factor` over `[t0, t1)` —
    /// a gas-peaker ramp or an interconnect import swing. Applied to the
    /// signal itself (see [`apply_ci_spikes`]), never to the event queue.
    CiSpike { region: Region, t0: f64, t1: f64, factor: f64 },
    /// Every server pinned to `region` dies at `t0` and is re-provisioned
    /// at `t1`; arrivals spill to the surviving regions in between, and
    /// jobs that find no live capacity park in the recovery queue.
    RegionOutage { region: Region, t0: f64, t1: f64 },
}

/// The fault schedule a run injects. `Default` (empty) is the fault-free
/// engine, bit for bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Builder: kill one server at `t`.
    pub fn server_death(mut self, t: f64, server: usize) -> FaultPlan {
        self.faults.push(Fault::ServerDeath { t, server });
        self
    }

    /// Builder: multiply `region`'s CI by `factor` over `[t0, t1)`.
    pub fn ci_spike(mut self, region: Region, t0: f64, t1: f64, factor: f64)
        -> FaultPlan {
        self.faults.push(Fault::CiSpike { region, t0, t1, factor });
        self
    }

    /// Builder: take `region` down over `[t0, t1)`.
    pub fn region_outage(mut self, region: Region, t0: f64, t1: f64)
        -> FaultPlan {
        self.faults.push(Fault::RegionOutage { region, t0, t1 });
        self
    }

    /// Interpret every time field as a fraction of `duration_s` and
    /// return the absolute-time plan. Scenario specs store fractions so
    /// the same storm shape lands mid-trace at any `--duration`.
    pub fn scale_to(&self, duration_s: f64) -> FaultPlan {
        FaultPlan {
            faults: self.faults.iter()
                .map(|f| match *f {
                    Fault::ServerDeath { t, server } =>
                        Fault::ServerDeath { t: t * duration_s, server },
                    Fault::CiSpike { region, t0, t1, factor } =>
                        Fault::CiSpike { region, t0: t0 * duration_s,
                                         t1: t1 * duration_s, factor },
                    Fault::RegionOutage { region, t0, t1 } =>
                        Fault::RegionOutage { region, t0: t0 * duration_s,
                                              t1: t1 * duration_s },
                })
                .collect(),
        }
    }

    /// The spike windows this plan holds for `region`.
    fn spikes_for(&self, region: Region) -> Vec<(f64, f64, f64)> {
        self.faults.iter()
            .filter_map(|f| match *f {
                Fault::CiSpike { region: r, t0, t1, factor } if r == region =>
                    Some((t0, t1, factor)),
                _ => None,
            })
            .collect()
    }
}

/// Apply the plan's CI-spike faults for `region` to `sig`, returning the
/// spiked signal. With no matching spike the signal is returned untouched
/// (same bytes), so wiring this into a scenario pipeline is free for
/// fault-free runs.
///
/// The spiked signal is a materialized [`CiTrace`] sampled at the source
/// signal's own step (60 s for flat signals): unspiked buckets keep their
/// exact source values, buckets whose start falls in a spike window are
/// multiplied, and coverage extends one bucket past both the source's own
/// extent and the last spike window — so the clamped ∞-tail every
/// [`CiTrace`] carries stays spike-free.
pub fn apply_ci_spikes(sig: &CiSignal, region: Region, plan: &FaultPlan,
                       horizon_s: f64) -> CiSignal {
    let windows = plan.spikes_for(region);
    if windows.is_empty() {
        return sig.clone();
    }
    let step = sig.step_s().unwrap_or(60.0).max(1e-9);
    let native_end = match sig {
        CiSignal::Trace(t) => t.step_s * t.values.len() as f64,
        _ => 0.0,
    };
    let max_t1 = windows.iter().fold(0.0f64, |m, &(_, t1, _)| m.max(t1));
    let end = horizon_s.max(native_end).max(max_t1) + step;
    let n = ((end / step).ceil() as usize).max(1) + 1;
    let values = (0..n)
        .map(|i| {
            let t = i as f64 * step;
            let mut v = sig.at(t);
            for &(t0, t1, factor) in &windows {
                if t >= t0 && t < t1 {
                    v *= factor;
                }
            }
            v
        })
        .collect();
    CiSignal::Trace(CiTrace { region, step_s: step, values })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_to_turns_fractions_into_seconds() {
        let plan = FaultPlan::new()
            .server_death(0.5, 2)
            .region_outage(Region::SwedenNorth, 0.25, 0.75);
        let abs = plan.scale_to(1000.0);
        assert_eq!(abs.faults[0], Fault::ServerDeath { t: 500.0, server: 2 });
        assert_eq!(abs.faults[1],
                   Fault::RegionOutage { region: Region::SwedenNorth,
                                         t0: 250.0, t1: 750.0 });
        // Scaling an empty plan stays empty (and cheap).
        assert!(FaultPlan::new().scale_to(1000.0).is_empty());
    }

    #[test]
    fn spikes_multiply_only_their_window_and_region() {
        let plan = FaultPlan::new()
            .ci_spike(Region::California, 100.0, 200.0, 3.0);
        let flat = CiSignal::flat(100.0);
        let spiked = apply_ci_spikes(&flat, Region::California, &plan, 300.0);
        assert_eq!(spiked.at(50.0), 100.0);
        assert_eq!(spiked.at(150.0), 300.0);
        assert_eq!(spiked.at(250.0), 100.0);
        // The clamped tail beyond coverage is unspiked.
        assert_eq!(spiked.at(1e9), 100.0);
        // A different region's signal passes through untouched (still the
        // flat variant — no materialization happened).
        let other = apply_ci_spikes(&flat, Region::SwedenNorth, &plan, 300.0);
        assert!(matches!(other, CiSignal::Flat(v) if v == 100.0));
    }

    #[test]
    fn spiking_a_trace_keeps_unspiked_buckets_exact() {
        let base = CiTrace {
            region: Region::California,
            step_s: 10.0,
            values: vec![1.0, 2.0, 3.0, 4.0],
        };
        let plan = FaultPlan::new()
            .ci_spike(Region::California, 10.0, 30.0, 2.0);
        let sig = CiSignal::Trace(base.clone());
        let spiked = apply_ci_spikes(&sig, Region::California, &plan, 40.0);
        assert_eq!(spiked.at(0.0).to_bits(), 1.0f64.to_bits());
        assert_eq!(spiked.at(10.0), 4.0);
        assert_eq!(spiked.at(20.0), 6.0);
        assert_eq!(spiked.at(30.0).to_bits(), 4.0f64.to_bits());
    }

    #[test]
    fn spike_window_past_trace_end_does_not_poison_the_tail() {
        let base = CiTrace {
            region: Region::California,
            step_s: 10.0,
            values: vec![5.0, 5.0],
        };
        let plan = FaultPlan::new()
            .ci_spike(Region::California, 10.0, 100.0, 4.0);
        let spiked = apply_ci_spikes(&CiSignal::Trace(base),
                                     Region::California, &plan, 20.0);
        assert_eq!(spiked.at(50.0), 20.0, "inside the window: spiked");
        assert_eq!(spiked.at(100.0), 5.0, "window closed: back to base");
        assert_eq!(spiked.at(1e9), 5.0, "clamped tail: unspiked");
    }
}
