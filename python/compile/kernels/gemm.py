"""L1 Pallas kernel: blocked GEMM with an accumulating K-grid.

The TPU analogue of EcoServe's tiled Linear-operator slicing (paper Fig 9):
the paper co-selects tile shape and parallelism degree so each slice's
arithmetic intensity sits at the roofline knee; here BlockSpecs carve
A/B into MXU-shaped (default 128x128) VMEM tiles and the third grid axis
accumulates partial products over K, which is exactly the HBM<->VMEM
schedule the CPU implementation expresses with cache blocking.

Lowered with interpret=True (see decode_attention.py for why); validated
against ``ref.gemm_ref`` / jnp.dot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One (m, n, k) grid step: accumulate an MXU-shaped partial product."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # fp32 accumulation regardless of input dtype (bf16 on real MXU).
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a: jax.Array, b: jax.Array, *,
         bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Blocked matmul ``a @ b``.

    Args:
      a: [M, K]; M, K must be multiples of bm, bk.
      b: [K, N]; N must be a multiple of bn.
      bm/bn/bk: VMEM tile shape (default MXU-shaped 128^3).

    Returns:
      [M, N] product, fp32-accumulated.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"({m},{n},{k}) not tileable by ({bm},{bn},{bk})"

    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def vmem_bytes_per_program(bm: int, bn: int, bk: int,
                           dtype_bytes: int = 4) -> int:
    """VMEM bytes per grid program: A tile + B tile + accumulator."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(bm: int, bn: int, bk: int,
                             mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for a (bm, bn, bk) tile (DESIGN.md §7)."""
    eff_m = min(bm, mxu) / mxu
    eff_n = min(bn, mxu) / mxu
    eff_k = min(bk, mxu) / mxu
    return eff_m * eff_n * eff_k
