//! The shipped scenario registry: 16 named end-to-end design points
//! spanning the paper's evaluation axes — latency-optimized online
//! serving, offline batch, the mixed 4R deployment, Splitwise-style
//! prefill/decode disaggregation, multi-region carbon intensity,
//! legacy-hardware Reuse, temporal shifting, carbon-aware routing, the
//! rolling-horizon autoscaling pair (diurnal tracking + demand surge),
//! the honest-energy pair (`keepalive-surge` cold-start/keep-alive
//! tension + `nonlinear-power` per-phase DVFS), the production-scale
//! pair (`production-day` / `production-week`) that exercises the
//! streaming core at multi-million-request trace lengths, and the
//! trace-replay pair (`replay-day` / `replay-year`) that replays the
//! committed production request + grid-CI fixtures through the full
//! stack. Each wires config → planner → solver → sim → carbon into one
//! [`super::ScenarioOutcome`].

use super::{CiProfile, FleetPolicy, Pack, Scenario, ScenarioSpec, WorkloadSpec};
use crate::carbon::intensity::Region;
use crate::planner::horizon::HorizonConfig;
use crate::sim::{FaultPlan, KeepAlivePolicy, Router};
use crate::strategies::Strategy;
use crate::workload::slo::Slo;
use crate::workload::{Arrivals, LengthDist, RequestClass, TraceDialect,
                      TraceErrorPolicy, TraceRescale};

/// Absolute path of a committed trace fixture. Resolved from the crate
/// root at compile time so sweeps work from any working directory; the
/// path never enters outcome JSON, so reports stay machine-portable.
fn fixture(name: &str) -> String {
    format!("{}/fixtures/traces/{name}", env!("CARGO_MANIFEST_DIR"))
}

/// A registry entry: static metadata plus a spec constructor.
struct DesignPoint {
    name: &'static str,
    description: &'static str,
    build: fn() -> ScenarioSpec,
    /// Sized for explicit long `--duration` runs; skipped by `--all`
    /// sweeps that did not pass a duration.
    long_haul: bool,
    /// `sweep --pack` group.
    pack: Pack,
}

impl Scenario for DesignPoint {
    fn name(&self) -> &'static str {
        self.name
    }

    fn description(&self) -> &'static str {
        self.description
    }

    fn spec(&self) -> ScenarioSpec {
        (self.build)()
    }

    fn long_haul(&self) -> bool {
        self.long_haul
    }

    fn pack(&self) -> Pack {
        self.pack
    }
}

fn base_spec(model: &'static str, region: Region, strategy: Strategy)
    -> ScenarioSpec {
    ScenarioSpec {
        model,
        region,
        strategy,
        gpu_menu: None,
        workloads: Vec::new(),
        slo: None,
        fleet: FleetPolicy::Planned,
        router: Router::WorkloadAware,
        ci_profile: CiProfile::Flat,
        defer_offline: false,
        reprovision: None,
        compare_regions: Vec::new(),
        coldstart_s: 0.0,
        keepalive: KeepAlivePolicy::Immediate,
        decode_freq: 1.0,
        faults: FaultPlan::default(),
    }
}

fn online_latency() -> ScenarioSpec {
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 12.0 },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        ..base_spec("llama-8b", Region::California, Strategy::PerfOpt)
    }
}

fn offline_batch() -> ScenarioSpec {
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 2.0 },
            lengths: LengthDist::LongBench,
            class: RequestClass::Offline,
        }],
        ..base_spec("gemma-27b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn mixed_4r() -> ScenarioSpec {
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Bursty { rate: 8.0, cv: 2.0 },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 3.0 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn splitwise_pd() -> ScenarioSpec {
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 0.6 },
            lengths: LengthDist::AzureCode,
            class: RequestClass::Online,
        }],
        fleet: FleetPolicy::SplitwisePd,
        router: Router::Jsq,
        ..base_spec("llama-70b", Region::California, Strategy::Splitwise)
    }
}

fn multi_region() -> ScenarioSpec {
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Diurnal { rate: 10.0, amplitude: 0.5 },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 4.0 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        compare_regions: vec![Region::SwedenNorth, Region::Midcontinent,
                              Region::Europe],
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn legacy_reuse() -> ScenarioSpec {
    ScenarioSpec {
        gpu_menu: Some(vec!["T4", "V100", "A40", "A6000"]),
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 3.0 },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 2.0 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        // Loosened SLO: legacy cards cannot hit the paper's H100-class
        // targets; the design point studies carbon, not latency records.
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ..base_spec("llama-8b", Region::SwedenNorth, Strategy::EcoReuse)
    }
}

fn diurnal_shift() -> ScenarioSpec {
    // Online chat rides alongside an offline LongBench stream; the grid is
    // a compressed solar day, and offline work is temporally shifted into
    // the midday low-CI dip under its deadline. The run-immediately
    // baseline lands in extras (op_kg_immediate et al.).
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 6.0 },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 3.0 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        ci_profile: CiProfile::CompressedDiurnal,
        defer_offline: true,
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn carbon_router() -> ScenarioSpec {
    // One planned fleet split across a clean and a dirty grid; the
    // carbon-greedy router steers load to the clean half while the JSQ
    // baseline (op_kg_jsq in extras) stays carbon-blind.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 8.0 },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        fleet: FleetPolicy::TwoRegion { low: Region::SwedenNorth },
        router: Router::CarbonGreedy,
        ..base_spec("llama-8b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn autoscale_diurnal() -> ScenarioSpec {
    // Elastic fleet tracking one compressed demand + CI day: the
    // rolling-horizon controller re-solves the allocation ILP each epoch
    // against the observed window and drains the surplus off-peak, so
    // embodied + idle carbon amortize over actual provisioned hours. The
    // static peak-provisioned baseline lands in extras (carbon_kg_static
    // et al.). The loose chat SLO keeps both variants at full attainment
    // so the comparison isolates carbon, not latency records.
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::CompressedDiurnal {
                    rate: 8.0, amplitude: 0.7, period_s: 0.0,
                },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 1.5 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ci_profile: CiProfile::CompressedDiurnal,
        // SLO-conservative elasticity: generous headroom over the observed
        // window and a 2-server floor keep attainment pinned at the static
        // baseline's level while the off-peak drains still shed most of
        // the fleet's provisioned hours.
        reprovision: Some(HorizonConfig {
            headroom: 1.5,
            min_active: 2,
            ..Default::default()
        }),
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn demand_surge() -> ScenarioSpec {
    // Step-function load spike: a quiet baseline with a 5x surge over the
    // middle fifth of the trace. The peak-provisioned static fleet burns
    // embodied + idle carbon all day for a spike it serves briefly; the
    // elastic fleet provisions up for the surge window and drains after.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Step {
                base: 3.0, surge: 12.0, start_frac: 0.4, end_frac: 0.6,
            },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        reprovision: Some(HorizonConfig { headroom: 1.5, ..Default::default() }),
        ..base_spec("llama-8b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn keepalive_surge() -> ScenarioSpec {
    // The cold-start / keep-alive tension on a step surge: provisioning a
    // retired server takes a real boot delay, so when the surge hits, an
    // aggressively-retired fleet serves the ramp with too little capacity
    // (SLO misses) while a keep-alive fleet paid warm idle carbon to be
    // ready. The main run holds a fixed 30 s window; the extras panel
    // (`*_ka_immediate` / `*_ka_fixed` / `*_ka_hybrid`) sweeps the
    // policies on the identical schedule, with the static always-warm
    // fleet (`*_static`) as the zero-cold-start anchor.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Step {
                base: 3.0, surge: 15.0, start_frac: 0.35, end_frac: 0.55,
            },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        reprovision: Some(HorizonConfig { headroom: 1.2, ..Default::default() }),
        coldstart_s: 20.0,
        keepalive: KeepAlivePolicy::Fixed { window_s: 30.0 },
        ..base_spec("llama-8b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn nonlinear_power() -> ScenarioSpec {
    // Per-phase DVFS on the shared nonlinear power curve: decode is
    // memory-bound, so running it at 85% clocks cuts dynamic power ~f³
    // while stretching decode latency only 1/f. The stock-clock baseline
    // lands in extras (`*_stock_freq`), isolating the energy/latency
    // trade on one fleet.
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Bursty { rate: 8.0, cv: 2.0 },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 2.0 },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        decode_freq: 0.85,
        ..base_spec("llama-8b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn production_day() -> ScenarioSpec {
    // One compressed demand + CI day at production scale: ~300 req/s of
    // mixed chat + code traffic on a two-grid elastic fleet with
    // carbon-greedy routing — streaming arrivals, rolling-horizon
    // re-provisioning, and multi-region accounting all engaged at once.
    // At `--duration 7200` the day carries ≥ 2M requests; the streaming
    // core holds memory at the fleet + in-flight jobs (`peak_live_jobs`),
    // which the CI `scale-smoke` job asserts via peak RSS.
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::CompressedDiurnal {
                    rate: 230.0, amplitude: 0.6, period_s: 0.0,
                },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 70.0 },
                lengths: LengthDist::AzureCode,
                class: RequestClass::Offline,
            },
        ],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        fleet: FleetPolicy::TwoRegion { low: Region::SwedenNorth },
        router: Router::CarbonGreedy,
        ci_profile: CiProfile::CompressedDiurnal,
        reprovision: Some(HorizonConfig {
            epoch_s: 300.0,
            headroom: 1.5,
            min_active: 2,
            ..Default::default()
        }),
        ..base_spec("llama-8b", Region::Midcontinent, Strategy::EcoFull)
    }
}

fn production_week() -> ScenarioSpec {
    // Seven compressed diurnal cycles with weekday/weekend amplitude
    // (weekends at 45% of the weekday rate), demand and grid CI cycling
    // together, under rolling-horizon re-provisioning. Gated behind an
    // explicit `--duration` in `--all` sweeps; at `--duration 25200`
    // (one hour per simulated day) the week carries several million
    // requests.
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Week {
                    rate: 120.0, amplitude: 0.7, weekend_factor: 0.45,
                },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Poisson { rate: 30.0 },
                lengths: LengthDist::AzureCode,
                class: RequestClass::Offline,
            },
        ],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ci_profile: CiProfile::CompressedWeek,
        reprovision: Some(HorizonConfig {
            epoch_s: 600.0,
            headroom: 1.5,
            min_active: 2,
            ..Default::default()
        }),
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn replay_day() -> ScenarioSpec {
    // Replay reality: one anonymized production day — Azure-LLM-style
    // chat arrivals online, BurstGPT-style batch arrivals offline — with
    // the grid CI streamed from a committed CAISO-shaped duck-curve file
    // instead of a synthetic profile. Token counts come from the traces
    // (the LengthDist fields are inert), `fit_duration` compresses the
    // recorded day into the requested `--duration`, and the registry
    // fixtures run under the fail-fast error policy so a corrupted
    // checkout aborts loudly rather than silently skipping lines. The
    // burstiness extras panel (`burst_cv_replay` vs `burst_cv_synthetic`)
    // scores how well a rate-matched Poisson generator reproduces the
    // replayed arrival process.
    ScenarioSpec {
        workloads: vec![
            WorkloadSpec {
                arrivals: Arrivals::Trace {
                    path: fixture("azure_llm_day.csv"),
                    dialect: TraceDialect::Azure,
                    rescale: TraceRescale::default(),
                    errors: TraceErrorPolicy::Fail,
                },
                lengths: LengthDist::ShareGpt,
                class: RequestClass::Online,
            },
            WorkloadSpec {
                arrivals: Arrivals::Trace {
                    path: fixture("burstgpt_day.csv"),
                    dialect: TraceDialect::BurstGpt,
                    rescale: TraceRescale::default(),
                    errors: TraceErrorPolicy::Fail,
                },
                lengths: LengthDist::LongBench,
                class: RequestClass::Offline,
            },
        ],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ci_profile: CiProfile::TraceFile {
            path: fixture("caiso_ci_day.csv"),
        },
        reprovision: Some(HorizonConfig {
            headroom: 1.5,
            min_active: 2,
            ..Default::default()
        }),
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn replay_year() -> ScenarioSpec {
    // Long-haul replay: the same recorded day looped at 3x the recorded
    // rate so an explicit long `--duration` stands in for sustained
    // production traffic — the densified replay keeps the recorded
    // microstructure (bursts stay bursts) while the rolling-horizon
    // controller re-provisions against the streamed CI file for the whole
    // run. Gated behind `--duration` like `production-week`.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Trace {
                path: fixture("azure_llm_day.csv"),
                dialect: TraceDialect::Azure,
                rescale: TraceRescale { fit_duration: true, rate: 3.0 },
                errors: TraceErrorPolicy::Fail,
            },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ci_profile: CiProfile::TraceFile {
            path: fixture("caiso_ci_day.csv"),
        },
        reprovision: Some(HorizonConfig {
            epoch_s: 300.0,
            headroom: 1.5,
            min_active: 2,
            ..Default::default()
        }),
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn failure_storm() -> ScenarioSpec {
    // Correlated infrastructure failure under a grid emergency: three
    // server deaths land mid-trace (killing batches mid-flight) while the
    // primary grid's CI spikes 2.5x over the same window — the
    // fault-injection layer's flagship. Orphaned work re-routes to the
    // survivors (server 0 always lives, so nothing parks), and the
    // fault-free twin in extras (`*_nofault`) prices the storm in carbon
    // and SLO terms. Fault times are fractions of the run duration.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 8.0 },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        ci_profile: CiProfile::CompressedDiurnal,
        faults: FaultPlan::new()
            .server_death(0.45, 1)
            .server_death(0.50, 2)
            .server_death(0.55, 3)
            .ci_spike(Region::California, 0.45, 0.65, 2.5),
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

fn region_outage() -> ScenarioSpec {
    // A whole grid drops out: the half of a two-region fleet pinned to
    // the dirty Californian grid dies at 30% of the trace and returns at
    // 55%, spilling its arrivals onto the clean SE-North survivors. The
    // carbon-greedy router absorbs the spill (JSQ baseline in extras);
    // the `*_nofault` twin isolates what the outage cost in attainment
    // and recovery wait.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 8.0 },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        fleet: FleetPolicy::TwoRegion { low: Region::California },
        router: Router::CarbonGreedy,
        faults: FaultPlan::new()
            .region_outage(Region::California, 0.30, 0.55),
        ..base_spec("llama-8b", Region::SwedenNorth, Strategy::EcoFull)
    }
}

fn hetero_disaggregation() -> ScenarioSpec {
    // GreenLLM-style heterogeneous PD split: H100 prefill in front of a
    // decode tier recycled from the oldest catalog GPU that still clears
    // the component-reliability screens (carbon::reliability) at decode
    // utilization — old silicon stays useful where bandwidth, not
    // compute, is the binding resource.
    ScenarioSpec {
        workloads: vec![WorkloadSpec {
            arrivals: Arrivals::Poisson { rate: 6.0 },
            lengths: LengthDist::ShareGpt,
            class: RequestClass::Online,
        }],
        slo: Some(Slo { ttft_s: 2.0, tpot_s: 0.2 }),
        fleet: FleetPolicy::HeteroPd,
        router: Router::Jsq,
        ..base_spec("llama-8b", Region::California, Strategy::EcoFull)
    }
}

/// All shipped design points, in a stable order (seeds do not depend on
/// this order — see [`super::scenario_seed`]).
pub fn registry() -> Vec<Box<dyn Scenario>> {
    let point = |name, description, build| {
        Box::new(DesignPoint { name, description, build, long_haul: false,
                               pack: Pack::Core })
            as Box<dyn Scenario>
    };
    vec![
        point("online-latency",
              "latency-optimized online chat serving \
               (Llama-8B, ShareGPT, perf-opt planner)",
              online_latency),
        point("offline-batch",
              "offline-heavy long-context batch under a 24h \
               deadline (Gemma-27B, LongBench, 4R planner)",
              offline_batch),
        point("mixed-4r",
              "mixed online+offline production mix with all \
               four R strategies engaged (Llama-8B)",
              mixed_4r),
        point("splitwise-pd",
              "prefill/decode-disaggregated H100 fleet with a \
               fixed 3:1 split, Splitwise-style (Llama-70B)",
              splitwise_pd),
        point("multi-region",
              "one deployment cross-reported over low/mid/high \
               carbon-intensity regions (Llama-8B, 4R planner)",
              multi_region),
        point("legacy-reuse",
              "legacy GPU pool (T4/V100/A40/A6000) with host-CPU \
               Reuse in a clean grid (Llama-8B)",
              legacy_reuse),
        point("diurnal-shift",
              "offline batch temporally shifted into the diurnal \
               low-CI window vs run-immediately (Llama-8B)",
              diurnal_shift),
        point("carbon-router",
              "carbon-greedy routing over a two-grid fleet \
               (SE-North + MISO) vs carbon-blind JSQ (Llama-8B)",
              carbon_router),
        point("autoscale-diurnal",
              "rolling-horizon elastic fleet tracking a diurnal \
               demand + CI day vs a static peak-provisioned \
               baseline (Llama-8B)",
              autoscale_diurnal),
        point("demand-surge",
              "step-function load spike: epoch re-provisioning \
               absorbs a 5x surge, then drains the surplus \
               (Llama-8B, MISO)",
              demand_surge),
        point("keepalive-surge",
              "cold-start vs keep-alive on a load surge: warm-idle \
               carbon against boot-delay SLO misses, with a \
               fixed/hybrid/immediate policy panel (Llama-8B, MISO)",
              keepalive_surge),
        point("nonlinear-power",
              "per-phase DVFS on the shared nonlinear power curve: \
               decode at 85% clocks vs stock, f^3 dynamic-power cut \
               against the 1/f latency stretch (Llama-8B, MISO)",
              nonlinear_power),
        point("production-day",
              "production-scale compressed demand+CI day (~300 req/s) on \
               a two-grid elastic fleet: streaming arrivals + \
               rolling-horizon re-provisioning + carbon-greedy routing; \
               >=2M requests at --duration 7200 (Llama-8B)",
              production_day),
        Box::new(DesignPoint {
            name: "production-week",
            description: "seven compressed diurnal cycles with \
                          weekday/weekend amplitude under rolling-horizon \
                          re-provisioning; multi-million-request weeks at \
                          long --duration (Llama-8B)",
            build: production_week,
            long_haul: true,
            pack: Pack::Core,
        }),
        Box::new(DesignPoint {
            name: "replay-day",
            description: "anonymized production-day replay: Azure-LLM chat + \
                          BurstGPT batch request traces with streamed CAISO \
                          duck-curve grid CI and a burstiness validation \
                          panel (Llama-8B)",
            build: replay_day,
            long_haul: false,
            pack: Pack::Replay,
        }),
        Box::new(DesignPoint {
            name: "replay-year",
            description: "long-haul trace replay: the recorded day \
                          densified 3x under rolling-horizon \
                          re-provisioning against the streamed CI file; \
                          gated behind --duration (Llama-8B)",
            build: replay_year,
            long_haul: true,
            pack: Pack::Replay,
        }),
        Box::new(DesignPoint {
            name: "failure-storm",
            description: "correlated mid-trace server deaths plus a 2.5x \
                          grid-CI spike: mid-batch kills, re-routing onto \
                          survivors, fault-free twin in extras (Llama-8B)",
            build: failure_storm,
            long_haul: false,
            pack: Pack::Failure,
        }),
        Box::new(DesignPoint {
            name: "region-outage",
            description: "the dirty half of a two-grid fleet drops out for \
                          a quarter of the trace and arrivals spill onto \
                          the clean survivors; recovery wait and nofault \
                          twin in extras (Llama-8B)",
            build: region_outage,
            long_haul: false,
            pack: Pack::Failure,
        }),
        Box::new(DesignPoint {
            name: "hetero-disaggregation",
            description: "H100 prefill in front of a decode tier recycled \
                          from the oldest reliability-safe catalog GPU, \
                          GreenLLM-style (Llama-8B)",
            build: hetero_disaggregation,
            long_haul: false,
            pack: Pack::Failure,
        }),
    ]
}

/// Look up scenarios by name; `None` for an unknown name.
pub fn by_names(names: &[&str]) -> Option<Vec<Box<dyn Scenario>>> {
    let mut out = Vec::new();
    for want in names {
        let found = registry().into_iter().find(|s| s.name() == *want)?;
        out.push(found);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_nineteen_unique_named_scenarios() {
        let r = registry();
        assert!(r.len() >= 19, "only {} scenarios", r.len());
        let mut names: Vec<&str> = r.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), r.len(), "duplicate scenario names");
        for s in &r {
            assert!(!s.description().is_empty());
            assert!(!s.spec().workloads.is_empty(), "{} has no workload", s.name());
        }
    }

    #[test]
    fn by_names_selects_and_rejects() {
        let sel = by_names(&["mixed-4r", "online-latency", "diurnal-shift",
                             "carbon-router"]).unwrap();
        assert_eq!(sel.len(), 4);
        assert_eq!(sel[0].name(), "mixed-4r");
        assert!(by_names(&["no-such-scenario"]).is_none());
    }

    #[test]
    fn carbon_aware_specs_are_wired() {
        let d = by_names(&["diurnal-shift"]).unwrap().remove(0).spec();
        assert!(d.defer_offline);
        assert_eq!(d.ci_profile, CiProfile::CompressedDiurnal);
        assert!(d.workloads.iter().any(|w| w.class == RequestClass::Offline));
        assert!(d.workloads.iter().any(|w| w.class == RequestClass::Online));
        let c = by_names(&["carbon-router"]).unwrap().remove(0).spec();
        assert_eq!(c.router, Router::CarbonGreedy);
        assert!(matches!(c.fleet, FleetPolicy::TwoRegion { .. }));
    }

    #[test]
    fn autoscaling_specs_are_wired() {
        let a = by_names(&["autoscale-diurnal"]).unwrap().remove(0).spec();
        let h = a.reprovision.expect("autoscale-diurnal must re-provision");
        assert!(h.epoch_s > 0.0 && h.min_active >= 1 && h.headroom >= 1.0);
        assert_eq!(a.ci_profile, CiProfile::CompressedDiurnal);
        assert!(a.workloads.iter().any(|w| matches!(
            w.arrivals, Arrivals::CompressedDiurnal { .. })));
        let s = by_names(&["demand-surge"]).unwrap().remove(0).spec();
        assert!(s.reprovision.is_some());
        assert!(s.workloads.iter().any(|w| matches!(
            w.arrivals, Arrivals::Step { .. })));
    }

    #[test]
    fn scale_specs_are_wired() {
        let d = by_names(&["production-day"]).unwrap().remove(0);
        assert!(!d.long_haul(), "production-day must run in default sweeps");
        let spec = d.spec();
        assert!(spec.reprovision.is_some(), "production-day must re-provision");
        assert!(matches!(spec.fleet, FleetPolicy::TwoRegion { .. }));
        assert_eq!(spec.router, Router::CarbonGreedy);
        assert!(spec.workloads.iter().any(|w| matches!(
            w.arrivals, Arrivals::CompressedDiurnal { .. })));
        // The day is sized so 7200 s carries >= 2M requests: aggregate
        // mean rate must exceed 2e6 / 7200 ~ 278 req/s.
        let rate: f64 = spec.workloads.iter().map(|w| match w.arrivals {
            Arrivals::CompressedDiurnal { rate, .. } => rate,
            Arrivals::Poisson { rate } => rate,
            _ => 0.0,
        }).sum();
        assert!(rate >= 280.0, "production-day mean rate {rate} too low");

        let w = by_names(&["production-week"]).unwrap().remove(0);
        assert!(w.long_haul(), "production-week is gated behind --duration");
        let spec = w.spec();
        assert_eq!(spec.ci_profile, CiProfile::CompressedWeek);
        assert!(spec.reprovision.is_some());
        assert!(spec.workloads.iter().any(|wl| matches!(
            wl.arrivals, Arrivals::Week { .. })));
    }

    #[test]
    fn replay_specs_are_wired() {
        let d = by_names(&["replay-day"]).unwrap().remove(0);
        assert!(!d.long_haul(), "replay-day must run in default sweeps");
        let spec = d.spec();
        assert!(spec.reprovision.is_some(),
                "replay-day must feed streamed CI into the planner");
        assert!(matches!(spec.ci_profile, CiProfile::TraceFile { .. }));
        assert_eq!(spec.workloads.len(), 2);
        for w in &spec.workloads {
            match &w.arrivals {
                Arrivals::Trace { path, rescale, errors, .. } => {
                    assert!(std::path::Path::new(path).is_file(),
                            "missing committed fixture {path}");
                    assert!(rescale.fit_duration);
                    assert_eq!(*errors, TraceErrorPolicy::Fail,
                               "registry fixtures must fail loud");
                }
                other => panic!("replay-day workload is not a trace: {other:?}"),
            }
        }
        let dialects: Vec<TraceDialect> = spec.workloads.iter()
            .map(|w| match &w.arrivals {
                Arrivals::Trace { dialect, .. } => *dialect,
                _ => unreachable!(),
            }).collect();
        assert!(dialects.contains(&TraceDialect::Azure));
        assert!(dialects.contains(&TraceDialect::BurstGpt));
        if let CiProfile::TraceFile { path } = &spec.ci_profile {
            assert!(std::path::Path::new(path).is_file(),
                    "missing committed CI fixture {path}");
        }

        let y = by_names(&["replay-year"]).unwrap().remove(0);
        assert!(y.long_haul(), "replay-year is gated behind --duration");
        let spec = y.spec();
        assert!(matches!(spec.ci_profile, CiProfile::TraceFile { .. }));
        assert!(spec.workloads.iter().any(|w| matches!(
            &w.arrivals,
            Arrivals::Trace { rescale, .. } if rescale.rate > 1.0)));
    }

    #[test]
    fn packs_partition_the_registry() {
        let r = registry();
        let count = |p: Pack| r.iter().filter(|s| s.pack() == p).count();
        assert!(count(Pack::Core) >= 14);
        assert_eq!(count(Pack::Replay), 2);
        assert_eq!(count(Pack::Failure), 3);
        assert_eq!(count(Pack::Core) + count(Pack::Replay)
                       + count(Pack::Failure), r.len());
        // Non-failure packs must stay fault-free: an empty FaultPlan is
        // the engine's byte-neutrality guarantee for the legacy points.
        for s in &r {
            if s.pack() != Pack::Failure {
                assert!(s.spec().faults.is_empty(),
                        "{} injects faults outside the failure pack",
                        s.name());
            }
        }
        assert_eq!(Pack::parse("failure"), Some(Pack::Failure));
        assert_eq!(Pack::parse("bogus"), None);
        assert_eq!(Pack::Replay.name(), "replay");
    }

    #[test]
    fn failure_specs_are_wired() {
        let s = by_names(&["failure-storm"]).unwrap().remove(0);
        assert_eq!(s.pack(), Pack::Failure);
        let spec = s.spec();
        assert!(!spec.faults.is_empty());
        // Fraction-typed fault times: everything inside the unit run.
        for f in &spec.faults.faults {
            match *f {
                crate::sim::Fault::ServerDeath { t, .. } => {
                    assert!((0.0..=1.0).contains(&t));
                }
                crate::sim::Fault::CiSpike { t0, t1, factor, .. } => {
                    assert!(t0 < t1 && t1 <= 1.0 && factor > 1.0);
                }
                crate::sim::Fault::RegionOutage { t0, t1, .. } => {
                    assert!(t0 < t1 && t1 <= 1.0);
                }
            }
        }

        let o = by_names(&["region-outage"]).unwrap().remove(0).spec();
        assert!(matches!(o.fleet,
                         FleetPolicy::TwoRegion { low: Region::California }));
        assert!(o.faults.faults.iter().any(|f| matches!(
            f, crate::sim::Fault::RegionOutage {
                region: Region::California, .. })));

        let h = by_names(&["hetero-disaggregation"]).unwrap().remove(0).spec();
        assert_eq!(h.fleet, FleetPolicy::HeteroPd);
        assert!(h.faults.is_empty(),
                "hetero-disaggregation studies the fleet, not faults");
    }

    #[test]
    fn specs_reference_known_models_and_gpus() {
        for s in registry() {
            let spec = s.spec();
            assert!(crate::models::llm(spec.model).is_some(),
                    "{}: unknown model {}", s.name(), spec.model);
            if let Some(menu) = &spec.gpu_menu {
                for g in menu {
                    assert!(crate::hw::gpu(g).is_some(),
                            "{}: unknown gpu {g}", s.name());
                }
            }
        }
    }
}
