//! Performance models: GPU/CPU rooflines (Fig 8), per-phase latency/energy
//! (the planner's MaxTput inputs), and the CPU threading/tiling model
//! behind the Reuse strategy (Figs 9/18/19).

pub mod cpu;
pub mod roofline;

pub use roofline::{decode_step_perf, prefill_perf, Bound, Device, PhasePerf};
