//! Fig 12: A100-vs-H100 relative energy and carbon for Gemma-27B prompt
//! and decode phases across context length and batch (values > 1 mean the
//! A100 is preferable).
use ecoserve::carbon::embodied::gpu_embodied;
use ecoserve::hw;
use ecoserve::models;
use ecoserve::perf::roofline::{decode_step_perf, prefill_perf, Device};
use ecoserve::util::table::{fnum, Table};

fn main() {
    let m = models::llm("gemma-27b").unwrap();
    let a = Device::from_gpu(hw::gpu("A100-80").unwrap());
    let h = Device::from_gpu(hw::gpu("H100").unwrap());
    let emb_a = gpu_embodied(hw::gpu("A100-80").unwrap()).total();
    let emb_h = gpu_embodied(hw::gpu("H100").unwrap()).total();
    let ci = 261.0;
    println!("== Fig 12: H100-relative-to-A100 ratios, Gemma-27B (>1: A100 wins) ==");
    let mut t = Table::new(&["phase", "ctx", "batch", "energy H/A", "carbon H/A"]);
    for (phase, ctx, b) in [("prompt", 512usize, 4usize), ("prompt", 2048, 8),
                            ("prompt", 8192, 8), ("decode", 512, 4),
                            ("decode", 2048, 8), ("decode", 8192, 16)] {
        let (pa, ph) = if phase == "prompt" {
            (prefill_perf(m, &a, b, ctx, 2), prefill_perf(m, &h, b, ctx, 2))
        } else {
            (decode_step_perf(m, &a, b, ctx, 2), decode_step_perf(m, &h, b, ctx, 2))
        };
        let carbon = |p: &ecoserve::perf::PhasePerf, emb: f64, lt_h: f64| {
            p.energy_j / 3.6e6 * ci / 1000.0 + emb / lt_h * p.latency_s / 3600.0
        };
        let lt = 3.0 * 365.25 * 24.0;
        t.row(&[phase.into(), format!("{ctx}"), format!("{b}"),
                fnum(ph.energy_j / pa.energy_j),
                fnum(carbon(&ph, emb_h, lt) / carbon(&pa, emb_a, lt))]);
    }
    t.print();
    println!("(H100 wins long prompts; A100 preferred for decode)");
}
